"""L2: jax step functions for the simulation's update phase.

These wrap the L1 Pallas kernels into the exact computations the Rust
coordinator executes per simulation cycle via PJRT:

* ``lif_step_fn``       — one resolution step for B LIF neurons,
* ``lif_multistep_fn``  — K consecutive steps (a whole communication epoch of
                          the structure-aware strategy) via ``lax.scan``,
* ``ianf_step_fn``      — one step for B ignore-and-fire neurons.

Every function here is lowered once by ``aot.py`` to HLO text; Python never
runs on the request path.
"""

import jax
import jax.numpy as jnp

from .kernels.lif import lif_step, PARAM_LEN
from .kernels.ignore_and_fire import ianf_step

__all__ = [
    "PARAM_LEN",
    "lif_step_fn",
    "lif_multistep_fn",
    "ianf_step_fn",
    "lif_params",
]


def lif_params(tau_m=10.0, c_m=250.0, t_ref=2.0, theta_rel=15.0,
               v_reset_rel=0.0, i_e=0.0, h=0.1):
    """Build the f32[PARAM_LEN] parameter vector for the LIF kernel.

    All potentials are relative to the resting potential E_L.

    Args:
        tau_m: membrane time constant [ms].
        c_m: membrane capacitance [pF].
        t_ref: refractory period [ms].
        theta_rel: spike threshold above rest [mV].
        v_reset_rel: reset potential above rest [mV].
        i_e: constant external current [pA].
        h: resolution step [ms].
    """
    import math
    p22 = math.exp(-h / tau_m)
    r_m = tau_m / c_m  # GOhm when tau in ms, c in pF -> mV/pA
    drive = (1.0 - p22) * r_m * i_e
    ref_steps = round(t_ref / h)
    vec = [p22, drive, theta_rel, v_reset_rel, float(ref_steps)]
    vec += [0.0] * (PARAM_LEN - len(vec))
    return jnp.asarray(vec, dtype=jnp.float32)


def lif_step_fn(params, v, refr, syn):
    """One LIF resolution step.  Returns (v', refr', spikes)."""
    return tuple(lif_step(params, v, refr, syn))


def lif_multistep_fn(params, v, refr, syn_steps):
    """K consecutive LIF steps; ``syn_steps`` is f32[K, B].

    Returns (v', refr', spikes f32[K, B]).  Used by the structure-aware
    strategy when a rank can advance a whole epoch from pre-delivered
    intra-area input.
    """

    def body(carry, syn_k):
        v, refr = carry
        v, refr, spk = lif_step(params, v, refr, syn_k)
        return (v, refr), spk

    (v, refr), spikes = jax.lax.scan(body, (v, refr), syn_steps)
    return v, refr, spikes


def ianf_step_fn(phase, interval, syn):
    """One ignore-and-fire step.  Returns (phase', spikes)."""
    return tuple(ianf_step(phase, interval, syn))
