"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground-truth semantics the kernels (and the Rust native
implementation in ``rust/src/engine/neuron.rs``) must match.  Written in
straight-line jnp with the same operation order as the kernels so that f32
results agree bit-for-bit in practice.
"""

import jax.numpy as jnp


def lif_step_ref(params, v, refr, syn):
    """Reference single-step iaf_psc_delta update.  See kernels/lif.py."""
    p22, drive, theta, v_reset, ref_steps = (
        params[0], params[1], params[2], params[3], params[4])
    is_ref = refr > 0.0
    v_int = p22 * v + drive + syn
    v_new = jnp.where(is_ref, v_reset, v_int)
    spike = jnp.logical_and(jnp.logical_not(is_ref), v_new >= theta)
    v_out = jnp.where(spike, v_reset, v_new)
    refr_out = jnp.where(spike, ref_steps, jnp.maximum(refr - 1.0, 0.0))
    return v_out, refr_out, spike.astype(jnp.float32)


def lif_multistep_ref(params, v, refr, syn_steps):
    """Reference K-step update; syn_steps is f32[K, B]."""
    spikes = []
    for k in range(syn_steps.shape[0]):
        v, refr, spk = lif_step_ref(params, v, refr, syn_steps[k])
        spikes.append(spk)
    return v, refr, jnp.stack(spikes)


def ianf_step_ref(phase, interval, syn):
    """Reference ignore-and-fire update.  See kernels/ignore_and_fire.py."""
    del syn
    phase = phase + 1.0
    spike = phase >= interval
    phase_out = jnp.where(spike, 0.0, phase)
    return phase_out, spike.astype(jnp.float32)
