"""L1 Pallas kernel: ignore-and-fire neuron update (MAM-benchmark, §4.2).

The MAM-benchmark's neuron receives and emits spikes like an
integrate-and-fire neuron but does not propagate a membrane potential: it
fires at a predefined interval and phase, independent of synaptic input.
This keeps the update cost independent of activity so that weak-scaling
experiments hold workload constant.

State per neuron (all f32):
    phase     current position within the firing interval, in steps
    interval  firing interval in steps (integer-valued float, per neuron)

Update:  phase' = phase + 1;  spike where phase' >= interval; spiking
neurons wrap phase' to 0.  Synaptic input is accepted (so that delivery
workload is realistic) but ignored.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .lif import pick_block


def _ianf_kernel(phase_ref, interval_ref, syn_ref, phase_out_ref,
                 spk_out_ref):
    phase = phase_ref[...] + 1.0
    interval = interval_ref[...]
    _ = syn_ref[...]  # delivered but deliberately ignored
    spike = phase >= interval
    phase_out_ref[...] = jnp.where(spike, 0.0, phase)
    spk_out_ref[...] = spike.astype(jnp.float32)


def ianf_step(phase, interval, syn, *, block: int | None = None):
    """One resolution step for a batch of ignore-and-fire neurons.

    Args:
        phase, interval, syn: f32[B].

    Returns:
        (phase', spikes) — each f32[B]; spikes is a 0/1 mask.
    """
    (batch,) = phase.shape
    if block is None:
        block = pick_block(batch)
    if batch % block != 0:
        raise ValueError(f"block {block} does not divide batch {batch}")
    grid = (batch // block,)
    vec_spec = pl.BlockSpec((block,), lambda i: (i,))
    out_shape = [jax.ShapeDtypeStruct((batch,), jnp.float32)] * 2
    return pl.pallas_call(
        _ianf_kernel,
        grid=grid,
        in_specs=[vec_spec, vec_spec, vec_spec],
        out_specs=[vec_spec, vec_spec],
        out_shape=out_shape,
        interpret=True,
    )(phase, interval, syn)
