"""L1 Pallas kernel: leaky integrate-and-fire (iaf_psc_delta) state update.

The paper's update phase advances, per simulation cycle, the membrane state
of every process-local neuron by one resolution step ``h`` (0.1 ms).  This is
the arithmetic hot-spot of the update phase, expressed here as a Pallas
kernel so that the same code lowers into the model HLO at build time.

Exact-integration update for delta-current synapses (Rotter & Diesmann 1999
as used by NEST's ``iaf_psc_delta``), in terms of the deviation ``v`` of the
membrane potential from resting potential:

    non-refractory:  v' = p22 * v + drive + syn_in
    refractory:      v' = v_reset, input discarded, counter decrements
    threshold:       v' >= theta  ->  spike, v' := v_reset, refr := ref_steps

All state is f32 (the refractory counter holds small integers exactly) so
that a single dtype crosses the PJRT boundary.

Parameter vector layout (f32[PARAM_LEN]):
    [0] p22       membrane propagator  exp(-h / tau_m)
    [1] drive     constant external drive per step, (1 - p22) * R_m * I_e
    [2] theta     spike threshold (relative to resting potential)
    [3] v_reset   reset value (relative to resting potential)
    [4] ref_steps refractory period in steps (integer-valued float)
    [5..7]        reserved

TPU note (DESIGN.md §Hardware-Adaptation): the op is elementwise over the
neuron axis; blocks of 512 neurons keep each operand at 2 KiB in VMEM and the
update runs on the VPU.  ``interpret=True`` is mandatory on CPU PJRT.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PARAM_LEN = 8
#: default neuron-axis block; multiples keep HBM->VMEM streams aligned
DEFAULT_BLOCK = 512


def _lif_kernel(params_ref, v_ref, refr_ref, syn_ref, v_out_ref,
                refr_out_ref, spk_out_ref):
    """Single-step LIF update on one neuron block."""
    p22 = params_ref[0]
    drive = params_ref[1]
    theta = params_ref[2]
    v_reset = params_ref[3]
    ref_steps = params_ref[4]

    v = v_ref[...]
    refr = refr_ref[...]
    syn = syn_ref[...]

    is_ref = refr > 0.0
    # exact integration; refractory neurons are clamped and discard input
    v_int = p22 * v + drive + syn
    v_new = jnp.where(is_ref, v_reset, v_int)
    spike = jnp.logical_and(jnp.logical_not(is_ref), v_new >= theta)
    v_out_ref[...] = jnp.where(spike, v_reset, v_new)
    refr_out_ref[...] = jnp.where(spike, ref_steps,
                                  jnp.maximum(refr - 1.0, 0.0))
    spk_out_ref[...] = spike.astype(jnp.float32)


def pick_block(batch: int, preferred: int = DEFAULT_BLOCK) -> int:
    """Largest block <= preferred that divides ``batch``."""
    if batch <= 0:
        raise ValueError(f"batch must be positive, got {batch}")
    b = min(batch, preferred)
    while batch % b != 0:
        b -= 1
    return b


def lif_step(params, v, refr, syn, *, block: int | None = None):
    """One resolution step for a batch of LIF neurons via Pallas.

    Args:
        params: f32[PARAM_LEN] parameter vector (see module docstring).
        v, refr, syn: f32[B] membrane deviation, refractory counter,
            accumulated synaptic delta input for this step.
        block: neuron-axis block size; must divide B (default: largest
            divisor of B that is <= 512).

    Returns:
        (v', refr', spikes) — each f32[B]; spikes is a 0/1 mask.
    """
    (batch,) = v.shape
    if block is None:
        block = pick_block(batch)
    if batch % block != 0:
        raise ValueError(f"block {block} does not divide batch {batch}")
    grid = (batch // block,)
    out_shape = [jax.ShapeDtypeStruct((batch,), jnp.float32)] * 3
    param_spec = pl.BlockSpec((PARAM_LEN,), lambda i: (0,))
    vec_spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        _lif_kernel,
        grid=grid,
        in_specs=[param_spec, vec_spec, vec_spec, vec_spec],
        out_specs=[vec_spec, vec_spec, vec_spec],
        out_shape=out_shape,
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(params, v, refr, syn)


@partial(jax.jit, static_argnames=("block",))
def lif_step_jit(params, v, refr, syn, block: int | None = None):
    return lif_step(params, v, refr, syn, block=block)
