"""AOT pipeline: lower the L2 jax step functions to HLO text artifacts.

Interchange format is HLO *text*, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the published ``xla`` Rust crate) rejects
(``proto.id() <= INT_MAX``).  The text parser reassigns ids and round-trips
cleanly — see /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Writes one ``<name>.hlo.txt`` per entry in ``ARTIFACTS`` plus a
``manifest.json`` describing shapes so the Rust artifact registry
(`rust/src/runtime/registry.rs`) can pick executables without re-parsing
HLO.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def artifact_specs(batches=(512, 2048, 8192), multistep_k=(10,),
                   multistep_b=(2048,)):
    """Enumerate (name, fn, example_args, meta) artifact specs."""
    specs = []
    for b in batches:
        specs.append((
            f"lif_step_b{b}",
            model.lif_step_fn,
            (_f32(model.PARAM_LEN), _f32(b), _f32(b), _f32(b)),
            {"kind": "lif_step", "batch": b,
             "inputs": ["params", "v", "refr", "syn"],
             "outputs": ["v", "refr", "spikes"]},
        ))
        specs.append((
            f"ianf_step_b{b}",
            model.ianf_step_fn,
            (_f32(b), _f32(b), _f32(b)),
            {"kind": "ianf_step", "batch": b,
             "inputs": ["phase", "interval", "syn"],
             "outputs": ["phase", "spikes"]},
        ))
    for k in multistep_k:
        for b in multistep_b:
            specs.append((
                f"lif_multistep_k{k}_b{b}",
                model.lif_multistep_fn,
                (_f32(model.PARAM_LEN), _f32(b), _f32(b), _f32(k, b)),
                {"kind": "lif_multistep", "batch": b, "steps": k,
                 "inputs": ["params", "v", "refr", "syn_steps"],
                 "outputs": ["v", "refr", "spikes"]},
            ))
    return specs


def build(out_dir: str, specs=None, verbose: bool = True) -> dict:
    specs = specs if specs is not None else artifact_specs()
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": "hlo-text", "artifacts": []}
    for name, fn, args, meta in specs:
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        entry = {"name": name, "file": fname, **meta}
        manifest["artifacts"].append(entry)
        if verbose:
            print(f"  wrote {path} ({len(text)} chars)")
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    if verbose:
        print(f"  wrote {mpath} ({len(manifest['artifacts'])} artifacts)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts",
                    help="directory for *.hlo.txt + manifest.json")
    args = ap.parse_args()
    build(args.out_dir)


if __name__ == "__main__":
    main()
