"""Unit tests for the observability trace validator
(tools/trace_summary.py).

The validator must accept exactly the documents ``obs::trace`` and
``obs::report`` emit — properly nested spans, paired split-phase
posts, the ``nsim-stats-v1`` schema — and reject structural breakage:
partial overlaps, non-monotonic timelines, unmatched posts, a top
straggler that contradicts its own ledgers.
"""

import json
import os
import sys

sys.path.insert(
    0,
    os.path.join(os.path.dirname(__file__), "..", "..", "tools"),
)

import trace_summary as ts


def _ev(name, pid, t, dur, **args):
    e = {"ph": "X", "name": name, "pid": pid, "tid": 0,
         "ts": t, "dur": dur, "cat": "none"}
    if args:
        e["args"] = args
    return e


def _trace(events):
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def _valid_events():
    # rank 0: an update, then a traced alltoall with nested barrier
    # frames (exporter order: by start, longest first on ties); rank 1:
    # a post closed by a complete, plus an abandoned tail post
    return [
        _ev("update", 0, 0.0, 10.0, cycle=3),
        _ev("alltoall", 0, 10.0, 30.0, epoch=1),
        _ev("alltoall (sync barrier)", 0, 11.0, 5.0, src=1),
        _ev("alltoall (deposit)", 0, 20.0, 8.0),
        _ev("post", 1, 0.0, 2.0, epoch=0, ring_slot=0),
        _ev("complete", 1, 30.0, 4.0, epoch=0, src=0),
        _ev("post", 1, 40.0, 2.0, epoch=1, ring_slot=1),
        _ev("abandon", 1, 50.0, 1.0, epoch=1),
    ]


def _stats(top_rank=2, waits=(0, 0, 7, 0), late=(0.0, 0.0, 0.5, 0.0)):
    ledger = {"waits": list(waits), "lateness_secs": list(late)}
    empty = {"waits": [0] * 4, "lateness_secs": [0.0] * 4}
    return {
        "schema": "nsim-stats-v1",
        "config": {"model": "sanity", "m_ranks": 4},
        "result": {"s_cycles": 100},
        "phase_times": {},
        "comm": {},
        "intervals": [],
        "stragglers": {
            "global": [ledger, empty, empty, empty],
            "local": [],
            "top": {"rank": top_rank, "waits": sum(waits),
                    "lateness_secs": sum(late)},
        },
        "sync_model": {
            "fitted": {"mu_secs": 1e-3, "sigma_secs": 1e-4, "cv": 0.1},
            "tiers": {
                "global": {"predicted_secs": 0.1, "measured_secs": 0.12},
                "local": {"predicted_secs": 0.0, "measured_secs": 0.01},
            },
        },
    }


def test_valid_trace_passes():
    assert ts.validate_events(_valid_events()) == []


def test_empty_trace_rejected():
    assert ts.validate_events([])
    assert ts.span_events({"no": "events"}) is None


def test_negative_duration_rejected():
    events = _valid_events()
    events[0]["dur"] = -1.0
    assert any("bad dur" in p for p in ts.validate_events(events))


def test_partial_overlap_rejected():
    # a span stretching over its enclosing span's end is not a tree
    events = [
        _ev("alltoall", 0, 0.0, 10.0),
        _ev("alltoall (deposit)", 0, 5.0, 20.0),
    ]
    assert any("partially overlaps" in p
               for p in ts.validate_events(events))


def test_non_monotonic_order_rejected():
    events = [
        _ev("update", 0, 10.0, 1.0),
        _ev("update", 0, 0.0, 1.0),
    ]
    assert any("monotonic" in p for p in ts.validate_events(events))


def test_unmatched_post_rejected():
    events = _valid_events()
    events = [e for e in events if e["name"] != "complete"]
    assert any("post" in p for p in ts.validate_events(events))


def test_post_epoch_mismatch_rejected():
    events = _valid_events()
    for e in events:
        if e["name"] == "complete":
            e["args"]["epoch"] = 99
    assert any("pair up" in p for p in ts.validate_events(events))


def test_disjoint_ranks_validated_independently():
    # identical timestamps on different ranks never interact
    events = [
        _ev("update", 0, 0.0, 10.0),
        _ev("update", 1, 0.0, 10.0),
        _ev("update", 2, 0.0, 10.0),
    ]
    assert ts.validate_events(events) == []


def test_stats_schema_accepted(capsys):
    assert ts.check_stats(_stats()) == []
    out = capsys.readouterr().out
    assert "top straggler rank 2" in out
    assert "T_sync[global]" in out


def test_stats_transport_printed_and_defaulted(capsys):
    # reports written by current binaries carry config.transport ...
    doc = _stats()
    doc["config"]["transport"] = "socket"
    assert ts.check_stats(doc) == []
    assert "transport socket" in capsys.readouterr().out
    # ... and reports from older binaries lack it: still valid
    # (schema-stable), reported as the in-process default
    assert ts.check_stats(_stats()) == []
    assert "transport shmem" in capsys.readouterr().out


def test_stats_job_suffixed_documents_accepted(capsys):
    # per-job documents from `nsim serve --stats-json` stamp config.job
    # with the deterministic server id; the validator accepts and
    # surfaces it
    doc = _stats()
    doc["config"]["job"] = "job-3"
    assert ts.check_stats(doc) == []
    assert "job job-3" in capsys.readouterr().out
    # direct CLI documents lack the key entirely: still valid
    # (schema-stable optionality, mirroring config.transport)
    doc = _stats()
    assert "job" not in doc["config"]
    assert ts.check_stats(doc) == []
    assert "job" not in capsys.readouterr().out


def test_stats_malformed_job_rejected():
    for bad in ("3", "rank-3", "", 7, "job-"):
        doc = _stats()
        doc["config"]["job"] = bad
        assert any("config.job" in p for p in ts.check_stats(doc)), bad


def test_stats_malformed_transport_rejected():
    doc = _stats()
    doc["config"]["transport"] = 7
    assert any("transport" in p for p in ts.check_stats(doc))


def test_stats_wrong_schema_rejected():
    doc = _stats()
    doc["schema"] = "nsim-stats-v0"
    assert any("schema" in p for p in ts.check_stats(doc))


def test_stats_missing_section_rejected():
    doc = _stats()
    del doc["intervals"]
    assert any("intervals" in p for p in ts.check_stats(doc))


def test_stats_top_contradicting_ledgers_rejected():
    doc = _stats(top_rank=1)  # ledgers blame rank 2
    assert any("argmax" in p for p in ts.check_stats(doc))


def test_cli_end_to_end(tmp_path):
    trace = tmp_path / "trace.json"
    stats = tmp_path / "stats.json"
    trace.write_text(json.dumps(_trace(_valid_events())))
    stats.write_text(json.dumps(_stats()))
    assert ts.main([str(trace), "--stats", str(stats)]) == 0
    # a broken trace fails the run even when the stats are fine
    bad = _valid_events()
    bad[0]["ts"] = 100.0  # out of order
    trace.write_text(json.dumps(_trace(bad)))
    assert ts.main([str(trace), "--stats", str(stats)]) == 1
