"""AOT pipeline tests: HLO text emission + manifest consistency.

Uses tiny batch sizes so tracing stays fast; the real artifact set is built
by ``make artifacts``.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    specs = aot.artifact_specs(batches=(64,), multistep_k=(2,),
                               multistep_b=(64,))
    manifest = aot.build(out, specs, verbose=False)
    return out, manifest


class TestBuild:
    def test_writes_all_files(self, built):
        out, manifest = built
        for a in manifest["artifacts"]:
            assert os.path.exists(os.path.join(out, a["file"]))

    def test_hlo_is_text_with_entry(self, built):
        out, manifest = built
        for a in manifest["artifacts"]:
            text = open(os.path.join(out, a["file"])).read()
            assert "ENTRY" in text and "HloModule" in text
            # proto ids must survive the 32-bit parser; text format has none
            assert not text.startswith("\x08")

    def test_manifest_entries(self, built):
        _, manifest = built
        kinds = {a["kind"] for a in manifest["artifacts"]}
        assert kinds == {"lif_step", "ianf_step", "lif_multistep"}
        for a in manifest["artifacts"]:
            assert a["batch"] > 0
            assert a["inputs"] and a["outputs"]

    def test_manifest_file_is_valid_json(self, built):
        out, manifest = built
        loaded = json.load(open(os.path.join(out, "manifest.json")))
        assert loaded == manifest

    def test_lowered_computation_executes(self, built):
        """The lowered HLO must agree with direct jax execution."""
        b = 64
        p = model.lif_params(i_e=400.0)
        rng = np.random.default_rng(7)
        v = jnp.asarray(rng.normal(5, 4, b).astype(np.float32))
        refr = jnp.zeros(b, jnp.float32)
        syn = jnp.asarray(rng.normal(0, 1, b).astype(np.float32))
        direct = model.lif_step_fn(p, v, refr, syn)
        compiled = jax.jit(model.lif_step_fn).lower(p, v, refr, syn).compile()
        via_hlo = compiled(p, v, refr, syn)
        for d, h in zip(direct, via_hlo):
            np.testing.assert_allclose(np.asarray(d), np.asarray(h),
                                       rtol=1e-6)
