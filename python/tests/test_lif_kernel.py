"""Pallas LIF kernel vs pure-jnp oracle — the core L1 correctness signal."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import lif, ref


def _allclose(actual, expected):
    for a, e in zip(actual, expected):
        np.testing.assert_allclose(np.asarray(a), np.asarray(e),
                                   rtol=1e-6, atol=1e-6)


def _rand_state(rng, batch):
    v = rng.normal(5.0, 4.0, batch).astype(np.float32)
    refr = rng.integers(0, 4, batch).astype(np.float32)
    syn = rng.normal(0.2, 1.0, batch).astype(np.float32)
    return jnp.asarray(v), jnp.asarray(refr), jnp.asarray(syn)


class TestPickBlock:
    def test_small_batch_uses_batch(self):
        assert lif.pick_block(17) == 17

    def test_divisor_of_large_batch(self):
        b = lif.pick_block(2048)
        assert b == 512 and 2048 % b == 0

    def test_prime_batch_falls_back(self):
        b = lif.pick_block(1021)  # prime > 512
        assert 1021 % b == 0

    def test_rejects_nonpositive(self):
        with pytest.raises(ValueError):
            lif.pick_block(0)


class TestLifStep:
    def test_matches_ref_basic(self):
        rng = np.random.default_rng(1)
        p = model.lif_params(i_e=380.0)
        state = _rand_state(rng, 1024)
        _allclose(lif.lif_step(p, *state), ref.lif_step_ref(p, *state))

    def test_block_not_dividing_batch_raises(self):
        p = model.lif_params()
        z = jnp.zeros(100, jnp.float32)
        with pytest.raises(ValueError):
            lif.lif_step(p, z, z, z, block=33)

    def test_threshold_crossing_emits_spike_and_resets(self):
        p = model.lif_params(theta_rel=15.0, v_reset_rel=0.0)
        v = jnp.asarray([20.0, 1.0], jnp.float32)
        refr = jnp.zeros(2, jnp.float32)
        syn = jnp.zeros(2, jnp.float32)
        v2, refr2, spk = lif.lif_step(p, v, refr, syn)
        assert spk[0] == 1.0 and spk[1] == 0.0
        assert v2[0] == 0.0  # reset
        assert refr2[0] == 20.0  # t_ref=2ms / h=0.1ms

    def test_refractory_neuron_ignores_input(self):
        p = model.lif_params()
        v = jnp.asarray([0.0], jnp.float32)
        refr = jnp.asarray([5.0], jnp.float32)
        syn = jnp.asarray([100.0], jnp.float32)
        v2, refr2, spk = lif.lif_step(p, v, refr, syn)
        assert v2[0] == 0.0 and refr2[0] == 4.0 and spk[0] == 0.0

    def test_refractory_neuron_never_spikes(self):
        p = model.lif_params()
        v = jnp.asarray([50.0], jnp.float32)
        refr = jnp.asarray([1.0], jnp.float32)
        _, _, spk = lif.lif_step(p, v, refr, jnp.zeros(1, jnp.float32))
        assert spk[0] == 0.0

    def test_subthreshold_decay(self):
        p = model.lif_params(i_e=0.0)
        v = jnp.asarray([10.0], jnp.float32)
        z = jnp.zeros(1, jnp.float32)
        v2, _, _ = lif.lif_step(p, v, z, z)
        # exp(-0.1/10) * 10
        assert abs(float(v2[0]) - 10.0 * np.exp(-0.01)) < 1e-5

    def test_constant_drive_converges_to_ri(self):
        # with i_e only, fixed point of v' = p22 v + (1-p22) R I is R*I
        p = model.lif_params(i_e=200.0, theta_rel=1e9)
        v = jnp.zeros(1, jnp.float32)
        refr = jnp.zeros(1, jnp.float32)
        syn = jnp.zeros(1, jnp.float32)
        for _ in range(5000):
            v, refr, _ = ref.lif_step_ref(p, v, refr, syn)
        r_i = (10.0 / 250.0) * 200.0  # R_m * I_e = 8 mV
        assert abs(float(v[0]) - r_i) < 1e-2

    @settings(max_examples=25, deadline=None)
    @given(
        nblocks=st.integers(1, 4),
        block=st.sampled_from([8, 32, 128, 512]),
        seed=st.integers(0, 2**31 - 1),
        i_e=st.floats(0.0, 500.0),
        tau_m=st.floats(5.0, 30.0),
    )
    def test_matches_ref_property(self, nblocks, block, seed, i_e, tau_m):
        batch = nblocks * block
        rng = np.random.default_rng(seed)
        p = model.lif_params(i_e=i_e, tau_m=tau_m)
        state = _rand_state(rng, batch)
        _allclose(lif.lif_step(p, *state, block=block),
                  ref.lif_step_ref(p, *state))

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), steps=st.integers(1, 30))
    def test_iterated_step_is_stable(self, seed, steps):
        """State stays finite and refractory counter stays in range."""
        rng = np.random.default_rng(seed)
        p = model.lif_params(i_e=450.0)
        v, refr, _ = _rand_state(rng, 256)
        for _ in range(steps):
            syn = jnp.asarray(rng.normal(0.3, 0.8, 256).astype(np.float32))
            v, refr, spk = ref.lif_step_ref(p, v, refr, syn)
        assert np.isfinite(np.asarray(v)).all()
        assert (np.asarray(refr) >= 0).all()
        assert (np.asarray(refr) <= 20).all()
