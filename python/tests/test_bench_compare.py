"""Unit tests for the CI bench-regression gate (tools/bench_compare.py).

The gate must fail loudly on genuine regressions and never false-positive
on incomparable inputs: placeholder baselines, mismatched smoke/full
profiles, missing files, or smoke-profile noise within the advisory band.
"""

import json
import os
import sys

sys.path.insert(
    0,
    os.path.join(os.path.dirname(__file__), "..", "..", "tools"),
)

import bench_compare as bc
import record_baseline as rb


def _doc(smoke=True, micro=(), engine=(), engine_raw=()):
    return {
        "bench": "hotpath",
        "smoke": smoke,
        "micro": [
            {"name": n, "ns_per_op": ns, "mops_per_s": 1.0}
            for (n, ns) in micro
        ],
        "engine": [
            {
                "model": "m",
                "strategy": "conventional",
                "exec": "pooled",
                "comm": "overlap",
                "comm_depth": depth,
                "ranks_per_area": 1,
                "ranks": 4,
                "threads": 2,
                "rtf": rtf,
            }
            for (depth, rtf) in engine
        ]
        + list(engine_raw),
    }


def _hier_entry(rpa, rtf, ranks=4, with_key=True):
    e = {
        "model": "m",
        "strategy": "structure-aware",
        "exec": "pooled",
        "comm": "blocking",
        "comm_depth": 1,
        "ranks": ranks,
        "threads": 2,
        "rtf": rtf,
    }
    if with_key:
        e["ranks_per_area"] = rpa
    return e


def test_within_tolerance_passes():
    base = _doc(micro=[("a", 100.0)], engine=[(1, 10.0)])
    cur = _doc(micro=[("a", 112.0)], engine=[(1, 11.0)])
    rows, fails, warns = bc.compare(base, cur, 0.15)
    assert len(rows) == 2
    assert not fails and not warns


def test_regression_detected_on_full_profile():
    base = _doc(smoke=False, micro=[("a", 100.0)])
    cur = _doc(smoke=False, micro=[("a", 140.0)])
    _, fails, warns = bc.compare(base, cur, 0.15)
    assert len(fails) == 1
    assert not warns
    kind, name, old, new, delta = fails[0]
    assert (kind, name) == ("micro", "a")
    assert abs(delta - 0.4) < 1e-9


def test_improvement_never_fails():
    base = _doc(micro=[("a", 100.0)], engine=[(4, 10.0)])
    cur = _doc(micro=[("a", 40.0)], engine=[(4, 3.0)])
    _, fails, warns = bc.compare(base, cur, 0.15)
    assert not fails and not warns


def test_noise_floor_suppresses_tiny_absolute_deltas():
    # +50% relative but only +1 ns absolute: below the micro floor
    base = _doc(micro=[("a", 2.0)])
    cur = _doc(micro=[("a", 3.0)])
    _, fails, warns = bc.compare(base, cur, 0.15)
    assert not fails and not warns


def test_smoke_profile_warns_before_failing():
    base = _doc(micro=[("a", 100.0)])
    noisy = _doc(micro=[("a", 160.0)])  # +60%: advisory band
    _, fails, warns = bc.compare(base, noisy, 0.15, smoke_fail_factor=6.0)
    assert not fails and len(warns) == 1
    terrible = _doc(micro=[("a", 400.0)])  # +300%: beyond 6 x 15%
    _, fails, warns = bc.compare(base, terrible, 0.15, smoke_fail_factor=6.0)
    assert len(fails) == 1


def test_engine_keyed_by_full_config_including_depth():
    # same model at different depths must not be cross-compared
    base = _doc(engine=[(1, 10.0), (4, 5.0)])
    cur = _doc(engine=[(1, 10.0), (2, 50.0)])
    rows, fails, _ = bc.compare(base, cur, 0.15)
    assert len(rows) == 1  # only the depth-1 config overlaps
    assert not fails


def test_engine_keyed_by_ranks_per_area():
    # a hierarchical (ranks_per_area=2) config is a different schedule:
    # it must never be cross-compared with the flat config of the same
    # model/strategy/ranks
    base = _doc(engine_raw=[_hier_entry(1, 10.0), _hier_entry(2, 30.0)])
    cur = _doc(engine_raw=[_hier_entry(1, 10.5), _hier_entry(2, 31.0)])
    rows, fails, _ = bc.compare(base, cur, 0.15)
    assert len(rows) == 2
    assert not fails
    # regression only on the hierarchical variant is attributed to it
    worse = _doc(engine_raw=[_hier_entry(1, 10.0), _hier_entry(2, 300.0)])
    _, fails, warns = bc.compare(base, worse, 0.15, smoke_fail_factor=6.0)
    flagged = fails + warns
    assert len(flagged) == 1
    assert "/R2/" in flagged[0][1]


def _transport_entry(transport, rtf, with_key=True):
    e = {
        "model": "m",
        "strategy": "conventional",
        "exec": "pooled",
        "comm": "blocking",
        "comm_depth": 1,
        "ranks_per_area": 1,
        "ranks": 4,
        "threads": 1,
        "rtf": rtf,
    }
    if with_key:
        e["transport"] = transport
    return e


def test_engine_keyed_by_transport():
    # a socket (multi-process) run pays IPC costs the shared-memory run
    # does not; the two must never be cross-compared
    base = _doc(engine_raw=[
        _transport_entry("shmem", 10.0),
        _transport_entry("socket", 40.0),
    ])
    cur = _doc(engine_raw=[
        _transport_entry("shmem", 10.5),
        _transport_entry("socket", 42.0),
    ])
    rows, fails, _ = bc.compare(base, cur, 0.15)
    assert len(rows) == 2
    assert not fails
    # a regression only on the socket variant is attributed to it
    worse = _doc(engine_raw=[
        _transport_entry("shmem", 10.0),
        _transport_entry("socket", 400.0),
    ])
    _, fails, warns = bc.compare(base, worse, 0.15, smoke_fail_factor=6.0)
    flagged = fails + warns
    assert len(flagged) == 1
    assert "/socket/" in flagged[0][1]


def test_transport_defaults_to_shmem_for_old_baselines():
    # baselines recorded before the transport axis existed carry no
    # transport field; they must keep comparing against current shmem
    # runs but never against socket runs
    base = _doc(engine_raw=[_transport_entry("shmem", 10.0, with_key=False)])
    cur = _doc(engine_raw=[_transport_entry("shmem", 11.0)])
    rows, fails, _ = bc.compare(base, cur, 0.15)
    assert len(rows) == 1
    assert not fails
    sock = _doc(engine_raw=[_transport_entry("socket", 11.0)])
    rows, _, _ = bc.compare(base, sock, 0.15)
    assert rows == []


def test_ranks_per_area_defaults_to_one_for_old_baselines():
    # baselines recorded before the hierarchical key existed carry no
    # ranks_per_area field; they must keep comparing against current
    # flat (R=1) runs
    base = _doc(engine_raw=[_hier_entry(1, 10.0, with_key=False)])
    cur = _doc(engine_raw=[_hier_entry(1, 11.0)])
    rows, fails, _ = bc.compare(base, cur, 0.15)
    assert len(rows) == 1
    assert not fails


def test_disjoint_configs_compare_nothing():
    base = _doc(micro=[("a", 100.0)])
    cur = _doc(micro=[("b", 100.0)])
    rows, fails, warns = bc.compare(base, cur, 0.15)
    assert rows == [] and not fails and not warns


def test_missing_configs_reported():
    # configs that vanish from the current results are surfaced so a
    # green gate cannot silently mean "stopped measuring"
    base = _doc(micro=[("a", 100.0), ("b", 5.0)], engine=[(4, 10.0)])
    cur = _doc(micro=[("a", 100.0)])
    gone = bc.missing_configs(base, cur)
    assert gone == [
        "micro: b",
        "engine: m/conventional/pooled/overlap/d4/shmem/R1/M4/T2",
    ]
    assert bc.missing_configs(base, base) == []


def test_cli_paths(tmp_path):
    cur = tmp_path / "cur.json"
    cur.write_text(json.dumps(_doc(micro=[("a", 100.0)])))

    # no baseline at all: pass
    assert bc.main(["--current", str(cur)]) == 0

    # placeholder fallback: pass
    ph = tmp_path / "ph.json"
    ph.write_text(json.dumps({"placeholder": True, "smoke": True}))
    assert (
        bc.main(
            [
                "--current",
                str(cur),
                "--baseline",
                str(tmp_path / "missing.json"),
                "--fallback",
                str(ph),
            ]
        )
        == 0
    )

    # profile mismatch (full baseline vs smoke current): pass
    full = tmp_path / "full.json"
    full.write_text(json.dumps(_doc(smoke=False, micro=[("a", 1.0)])))
    assert (
        bc.main(["--current", str(cur), "--baseline", str(full)]) == 0
    )

    # genuine smoke regression beyond the advisory band: fail
    base = tmp_path / "base.json"
    base.write_text(json.dumps(_doc(micro=[("a", 10.0)])))
    assert (
        bc.main(["--current", str(cur), "--baseline", str(base)]) == 1
    )

    # missing current file is a usage error, not a silent pass
    assert bc.main(["--current", str(tmp_path / "nope.json")]) == 2


def test_placeholder_detection_covers_vacuous_baselines():
    # the declared flag
    assert bc.is_placeholder({"placeholder": True, "micro": [{}]})
    # empty metric families are just as vacuous, flag or no flag
    assert bc.is_placeholder({"micro": [], "engine": []})
    assert bc.is_placeholder({})
    # anything with at least one comparable family is real
    assert not bc.is_placeholder(_doc(micro=[("a", 1.0)]))
    assert not bc.is_placeholder(_doc(engine=[(1, 10.0)]))


def test_placeholder_baseline_is_flagged_loudly(tmp_path, capsys):
    cur = tmp_path / "cur.json"
    cur.write_text(json.dumps(_doc(micro=[("a", 100.0)])))

    # declared placeholder: passes by default, but emits the GitHub
    # annotation so the vacuous gate is visible on the run summary
    ph = tmp_path / "ph.json"
    ph.write_text(json.dumps({"placeholder": True, "smoke": True}))
    args = ["--current", str(cur), "--baseline", str(ph)]
    assert bc.main(args) == 0
    out = capsys.readouterr().out
    assert "::warning" in out
    assert "record_baseline" in out

    # --fail-on-placeholder turns the warning into a gate failure
    assert bc.main(args + ["--fail-on-placeholder"]) == 1

    # a baseline that is vacuous without saying so gets the same
    # treatment — empty arrays compare nothing
    vac = tmp_path / "vac.json"
    vac.write_text(json.dumps(
        {"smoke": True, "micro": [], "engine": []}))
    args = ["--current", str(cur), "--baseline", str(vac)]
    assert bc.main(args) == 0
    assert "::warning" in capsys.readouterr().out
    assert bc.main(args + ["--fail-on-placeholder"]) == 1


def test_record_baseline_rejects_vacuous_input(tmp_path):
    # a placeholder can never be promoted to a baseline
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(
        {"placeholder": True, "smoke": True, "micro": [], "engine": []}))
    out = tmp_path / "out.json"
    assert rb.main([str(bad), "-o", str(out)]) == 1
    assert not out.exists()

    # non-empty micro but empty engine is still not a full baseline
    half = tmp_path / "half.json"
    half.write_text(json.dumps(_doc(micro=[("a", 100.0)])))
    assert rb.main([str(half), "-o", str(out)]) == 1

    # missing / unreadable input is a usage error
    assert rb.main([str(tmp_path / "nope.json"), "-o", str(out)]) == 2


def test_record_baseline_stamps_usable_input(tmp_path):
    rec = tmp_path / "rec.json"
    doc = _doc(micro=[("a", 100.0)], engine=[(1, 10.0)])
    doc["placeholder"] = False  # any falsy leftover must be dropped
    rec.write_text(json.dumps(doc))
    out = tmp_path / "BENCH_baseline.json"
    assert rb.main([str(rec), "-o", str(out), "--label", "run-42"]) == 0

    stamped = json.loads(out.read_text())
    assert "placeholder" not in stamped
    assert "run-42" in stamped["note"]
    assert "record_baseline.py" in stamped["note"]
    # the stamped candidate is a real baseline for the gate...
    assert not bc.is_placeholder(stamped)
    # ...and compares cleanly against the run it was recorded from
    rows, fails, warns = bc.compare(stamped, doc, 0.15)
    assert len(rows) == 2 and not fails and not warns


def test_record_baseline_rejects_nonpositive_numbers(tmp_path):
    doc = _doc(micro=[("a", 100.0)], engine=[(1, 10.0)])
    doc["micro"][0]["ns_per_op"] = 0.0
    rec = tmp_path / "rec.json"
    rec.write_text(json.dumps(doc))
    assert rb.main([str(rec), "-o", str(tmp_path / "o.json")]) == 1
