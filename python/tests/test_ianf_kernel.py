"""Pallas ignore-and-fire kernel vs oracle and schedule semantics."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ignore_and_fire as ianf
from compile.kernels import ref


class TestIanfStep:
    def test_matches_ref(self):
        rng = np.random.default_rng(3)
        b = 512
        phase = jnp.asarray(rng.integers(0, 10, b).astype(np.float32))
        interval = jnp.asarray(rng.integers(5, 20, b).astype(np.float32))
        syn = jnp.asarray(rng.normal(size=b).astype(np.float32))
        got = ianf.ianf_step(phase, interval, syn)
        want = ref.ianf_step_ref(phase, interval, syn)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))

    def test_input_is_ignored(self):
        phase = jnp.asarray([3.0], jnp.float32)
        interval = jnp.asarray([10.0], jnp.float32)
        a = ianf.ianf_step(phase, interval, jnp.asarray([0.0], jnp.float32))
        b = ianf.ianf_step(phase, interval, jnp.asarray([1e6], jnp.float32))
        np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
        np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))

    def test_fires_exactly_at_interval(self):
        """A neuron with interval k spikes every k-th step."""
        k = 7
        phase = jnp.asarray([0.0], jnp.float32)
        interval = jnp.asarray([float(k)], jnp.float32)
        syn = jnp.zeros(1, jnp.float32)
        spikes = []
        for _ in range(3 * k):
            phase, spk = ianf.ianf_step(phase, interval, syn)
            spikes.append(int(spk[0]))
        assert sum(spikes) == 3
        idx = [i for i, s in enumerate(spikes) if s]
        assert np.diff(idx).tolist() == [k, k]

    @settings(max_examples=20, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        steps=st.integers(1, 50),
        interval=st.integers(2, 25),
    )
    def test_rate_matches_interval_property(self, seed, steps, interval):
        rng = np.random.default_rng(seed)
        b = 64
        phase = jnp.asarray(rng.integers(0, interval, b).astype(np.float32))
        iv = jnp.full((b,), float(interval), jnp.float32)
        syn = jnp.zeros(b, jnp.float32)
        total = 0
        for _ in range(steps):
            phase, spk = ref.ianf_step_ref(phase, iv, syn)
            total += int(np.asarray(spk).sum())
        # each neuron fires floor/ceil(steps/interval) times
        lo = b * (steps // interval)
        hi = b * (steps // interval + 1)
        assert lo <= total <= hi
