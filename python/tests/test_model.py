"""L2 model-level tests: composition, parameter builder, multi-step scan."""

import math

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


class TestLifParams:
    def test_propagator(self):
        p = model.lif_params(tau_m=10.0, h=0.1)
        assert abs(float(p[0]) - math.exp(-0.01)) < 1e-7

    def test_ref_steps_rounding(self):
        p = model.lif_params(t_ref=2.0, h=0.1)
        assert float(p[4]) == 20.0

    def test_drive_scaling(self):
        p0 = model.lif_params(i_e=0.0)
        p1 = model.lif_params(i_e=250.0)
        assert float(p0[1]) == 0.0
        # (1-p22) * R * I with R = tau/C = 0.04 GOhm
        want = (1 - math.exp(-0.01)) * 0.04 * 250.0
        assert abs(float(p1[1]) - want) < 1e-7

    def test_length(self):
        assert model.lif_params().shape == (model.PARAM_LEN,)


class TestMultistep:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), k=st.integers(1, 12))
    def test_scan_equals_iterated_single_step(self, seed, k):
        rng = np.random.default_rng(seed)
        b = 256
        p = model.lif_params(i_e=420.0)
        v = jnp.asarray(rng.normal(5, 4, b).astype(np.float32))
        refr = jnp.asarray(rng.integers(0, 4, b).astype(np.float32))
        syn = jnp.asarray(rng.normal(0.2, 1.0, (k, b)).astype(np.float32))

        v_m, refr_m, spk_m = model.lif_multistep_fn(p, v, refr, syn)
        v_r, refr_r, spk_r = ref.lif_multistep_ref(p, v, refr, syn)
        np.testing.assert_allclose(np.asarray(v_m), np.asarray(v_r),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(refr_m), np.asarray(refr_r))
        np.testing.assert_allclose(np.asarray(spk_m), np.asarray(spk_r))

    def test_spike_shape_is_k_by_b(self):
        p = model.lif_params()
        b, k = 128, 5
        z = jnp.zeros((b,), jnp.float32)
        _, _, spk = model.lif_multistep_fn(p, z, z, jnp.zeros((k, b)))
        assert spk.shape == (k, b)


class TestStepFunctions:
    def test_lif_step_fn_returns_triple(self):
        p = model.lif_params()
        z = jnp.zeros(64, jnp.float32)
        out = model.lif_step_fn(p, z, z, z)
        assert isinstance(out, tuple) and len(out) == 3

    def test_ianf_step_fn_returns_pair(self):
        z = jnp.zeros(64, jnp.float32)
        out = model.ianf_step_fn(z, jnp.full((64,), 10.0), z)
        assert isinstance(out, tuple) and len(out) == 2
