"""Pytest bootstrap: make `python/` importable so the suite runs both as
`cd python && pytest tests/` and as `pytest python/tests/` from the repo
root."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
