#!/usr/bin/env python3
"""Validate and summarize an nsim ``--trace`` / ``--stats-json`` pair.

Used by the CI ``observability-smoke`` job and by hand after a profiled
run::

    nsim simulate --model sanity --ranks 4 --trace trace.json \
        --stats-json stats.json
    python3 tools/trace_summary.py trace.json --stats stats.json

The trace is the Chrome-trace-event document ``obs::trace`` exports
(one ``X`` complete event per span, ``pid`` = rank).  The tool checks
the structural invariants the recorder promises, then prints a compact
per-phase/per-rank summary:

* every event has a name, non-negative ``ts``/``dur`` and a known
  ``pid``;
* per rank, span timestamps are monotonic in the file order the
  exporter wrote (sorted by start, longest-first on ties);
* per rank, spans are properly nested or disjoint — a span never
  partially overlaps an enclosing one;
* every split-phase ``post`` is closed by exactly one ``complete`` or
  ``abandon`` with the same exchange epoch on the same rank;
* with ``--stats``, the report parses, carries the expected schema tag,
  and its straggler ledger is consistent (the printed top straggler is
  the argmax of the per-rank ledgers).

Exit status: 0 = valid (summary printed), 1 = validation failure,
2 = usage/IO error.
"""

from __future__ import annotations

import argparse
import json
import re
import sys
from collections import defaultdict

SCHEMA = "nsim-stats-v1"


def load_json(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        print(f"error: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)


def span_events(doc):
    """The complete ('X') events of a Chrome trace document."""
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return None
    return [e for e in events if e.get("ph") == "X"]


def validate_events(events):
    """Return a list of violated invariants (empty = well formed)."""
    problems = []
    if not events:
        problems.append("trace contains no complete ('X') span events")
        return problems
    for i, e in enumerate(events):
        if not e.get("name"):
            problems.append(f"event {i} has no name")
        if not isinstance(e.get("pid"), int) or e["pid"] < 0:
            problems.append(f"event {i} ({e.get('name')!r}) has bad pid")
        ts, dur = e.get("ts"), e.get("dur")
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"event {i} ({e.get('name')!r}) has bad ts")
        if not isinstance(dur, (int, float)) or dur < 0:
            problems.append(f"event {i} ({e.get('name')!r}) has bad dur")
    if problems:
        return problems

    by_rank = defaultdict(list)
    for e in events:
        by_rank[(e["pid"], e.get("tid", 0))].append(e)
    for (pid, tid), rank in by_rank.items():
        # exporter order: by start, longest-first on equal starts — so
        # timestamps are monotonic and parents precede children
        for a, b in zip(rank, rank[1:]):
            if b["ts"] < a["ts"]:
                problems.append(
                    f"rank {pid}/{tid}: timestamps not monotonic "
                    f"({b['name']!r} at {b['ts']} after {a['name']!r} "
                    f"at {a['ts']})")
                break
        # stack nesting: spans nest or are disjoint, never partial
        stack = []
        for e in rank:
            end = e["ts"] + e["dur"]
            while stack and stack[-1][0] <= e["ts"]:
                stack.pop()
            if stack and end > stack[-1][0]:
                problems.append(
                    f"rank {pid}/{tid}: span {e['name']!r} "
                    f"[{e['ts']}, {end}] partially overlaps enclosing "
                    f"{stack[-1][1]!r} ending at {stack[-1][0]}")
            stack.append((end, e["name"]))
        # split-phase pairing: post epochs == complete/abandon epochs
        opens = sorted(e.get("args", {}).get("epoch", -1)
                       for e in rank if e["name"] == "post")
        closes = sorted(e.get("args", {}).get("epoch", -1)
                        for e in rank
                        if e["name"] in ("complete", "abandon"))
        if opens != closes:
            problems.append(
                f"rank {pid}/{tid}: {len(opens)} post(s) vs "
                f"{len(closes)} complete/abandon(s) and the exchange "
                f"epochs do not pair up")
    return problems


def summarize(events, top=3):
    """Per-name aggregates and the wait-attribution ranking."""
    agg = defaultdict(lambda: [0, 0.0])  # name -> [count, total µs]
    blame = defaultdict(lambda: [0, 0.0])  # src rank -> [waits, µs]
    ranks = set()
    for e in events:
        ranks.add(e["pid"])
        a = agg[e["name"]]
        a[0] += 1
        a[1] += e["dur"]
        src = e.get("args", {}).get("src", -1)
        if isinstance(src, int) and src >= 0:
            b = blame[src]
            b[0] += 1
            b[1] += e["dur"]
    print(f"{len(events)} spans over {len(ranks)} rank(s)")
    width = max(len(n) for n in agg)
    for name, (count, total) in sorted(
            agg.items(), key=lambda kv: -kv[1][1]):
        print(f"  {name:<{width}}  {count:>7} spans  "
              f"{total / 1e3:>10.3f} ms total")
    if blame:
        print("top stragglers (by attributed wait time):")
        culprits = sorted(blame.items(), key=lambda kv: -kv[1][1])
        for src, (waits, total) in culprits[:top]:
            print(f"  rank {src}: last arriver in {waits} wait(s), "
                  f"{total / 1e3:.3f} ms waited on it")
    return blame


def check_stats(doc):
    """Validate the --stats-json report; return problem list."""
    problems = []
    if doc.get("schema") != SCHEMA:
        problems.append(
            f"stats schema is {doc.get('schema')!r}, expected {SCHEMA!r}")
        return problems
    for section in ("config", "result", "phase_times", "comm",
                    "intervals", "stragglers", "sync_model"):
        if section not in doc:
            problems.append(f"stats report is missing {section!r}")
    if problems:
        return problems
    # transport is a recent addition to the config block — reports from
    # older binaries simply lack it, which stays valid (schema-stable);
    # absent means the in-process shared-memory backend
    cfg = doc["config"]
    transport = cfg.get("transport", "shmem")
    if not isinstance(transport, str) or not transport:
        problems.append(
            f"config.transport is {transport!r}, expected a name like "
            "'shmem' or 'socket'")
        return problems
    # config.job is stamped by the serving layer on per-job documents
    # (the `.job-<n>` suffixed files `nsim serve --stats-json` writes);
    # direct CLI reports simply lack it, which stays valid
    job = cfg.get("job")
    if job is not None and not re.fullmatch(r"job-\d+", str(job)):
        problems.append(
            f"config.job is {job!r}, expected a server job id like "
            "'job-3'")
        return problems
    tag = f", job {job}" if job is not None else ""
    print(f"stats: transport {transport}, {cfg.get('m_ranks')} rank(s)"
          f"{tag}")
    stragglers = doc["stragglers"]
    # each ledger is {"waits": [per blamed rank], "lateness_secs": [..]};
    # fold them and check the report's own top entry is their argmax
    # (wait count, lateness as tie-break — mirroring obs::blame)
    totals = defaultdict(lambda: [0, 0.0])
    for ledger in stragglers.get("global", []) + stragglers.get("local", []):
        waits = ledger.get("waits", [])
        late = ledger.get("lateness_secs", [])
        for rank, (w, l) in enumerate(zip(waits, late)):
            t = totals[rank]
            t[0] += w
            t[1] += l
    blamed = {r: t for r, t in totals.items() if t[0] > 0}
    top = stragglers.get("top")
    if blamed:
        best = max(blamed, key=lambda r: (blamed[r][0], blamed[r][1]))
        if top is None:
            problems.append("stragglers.top missing despite ledger entries")
        elif top["rank"] != best:
            problems.append(
                f"stragglers.top names rank {top['rank']} but the "
                f"ledgers' argmax is rank {best}")
        else:
            print(f"stats: top straggler rank {top['rank']} "
                  f"({top['waits']} waits, "
                  f"{top['lateness_secs'] * 1e3:.3f} ms lateness)")
    sm = doc["sync_model"]
    tiers = sm.get("tiers") or {}
    for tier in ("global", "local"):
        t = tiers.get(tier)
        if t is not None:
            print(f"stats: T_sync[{tier}] predicted "
                  f"{t['predicted_secs']:.6f} s, measured "
                  f"{t['measured_secs']:.6f} s")
    return problems


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Chrome trace JSON written by --trace")
    ap.add_argument("--stats", default=None,
                    help="stats report written by --stats-json")
    ap.add_argument("--top", type=int, default=3,
                    help="stragglers to list (default 3)")
    args = ap.parse_args(argv)

    events = span_events(load_json(args.trace))
    if events is None:
        print(f"error: {args.trace} has no traceEvents array",
              file=sys.stderr)
        return 2
    problems = validate_events(events)
    if problems:
        for p in problems:
            print(f"INVALID: {p}", file=sys.stderr)
        return 1
    summarize(events, top=args.top)
    if args.stats:
        problems = check_stats(load_json(args.stats))
        if problems:
            for p in problems:
                print(f"INVALID: {p}", file=sys.stderr)
            return 1
    print("trace OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
