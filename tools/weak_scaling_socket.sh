#!/usr/bin/env bash
# Weak-scaling sweep over the multi-process socket transport: for each
# rank count M in the config, `nsim launch` spawns M OS processes on a
# model with one area per rank (constant work per rank), and the wall
# time of the whole launch is recorded.  Output is a JSON document
# (schema nsim-weak-scaling-v1) meant to be uploaded as an advisory CI
# artifact — never a gate: shared-runner timings are noise.
#
# usage: weak_scaling_socket.sh CONFIG OUT_JSON   (run from rust/)
# The binary defaults to target/release/nsim; override with NSIM_BIN.
set -euo pipefail

usage="usage: weak_scaling_socket.sh CONFIG OUT_JSON (run from rust/)"
cfg="${1:?$usage}"
out="${2:?$usage}"
bin="${NSIM_BIN:-target/release/nsim}"

if [ ! -x "$bin" ]; then
  echo "error: $bin not found or not executable (build release first," \
    "or set NSIM_BIN)" >&2
  exit 1
fi

read -r model n_per_area t_model seed ranks <<EOF
$(python3 -c 'import json, sys
c = json.load(open(sys.argv[1]))
print(c["model"], c["n_per_area"], c["t_model_ms"], c["seed"],
      ",".join(str(m) for m in c["ranks"]))' "$cfg")
EOF

rows=""
for m in ${ranks//,/ }; do
  echo "== weak scaling: M=$m processes" \
    "($model, $n_per_area neurons/area, $m areas) =="
  t0=$(date +%s.%N)
  "$bin" launch --ranks "$m" --model "$model" \
    --n-per-area "$n_per_area" --areas "$m" \
    --t-model "$t_model" --seed "$seed"
  t1=$(date +%s.%N)
  wall=$(awk -v a="$t0" -v b="$t1" 'BEGIN { printf "%.3f", b - a }')
  rows="$rows $m:$wall"
done

python3 -c 'import json, sys
out, model, n, t, rows = sys.argv[1:6]
runs = []
for item in rows.split():
    m, wall = item.split(":")
    runs.append({"ranks": int(m), "areas": int(m),
                 "wall_s": float(wall)})
doc = {
    "schema": "nsim-weak-scaling-v1",
    "transport": "socket",
    "model": model,
    "n_per_area": int(n),
    "t_model_ms": float(t),
    "runs": runs,
}
with open(out, "w") as f:
    json.dump(doc, f, indent=2)
    f.write("\n")
print(f"wrote {out} ({len(runs)} runs)")' \
  "$out" "$model" "$n_per_area" "$t_model" "$rows"
