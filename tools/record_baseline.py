#!/usr/bin/env python3
"""Stamp a recorded BENCH_hotpath.json as a committable bench baseline.

The CI bench-regression gate (``tools/bench_compare.py``) diffs each
run against ``BENCH_baseline.json``.  A placeholder baseline (empty
``micro``/``engine`` arrays) makes that gate vacuous, so this tool
turns a *real* recorded result into a baseline candidate:

    cargo bench --bench hotpath -- --smoke --bench-json BENCH_hotpath.json
    python3 tools/record_baseline.py BENCH_hotpath.json -o BENCH_baseline.json

It validates that the input actually measured something (non-empty
``micro`` AND ``engine`` sections, no ``placeholder`` flag), refuses to
stamp anything vacuous, and writes the result with a provenance note so
a committed baseline is self-describing.  CI runs it on every build and
uploads the output as the ``BENCH_baseline_candidate`` artifact —
replacing the committed placeholder is then a one-file commit of that
artifact.

Exit status: 0 = candidate written, 1 = input is not a valid baseline,
2 = usage/IO error.
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def validate(doc):
    """Return a list of reasons `doc` cannot serve as a baseline."""
    problems = []
    if doc.get("placeholder"):
        problems.append("input carries \"placeholder\": true — it never "
                        "held recorded numbers")
    if not doc.get("micro"):
        problems.append("\"micro\" section is empty: no micro-bench "
                        "numbers were recorded")
    if not doc.get("engine"):
        problems.append("\"engine\" section is empty: no engine-run "
                        "numbers were recorded")
    for m in doc.get("micro", []):
        if not isinstance(m.get("ns_per_op"), (int, float)) or \
                m["ns_per_op"] <= 0:
            problems.append(f"micro bench {m.get('name')!r} has no "
                            "positive ns_per_op")
    for e in doc.get("engine", []):
        if not isinstance(e.get("rtf"), (int, float)) or e["rtf"] <= 0:
            problems.append("engine config "
                            f"{e.get('model')!r}/{e.get('strategy')!r} "
                            "has no positive rtf")
    return problems


def stamp(doc, source, label=None):
    """Return `doc` annotated as a baseline candidate (non-destructive)."""
    out = dict(doc)
    out.pop("placeholder", None)
    note = (f"Recorded bench baseline for tools/bench_compare.py, "
            f"stamped by tools/record_baseline.py from {source}. "
            f"Profile: {'smoke' if out.get('smoke') else 'full'}; "
            f"{len(out.get('micro', []))} micro bench(es), "
            f"{len(out.get('engine', []))} engine config(s). "
            "Re-record after intentional perf changes with: "
            "cargo bench --bench hotpath -- --smoke --bench-json "
            "BENCH_hotpath.json && python3 tools/record_baseline.py "
            "BENCH_hotpath.json -o BENCH_baseline.json")
    if label:
        note = f"[{label}] " + note
    out["note"] = note
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("input",
                    help="recorded BENCH_hotpath.json to promote")
    ap.add_argument("-o", "--output", default="BENCH_baseline.json",
                    help="where to write the stamped baseline candidate "
                         "(default: BENCH_baseline.json)")
    ap.add_argument("--label",
                    help="optional provenance tag for the note (e.g. a "
                         "commit SHA or CI run id)")
    args = ap.parse_args(argv)

    if not os.path.exists(args.input):
        print(f"record_baseline: input {args.input!r} missing")
        return 2
    try:
        doc = json.load(open(args.input, "r", encoding="utf-8"))
    except (OSError, json.JSONDecodeError) as e:
        print(f"record_baseline: cannot read {args.input!r}: {e}")
        return 2

    problems = validate(doc)
    if problems:
        print(f"record_baseline: {args.input!r} is not a usable baseline:")
        for p in problems:
            print(f"  - {p}")
        print("record_baseline: refusing to stamp a vacuous baseline — "
              "the regression gate would pass forever.")
        return 1

    out = stamp(doc, os.path.basename(args.input), label=args.label)
    with open(args.output, "w", encoding="utf-8") as fh:
        json.dump(out, fh, indent=2, sort_keys=False)
        fh.write("\n")
    print(f"record_baseline: wrote baseline candidate {args.output!r} "
          f"({len(out.get('micro', []))} micro, "
          f"{len(out.get('engine', []))} engine configs, "
          f"{'smoke' if out.get('smoke') else 'full'} profile). "
          "Commit it as BENCH_baseline.json to arm the gate.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
