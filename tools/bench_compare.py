#!/usr/bin/env python3
"""Compare a BENCH_hotpath.json against a baseline and gate on regressions.

Used by the CI ``bench-regression`` job: the previous ``BENCH_hotpath``
artifact of the base branch is the baseline; when no artifact exists the
committed ``BENCH_baseline.json`` is used; when neither exists the gate
passes with a note, never fails.

A *placeholder* baseline (``"placeholder": true``, or empty ``micro``
AND ``engine`` arrays — a baseline that compares nothing is vacuous no
matter what it calls itself) makes the gate meaningless, so it is
flagged loudly: a banner plus a GitHub Actions ``::warning::``
annotation, and exit 1 under ``--fail-on-placeholder``.  Record a real
baseline with ``tools/record_baseline.py`` (CI uploads one as the
``BENCH_baseline_candidate`` artifact on every run).

Two metric families are compared, both lower-is-better:

* micro benches: ``ns_per_op`` keyed by bench name;
* engine runs: ``rtf`` (real-time factor) keyed by the full config tuple
  (model, strategy, exec, comm, comm_depth, transport, ranks_per_area,
  ranks, threads).

A config regresses when the relative delta exceeds the tolerance *and*
the absolute delta exceeds a noise floor.  Smoke-profile runs (tiny
measurement windows, shared CI runners) are far noisier than full runs,
so on smoke data the strict tolerance only *warns*; the job fails only
beyond the generous ``--smoke-fail-factor`` multiple.  Profiles are
never cross-compared: a smoke baseline cannot judge a full run.

Exit status: 0 = pass (possibly with warnings), 1 = regression,
2 = usage/IO error on the *current* file (the baseline is optional by
design, the current result is not).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

# Absolute noise floors: deltas below these are never regressions even
# if the relative tolerance is exceeded (sub-ns micro jitter, scheduler
# hiccups on near-instant engine runs).
MICRO_FLOOR_NS = 2.0
ENGINE_FLOOR_RTF = 0.5


def load(path):
    with open(path, "r", encoding="utf-8") as fh:
        return json.load(fh)


def is_placeholder(doc):
    """A baseline that cannot gate anything.

    Either it says so (``"placeholder": true``) or it is *vacuous* —
    both metric families empty, so every comparison set is empty and
    the gate passes no matter how bad the current numbers are.
    """
    if doc.get("placeholder"):
        return True
    return not doc.get("micro") and not doc.get("engine")


def micro_map(doc):
    return {m["name"]: m["ns_per_op"] for m in doc.get("micro", [])}


def engine_map(doc):
    out = {}
    for e in doc.get("engine", []):
        key = (
            e.get("model"),
            e.get("strategy"),
            e.get("exec"),
            e.get("comm"),
            e.get("comm_depth", 1),
            # shared-memory vs multi-process socket runs are different
            # machines as far as timing goes; default "shmem" keeps old
            # baselines readable
            e.get("transport", "shmem"),
            # hierarchical configs (areas spanning rank groups) are a
            # distinct schedule; default 1 keeps old baselines readable
            e.get("ranks_per_area", 1),
            e.get("ranks"),
            e.get("threads"),
        )
        out[key] = e.get("rtf")
    return out


def missing_configs(baseline, current):
    """Baseline configs with no counterpart in the current results —
    silently shrinking coverage must at least be called out."""
    gone = []
    for name in sorted(set(micro_map(baseline)) - set(micro_map(current))):
        gone.append(f"micro: {name}")
    base_eng, cur_eng = engine_map(baseline), engine_map(current)
    for key in sorted(set(base_eng) - set(cur_eng), key=str):
        gone.append("engine: {}/{}/{}/{}/d{}/{}/R{}/M{}/T{}".format(*key))
    return gone


def compare(baseline, current, tolerance, smoke_fail_factor=None):
    """Pure comparison: returns (rows, failures, warnings).

    ``rows`` is the full delta table (one tuple per config present in
    both documents); ``failures`` / ``warnings`` are subsets of rows.
    ``smoke_fail_factor``: when not None, the data is smoke-profile —
    deltas beyond ``tolerance`` only warn, deltas beyond
    ``tolerance * smoke_fail_factor`` fail.
    """
    rows, failures, warnings = [], [], []

    def judge(kind, name, old, new, floor):
        if old is None or new is None or old <= 0:
            return
        delta = (new - old) / old
        row = (kind, name, old, new, delta)
        rows.append(row)
        if delta <= tolerance or (new - old) <= floor:
            return
        if smoke_fail_factor is not None:
            if delta > tolerance * smoke_fail_factor:
                failures.append(row)
            else:
                warnings.append(row)
        else:
            failures.append(row)

    base_micro, cur_micro = micro_map(baseline), micro_map(current)
    for name in sorted(set(base_micro) & set(cur_micro)):
        judge("micro", name, base_micro[name], cur_micro[name],
              MICRO_FLOOR_NS)

    base_eng, cur_eng = engine_map(baseline), engine_map(current)
    for key in sorted(set(base_eng) & set(cur_eng), key=str):
        name = "{}/{}/{}/{}/d{}/{}/R{}/M{}/T{}".format(*key)
        judge("engine", name, base_eng[key], cur_eng[key],
              ENGINE_FLOOR_RTF)

    return rows, failures, warnings


def render_table(rows, failures, warnings):
    failed, warned = set(map(id, failures)), set(map(id, warnings))
    lines = []
    header = "{:<7} {:<52} {:>12} {:>12} {:>8}".format(
        "kind", "config", "baseline", "current", "delta")
    lines.append(header)
    lines.append("-" * len(header))
    for row in rows:
        kind, name, old, new, delta = row
        mark = ""
        if id(row) in failed:
            mark = "  << REGRESSION"
        elif id(row) in warned:
            mark = "  <- above tolerance (smoke noise, not gating)"
        lines.append(
            "{:<7} {:<52} {:>12.4g} {:>12.4g} {:>+7.1%}{}".format(
                kind, name[:52], old, new, delta, mark))
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--current", required=True,
                    help="BENCH_hotpath.json of this run")
    ap.add_argument("--baseline",
                    help="baseline BENCH_hotpath.json (base-branch artifact)")
    ap.add_argument("--fallback",
                    help="committed fallback baseline when no artifact exists")
    ap.add_argument("--tolerance", type=float, default=0.15,
                    help="relative regression tolerance (default 0.15)")
    ap.add_argument("--smoke-fail-factor", type=float, default=6.0,
                    help="on smoke profiles, fail only beyond "
                         "tolerance*factor (default 6.0, i.e. 90%%)")
    ap.add_argument("--fail-on-placeholder", action="store_true",
                    help="exit 1 when the baseline is a placeholder or "
                         "vacuous (empty micro+engine) instead of "
                         "passing with a warning")
    args = ap.parse_args(argv)

    if not os.path.exists(args.current):
        print(f"bench_compare: current results {args.current!r} missing")
        return 2
    current = load(args.current)

    baseline_path = None
    for cand in (args.baseline, args.fallback):
        if cand and os.path.exists(cand):
            baseline_path = cand
            break
    if baseline_path is None:
        print("bench_compare: no baseline available (first run on this "
              "branch?) — passing without comparison")
        return 0
    baseline = load(baseline_path)

    if is_placeholder(baseline):
        kind = ("declared placeholder" if baseline.get("placeholder")
                else "vacuous (empty micro AND engine arrays)")
        banner = "!" * 66
        print(banner)
        print(f"!! bench_compare: baseline {baseline_path!r}")
        print(f"!! is a {kind}: the regression gate compares NOTHING and")
        print("!! passes no matter how bad the current numbers are.")
        print("!! Record a real baseline:")
        print("!!   cargo bench --bench hotpath -- --smoke "
              "--bench-json BENCH_hotpath.json")
        print("!!   python3 tools/record_baseline.py BENCH_hotpath.json "
              "-o BENCH_baseline.json")
        print("!! (CI uploads a ready-to-commit candidate as the "
              "BENCH_baseline_candidate artifact.)")
        print(banner)
        # GitHub Actions annotation: visible on the run summary page,
        # harmless plain text everywhere else
        print(f"::warning title=vacuous bench baseline::{baseline_path} "
              f"is a {kind}; the bench-regression gate is not gating. "
              "Commit a recorded baseline (see tools/record_baseline.py).")
        if args.fail_on_placeholder:
            print("bench_compare: --fail-on-placeholder set — failing.")
            return 1
        return 0
    if bool(baseline.get("smoke")) != bool(current.get("smoke")):
        print("bench_compare: baseline and current use different bench "
              "profiles (smoke vs full) — incomparable, passing")
        return 0

    smoke = bool(current.get("smoke"))
    rows, failures, warnings = compare(
        baseline, current, args.tolerance,
        smoke_fail_factor=args.smoke_fail_factor if smoke else None)

    profile = "smoke" if smoke else "full"
    print(f"bench_compare: {len(rows)} comparable configs "
          f"({profile} profile, baseline {baseline_path})")
    print(render_table(rows, failures, warnings))
    gone = missing_configs(baseline, current)
    if gone:
        print(f"\nWARNING: {len(gone)} baseline config(s) have no "
              "counterpart in the current results — coverage shrank, "
              "these are NOT being gated:")
        for g in gone:
            print(f"  - {g}")
    if warnings:
        print(f"\n{len(warnings)} config(s) above the strict tolerance on "
              "the smoke profile; not gating (measurement noise).")
    if failures:
        print(f"\n{len(failures)} regression(s) beyond tolerance "
              f"{args.tolerance:.0%}"
              + (f" x {args.smoke_fail_factor:g} (smoke)" if smoke else "")
              + " — failing the gate.")
        return 1
    print("\nno regressions beyond tolerance.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
