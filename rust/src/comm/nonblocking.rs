//! Split-phase (nonblocking) global exchange over epoch-stamped
//! double-buffered mailboxes.
//!
//! The blocking [`Transport::alltoall_into`](super::Transport) pays the
//! full synchronization skew on the critical path: an explicit barrier
//! in front of the collective makes every rank wait for the slowest one
//! before any data moves.  The split-phase protocol decomposes the
//! collective into
//!
//! * [`SplitTransport::alltoall_start`] — the *post* side.  The sender
//!   deposits its per-destination buffers into the mailboxes and returns
//!   immediately; no rank ever waits here.  Returns a [`PendingExchange`]
//!   handle representing the in-flight collective.
//! * [`PendingExchange::complete`] — the *completion* side.  The receiver
//!   rendezvous with each sender's deposit only at the moment it actually
//!   needs the data; senders that already deposited cost nothing, and the
//!   wait for stragglers is exactly the latency that could not be hidden
//!   by the work done since the post.
//!
//! # Epoch-stamped double buffering
//!
//! Every (dest, src) pair owns **two** mailbox slots, indexed by the
//! parity of the exchange sequence number, and each deposit is stamped
//! with its sequence number.  A sender may therefore post exchange `k+1`
//! before its receivers have drained exchange `k` (the two live in
//! different slots), which is what lets the engine keep **one exchange
//! in flight** while the next epoch's spikes accumulate.  Depth is
//! bounded at one in-flight exchange per rank: posting `k+1` requires
//! having completed `k` (debug-asserted), which in turn guarantees a
//! slot's previous occupant (`k-2`, same parity) was consumed before it
//! is overwritten.
//!
//! # The split-phase quota-resize protocol
//!
//! The blocking collective agrees on buffer overflow via a flag guarded
//! by two barriers.  Split-phase, the agreement rides on the rendezvous
//! that happens anyway: a sender whose largest per-pair deposit exceeds
//! the current quota marks the exchange round's overflow flag at post
//! time; completion waits for all `M` deposits, so by the time any rank
//! finishes completing, the flag is final.  The **last** rank to
//! complete the round settles it — doubling the quota until the largest
//! observed message fits and counting one secondary round — exactly the
//! two-round semantics of the blocking protocol, with both rounds
//! posted eagerly and no extra synchronization.
//!
//! # Buffer recycling
//!
//! Deposits and drains both *swap* vectors with the mailbox slot, so
//! capacity circulates sender → slot → receiver → sender per parity and
//! no steady-state round allocates — the same contract as the blocking
//! [`Transport`](super::Transport) (see the module docs of
//! [`crate::comm`]).
//!
//! # Latency-hiding accounting
//!
//! Each deposit is timestamped.  At completion the receiver computes the
//! *hidden* latency of the exchange — the part of the peers' post skew
//! that elapsed while this rank was doing useful work between
//! [`SplitTransport::alltoall_start`] and [`PendingExchange::complete`]:
//!
//! ```text
//! hidden = clamp(min(t_complete_entry, t_last_deposit) - t_post, >= 0)
//! ```
//!
//! A blocking exchange would have waited `t_last_deposit - t_post` at
//! the barrier; the completion side only waits for whatever of that is
//! left.  The sums land in
//! [`CommStats::hidden_nanos`](super::CommStats) /
//! [`CommStats::overlapped_exchanges`](super::CommStats) and surface
//! through [`CommStatsSnapshot`](super::CommStatsSnapshot).

use super::{Communicator, SpikeMsg, Transport, WorldInner, SPIKE_WIRE_BYTES};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// One epoch-stamped mailbox slot of a (dest, src) pair.
#[derive(Default)]
struct NbSlot {
    state: Mutex<SlotState>,
    ready: Condvar,
}

#[derive(Default)]
struct SlotState {
    /// Sequence number of the current deposit (valid when `filled`).
    seq: u64,
    filled: bool,
    payload: Vec<SpikeMsg>,
    deposited_at: Option<Instant>,
}

/// Shared per-round state of the split-phase resize protocol, indexed by
/// sequence parity.  Reused every second exchange; the depth-one flight
/// bound guarantees a round is fully completed (and reset by its last
/// completer) before the parity is reused.
struct RoundState {
    overflow: AtomicBool,
    /// Counts down from M as ranks complete the round; the rank that
    /// takes it to zero settles the resize and re-arms the counter.
    pending_completions: AtomicUsize,
}

/// Split-phase mailbox state of a [`super::World`]; lives next to the
/// blocking mailboxes so the two protocols can be mixed call-by-call
/// (the engine builds with the blocking collective and runs overlapped).
pub(super) struct NbWorld {
    /// `slots[dest][src][seq % 2]`.
    slots: Vec<Vec<[NbSlot; 2]>>,
    rounds: [RoundState; 2],
    /// Per-rank posted-exchange counter (the sequence number source).
    next_seq: Vec<AtomicU64>,
    /// Per-rank completed-exchange counter (depth bookkeeping).
    completed: Vec<AtomicU64>,
}

impl NbWorld {
    pub(super) fn new(m: usize) -> NbWorld {
        NbWorld {
            slots: (0..m)
                .map(|_| {
                    (0..m)
                        .map(|_| [NbSlot::default(), NbSlot::default()])
                        .collect()
                })
                .collect(),
            rounds: [
                RoundState {
                    overflow: AtomicBool::new(false),
                    pending_completions: AtomicUsize::new(m),
                },
                RoundState {
                    overflow: AtomicBool::new(false),
                    pending_completions: AtomicUsize::new(m),
                },
            ],
            next_seq: (0..m).map(|_| AtomicU64::new(0)).collect(),
            completed: (0..m).map(|_| AtomicU64::new(0)).collect(),
        }
    }
}

/// Timing of the completion side of a split-phase exchange.
#[derive(Clone, Copy, Debug, Default)]
pub struct CompletionTiming {
    /// Time spent blocked waiting for deposits that had not landed yet —
    /// the completion-side synchronization wait (the un-hidden residue
    /// of the peers' skew).
    pub wait_secs: f64,
    /// Time spent draining the mailboxes (the data movement proper).
    pub drain_secs: f64,
}

/// An in-flight split-phase collective.  Must be completed exactly once;
/// dropping it without [`PendingExchange::complete`] panics in debug
/// builds (a dropped exchange would deadlock the peers' completions, as
/// losing an `MPI_Ialltoall` request would).
pub trait Pending {
    /// Seconds the post side spent depositing (never waits on peers).
    fn post_secs(&self) -> f64;

    /// Rendezvous with all deposits of this exchange: `recv` is resized
    /// to M slots and `recv[s]` is overwritten with the spikes from
    /// source rank `s` (per-source order preserved, capacity recycled
    /// through the mailbox).  Blocks only for senders that have not
    /// deposited yet.
    fn complete(self, recv: &mut Vec<Vec<SpikeMsg>>) -> CompletionTiming;
}

/// A transport with a split-phase global exchange in addition to the
/// blocking collectives of [`Transport`].  All ranks must issue the same
/// sequence of starts and completions (collective semantics), with at
/// most one exchange in flight per rank.
pub trait SplitTransport: Transport {
    type Pending: Pending;

    /// Post the send buffers of a global exchange without waiting for
    /// any other rank.  `send[d]` is drained into the mailbox for rank
    /// `d` (capacity recycled).  The returned handle must be completed
    /// before the next `alltoall_start` on this rank.
    fn alltoall_start(&self, send: &mut [Vec<SpikeMsg>]) -> Self::Pending;
}

/// Handle to an in-flight exchange of the shared-memory world.
#[must_use = "an unfinished exchange deadlocks its peers; call complete()"]
pub struct PendingExchange {
    world: Arc<WorldInner>,
    rank: usize,
    seq: u64,
    posted_at: Instant,
    post_secs: f64,
    completed: bool,
}

impl Drop for PendingExchange {
    fn drop(&mut self) {
        if !self.completed && !std::thread::panicking() {
            debug_assert!(
                false,
                "PendingExchange (rank {}, seq {}) dropped without \
                 complete(); peers would deadlock at their rendezvous",
                self.rank, self.seq
            );
        }
    }
}

impl Pending for PendingExchange {
    fn post_secs(&self) -> f64 {
        self.post_secs
    }

    fn complete(mut self, recv: &mut Vec<Vec<SpikeMsg>>) -> CompletionTiming {
        self.completed = true;
        let w = &*self.world;
        let seq = self.seq;
        let parity = (seq % 2) as usize;
        let t0 = Instant::now();
        let mut wait_secs = 0.0;
        let mut last_arrival = self.posted_at;

        recv.resize_with(w.m, Vec::new);
        for (src, out) in recv.iter_mut().enumerate() {
            let slot = &w.nb.slots[self.rank][src][parity];
            let mut st = slot.state.lock().unwrap();
            if !(st.filled && st.seq == seq) {
                let w0 = Instant::now();
                while !(st.filled && st.seq == seq) {
                    st = slot.ready.wait(st).unwrap();
                }
                wait_secs += w0.elapsed().as_secs_f64();
            }
            if let Some(at) = st.deposited_at {
                if at > last_arrival {
                    last_arrival = at;
                }
            }
            out.clear();
            std::mem::swap(&mut st.payload, out);
            st.filled = false;
        }

        // settle the split-phase resize round (see module docs): the
        // last rank to complete applies the quota growth and re-arms
        // the round for its next (same-parity) reuse
        let round = &w.nb.rounds[parity];
        if round.pending_completions.fetch_sub(1, Ordering::AcqRel) == 1 {
            if round.overflow.swap(false, Ordering::Relaxed) {
                let need = w.stats.max_send_per_pair.load(Ordering::Relaxed);
                let mut q = w.quota.load(Ordering::Relaxed);
                while q < need {
                    q *= 2;
                }
                w.quota.store(q, Ordering::Relaxed);
                w.stats.resize_rounds.fetch_add(1, Ordering::Relaxed);
            }
            round.pending_completions.store(w.m, Ordering::Release);
        }

        w.nb.completed[self.rank].fetch_add(1, Ordering::Relaxed);
        w.stats.alltoall_calls.fetch_add(1, Ordering::Relaxed);
        w.stats.overlapped_exchanges.fetch_add(1, Ordering::Relaxed);
        // hidden latency: the part of the peers' post skew that elapsed
        // while this rank computed between post and completion
        let hidden_end = if last_arrival < t0 { last_arrival } else { t0 };
        let hidden = hidden_end.duration_since(self.posted_at);
        w.stats
            .hidden_nanos
            .fetch_add(hidden.as_nanos() as u64, Ordering::Relaxed);
        w.stats.complete_wait_nanos.fetch_add(
            (wait_secs * 1e9) as u64,
            Ordering::Relaxed,
        );

        let total = t0.elapsed().as_secs_f64();
        CompletionTiming {
            wait_secs,
            drain_secs: (total - wait_secs).max(0.0),
        }
    }
}

impl SplitTransport for Communicator {
    type Pending = PendingExchange;

    fn alltoall_start(&self, send: &mut [Vec<SpikeMsg>]) -> PendingExchange {
        let w = &*self.world;
        assert_eq!(send.len(), w.m, "send buffer per rank required");
        let t0 = Instant::now();
        let seq = w.nb.next_seq[self.rank].fetch_add(1, Ordering::Relaxed);
        debug_assert_eq!(
            seq,
            w.nb.completed[self.rank].load(Ordering::Relaxed),
            "rank {}: more than one exchange in flight",
            self.rank
        );
        let quota = w.quota.load(Ordering::Relaxed);
        let parity = (seq % 2) as usize;
        let my_max = send.iter().map(|b| b.len()).max().unwrap_or(0);
        let bytes: usize =
            send.iter().map(|b| b.len() * SPIKE_WIRE_BYTES).sum();
        // publish the overflow mark and the per-pair maximum *before*
        // depositing: consuming any of this rank's deposits (through the
        // slot mutex) then implies both are visible, so the round's last
        // completer can neither settle the resize ahead of a straggling
        // flag nor size the quota below the largest message
        if my_max > quota {
            w.nb.rounds[parity].overflow.store(true, Ordering::Relaxed);
        }
        w.stats
            .max_send_per_pair
            .fetch_max(my_max, Ordering::Relaxed);
        let now = Instant::now();
        for (dest, buf) in send.iter_mut().enumerate() {
            let slot = &w.nb.slots[dest][self.rank][parity];
            let mut st = slot.state.lock().unwrap();
            debug_assert!(
                !st.filled,
                "mailbox slot overrun: deposit {} not yet consumed",
                st.seq
            );
            debug_assert!(st.payload.is_empty(), "recycled slot not drained");
            std::mem::swap(&mut st.payload, buf);
            st.seq = seq;
            st.filled = true;
            st.deposited_at = Some(now);
            slot.ready.notify_all();
        }
        w.stats
            .bytes_sent
            .fetch_add(bytes as u64, Ordering::Relaxed);
        let post_secs = t0.elapsed().as_secs_f64();
        w.stats
            .post_nanos
            .fetch_add((post_secs * 1e9) as u64, Ordering::Relaxed);
        PendingExchange {
            world: self.world.clone(),
            rank: self.rank,
            seq,
            posted_at: t0,
            post_secs,
            completed: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::World;
    use crate::network::Gid;
    use std::thread;
    use std::time::Duration;

    fn msg(source: Gid, cycle: u32) -> SpikeMsg {
        SpikeMsg { source, cycle }
    }

    /// Run `f(rank, comm)` on m rank threads, collect results by rank.
    fn run_ranks<F, R>(m: usize, quota: usize, f: F) -> (World, Vec<R>)
    where
        F: Fn(usize, Communicator) -> R + Send + Sync,
        R: Send,
    {
        let world = World::new(m, quota);
        let results = thread::scope(|s| {
            let handles: Vec<_> = (0..m)
                .map(|rank| {
                    let comm = world.communicator(rank);
                    let f = &f;
                    s.spawn(move || f(rank, comm))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect::<Vec<_>>()
        });
        (world, results)
    }

    #[test]
    fn split_phase_routes_messages() {
        let (_, results) = run_ranks(4, 64, |rank, comm| {
            let mut send: Vec<Vec<SpikeMsg>> = (0..4)
                .map(|d| vec![msg((100 * rank + d) as Gid, 7)])
                .collect();
            let pending = comm.alltoall_start(&mut send);
            assert!(send.iter().all(|b| b.is_empty()), "send not drained");
            let mut recv = Vec::new();
            pending.complete(&mut recv);
            recv
        });
        for (rank, recv) in results.iter().enumerate() {
            assert_eq!(recv.len(), 4);
            for (src, buf) in recv.iter().enumerate() {
                assert_eq!(buf.len(), 1);
                assert_eq!(buf[0].source, (100 * src + rank) as Gid);
                assert_eq!(buf[0].cycle, 7);
            }
        }
    }

    #[test]
    fn split_phase_preserves_per_source_order() {
        let (_, results) = run_ranks(2, 64, |rank, comm| {
            let mut send: Vec<Vec<SpikeMsg>> = (0..2)
                .map(|_| (0..10).map(|i| msg(rank as Gid, i)).collect())
                .collect();
            let pending = comm.alltoall_start(&mut send);
            let mut recv = Vec::new();
            pending.complete(&mut recv);
            recv
        });
        for recv in &results {
            for (src, buf) in recv.iter().enumerate() {
                let cycles: Vec<u32> = buf.iter().map(|m| m.cycle).collect();
                assert_eq!(cycles, (0..10).collect::<Vec<_>>());
                assert!(buf.iter().all(|m| m.source == src as Gid));
            }
        }
    }

    #[test]
    fn many_rounds_recycle_capacity_and_do_not_leak() {
        // one in-flight exchange per rank, 40 rounds over both slot
        // parities; payload varies per round so stale spikes would show
        const M: usize = 3;
        let (world, results) = run_ranks(M, 64, |rank, comm| {
            let mut send: Vec<Vec<SpikeMsg>> =
                (0..M).map(|_| Vec::new()).collect();
            let mut recv: Vec<Vec<SpikeMsg>> = Vec::new();
            let mut total = 0usize;
            for round in 0..40u32 {
                let n = 1 + (round as usize % 4);
                for buf in &mut send {
                    for i in 0..n {
                        buf.push(msg((1000 * rank + i) as Gid, round));
                    }
                }
                let pending = comm.alltoall_start(&mut send);
                pending.complete(&mut recv);
                for (src, buf) in recv.iter().enumerate() {
                    assert_eq!(buf.len(), n, "round {round} from {src}");
                    assert!(
                        buf.iter().all(|m| m.cycle == round),
                        "stale spikes leaked into round {round}"
                    );
                }
                total += recv.iter().map(|b| b.len()).sum::<usize>();
            }
            total
        });
        let expect: usize = (0..40u32).map(|r| (1 + r as usize % 4) * M).sum();
        assert!(results.iter().all(|&t| t == expect), "{results:?}");
        let snap = world.stats().snapshot();
        assert_eq!(snap.alltoall_calls, 40 * M as u64);
        assert_eq!(snap.overlapped_exchanges, 40 * M as u64);
        assert_eq!(snap.resize_rounds, 0);
    }

    #[test]
    fn resize_triggered_while_in_flight() {
        // quota 4; rank 0 posts 10 spikes per pair, keeps computing with
        // the exchange in flight, then completes: the overflow must be
        // settled by the completion rendezvous (one secondary round)
        let (world, results) = run_ranks(2, 4, |rank, comm| {
            let n = if rank == 0 { 10 } else { 1 };
            let mut send: Vec<Vec<SpikeMsg>> = (0..2)
                .map(|_| (0..n).map(|i| msg(rank as Gid, i)).collect())
                .collect();
            let pending = comm.alltoall_start(&mut send);
            // simulated compute while the exchange is in flight
            std::hint::black_box(
                (0..200_000u64).map(|x| x.wrapping_mul(7)).sum::<u64>(),
            );
            let mut recv = Vec::new();
            pending.complete(&mut recv);
            recv.iter().map(|b| b.len()).sum::<usize>()
        });
        assert!(results.iter().all(|&t| t == 11));
        let snap = world.stats().snapshot();
        assert_eq!(snap.resize_rounds, 1, "overflow must settle one round");
        assert_eq!(snap.max_send_per_pair, 10);
        assert!(world.current_quota() >= 10);

        // follow-up rounds settle under the grown quota: the resize
        // count stops growing once the quota fits (a rank may post its
        // second round before the last completer of the first grew the
        // quota, so up to one extra settle is legitimate — never more)
        let (world2, _) = run_ranks(2, 4, |rank, comm| {
            for round in 0..4u32 {
                let mut send: Vec<Vec<SpikeMsg>> = (0..2)
                    .map(|_| {
                        (0..10).map(|i| msg(rank as Gid, i + round)).collect()
                    })
                    .collect();
                let pending = comm.alltoall_start(&mut send);
                let mut recv = Vec::new();
                pending.complete(&mut recv);
                assert!(recv.iter().all(|b| b.len() == 10));
            }
        });
        let resizes = world2.stats().snapshot().resize_rounds;
        assert!((1..=2).contains(&resizes), "resize rounds: {resizes}");
        assert!(world2.current_quota() >= 10);
    }

    #[test]
    fn completion_reports_hidden_latency() {
        // rank 1 posts late; rank 0 posts early and completes even
        // later, so rank 1's post latency is fully hidden for rank 0
        let (world, _) = run_ranks(2, 64, |rank, comm| {
            if rank == 1 {
                thread::sleep(Duration::from_millis(20));
            }
            let mut send: Vec<Vec<SpikeMsg>> =
                (0..2).map(|_| vec![msg(rank as Gid, 0)]).collect();
            let pending = comm.alltoall_start(&mut send);
            if rank == 0 {
                thread::sleep(Duration::from_millis(60));
            }
            let mut recv = Vec::new();
            let timing = pending.complete(&mut recv);
            assert!(timing.wait_secs >= 0.0 && timing.drain_secs >= 0.0);
        });
        let snap = world.stats().snapshot();
        assert_eq!(snap.overlapped_exchanges, 2);
        assert!(
            snap.hidden_secs > 0.005,
            "rank 1's late post should be hidden: {snap:?}"
        );
    }

    #[test]
    fn mixes_with_blocking_collective_on_one_world() {
        // the engine builds its tables with the blocking collective and
        // then runs split-phase; both must coexist on one world
        let (_, results) = run_ranks(2, 64, |rank, comm| {
            let mut send: Vec<Vec<SpikeMsg>> =
                (0..2).map(|_| vec![msg(rank as Gid, 1)]).collect();
            let (recv_blocking, _) = comm.alltoall(&mut send);
            let mut send: Vec<Vec<SpikeMsg>> =
                (0..2).map(|_| vec![msg(rank as Gid, 2)]).collect();
            let pending = comm.alltoall_start(&mut send);
            let mut recv = Vec::new();
            pending.complete(&mut recv);
            (recv_blocking, recv)
        });
        for (blocking, split) in &results {
            assert!(blocking.iter().flatten().all(|m| m.cycle == 1));
            assert!(split.iter().flatten().all(|m| m.cycle == 2));
            assert_eq!(blocking.iter().flatten().count(), 2);
            assert_eq!(split.iter().flatten().count(), 2);
        }
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "dropped without")]
    fn drop_without_complete_panics_in_debug() {
        let world = World::new(1, 4);
        let comm = world.communicator(0);
        let mut send = vec![vec![msg(1, 0)]];
        let pending = comm.alltoall_start(&mut send);
        drop(pending);
    }
}
