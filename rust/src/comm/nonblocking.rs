//! Split-phase (nonblocking) global exchange over epoch-stamped
//! double-buffered mailboxes.
//!
//! The blocking [`Transport::alltoall_into`](super::Transport) pays the
//! full synchronization skew on the critical path: an explicit barrier
//! in front of the collective makes every rank wait for the slowest one
//! before any data moves.  The split-phase protocol decomposes the
//! collective into
//!
//! * [`SplitTransport::alltoall_start`] — the *post* side.  The sender
//!   deposits its per-destination buffers into the mailboxes and returns
//!   immediately; no rank ever waits here.  Returns a [`PendingExchange`]
//!   handle representing the in-flight collective.
//! * [`PendingExchange::complete`] — the *completion* side.  The receiver
//!   rendezvous with each sender's deposit only at the moment it actually
//!   needs the data; senders that already deposited cost nothing, and the
//!   wait for stragglers is exactly the latency that could not be hidden
//!   by the work done since the post.
//!
//! # The depth-D ring of epoch-stamped slots
//!
//! The slot rings are **keyed by communicator**: every world — including
//! each sub-world produced by [`Transport::split`](super::Transport) —
//! owns a complete, independent set of rings, sequence counters and
//! round states, so split-phase pipelines on the global communicator and
//! collectives on the per-area local communicators never share mailbox
//! state (mixing tiers call-by-call is safe by construction).
//!
//! Every (dest, src) pair owns a **ring of `2·D` mailbox slots** (`D` =
//! the world's pipeline depth, [`super::WorldBuilder::depth`]), indexed by
//! `seq % 2D`, and each deposit is stamped with its sequence number.  A
//! sender may therefore post up to `D` exchanges before its receivers
//! have drained the oldest one — each lives in its own slot — which is
//! what lets a conventional run keep one exchange in flight per
//! min-delay interval across `D` consecutive intervals:
//!
//! ```text
//!   cycle:      s          s+1        s+2        s+3    ...
//!   post:       k          k+1        k+2         │
//!               │           │          │          ▼
//!   slot k%2D   ▼ deposit   │          │      complete k
//!   slot k+1%2D             ▼ deposit  │      (deadline =
//!   slot k+2%2D                        ▼       arrival of k's
//!               ◀─────── D = 3 in flight ────▶ earliest spike)
//! ```
//!
//! The flight bound is the safety argument for slot reuse.  Posting `k`
//! requires having completed `k−D` (debug-asserted: at most `D` in
//! flight per rank).  Completing `k−D` drained *every* peer's deposit of
//! that exchange, so every peer had posted `k−D`, which in turn required
//! each of them to have completed — and therefore fully drained —
//! exchange `k−2D`.  The slot `k` is about to overwrite last held
//! exchange `k−2D`, so a ring of `2D` slots per pair is exactly deep
//! enough: by the time any rank posts `k`, every occupant of `k`'s slot
//! (and every settle of `k`'s resize round, below) is history.  For
//! `D = 1` this degenerates to the double-buffered parity scheme.
//!
//! # Per-source incremental completion
//!
//! [`Pending::try_complete_source`] is the condvar-free fast path over
//! the epoch-stamped slots: the receiver *try-locks* one (src, seq)
//! slot and, if the deposit already landed, drains it immediately —
//! during the in-flight window, while the exchange as a whole is still
//! pending.  The engine polls this every cycle, so by the deadline only
//! the genuinely late peers remain and [`Pending::complete`] waits for
//! exactly those.  Early drains are counted in
//! [`CommStats::early_drained_sources`](super::CommStats); the deadline
//! rendezvous, quota settling and depth bookkeeping stay with
//! `complete`, which must still be called exactly once per exchange.
//!
//! # The completion watchdog
//!
//! With a deadline armed on the world
//! ([`super::WorldBuilder::timeout`]), the rendezvous wait inside
//! [`Pending::complete`] expires into a structured
//! [`CommError::Timeout`](super::CommError) instead of waiting forever
//! on a dead peer.  The diagnostic names the tier, the exchange epoch
//! (`seq`), the mailbox ring slot (`seq % 2D`) and — from the per-source
//! drain flags that the incremental fast path maintains anyway — exactly
//! which source ranks have deposited and which are missing.
//!
//! # The split-phase quota-resize protocol
//!
//! The blocking collective agrees on buffer overflow via a flag guarded
//! by two barriers.  Split-phase, the agreement rides on the rendezvous
//! that happens anyway: a sender whose largest per-pair deposit exceeds
//! the current quota marks the exchange round's overflow flag at post
//! time; completion consumes all `M` deposits, so by the time any rank
//! finishes completing, the flag is final.  The **last** rank to
//! complete the round settles it — doubling the quota until the largest
//! observed message fits and counting one secondary round — exactly the
//! two-round semantics of the blocking protocol, with both rounds
//! posted eagerly and no extra synchronization.  Rounds live in the same
//! `2D`-deep ring as the slots (one `RoundState` per ring index), and
//! the reuse argument above covers them: a round is settled and re-armed
//! strictly before the ring wraps onto it.  With several rounds in
//! flight a later post may read a quota that an earlier, not yet
//! settled, round is about to grow — the stale read only causes a
//! spurious overflow mark, i.e. at most one extra settle, never a lost
//! resize.
//!
//! # Buffer recycling
//!
//! Deposits and drains both *swap* vectors with the mailbox slot, so
//! capacity circulates sender → slot → receiver → sender per parity and
//! no steady-state round allocates — the same contract as the blocking
//! [`Transport`](super::Transport) (see the module docs of
//! [`crate::comm`]).
//!
//! # Latency-hiding accounting
//!
//! Each deposit is timestamped.  At completion the receiver computes the
//! *hidden* latency of the exchange — the part of the peers' post skew
//! that elapsed while this rank was doing useful work between
//! [`SplitTransport::alltoall_start`] and [`PendingExchange::complete`]:
//!
//! ```text
//! hidden = clamp(min(t_complete_entry, t_last_deposit) - t_post, >= 0)
//! ```
//!
//! A blocking exchange would have waited `t_last_deposit - t_post` at
//! the barrier; the completion side only waits for whatever of that is
//! left.  The sums land in
//! [`CommStats::hidden_nanos`](super::CommStats) /
//! [`CommStats::overlapped_exchanges`](super::CommStats) and surface
//! through [`CommStatsSnapshot`](super::CommStatsSnapshot).

use super::{
    CommError, Communicator, SpikeMsg, Transport, WorldInner,
    SPIKE_WIRE_BYTES,
};
use crate::obs::SpanCtx;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, TryLockError};
use std::time::{Duration, Instant};

/// One epoch-stamped mailbox slot of a (dest, src) pair.
#[derive(Default)]
struct NbSlot {
    state: Mutex<SlotState>,
    ready: Condvar,
}

#[derive(Default)]
struct SlotState {
    /// Sequence number of the current deposit (valid when `filled`).
    seq: u64,
    filled: bool,
    payload: Vec<SpikeMsg>,
    deposited_at: Option<Instant>,
}

/// Shared per-round state of the split-phase resize protocol, indexed by
/// ring slot (`seq % 2·depth`).  The flight bound guarantees a round is
/// fully completed (and reset by its last completer) before the ring
/// wraps onto its index (see the module docs).
struct RoundState {
    overflow: AtomicBool,
    /// Counts down from M as ranks complete the round; the rank that
    /// takes it to zero settles the resize and re-arms the counter.
    pending_completions: AtomicUsize,
}

/// Split-phase mailbox state of a [`super::World`]; lives next to the
/// blocking mailboxes so the two protocols can be mixed call-by-call
/// (the engine builds with the blocking collective and runs overlapped).
pub(super) struct NbWorld {
    /// `slots[dest][src][seq % ring]` with `ring = 2·depth`.
    slots: Vec<Vec<Vec<NbSlot>>>,
    rounds: Vec<RoundState>,
    /// Maximum exchanges in flight per rank.
    depth: u64,
    /// Per-rank posted-exchange counter (the sequence number source).
    next_seq: Vec<AtomicU64>,
    /// Per-rank completed-exchange counter (depth bookkeeping).
    completed: Vec<AtomicU64>,
}

impl NbWorld {
    pub(super) fn new(m: usize, depth: usize) -> NbWorld {
        assert!(depth >= 1);
        let ring = 2 * depth;
        NbWorld {
            slots: (0..m)
                .map(|_| {
                    (0..m)
                        .map(|_| {
                            (0..ring).map(|_| NbSlot::default()).collect()
                        })
                        .collect()
                })
                .collect(),
            rounds: (0..ring)
                .map(|_| RoundState {
                    overflow: AtomicBool::new(false),
                    pending_completions: AtomicUsize::new(m),
                })
                .collect(),
            depth: depth as u64,
            next_seq: (0..m).map(|_| AtomicU64::new(0)).collect(),
            completed: (0..m).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Ring size (`2·depth`) — the slot index of exchange `seq` is
    /// `seq % ring`.
    fn ring(&self) -> u64 {
        self.rounds.len() as u64
    }
}

/// Timing of the completion side of a split-phase exchange.
#[derive(Clone, Copy, Debug, Default)]
pub struct CompletionTiming {
    /// Time spent blocked waiting for deposits that had not landed yet —
    /// the completion-side synchronization wait (the un-hidden residue
    /// of the peers' skew).
    pub wait_secs: f64,
    /// Time spent draining the mailboxes (the data movement proper).
    pub drain_secs: f64,
}

/// An in-flight split-phase collective.  Must be completed exactly once;
/// dropping it without [`PendingExchange::complete`] panics in debug
/// builds (a dropped exchange would deadlock the peers' completions, as
/// losing an `MPI_Ialltoall` request would).
pub trait Pending {
    /// Seconds the post side spent depositing (never waits on peers).
    fn post_secs(&self) -> f64;

    /// Incremental per-source completion: if source rank `src`'s deposit
    /// for this exchange has already landed, drain it into `out`
    /// (overwriting it, capacity recycled through the mailbox) and
    /// return `Ok(true)`; return `Ok(true)` immediately if `src` was
    /// drained by an earlier call (leaving `out` untouched).  **Never
    /// blocks** — a missing deposit, or a sender currently holding the
    /// slot lock, yields `Ok(false)`.  A poisoned slot (a peer panicked
    /// mid-deposit) surfaces as
    /// [`CommError::Poisoned`](super::CommError).  A successful drain is
    /// remembered: [`Pending::complete`] skips the source and must still
    /// be called exactly once to finish the exchange.
    fn try_complete_source(
        &mut self,
        src: usize,
        out: &mut Vec<SpikeMsg>,
    ) -> Result<bool, CommError>;

    /// Rendezvous with all remaining deposits of this exchange: `recv`
    /// is resized to M slots and `recv[s]` is overwritten with the
    /// spikes from source rank `s` (per-source order preserved, capacity
    /// recycled through the mailbox).  Sources already drained by
    /// [`Pending::try_complete_source`] are skipped — their `recv[s]`
    /// entry is left exactly as the early drain filled it.  Blocks only
    /// for senders that have not deposited yet; with a watchdog deadline
    /// armed on the world, an expired wait returns
    /// [`CommError::Timeout`](super::CommError) naming the exchange
    /// epoch, ring slot and the missing source ranks.
    fn complete(
        self,
        recv: &mut Vec<Vec<SpikeMsg>>,
    ) -> Result<CompletionTiming, CommError>;

    /// Consume the handle *without* completing the exchange — the
    /// error-path teardown.  Once one collective has returned a typed
    /// [`CommError`](super::CommError), the run is unwinding and the
    /// peers' rendezvous is already lost; abandoning the remaining
    /// in-flight handles keeps the drop-time debug assert (which exists
    /// to catch *forgotten* completions on the happy path) from turning
    /// the typed error into a panic.
    fn abandon(self);
}

/// A transport with a split-phase global exchange in addition to the
/// blocking collectives of [`Transport`].  All ranks must issue the same
/// sequence of starts and completions (collective semantics), with at
/// most `depth` exchanges in flight per rank (the depth the world was
/// built with; completions must happen in post order).
pub trait SplitTransport: Transport {
    type Pending: Pending;

    /// Post the send buffers of a global exchange without waiting for
    /// any other rank.  `send[d]` is drained into the mailbox for rank
    /// `d` (capacity recycled).  The returned handle must be completed
    /// before this rank posts its `depth`-th successor.
    fn alltoall_start(
        &self,
        send: &mut [Vec<SpikeMsg>],
    ) -> Result<Self::Pending, CommError>;
}

/// Handle to an in-flight exchange of the shared-memory world.
#[must_use = "an unfinished exchange deadlocks its peers; call complete()"]
pub struct PendingExchange {
    world: Arc<WorldInner>,
    rank: usize,
    seq: u64,
    posted_at: Instant,
    post_secs: f64,
    /// Latest deposit timestamp observed so far (early drains included);
    /// feeds the hidden-latency accounting at completion.
    last_arrival: Instant,
    /// Per-source early-drain flags (the one small allocation a posted
    /// exchange makes; every spike buffer is recycled).  Doubles as the
    /// deposited/missing ledger of the watchdog diagnostic.
    drained: Vec<bool>,
    completed: bool,
}

impl Drop for PendingExchange {
    fn drop(&mut self) {
        if !self.completed && !std::thread::panicking() {
            debug_assert!(
                false,
                "PendingExchange (rank {}, seq {}) dropped without \
                 complete(); peers would deadlock at their rendezvous",
                self.rank, self.seq
            );
        }
    }
}

impl PendingExchange {
    /// Count and build the watchdog diagnostic of an expired completion
    /// wait: which sources have deposited (or were drained early) and
    /// which are still missing.
    fn deposit_timeout(&self, waited: Duration) -> CommError {
        let w = &*self.world;
        let slot_idx = (self.seq % w.nb.ring()) as usize;
        let mut missing = Vec::new();
        let mut present = Vec::new();
        for s in 0..w.m {
            let deposited = self.drained[s]
                || match w.nb.slots[self.rank][s][slot_idx].state.try_lock()
                {
                    Ok(st) => st.filled && st.seq == self.seq,
                    // a sender mid-deposit or a poisoned slot: either
                    // way the deposit has not been consumable yet
                    Err(_) => false,
                };
            if deposited {
                present.push(s);
            } else {
                missing.push(s);
            }
        }
        w.stats.timeouts.fetch_add(1, Ordering::Relaxed);
        CommError::Timeout {
            tier: w.tier,
            op: "split-phase complete",
            rank: self.rank,
            epoch: Some(self.seq),
            ring_slot: Some(slot_idx),
            waited,
            missing,
            present,
        }
    }

    fn slot_poisoned(&self, src: usize) -> CommError {
        let w = &*self.world;
        let slot_idx = (self.seq % w.nb.ring()) as usize;
        w.poisoned(
            self.rank,
            format!(
                "holding split-phase slot (dest={}, src={src}, \
                 ring={slot_idx})",
                self.rank
            ),
        )
    }
}

impl Pending for PendingExchange {
    fn post_secs(&self) -> f64 {
        self.post_secs
    }

    fn abandon(mut self) {
        self.completed = true;
        let w = &*self.world;
        let tracer = &w.tracers[self.rank];
        let span_start = tracer.start();
        tracer.span(
            "abandon",
            span_start,
            SpanCtx {
                tier: w.obs_tier(),
                epoch: self.seq as i64,
                slot: (self.seq % w.nb.ring()) as i32,
                ..SpanCtx::NONE
            },
        );
    }

    fn try_complete_source(
        &mut self,
        src: usize,
        out: &mut Vec<SpikeMsg>,
    ) -> Result<bool, CommError> {
        if self.drained[src] {
            return Ok(true);
        }
        let w = &*self.world;
        let tracer = &w.tracers[self.rank];
        let span_start = tracer.start();
        let slot_idx = (self.seq % w.nb.ring()) as usize;
        let slot = &w.nb.slots[self.rank][src][slot_idx];
        // condvar-free fast path: never block, not even on the slot
        // mutex (a sender mid-deposit just means "not ready yet")
        let mut st = match slot.state.try_lock() {
            Ok(st) => st,
            Err(TryLockError::WouldBlock) => return Ok(false),
            Err(TryLockError::Poisoned(_)) => {
                return Err(self.slot_poisoned(src));
            }
        };
        if !(st.filled && st.seq == self.seq) {
            return Ok(false);
        }
        if let Some(at) = st.deposited_at {
            if at > self.last_arrival {
                self.last_arrival = at;
            }
        }
        out.clear();
        std::mem::swap(&mut st.payload, out);
        st.filled = false;
        drop(st);
        self.drained[src] = true;
        w.stats.early_drained_sources.fetch_add(1, Ordering::Relaxed);
        tracer.span(
            "drain",
            span_start,
            SpanCtx {
                tier: w.obs_tier(),
                epoch: self.seq as i64,
                slot: slot_idx as i32,
                src: w.world_ranks[src] as i32,
                ..SpanCtx::NONE
            },
        );
        Ok(true)
    }

    fn complete(
        mut self,
        recv: &mut Vec<Vec<SpikeMsg>>,
    ) -> Result<CompletionTiming, CommError> {
        // mark completed up front: the Drop assert must not fire a
        // second panic while an error from this method unwinds
        self.completed = true;
        let w = &*self.world;
        let seq = self.seq;
        let slot_idx = (seq % w.nb.ring()) as usize;
        let tracer = &w.tracers[self.rank];
        let span_start = tracer.start();
        let t0 = Instant::now();
        let mut wait_secs = 0.0;
        let mut last_arrival = self.last_arrival;
        // straggler attribution: among the sources this completion
        // actually blocked on, the one whose deposit landed last is
        // the peer the whole wait is charged to
        let mut blamed: Option<(Instant, usize)> = None;

        recv.resize_with(w.m, Vec::new);
        for src in 0..w.m {
            if self.drained[src] {
                // consumed by the incremental fast path during the
                // in-flight window; recv[src] already holds the payload
                continue;
            }
            let slot = &w.nb.slots[self.rank][src][slot_idx];
            let mut st = slot
                .state
                .lock()
                .map_err(|_| self.slot_poisoned(src))?;
            if !(st.filled && st.seq == seq) {
                let w0 = Instant::now();
                match w.timeout {
                    None => {
                        while !(st.filled && st.seq == seq) {
                            st = slot
                                .ready
                                .wait(st)
                                .map_err(|_| self.slot_poisoned(src))?;
                        }
                    }
                    Some(limit) => {
                        let deadline = w0 + limit;
                        while !(st.filled && st.seq == seq) {
                            let now = Instant::now();
                            if now >= deadline {
                                drop(st);
                                return Err(
                                    self.deposit_timeout(w0.elapsed())
                                );
                            }
                            st = slot
                                .ready
                                .wait_timeout(st, deadline - now)
                                .map_err(|_| self.slot_poisoned(src))?
                                .0;
                        }
                    }
                }
                wait_secs += w0.elapsed().as_secs_f64();
                if let Some(at) = st.deposited_at {
                    if blamed.is_none_or(|(b_at, _)| at > b_at) {
                        blamed = Some((at, src));
                    }
                }
            }
            if let Some(at) = st.deposited_at {
                if at > last_arrival {
                    last_arrival = at;
                }
            }
            let out = &mut recv[src];
            out.clear();
            std::mem::swap(&mut st.payload, out);
            st.filled = false;
            drop(st);
            self.drained[src] = true;
        }

        // settle the split-phase resize round (see module docs): the
        // last rank to complete applies the quota growth and re-arms
        // the round for its next (ring-wrapped) reuse
        let round = &w.nb.rounds[slot_idx];
        if round.pending_completions.fetch_sub(1, Ordering::AcqRel) == 1 {
            if round.overflow.swap(false, Ordering::Relaxed) {
                let need = w.stats.max_send_per_pair.load(Ordering::Relaxed);
                let mut q = w.quota.load(Ordering::Relaxed);
                while q < need {
                    q *= 2;
                }
                w.quota.store(q, Ordering::Relaxed);
                w.stats.resize_rounds.fetch_add(1, Ordering::Relaxed);
            }
            round.pending_completions.store(w.m, Ordering::Release);
        }

        w.nb.completed[self.rank].fetch_add(1, Ordering::Relaxed);
        w.stats.alltoall_calls.fetch_add(1, Ordering::Relaxed);
        w.stats.overlapped_exchanges.fetch_add(1, Ordering::Relaxed);
        // hidden latency: the part of the peers' post skew that elapsed
        // while this rank computed between post and completion
        let hidden_end = if last_arrival < t0 { last_arrival } else { t0 };
        let hidden = hidden_end.duration_since(self.posted_at);
        w.stats
            .hidden_nanos
            .fetch_add(hidden.as_nanos() as u64, Ordering::Relaxed);
        w.stats.complete_wait_nanos.fetch_add(
            (wait_secs * 1e9) as u64,
            Ordering::Relaxed,
        );

        let mut blamed_abs = -1;
        if let Some((_, src)) = blamed {
            w.record_blame(self.rank, src, wait_secs);
            blamed_abs = w.world_ranks[src] as i32;
        }
        tracer.span(
            "complete",
            span_start,
            SpanCtx {
                tier: w.obs_tier(),
                epoch: seq as i64,
                slot: slot_idx as i32,
                src: blamed_abs,
                ..SpanCtx::NONE
            },
        );

        let total = t0.elapsed().as_secs_f64();
        Ok(CompletionTiming {
            wait_secs,
            drain_secs: (total - wait_secs).max(0.0),
        })
    }
}

impl SplitTransport for Communicator {
    type Pending = PendingExchange;

    fn alltoall_start(
        &self,
        send: &mut [Vec<SpikeMsg>],
    ) -> Result<PendingExchange, CommError> {
        let w = &*self.world;
        assert_eq!(send.len(), w.m, "send buffer per rank required");
        let tracer = &w.tracers[self.rank];
        let span_start = tracer.start();
        let t0 = Instant::now();
        let seq = w.nb.next_seq[self.rank].fetch_add(1, Ordering::Relaxed);
        debug_assert!(
            seq - w.nb.completed[self.rank].load(Ordering::Relaxed)
                < w.nb.depth,
            "rank {}: more than {} exchanges in flight",
            self.rank,
            w.nb.depth
        );
        let quota = w.quota.load(Ordering::Relaxed);
        let slot_idx = (seq % w.nb.ring()) as usize;
        let my_max = send.iter().map(|b| b.len()).max().unwrap_or(0);
        let bytes: usize =
            send.iter().map(|b| b.len() * SPIKE_WIRE_BYTES).sum();
        // publish the overflow mark and the per-pair maximum *before*
        // depositing: consuming any of this rank's deposits (through the
        // slot mutex) then implies both are visible, so the round's last
        // completer can neither settle the resize ahead of a straggling
        // flag nor size the quota below the largest message
        if my_max > quota {
            w.nb.rounds[slot_idx].overflow.store(true, Ordering::Relaxed);
        }
        w.stats
            .max_send_per_pair
            .fetch_max(my_max, Ordering::Relaxed);
        let now = Instant::now();
        for (dest, buf) in send.iter_mut().enumerate() {
            let slot = &w.nb.slots[dest][self.rank][slot_idx];
            let mut st = slot.state.lock().map_err(|_| {
                w.poisoned(
                    self.rank,
                    format!(
                        "holding split-phase slot (dest={dest}, src={}, \
                         ring={slot_idx})",
                        self.rank
                    ),
                )
            })?;
            debug_assert!(
                !st.filled,
                "mailbox slot overrun: deposit {} not yet consumed",
                st.seq
            );
            debug_assert!(st.payload.is_empty(), "recycled slot not drained");
            std::mem::swap(&mut st.payload, buf);
            st.seq = seq;
            st.filled = true;
            st.deposited_at = Some(now);
            slot.ready.notify_all();
        }
        w.stats
            .bytes_sent
            .fetch_add(bytes as u64, Ordering::Relaxed);
        let post_secs = t0.elapsed().as_secs_f64();
        w.stats
            .post_nanos
            .fetch_add((post_secs * 1e9) as u64, Ordering::Relaxed);
        tracer.span(
            "post",
            span_start,
            SpanCtx {
                tier: w.obs_tier(),
                epoch: seq as i64,
                slot: slot_idx as i32,
                ..SpanCtx::NONE
            },
        );
        Ok(PendingExchange {
            world: self.world.clone(),
            rank: self.rank,
            seq,
            posted_at: t0,
            post_secs,
            last_arrival: t0,
            drained: vec![false; w.m],
            completed: false,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::WorldBuilder;
    use crate::network::Gid;
    use std::thread;
    use std::time::Duration;

    fn msg(source: Gid, cycle: u32) -> SpikeMsg {
        SpikeMsg { source, cycle }
    }

    /// Run `f(rank, comm)` on m rank threads, collect results by rank.
    fn run_ranks<F, R>(
        m: usize,
        quota: usize,
        f: F,
    ) -> (crate::comm::World, Vec<R>)
    where
        F: Fn(usize, Communicator) -> R + Send + Sync,
        R: Send,
    {
        run_ranks_depth(m, quota, 1, f)
    }

    /// As [`run_ranks`], on a world sized for `depth` in-flight
    /// exchanges per rank.
    fn run_ranks_depth<F, R>(
        m: usize,
        quota: usize,
        depth: usize,
        f: F,
    ) -> (crate::comm::World, Vec<R>)
    where
        F: Fn(usize, Communicator) -> R + Send + Sync,
        R: Send,
    {
        let world = WorldBuilder::new(m).quota(quota).depth(depth).build();
        let results = thread::scope(|s| {
            let handles: Vec<_> = (0..m)
                .map(|rank| {
                    let comm = world.communicator(rank);
                    let f = &f;
                    s.spawn(move || f(rank, comm))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect::<Vec<_>>()
        });
        (world, results)
    }

    #[test]
    fn split_phase_routes_messages() {
        let (_, results) = run_ranks(4, 64, |rank, comm| {
            let mut send: Vec<Vec<SpikeMsg>> = (0..4)
                .map(|d| vec![msg((100 * rank + d) as Gid, 7)])
                .collect();
            let pending = comm.alltoall_start(&mut send).unwrap();
            assert!(send.iter().all(|b| b.is_empty()), "send not drained");
            let mut recv = Vec::new();
            pending.complete(&mut recv).unwrap();
            recv
        });
        for (rank, recv) in results.iter().enumerate() {
            assert_eq!(recv.len(), 4);
            for (src, buf) in recv.iter().enumerate() {
                assert_eq!(buf.len(), 1);
                assert_eq!(buf[0].source, (100 * src + rank) as Gid);
                assert_eq!(buf[0].cycle, 7);
            }
        }
    }

    #[test]
    fn split_phase_preserves_per_source_order() {
        let (_, results) = run_ranks(2, 64, |rank, comm| {
            let mut send: Vec<Vec<SpikeMsg>> = (0..2)
                .map(|_| (0..10).map(|i| msg(rank as Gid, i)).collect())
                .collect();
            let pending = comm.alltoall_start(&mut send).unwrap();
            let mut recv = Vec::new();
            pending.complete(&mut recv).unwrap();
            recv
        });
        for recv in &results {
            for (src, buf) in recv.iter().enumerate() {
                let cycles: Vec<u32> = buf.iter().map(|m| m.cycle).collect();
                assert_eq!(cycles, (0..10).collect::<Vec<_>>());
                assert!(buf.iter().all(|m| m.source == src as Gid));
            }
        }
    }

    #[test]
    fn many_rounds_recycle_capacity_and_do_not_leak() {
        // one in-flight exchange per rank, 40 rounds over both slot
        // parities; payload varies per round so stale spikes would show
        const M: usize = 3;
        let (world, results) = run_ranks(M, 64, |rank, comm| {
            let mut send: Vec<Vec<SpikeMsg>> =
                (0..M).map(|_| Vec::new()).collect();
            let mut recv: Vec<Vec<SpikeMsg>> = Vec::new();
            let mut total = 0usize;
            for round in 0..40u32 {
                let n = 1 + (round as usize % 4);
                for buf in &mut send {
                    for i in 0..n {
                        buf.push(msg((1000 * rank + i) as Gid, round));
                    }
                }
                let pending = comm.alltoall_start(&mut send).unwrap();
                pending.complete(&mut recv).unwrap();
                for (src, buf) in recv.iter().enumerate() {
                    assert_eq!(buf.len(), n, "round {round} from {src}");
                    assert!(
                        buf.iter().all(|m| m.cycle == round),
                        "stale spikes leaked into round {round}"
                    );
                }
                total += recv.iter().map(|b| b.len()).sum::<usize>();
            }
            total
        });
        let expect: usize = (0..40u32).map(|r| (1 + r as usize % 4) * M).sum();
        assert!(results.iter().all(|&t| t == expect), "{results:?}");
        let snap = world.stats().snapshot();
        assert_eq!(snap.alltoall_calls, 40 * M as u64);
        assert_eq!(snap.overlapped_exchanges, 40 * M as u64);
        assert_eq!(snap.resize_rounds, 0);
    }

    #[test]
    fn resize_triggered_while_in_flight() {
        // quota 4; rank 0 posts 10 spikes per pair, keeps computing with
        // the exchange in flight, then completes: the overflow must be
        // settled by the completion rendezvous (one secondary round)
        let (world, results) = run_ranks(2, 4, |rank, comm| {
            let n = if rank == 0 { 10 } else { 1 };
            let mut send: Vec<Vec<SpikeMsg>> = (0..2)
                .map(|_| (0..n).map(|i| msg(rank as Gid, i)).collect())
                .collect();
            let pending = comm.alltoall_start(&mut send).unwrap();
            // simulated compute while the exchange is in flight
            std::hint::black_box(
                (0..200_000u64).map(|x| x.wrapping_mul(7)).sum::<u64>(),
            );
            let mut recv = Vec::new();
            pending.complete(&mut recv).unwrap();
            recv.iter().map(|b| b.len()).sum::<usize>()
        });
        assert!(results.iter().all(|&t| t == 11));
        let snap = world.stats().snapshot();
        assert_eq!(snap.resize_rounds, 1, "overflow must settle one round");
        assert_eq!(snap.max_send_per_pair, 10);
        assert!(world.current_quota() >= 10);

        // follow-up rounds settle under the grown quota: the resize
        // count stops growing once the quota fits (a rank may post its
        // second round before the last completer of the first grew the
        // quota, so up to one extra settle is legitimate — never more)
        let (world2, _) = run_ranks(2, 4, |rank, comm| {
            for round in 0..4u32 {
                let mut send: Vec<Vec<SpikeMsg>> = (0..2)
                    .map(|_| {
                        (0..10).map(|i| msg(rank as Gid, i + round)).collect()
                    })
                    .collect();
                let pending = comm.alltoall_start(&mut send).unwrap();
                let mut recv = Vec::new();
                pending.complete(&mut recv).unwrap();
                assert!(recv.iter().all(|b| b.len() == 10));
            }
        });
        let resizes = world2.stats().snapshot().resize_rounds;
        assert!((1..=2).contains(&resizes), "resize rounds: {resizes}");
        assert!(world2.current_quota() >= 10);
    }

    #[test]
    fn completion_reports_hidden_latency() {
        // rank 1 posts late; rank 0 posts early and completes even
        // later, so rank 1's post latency is fully hidden for rank 0
        let (world, _) = run_ranks(2, 64, |rank, comm| {
            if rank == 1 {
                thread::sleep(Duration::from_millis(20));
            }
            let mut send: Vec<Vec<SpikeMsg>> =
                (0..2).map(|_| vec![msg(rank as Gid, 0)]).collect();
            let pending = comm.alltoall_start(&mut send).unwrap();
            if rank == 0 {
                thread::sleep(Duration::from_millis(60));
            }
            let mut recv = Vec::new();
            let timing = pending.complete(&mut recv).unwrap();
            assert!(timing.wait_secs >= 0.0 && timing.drain_secs >= 0.0);
        });
        let snap = world.stats().snapshot();
        assert_eq!(snap.overlapped_exchanges, 2);
        assert!(
            snap.hidden_secs > 0.005,
            "rank 1's late post should be hidden: {snap:?}"
        );
    }

    #[test]
    fn mixes_with_blocking_collective_on_one_world() {
        // the engine builds its tables with the blocking collective and
        // then runs split-phase; both must coexist on one world
        let (_, results) = run_ranks(2, 64, |rank, comm| {
            let mut send: Vec<Vec<SpikeMsg>> =
                (0..2).map(|_| vec![msg(rank as Gid, 1)]).collect();
            let (recv_blocking, _) = comm.alltoall(&mut send).unwrap();
            let mut send: Vec<Vec<SpikeMsg>> =
                (0..2).map(|_| vec![msg(rank as Gid, 2)]).collect();
            let pending = comm.alltoall_start(&mut send).unwrap();
            let mut recv = Vec::new();
            pending.complete(&mut recv).unwrap();
            (recv_blocking, recv)
        });
        for (blocking, split) in &results {
            assert!(blocking.iter().flatten().all(|m| m.cycle == 1));
            assert!(split.iter().flatten().all(|m| m.cycle == 2));
            assert_eq!(blocking.iter().flatten().count(), 2);
            assert_eq!(split.iter().flatten().count(), 2);
        }
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "dropped without")]
    fn drop_without_complete_panics_in_debug() {
        let world = WorldBuilder::new(1).quota(4).build();
        let comm = world.communicator(0);
        let mut send = vec![vec![msg(1, 0)]];
        let pending = comm.alltoall_start(&mut send).unwrap();
        drop(pending);
    }

    fn fill_send(m: usize, rank: usize, round: u32, n: usize) -> Vec<Vec<SpikeMsg>> {
        (0..m)
            .map(|_| {
                (0..n)
                    .map(|i| msg((1000 * rank + i) as Gid, round))
                    .collect()
            })
            .collect()
    }

    #[test]
    fn depth_two_pipeline_keeps_two_rounds_in_flight() {
        // post k and k+1 before completing k: deposits land in distinct
        // ring slots and complete in post order with nothing leaked
        const M: usize = 3;
        let (world, results) = run_ranks_depth(M, 64, 2, |rank, comm| {
            let mut total = 0usize;
            let mut older: Option<PendingExchange> = None;
            for round in 0..30u32 {
                let n = 1 + (round as usize % 3);
                let mut send = fill_send(M, rank, round, n);
                let pending = comm.alltoall_start(&mut send).unwrap();
                if let Some(p) = older.take() {
                    let mut recv = Vec::new();
                    p.complete(&mut recv).unwrap();
                    for (src, buf) in recv.iter().enumerate() {
                        let exp = 1 + ((round - 1) as usize % 3);
                        assert_eq!(buf.len(), exp, "round {round} src {src}");
                        assert!(buf.iter().all(|m| m.cycle == round - 1));
                    }
                    total += recv.iter().map(|b| b.len()).sum::<usize>();
                }
                older = Some(pending);
            }
            let mut recv = Vec::new();
            older.take().unwrap().complete(&mut recv).unwrap();
            total += recv.iter().map(|b| b.len()).sum::<usize>();
            total
        });
        let expect: usize = (0..30u32).map(|r| (1 + r as usize % 3) * M).sum();
        assert!(results.iter().all(|&t| t == expect), "{results:?}");
        let snap = world.stats().snapshot();
        assert_eq!(snap.alltoall_calls, 30 * M as u64);
        assert_eq!(snap.resize_rounds, 0);
    }

    #[test]
    fn incremental_completion_drains_early_deposits() {
        // all peers deposit, receiver polls try_complete_source until
        // every source is drained, then complete() has nothing to wait
        // for; the early-drain counter accounts peers x rounds
        const M: usize = 3;
        const ROUNDS: u32 = 5;
        let (world, _) = run_ranks(M, 64, |rank, comm| {
            for round in 0..ROUNDS {
                let mut send = fill_send(M, rank, round, 2);
                let mut pending = comm.alltoall_start(&mut send).unwrap();
                let mut recv: Vec<Vec<SpikeMsg>> =
                    (0..M).map(|_| Vec::new()).collect();
                let mut drained = vec![false; M];
                while drained.iter().any(|&d| !d) {
                    for (src, out) in recv.iter_mut().enumerate() {
                        if !drained[src] {
                            drained[src] = pending
                                .try_complete_source(src, out)
                                .unwrap();
                        }
                    }
                    std::hint::spin_loop();
                }
                // repeat polls on a drained source are no-ops
                assert!(pending
                    .try_complete_source(0, &mut Vec::new())
                    .unwrap());
                let timing = pending.complete(&mut recv).unwrap();
                assert_eq!(timing.wait_secs, 0.0, "all sources pre-drained");
                for (src, buf) in recv.iter().enumerate() {
                    assert_eq!(buf.len(), 2, "round {round} src {src}");
                    assert!(buf.iter().all(|m| m.cycle == round));
                    assert!(buf
                        .iter()
                        .all(|m| m.source / 1000 == src as Gid));
                }
            }
        });
        let snap = world.stats().snapshot();
        assert_eq!(
            snap.early_drained_sources,
            (M * M) as u64 * ROUNDS as u64,
            "every source of every round must drain early"
        );
        assert_eq!(snap.alltoall_calls, M as u64 * ROUNDS as u64);
        assert_eq!(snap.complete_wait_secs, 0.0);
    }

    #[test]
    fn early_drain_survives_complete() {
        // a source drained through the fast path keeps its payload in
        // recv[src] across the final complete() (which must skip it)
        let world = WorldBuilder::new(1).quota(64).build();
        let comm = world.communicator(0);
        let mut send = vec![vec![msg(7, 0)]];
        let mut pending = comm.alltoall_start(&mut send).unwrap();
        let mut recv = vec![Vec::new()];
        assert!(pending.try_complete_source(0, &mut recv[0]).unwrap());
        assert_eq!(recv[0].len(), 1);
        pending.complete(&mut recv).unwrap();
        assert_eq!(recv[0].len(), 1, "early drain must survive complete");
        assert_eq!(recv[0][0].source, 7);
    }

    #[test]
    fn depth_recycling_stress_with_resize_on_non_head_slot() {
        // depth-3 pipeline over 60 rounds (ring wraps 10 times); round
        // 31 overflows the quota while it is the *youngest* of three
        // in-flight exchanges (a non-head ring slot), so the resize
        // settles through the rendezvous two completions later
        const M: usize = 3;
        const DEPTH: usize = 3;
        let per_round = |round: u32| -> usize {
            if round == 31 {
                17
            } else {
                1 + (round as usize % 4)
            }
        };
        let (world, results) = run_ranks_depth(M, 4, DEPTH, |rank, comm| {
            use std::collections::VecDeque;
            let mut inflight: VecDeque<(u32, PendingExchange)> =
                VecDeque::new();
            let mut total = 0usize;
            let mut complete_one =
                |inflight: &mut VecDeque<(u32, PendingExchange)>,
                 total: &mut usize| {
                    let (round, p) = inflight.pop_front().unwrap();
                    let mut recv = Vec::new();
                    p.complete(&mut recv).unwrap();
                    let n = per_round(round);
                    for (src, buf) in recv.iter().enumerate() {
                        assert_eq!(buf.len(), n, "round {round} src {src}");
                        assert!(
                            buf.iter().all(|m| m.cycle == round),
                            "stale spikes leaked into round {round}"
                        );
                    }
                    *total += recv.iter().map(|b| b.len()).sum::<usize>();
                };
            for round in 0..60u32 {
                if inflight.len() == DEPTH {
                    complete_one(&mut inflight, &mut total);
                }
                let mut send =
                    fill_send(M, rank, round, per_round(round));
                inflight.push_back((
                    round,
                    comm.alltoall_start(&mut send).unwrap(),
                ));
            }
            while !inflight.is_empty() {
                complete_one(&mut inflight, &mut total);
            }
            total
        });
        let expect: usize = (0..60u32).map(|r| per_round(r) * M).sum();
        assert!(results.iter().all(|&t| t == expect), "{results:?}");
        let snap = world.stats().snapshot();
        assert_eq!(snap.alltoall_calls, 60 * M as u64);
        assert_eq!(snap.max_send_per_pair, 17);
        assert!(world.current_quota() >= 17);
        // only round 31 ever exceeds the quota (later rounds stay at or
        // below the original quota of 4, strictly-greater never fires),
        // so exactly one settle despite the slot's ten reuses
        assert_eq!(snap.resize_rounds, 1);
    }

    #[test]
    fn split_groups_pipeline_independently_under_depth() {
        // depth-2 world split into two groups of two: each group runs a
        // split-phase pipeline on its sub-communicator *while* the
        // parent pipelines global exchanges.  Slot rings are keyed by
        // communicator, so the interleaving cannot cross state: every
        // deposit completes on the tier it was posted on.
        const ROUNDS: u32 = 12;
        let world = WorldBuilder::new(4).quota(64).depth(2).build();
        thread::scope(|s| {
            for rank in 0..4usize {
                let comm = world.communicator(rank);
                s.spawn(move || {
                    let group = rank / 2;
                    let local =
                        comm.split(group as u64, rank as u64).unwrap();
                    assert_eq!(local.m_ranks(), 2);
                    let check_local = |round: u32,
                                       recv: &Vec<Vec<SpikeMsg>>| {
                        assert_eq!(recv.len(), 2);
                        for (src_local, buf) in recv.iter().enumerate() {
                            assert_eq!(buf.len(), 1, "round {round}");
                            assert_eq!(
                                buf[0].source as usize,
                                group * 2 + src_local,
                                "cross-group deposit leaked"
                            );
                            assert_eq!(buf[0].cycle, round);
                        }
                    };
                    let check_global = |round: u32,
                                        recv: &Vec<Vec<SpikeMsg>>| {
                        assert_eq!(recv.len(), 4);
                        for (src, buf) in recv.iter().enumerate() {
                            assert_eq!(buf.len(), 1, "round {round}");
                            assert_eq!(buf[0].source as usize, 100 + src);
                            assert_eq!(buf[0].cycle, round);
                        }
                    };
                    let mut local_pipe: Option<(u32, PendingExchange)> =
                        None;
                    let mut global_pipe: Option<(u32, PendingExchange)> =
                        None;
                    for round in 0..ROUNDS {
                        // one exchange in flight per tier (the depth of
                        // 2 is inherited by the sub-world)
                        let mut lsend: Vec<Vec<SpikeMsg>> = (0..2)
                            .map(|_| vec![msg(rank as Gid, round)])
                            .collect();
                        let lp = local.alltoall_start(&mut lsend).unwrap();
                        let mut gsend: Vec<Vec<SpikeMsg>> = (0..4)
                            .map(|_| vec![msg((100 + rank) as Gid, round)])
                            .collect();
                        let gp = comm.alltoall_start(&mut gsend).unwrap();
                        if let Some((r0, p)) = local_pipe.take() {
                            let mut recv = Vec::new();
                            p.complete(&mut recv).unwrap();
                            check_local(r0, &recv);
                        }
                        if let Some((r0, p)) = global_pipe.take() {
                            let mut recv = Vec::new();
                            p.complete(&mut recv).unwrap();
                            check_global(r0, &recv);
                        }
                        local_pipe = Some((round, lp));
                        global_pipe = Some((round, gp));
                    }
                    let mut recv = Vec::new();
                    let (r0, p) = local_pipe.take().unwrap();
                    p.complete(&mut recv).unwrap();
                    check_local(r0, &recv);
                    let (r0, p) = global_pipe.take().unwrap();
                    p.complete(&mut recv).unwrap();
                    check_global(r0, &recv);
                });
            }
        });
        let tiers = world.tiered_stats();
        assert_eq!(tiers.global.alltoall_calls, ROUNDS as u64 * 4);
        assert_eq!(tiers.local.alltoall_calls, ROUNDS as u64 * 4);
        assert_eq!(tiers.global.overlapped_exchanges, ROUNDS as u64 * 4);
        assert_eq!(tiers.local.overlapped_exchanges, ROUNDS as u64 * 4);
    }

    #[test]
    fn hidden_and_wait_accounting_consistent_under_overlap() {
        // rank 1 posts late: rank 0 completes immediately and must
        // charge the skew to complete_wait; a second round where rank 0
        // computes past rank 1's post hides it instead.  Either way
        // hidden + wait bounds the skew from both sides: both are
        // non-negative and hidden never exceeds post-to-complete time.
        let (world, _) = run_ranks(2, 64, |rank, comm| {
            // round 1: receiver waits (nothing hidden for rank 0)
            if rank == 1 {
                thread::sleep(Duration::from_millis(15));
            }
            let mut send = fill_send(2, rank, 1, 1);
            let pending = comm.alltoall_start(&mut send).unwrap();
            let mut recv = Vec::new();
            let t = pending.complete(&mut recv).unwrap();
            assert!(t.wait_secs >= 0.0 && t.drain_secs >= 0.0);
            // round 2: receiver computes long enough to hide the skew
            if rank == 1 {
                thread::sleep(Duration::from_millis(15));
            }
            let mut send = fill_send(2, rank, 2, 1);
            let pending = comm.alltoall_start(&mut send).unwrap();
            if rank == 0 {
                thread::sleep(Duration::from_millis(40));
            }
            let mut recv = Vec::new();
            pending.complete(&mut recv).unwrap();
        });
        let snap = world.stats().snapshot();
        assert_eq!(snap.overlapped_exchanges, 4);
        assert!(snap.complete_wait_secs > 0.005, "{snap:?}");
        assert!(snap.hidden_secs > 0.005, "{snap:?}");
        assert!(snap.post_secs >= 0.0);
        // the overall ledger stays sane: hidden skew cannot exceed the
        // total in-flight time of all exchanges (loose bound — CI boxes
        // stretch sleeps, they do not shrink them)
        assert!(snap.hidden_secs < 2.0, "{snap:?}");
    }

    #[test]
    fn completion_watchdog_names_missing_depositor() {
        // rank 1 never posts: rank 0's completion wait must expire into
        // a diagnostic carrying the exchange epoch, the ring slot and
        // exactly which source deposited (itself) vs. is missing (1)
        let world = WorldBuilder::new(2)
            .quota(64)
            .timeout(Some(Duration::from_millis(50)))
            .build();
        let comm = world.communicator(0);
        let mut send: Vec<Vec<SpikeMsg>> =
            (0..2).map(|_| vec![msg(0, 0)]).collect();
        let pending = comm.alltoall_start(&mut send).unwrap();
        let mut recv = Vec::new();
        let err = pending
            .complete(&mut recv)
            .expect_err("watchdog did not fire");
        match &err {
            CommError::Timeout {
                tier,
                epoch,
                ring_slot,
                missing,
                present,
                ..
            } => {
                assert_eq!(*tier, "global");
                assert_eq!(*epoch, Some(0));
                assert_eq!(*ring_slot, Some(0));
                assert_eq!(missing, &vec![1]);
                assert_eq!(
                    present,
                    &vec![0],
                    "own deposit must be visible"
                );
            }
            other => panic!("unexpected error variant: {other}"),
        }
        let msg = err.to_string();
        assert!(msg.contains("split-phase complete"), "{msg}");
        assert!(msg.contains("missing ranks [1]"), "{msg}");
        assert_eq!(world.stats().snapshot().timeouts, 1);
    }

    #[test]
    fn armed_watchdog_tolerates_late_but_alive_peers() {
        // a generous deadline with a merely-slow peer: the rendezvous
        // completes normally and counts no timeouts
        let world = WorldBuilder::new(2)
            .quota(64)
            .timeout(Some(Duration::from_secs(10)))
            .build();
        thread::scope(|s| {
            for rank in 0..2usize {
                let comm = world.communicator(rank);
                s.spawn(move || {
                    if rank == 1 {
                        thread::sleep(Duration::from_millis(20));
                    }
                    let mut send = fill_send(2, rank, 0, 1);
                    let pending = comm.alltoall_start(&mut send).unwrap();
                    let mut recv = Vec::new();
                    pending.complete(&mut recv).unwrap();
                    assert!(recv.iter().all(|b| b.len() == 1));
                });
            }
        });
        assert_eq!(world.stats().snapshot().timeouts, 0);
    }

    #[test]
    fn completion_blames_the_late_depositor() {
        // rank 1 posts late every round: ranks 0 and 2 block in
        // complete() and must charge the wait to rank 1
        let world = WorldBuilder::new(3).quota(64).build();
        thread::scope(|s| {
            for rank in 0..3usize {
                let comm = world.communicator(rank);
                s.spawn(move || {
                    for round in 0..4u32 {
                        if rank == 1 {
                            thread::sleep(Duration::from_millis(5));
                        }
                        let mut send = fill_send(3, rank, round, 1);
                        let pending =
                            comm.alltoall_start(&mut send).unwrap();
                        let mut recv = Vec::new();
                        pending.complete(&mut recv).unwrap();
                    }
                });
            }
        });
        let blame = world.blame_report();
        for waiter in [0usize, 2] {
            let (top, waits, late) = blame.global[waiter].top().unwrap();
            assert_eq!(top, 1, "rank {waiter} should blame rank 1");
            assert!(waits >= 3);
            assert!(late > 0.0);
        }
        assert_eq!(blame.global[1].waits[1], 0, "no self-blame");
    }

    #[test]
    fn traced_split_phase_pairs_posts_with_completions() {
        use crate::obs::{Tier, TraceBuf};
        let buf = TraceBuf::new(2);
        let world =
            WorldBuilder::new(2).quota(64).trace(Some(buf.clone())).build();
        thread::scope(|s| {
            for rank in 0..2usize {
                let comm = world.communicator(rank);
                s.spawn(move || {
                    for round in 0..3u32 {
                        let mut send = fill_send(2, rank, round, 1);
                        let pending =
                            comm.alltoall_start(&mut send).unwrap();
                        let mut recv = Vec::new();
                        pending.complete(&mut recv).unwrap();
                    }
                });
            }
        });
        let spans = buf.drain();
        for pid in 0..2u32 {
            let posts: Vec<_> = spans
                .iter()
                .filter(|s| s.pid == pid && s.name == "post")
                .collect();
            let completes: Vec<_> = spans
                .iter()
                .filter(|s| s.pid == pid && s.name == "complete")
                .collect();
            assert_eq!(posts.len(), 3);
            assert_eq!(completes.len(), 3);
            for (i, p) in posts.iter().enumerate() {
                assert_eq!(p.ctx.epoch, i as i64);
                assert_eq!(p.ctx.tier, Tier::Global);
                assert_eq!(p.ctx.slot, (i % 2) as i32);
                let c = completes
                    .iter()
                    .find(|c| c.ctx.epoch == p.ctx.epoch)
                    .expect("unmatched post");
                assert!(c.ts_us >= p.ts_us);
            }
        }
    }

    #[test]
    fn traced_abandon_closes_the_post() {
        use crate::obs::TraceBuf;
        let buf = TraceBuf::new(2);
        let world =
            WorldBuilder::new(2).quota(64).trace(Some(buf.clone())).build();
        thread::scope(|s| {
            for rank in 0..2usize {
                let comm = world.communicator(rank);
                s.spawn(move || {
                    let mut send = fill_send(2, rank, 0, 1);
                    let pending = comm.alltoall_start(&mut send).unwrap();
                    pending.abandon();
                });
            }
        });
        let spans = buf.drain();
        for pid in 0..2u32 {
            let mine: Vec<_> =
                spans.iter().filter(|s| s.pid == pid).collect();
            assert!(mine.iter().any(|s| s.name == "post"));
            let ab = mine
                .iter()
                .find(|s| s.name == "abandon")
                .expect("missing abandon span");
            assert_eq!(ab.ctx.epoch, 0);
        }
    }

    #[test]
    fn traced_early_drain_records_drain_spans() {
        use crate::obs::TraceBuf;
        let buf = TraceBuf::new(2);
        let world =
            WorldBuilder::new(2).quota(64).trace(Some(buf.clone())).build();
        thread::scope(|s| {
            for rank in 0..2usize {
                let comm = world.communicator(rank);
                s.spawn(move || {
                    let mut send = fill_send(2, rank, 0, 1);
                    let mut pending =
                        comm.alltoall_start(&mut send).unwrap();
                    // poll until both sources drain early, then complete
                    let mut outs = vec![Vec::new(); 2];
                    let mut done = [false; 2];
                    while !done.iter().all(|&d| d) {
                        for src in 0..2 {
                            if !done[src] {
                                done[src] = pending
                                    .try_complete_source(
                                        src,
                                        &mut outs[src],
                                    )
                                    .unwrap();
                            }
                        }
                    }
                    let mut recv = Vec::new();
                    pending.complete(&mut recv).unwrap();
                });
            }
        });
        let spans = buf.drain();
        let drains: Vec<_> =
            spans.iter().filter(|s| s.name == "drain").collect();
        assert_eq!(drains.len(), 4, "2 ranks x 2 sources drained early");
        assert!(drains.iter().all(|s| s.ctx.src >= 0));
    }
}
