//! Simulated MPI layer: collective spike exchange between ranks that live
//! as OS threads in one address space.
//!
//! Semantics follow the paper's communication scheme (§4.1):
//!
//! * [`Transport::alltoall_into`] — the global exchange.  An explicit
//!   barrier in front of the collective separates *synchronization*
//!   (waiting for the slowest rank) from the *data exchange* proper,
//!   exactly like the instrumentation NEST uses (§4.1).  Spike buffers
//!   grow via the two-round resize protocol: if any rank exceeds the
//!   current quota, all ranks double their buffers and a secondary
//!   exchange round follows.
//! * [`Transport::local_swap_into`] — the structure-aware local pathway:
//!   a rank-local swap of send and receive buffers, no synchronization.
//! * [`SplitTransport::alltoall_start`] / [`PendingExchange::complete`] —
//!   the **split-phase** form of the global exchange ([`nonblocking`]):
//!   the post side deposits into a ring of epoch-stamped mailbox slots
//!   without waiting, and the completion side rendezvous with each
//!   sender's deposit only when the receiver actually needs the data —
//!   or earlier, source by source, through the incremental
//!   [`Pending::try_complete_source`] fast path.  The ring holds up to a
//!   configurable depth of exchanges in flight per rank
//!   ([`WorldBuilder::depth`]); the slack between post and completion —
//!   bounded by the inter-area delay of the spikes on the wire — is
//!   latency-hiding budget: compute of the following cycles runs while
//!   peers catch up.  See the [`nonblocking`] module docs for the ring
//!   protocol, the split-phase quota-resize and the hidden-latency
//!   accounting.
//!
//! # Fault model ([`CommError`], [`WorldBuilder::timeout`])
//!
//! Every blocking rendezvous in this layer — the barrier frames of the
//! collectives and the split-phase completion wait — is *watchdogged*:
//! with a deadline configured ([`WorldBuilder::timeout`], the engine's
//! `--comm-timeout` knob; default off = wait forever, the historical
//! behavior), a wait that expires returns a structured
//! [`CommError::Timeout`] naming the communicator tier, the operation,
//! the exchange epoch and ring slot (split-phase), and exactly which
//! peer ranks have and haven't arrived/deposited — turning a silent
//! deadlock caused by a stalled or dead rank into an actionable
//! diagnostic.  A rank that panics while holding a mailbox or slot lock
//! surfaces to its peers as [`CommError::Poisoned`] instead of a second
//! opaque panic cascading through the barrier frames.  Timed-out waits
//! are counted in [`CommStats::timeouts`].
//!
//! # Hierarchical communicators ([`Transport::split`])
//!
//! The paper's hybrid architecture maps every area onto a *group* of
//! compute nodes: the group exchanges its short-range spikes over a
//! **local communicator** every min-delay interval, while the global
//! exchange across areas runs only once per epoch.  [`Transport::split`]
//! is the primitive that builds this hierarchy (the `MPI_Comm_split`
//! shape): a collective call in which every rank passes a `color` and a
//! `key`; ranks sharing a color form one sub-communicator, ranked by
//! `(key, rank)`.  A sub-communicator is a full [`Transport`] (and, for
//! the shared-memory world, a full [`SplitTransport`]) with its **own**
//! barrier, mailboxes, quota, split-phase slot rings and [`CommStats`] —
//! collectives on different sub-communicators never synchronize with
//! each other, and statistics stay attributable per tier
//! ([`World::tiered_stats`] aggregates the children as the *local* tier
//! next to the parent's *global* tier).  Splitting is a cold-path setup
//! operation; the per-cycle hot paths are unchanged.  Sub-communicators
//! inherit the parent's watchdog deadline and report themselves as the
//! `"local"` tier in diagnostics.
//!
//! # The [`Transport`] abstraction
//!
//! The engine talks to the communication layer exclusively through the
//! [`Transport`] trait, so the shared-memory [`World`] of this module is
//! one implementation among possible others (a real MPI binding, an
//! RDMA fabric, a loopback test double).  [`Communicator`] — the
//! per-rank handle into a [`World`] — is the first implementor; because
//! [`Transport::split`] yields the implementor's own communicator type
//! ([`Transport::Sub`]), every backend exposes one coherent two-tier
//! API.
//!
//! # Buffer-recycling contract
//!
//! The hot-path entry points take *caller-owned* buffers and never
//! allocate in steady state:
//!
//! * [`Transport::alltoall_into`] drains every `send[d]` into the wire
//!   (leaving it empty but with its capacity intact for refilling) and
//!   overwrites `recv[s]` with the spikes received from source rank `s`.
//!   Internally the shared-memory world *swaps* vectors through the
//!   per-pair mailbox on both the write and the read side, so buffer
//!   capacity circulates sender → mailbox → receiver → sender and after
//!   a warm-up round no exchange allocates.
//! * [`Transport::local_swap_into`] swaps `send` and `recv` (clearing
//!   `recv` first): the received spikes land in `recv`, and `send` comes
//!   back empty with the capacity of the previous receive buffer.
//!
//! Callers must not assume a buffer keeps its identity across calls —
//! only that contents are delivered exactly once and capacity is
//! conserved by the layer as a whole.
//!
//! The transport is shared-memory mailboxes; the *timing* of a real
//! interconnect is modelled separately by `vcluster::interconnect` (the
//! hardware substitution of DESIGN.md §2).

pub mod nonblocking;
#[cfg(unix)]
pub mod socket;

pub use nonblocking::{
    CompletionTiming, Pending, PendingExchange, SplitTransport,
};

use crate::network::Gid;
use crate::obs::blame::{Blame, TieredBlame};
use crate::obs::{SpanCtx, Tier, TraceBuf, Tracer};
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// One spike on the wire: source neuron and emission cycle.  The paper's
/// spikes carry only the source id; we add the cycle so that lumped
/// epoch-wise delivery of the structure-aware scheme stays explicit (and
/// assertable).  Wire size is accounted as 8 bytes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpikeMsg {
    pub source: Gid,
    pub cycle: u32,
}

pub const SPIKE_WIRE_BYTES: usize = 8;

/// Typed failure of a communication primitive.
///
/// With a watchdog deadline armed ([`WorldBuilder::timeout`]) every
/// blocking rendezvous can expire into [`CommError::Timeout`] instead of
/// hanging forever on a stalled peer; a peer that panicked while holding
/// shared comm state surfaces as [`CommError::Poisoned`].  Both unwind
/// the run cleanly through the engine's `Result` plumbing.
#[derive(Clone, Debug)]
pub enum CommError {
    /// A collective wait expired: one or more peers never arrived.
    Timeout {
        /// Communicator tier ("global" or "local").
        tier: &'static str,
        /// The operation that was waiting (e.g. "alltoall",
        /// "split-phase complete").
        op: &'static str,
        /// The rank that observed the expiry.
        rank: usize,
        /// Exchange epoch (split-phase sequence number), when the wait
        /// belongs to a specific exchange round.
        epoch: Option<u64>,
        /// Mailbox ring slot of a split-phase wait (`seq % ring`).
        ring_slot: Option<usize>,
        /// How long the watchdog waited before firing.
        waited: Duration,
        /// Peer ranks that have **not** arrived/deposited.
        missing: Vec<usize>,
        /// Peer ranks that already arrived/deposited.
        present: Vec<usize>,
    },
    /// A peer panicked while holding shared communication state.
    Poisoned {
        /// Communicator tier ("global" or "local").
        tier: &'static str,
        /// The rank that observed the poisoned lock.
        rank: usize,
        /// What the poisoning peer was holding, e.g.
        /// "holding mailbox slot (dest=2, src=0)".
        context: String,
    },
}

impl fmt::Display for CommError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CommError::Timeout {
                tier,
                op,
                rank,
                epoch,
                ring_slot,
                waited,
                missing,
                present,
            } => {
                write!(
                    f,
                    "comm watchdog: rank {rank} timed out after {:.3}s \
                     in {op} on the {tier} tier",
                    waited.as_secs_f64()
                )?;
                if let Some(e) = epoch {
                    write!(f, " (exchange epoch {e}")?;
                    if let Some(s) = ring_slot {
                        write!(f, ", ring slot {s}")?;
                    }
                    write!(f, ")")?;
                }
                write!(
                    f,
                    "; missing ranks {missing:?}, arrived {present:?}"
                )
            }
            CommError::Poisoned { tier, rank, context } => write!(
                f,
                "comm fabric poisoned on the {tier} tier: a rank \
                 panicked while {context} (observed by rank {rank})"
            ),
        }
    }
}

impl std::error::Error for CommError {}

/// Aggregate communication statistics across all ranks of one
/// communicator.  Every [`World`] — including the sub-worlds produced by
/// [`Transport::split`] — owns its own instance, so exchanges stay
/// attributable to the communicator (and therefore the tier) that
/// carried them.
#[derive(Debug, Default)]
pub struct CommStats {
    pub alltoall_calls: AtomicU64,
    pub local_swaps: AtomicU64,
    pub bytes_sent: AtomicU64,
    pub resize_rounds: AtomicU64,
    pub max_send_per_pair: AtomicUsize,
    /// Barrier wait in front of blocking collectives — the
    /// synchronization share of [`Transport::alltoall_into`].
    pub sync_nanos: AtomicU64,
    /// Split-phase exchanges completed (counted per rank, like
    /// `alltoall_calls`, which also counts them).
    pub overlapped_exchanges: AtomicU64,
    /// Post-side time of split-phase exchanges (depositing; never waits).
    pub post_nanos: AtomicU64,
    /// Completion-side time blocked waiting for missing deposits — the
    /// un-hidden residue of the peers' synchronization skew.
    pub complete_wait_nanos: AtomicU64,
    /// Peer skew that elapsed between post and completion while the rank
    /// was computing — synchronization time moved off the critical path.
    pub hidden_nanos: AtomicU64,
    /// Sources drained *early* through the incremental completion fast
    /// path ([`Pending::try_complete_source`]) — deposits consumed during
    /// the in-flight window instead of at the deadline rendezvous.
    pub early_drained_sources: AtomicU64,
    /// Watchdogged waits that expired into [`CommError::Timeout`].
    pub timeouts: AtomicU64,
}

/// Point-in-time view of [`CommStats`], with durations in seconds.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CommStatsSnapshot {
    pub alltoall_calls: u64,
    pub local_swaps: u64,
    pub bytes_sent: u64,
    pub resize_rounds: u64,
    pub max_send_per_pair: u64,
    pub overlapped_exchanges: u64,
    pub early_drained_sources: u64,
    /// Watchdogged waits that expired into [`CommError::Timeout`].
    pub timeouts: u64,
    /// Barrier wait of blocking collectives (see
    /// [`CommStats::sync_nanos`]).
    pub sync_secs: f64,
    pub post_secs: f64,
    pub complete_wait_secs: f64,
    pub hidden_secs: f64,
}

impl CommStatsSnapshot {
    /// Field-wise combination of two tiers' snapshots: counters and
    /// durations add, the per-pair maximum takes the larger tier.
    pub fn merged(&self, other: &CommStatsSnapshot) -> CommStatsSnapshot {
        CommStatsSnapshot {
            alltoall_calls: self.alltoall_calls + other.alltoall_calls,
            local_swaps: self.local_swaps + other.local_swaps,
            bytes_sent: self.bytes_sent + other.bytes_sent,
            resize_rounds: self.resize_rounds + other.resize_rounds,
            max_send_per_pair: self
                .max_send_per_pair
                .max(other.max_send_per_pair),
            overlapped_exchanges: self.overlapped_exchanges
                + other.overlapped_exchanges,
            early_drained_sources: self.early_drained_sources
                + other.early_drained_sources,
            timeouts: self.timeouts + other.timeouts,
            sync_secs: self.sync_secs + other.sync_secs,
            post_secs: self.post_secs + other.post_secs,
            complete_wait_secs: self.complete_wait_secs
                + other.complete_wait_secs,
            hidden_secs: self.hidden_secs + other.hidden_secs,
        }
    }
}

/// Per-tier communication statistics of a hierarchical run: the parent
/// communicator's traffic (`global`) next to the aggregate of every
/// sub-communicator split off it (`local`).  [`TieredCommStats::combined`]
/// is the flat single-communicator view kept for existing consumers.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TieredCommStats {
    pub global: CommStatsSnapshot,
    pub local: CommStatsSnapshot,
}

impl TieredCommStats {
    pub fn combined(&self) -> CommStatsSnapshot {
        self.global.merged(&self.local)
    }
}

impl CommStats {
    pub fn snapshot(&self) -> CommStatsSnapshot {
        CommStatsSnapshot {
            alltoall_calls: self.alltoall_calls.load(Ordering::Relaxed),
            local_swaps: self.local_swaps.load(Ordering::Relaxed),
            bytes_sent: self.bytes_sent.load(Ordering::Relaxed),
            resize_rounds: self.resize_rounds.load(Ordering::Relaxed),
            max_send_per_pair: self.max_send_per_pair.load(Ordering::Relaxed)
                as u64,
            overlapped_exchanges: self
                .overlapped_exchanges
                .load(Ordering::Relaxed),
            early_drained_sources: self
                .early_drained_sources
                .load(Ordering::Relaxed),
            timeouts: self.timeouts.load(Ordering::Relaxed),
            sync_secs: self.sync_nanos.load(Ordering::Relaxed) as f64 / 1e9,
            post_secs: self.post_nanos.load(Ordering::Relaxed) as f64 / 1e9,
            complete_wait_secs: self.complete_wait_nanos.load(Ordering::Relaxed)
                as f64
                / 1e9,
            hidden_secs: self.hidden_nanos.load(Ordering::Relaxed) as f64 / 1e9,
        }
    }
}

/// A reusable generation barrier that knows *who* has arrived, so an
/// expired wait can name the missing ranks — the watchdog form of
/// `std::sync::Barrier`.
///
/// `wait(rank, None)` blocks forever like the std barrier; with a
/// deadline it returns `Err(missing_ranks)` on expiry.  The expiring
/// rank's own arrival stays registered, so peers armed with the same
/// deadline expire too (everyone unwinds; nobody is left inside a
/// half-completed generation that could complete later and corrupt
/// state — the run is over either way).
struct WaitBarrier {
    state: Mutex<BarrierGen>,
    cv: Condvar,
    m: usize,
}

struct BarrierGen {
    arrived: Vec<bool>,
    n_arrived: usize,
    generation: u64,
    /// The rank whose arrival released the previous generation — by
    /// definition the straggler every other rank waited for.  Read by
    /// waiters right after their generation advances; safe because no
    /// further generation can complete until *this* waiter re-enters
    /// the barrier (its own `arrived` flag gates the count).
    last_arriver: usize,
}

impl WaitBarrier {
    fn new(m: usize) -> WaitBarrier {
        WaitBarrier {
            state: Mutex::new(BarrierGen {
                arrived: vec![false; m],
                n_arrived: 0,
                generation: 0,
                last_arriver: 0,
            }),
            cv: Condvar::new(),
            m,
        }
    }

    /// Collective wait.  Returns `Ok(last_arriver)` — the rank whose
    /// arrival completed the generation (the releaser names itself) —
    /// or `Err(missing)` if `timeout` expires first, with the ranks
    /// that never arrived in this generation.
    fn wait(
        &self,
        rank: usize,
        timeout: Option<Duration>,
    ) -> Result<usize, Vec<usize>> {
        // the barrier holds only bookkeeping flags: recover from a
        // poisoned lock instead of cascading the peer's panic
        let mut st =
            self.state.lock().unwrap_or_else(|e| e.into_inner());
        debug_assert!(
            !st.arrived[rank],
            "rank {rank} re-entered the barrier within one generation"
        );
        st.arrived[rank] = true;
        st.n_arrived += 1;
        if st.n_arrived == self.m {
            st.n_arrived = 0;
            st.arrived.iter_mut().for_each(|a| *a = false);
            st.generation = st.generation.wrapping_add(1);
            st.last_arriver = rank;
            self.cv.notify_all();
            return Ok(rank);
        }
        let generation = st.generation;
        match timeout {
            None => {
                while st.generation == generation {
                    st = self
                        .cv
                        .wait(st)
                        .unwrap_or_else(|e| e.into_inner());
                }
                Ok(st.last_arriver)
            }
            Some(limit) => {
                let deadline = Instant::now() + limit;
                while st.generation == generation {
                    let now = Instant::now();
                    if now >= deadline {
                        return Err(st
                            .arrived
                            .iter()
                            .enumerate()
                            .filter(|&(_, &a)| !a)
                            .map(|(r, _)| r)
                            .collect());
                    }
                    st = self
                        .cv
                        .wait_timeout(st, deadline - now)
                        .unwrap_or_else(|e| e.into_inner())
                        .0;
                }
                Ok(st.last_arriver)
            }
        }
    }
}

pub(crate) struct WorldInner {
    pub(crate) m: usize,
    barrier: WaitBarrier,
    /// mailboxes[dest][src]
    mailboxes: Vec<Vec<Mutex<Vec<SpikeMsg>>>>,
    /// Current buffer quota in spikes per rank pair (grows on overflow).
    pub(crate) quota: AtomicUsize,
    overflow: AtomicBool,
    /// Split-phase pipeline depth (sub-worlds inherit it on split).
    pub(crate) depth: usize,
    /// Watchdog deadline of every blocking rendezvous (None = wait
    /// forever); sub-worlds inherit it on split.
    pub(crate) timeout: Option<Duration>,
    /// Tier label of diagnostics: "global" for a root world, "local"
    /// for every sub-world produced by [`Transport::split`].
    pub(crate) tier: &'static str,
    /// Scratch register of [`Transport::allreduce_min_u64`].
    reduce_slot: AtomicU64,
    /// Per-rank `(color, key)` contributions of the in-flight
    /// [`Transport::split`] collective (barrier-framed, cold path).
    split_slots: Mutex<Vec<(u64, u64)>>,
    /// Published outcome of the split: each rank's sub-world and its
    /// rank within it, deposited by rank 0 and taken by the owner.
    split_result: Mutex<Vec<Option<(World, usize)>>>,
    /// Sub-worlds created by [`Transport::split`], kept for per-tier
    /// statistics aggregation ([`World::local_stats`]).
    children: Mutex<Vec<World>>,
    /// Split-phase mailbox state (epoch-stamped ring buffers).
    pub(crate) nb: nonblocking::NbWorld,
    pub(crate) stats: CommStats,
    /// Local → absolute rank mapping: a root world is the identity,
    /// a sub-world maps its members through the parent's mapping, so
    /// attribution (blame, trace pids) is in root-world rank numbers.
    pub(crate) world_ranks: Vec<usize>,
    /// Root-world rank count — the index space of `world_ranks` and of
    /// every blame ledger.
    pub(crate) root_m: usize,
    /// Straggler ledgers, one per *waiting* local rank (each rank only
    /// locks its own — uncontended until run-end collection), indexed
    /// inside by *blamed absolute* rank.
    pub(crate) blame: Vec<Mutex<Blame>>,
    /// Per-rank span recorders ([`Tracer::off`] when tracing is not
    /// requested) plus the shared buffer, kept so `split` can hand the
    /// same trace to sub-worlds.
    pub(crate) tracers: Vec<Tracer>,
    trace: Option<Arc<TraceBuf>>,
}

impl WorldInner {
    /// Count and build a [`CommError::Timeout`] from a barrier expiry.
    fn barrier_timeout(
        &self,
        rank: usize,
        op: &'static str,
        missing: Vec<usize>,
    ) -> CommError {
        self.stats.timeouts.fetch_add(1, Ordering::Relaxed);
        let present = (0..self.m)
            .filter(|r| !missing.contains(r))
            .collect();
        CommError::Timeout {
            tier: self.tier,
            op,
            rank,
            epoch: None,
            ring_slot: None,
            waited: self.timeout.unwrap_or_default(),
            missing,
            present,
        }
    }

    /// Count and build a [`CommError::Poisoned`].
    pub(crate) fn poisoned(
        &self,
        rank: usize,
        context: String,
    ) -> CommError {
        CommError::Poisoned { tier: self.tier, rank, context }
    }

    /// The observability tier of this world's events.
    pub(crate) fn obs_tier(&self) -> Tier {
        Tier::from_tier_str(self.tier)
    }

    /// Record one wait verdict into `waiter`'s ledger: local rank
    /// `blamed_local` arrived last, costing `lateness_secs` of wait.
    pub(crate) fn record_blame(
        &self,
        waiter: usize,
        blamed_local: usize,
        lateness_secs: f64,
    ) {
        self.blame[waiter]
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .record(self.world_ranks[blamed_local], lateness_secs);
    }
}

/// Shared communication world; build once via [`WorldBuilder`], then
/// [`World::communicator`] per rank thread.
#[derive(Clone)]
pub struct World {
    inner: Arc<WorldInner>,
}

/// The one constructor of [`World`]: number of ranks plus the tuning
/// knobs that used to be spread over a constructor pair.
///
/// * `quota` — starting spike-buffer size per rank pair (NEST starts
///   small and grows via the two-round resize protocol; tests exercise
///   it with tiny quotas).  Default 1024.
/// * `depth` — split-phase pipeline depth: the mailbox ring holds up to
///   this many exchanges in flight per rank (`2·depth` epoch-stamped
///   slots per (dest, src) pair — see the [`nonblocking`] module docs
///   for why `2·depth` suffices).  Default 1.
/// * `timeout` — watchdog deadline of every blocking rendezvous
///   (barrier frames and split-phase completion waits).  Default `None`
///   = wait forever, the historical behavior.
///
/// Sub-worlds created by [`Transport::split`] inherit the parent's
/// depth, timeout, trace buffer and its *current* quota.
#[derive(Clone)]
pub struct WorldBuilder {
    m: usize,
    quota: usize,
    depth: usize,
    timeout: Option<Duration>,
    tier: &'static str,
    trace: Option<Arc<TraceBuf>>,
    world_ranks: Option<Vec<usize>>,
    root_m: Option<usize>,
}

impl WorldBuilder {
    pub fn new(m: usize) -> WorldBuilder {
        WorldBuilder {
            m,
            quota: 1024,
            depth: 1,
            timeout: None,
            tier: "global",
            trace: None,
            world_ranks: None,
            root_m: None,
        }
    }

    pub fn quota(mut self, quota: usize) -> WorldBuilder {
        self.quota = quota;
        self
    }

    pub fn depth(mut self, depth: usize) -> WorldBuilder {
        self.depth = depth;
        self
    }

    /// Watchdog deadline for every blocking rendezvous of the world
    /// (None = wait forever).
    pub fn timeout(mut self, timeout: Option<Duration>) -> WorldBuilder {
        self.timeout = timeout;
        self
    }

    fn tier(mut self, tier: &'static str) -> WorldBuilder {
        self.tier = tier;
        self
    }

    /// Attach a shared span recorder: every comm operation of the world
    /// (and of sub-worlds split off it) records trace spans into `buf`.
    /// `None` (the default) leaves tracing compiled-out-cheap.
    pub fn trace(mut self, buf: Option<Arc<TraceBuf>>) -> WorldBuilder {
        self.trace = buf;
        self
    }

    /// Local → absolute rank mapping of a sub-world ([`Transport::split`]
    /// composes the members through the parent's mapping).
    fn world_ranks(
        mut self,
        ranks: Vec<usize>,
        root_m: usize,
    ) -> WorldBuilder {
        assert_eq!(ranks.len(), self.m);
        self.world_ranks = Some(ranks);
        self.root_m = Some(root_m);
        self
    }

    pub fn build(self) -> World {
        let WorldBuilder {
            m,
            quota,
            depth,
            timeout,
            tier,
            trace,
            world_ranks,
            root_m,
        } = self;
        assert!(m >= 1);
        assert!(depth >= 1, "pipeline depth must be >= 1");
        let mailboxes = (0..m)
            .map(|_| (0..m).map(|_| Mutex::new(Vec::new())).collect())
            .collect();
        let world_ranks =
            world_ranks.unwrap_or_else(|| (0..m).collect());
        let root_m = root_m.unwrap_or(m);
        let tracers = match &trace {
            Some(buf) => (0..m)
                .map(|r| Tracer::new(buf, world_ranks[r]))
                .collect(),
            None => vec![Tracer::off(); m],
        };
        let blame =
            (0..m).map(|_| Mutex::new(Blame::sized(root_m))).collect();
        World {
            inner: Arc::new(WorldInner {
                m,
                barrier: WaitBarrier::new(m),
                mailboxes,
                quota: AtomicUsize::new(quota.max(1)),
                overflow: AtomicBool::new(false),
                depth,
                timeout,
                tier,
                reduce_slot: AtomicU64::new(u64::MAX),
                split_slots: Mutex::new(vec![(0, 0); m]),
                split_result: Mutex::new((0..m).map(|_| None).collect()),
                children: Mutex::new(Vec::new()),
                nb: nonblocking::NbWorld::new(m, depth),
                stats: CommStats::default(),
                world_ranks,
                root_m,
                blame,
                tracers,
                trace,
            }),
        }
    }
}

impl World {
    pub fn communicator(&self, rank: usize) -> Communicator {
        assert!(rank < self.inner.m);
        Communicator { world: self.inner.clone(), rank }
    }

    pub fn m_ranks(&self) -> usize {
        self.inner.m
    }

    pub fn stats(&self) -> &CommStats {
        &self.inner.stats
    }

    /// Aggregate statistics of every sub-communicator split off this
    /// world (recursively) — the *local* tier of a hierarchical run.
    /// Empty-default when no split ever happened.
    pub fn local_stats(&self) -> CommStatsSnapshot {
        let children = self.inner.children.lock().unwrap();
        children.iter().fold(CommStatsSnapshot::default(), |acc, c| {
            acc.merged(&c.stats().snapshot()).merged(&c.local_stats())
        })
    }

    /// Per-tier view: this world's own traffic as the *global* tier,
    /// the aggregated sub-communicators as the *local* tier.
    pub fn tiered_stats(&self) -> TieredCommStats {
        TieredCommStats {
            global: self.stats().snapshot(),
            local: self.local_stats(),
        }
    }

    pub fn current_quota(&self) -> usize {
        self.inner.quota.load(Ordering::Relaxed)
    }

    /// Fold this world's blame ledgers — and recursively every
    /// sub-world's — into `out`, indexed by the *waiting* absolute
    /// rank (ledgers inside are already in absolute blamed ranks).
    fn fold_blame(&self, out: &mut [Blame]) {
        for (local, ledger) in self.inner.blame.iter().enumerate() {
            let abs = self.inner.world_ranks[local];
            out[abs].merge(
                &ledger.lock().unwrap_or_else(|e| e.into_inner()),
            );
        }
        for c in self
            .inner
            .children
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .iter()
        {
            c.fold_blame(out);
        }
    }

    /// Per-tier straggler attribution of the run: this world's own
    /// barrier waits as the *global* tier, every sub-communicator's
    /// (recursively, in absolute ranks) as the *local* tier.
    pub fn blame_report(&self) -> TieredBlame {
        let m = self.inner.root_m;
        let mut global = vec![Blame::sized(m); m];
        for (local, ledger) in self.inner.blame.iter().enumerate() {
            global[self.inner.world_ranks[local]].merge(
                &ledger.lock().unwrap_or_else(|e| e.into_inner()),
            );
        }
        let mut local_tier = vec![Blame::sized(m); m];
        for c in self
            .inner
            .children
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .iter()
        {
            c.fold_blame(&mut local_tier);
        }
        TieredBlame { global, local: local_tier }
    }
}

/// Per-rank handle into the [`World`].
pub struct Communicator {
    pub(crate) world: Arc<WorldInner>,
    pub(crate) rank: usize,
}

/// Per-rank view of a communication fabric: the collective global
/// exchange and the rank-local pathway, with recycled buffers (see the
/// module docs for the buffer-recycling contract).
///
/// Collectives are fallible: with a watchdog deadline armed they return
/// [`CommError::Timeout`] instead of hanging on a dead peer, and a
/// poisoned shared lock surfaces as [`CommError::Poisoned`].  Without a
/// deadline the historical wait-forever semantics apply and the
/// `Result` is always `Ok` absent peer panics.
pub trait Transport {
    /// Communicator type produced by [`Transport::split`].  The
    /// shared-memory world splits into further shared-memory worlds; an
    /// MPI binding would split into MPI sub-communicators.
    type Sub: Transport;

    /// This rank's id within the world.
    fn rank(&self) -> usize;

    /// Number of ranks in the world.
    fn m_ranks(&self) -> usize;

    /// Current spike-buffer quota per rank pair (grows via the resize
    /// protocol; checkpoints record it so a restored run starts from
    /// the grown value instead of re-learning it).
    fn quota(&self) -> usize;

    /// Collective communicator split, the `MPI_Comm_split` shape: every
    /// rank calls `split` concurrently; ranks passing the same `color`
    /// form one sub-communicator, with ranks assigned in ascending
    /// `(key, rank)` order.  The sub-communicator is fully independent
    /// of its parent — own barrier, own mailboxes and quota, own
    /// statistics — so collectives on disjoint groups never synchronize
    /// with each other.  Cold path (setup only): the engine splits once
    /// to build the per-area-group local tier.
    fn split(&self, color: u64, key: u64) -> Result<Self::Sub, CommError>;

    /// Collective all-to-all spike exchange.  `send[d]` is the buffer
    /// destined for rank `d` (must have length M) and is drained by the
    /// call; `recv` is resized to M slots and `recv[s]` is overwritten
    /// with the spikes from source rank `s` (per-source order
    /// preserved).  Returns the timing split into synchronization and
    /// data-exchange parts.
    ///
    /// All ranks must call this the same number of times (collective
    /// semantics); mismatch deadlocks — or, with a watchdog armed,
    /// expires into [`CommError::Timeout`].
    fn alltoall_into(
        &self,
        send: &mut [Vec<SpikeMsg>],
        recv: &mut Vec<Vec<SpikeMsg>>,
    ) -> Result<ExchangeTiming, CommError>;

    /// Rank-local exchange of the structure-aware short-range pathway:
    /// `recv` is cleared and swapped with `send`, so the sent spikes
    /// come back in `recv` and `send` is left empty (capacity
    /// recycled).  No synchronization with other ranks (and therefore
    /// infallible).
    fn local_swap_into(
        &self,
        send: &mut Vec<SpikeMsg>,
        recv: &mut Vec<SpikeMsg>,
    );

    /// Control-plane collective: the minimum of `v` over all ranks (an
    /// `MPI_Allreduce(MIN)`).  Cold path — used to agree on run-wide
    /// parameters derived from rank-local state (e.g. the sustainable
    /// split-phase pipeline depth) and as the barrier framing of the
    /// collective checkpoint write, so it deliberately stays off the
    /// spike-statistics counters.  Collective semantics: every rank must
    /// call it the same number of times.
    fn allreduce_min_u64(&self, v: u64) -> Result<u64, CommError>;

    /// Allocating convenience wrapper around [`Transport::alltoall_into`]
    /// for cold paths (setup exchanges, tests).
    #[allow(clippy::type_complexity)]
    fn alltoall(
        &self,
        send: &mut [Vec<SpikeMsg>],
    ) -> Result<(Vec<Vec<SpikeMsg>>, ExchangeTiming), CommError> {
        let mut recv = Vec::new();
        let timing = self.alltoall_into(send, &mut recv)?;
        Ok((recv, timing))
    }

    /// Allocating convenience wrapper around
    /// [`Transport::local_swap_into`].
    fn local_swap(&self, send: &mut Vec<SpikeMsg>) -> Vec<SpikeMsg> {
        let mut recv = Vec::new();
        self.local_swap_into(send, &mut recv);
        recv
    }
}

/// Timing of one collective call, in seconds of real wall clock.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExchangeTiming {
    /// Waiting at the barrier in front of the collective.
    pub sync_secs: f64,
    /// The data exchange itself (write + release + read).
    pub data_secs: f64,
}

impl Communicator {
    /// Watchdogged barrier frame: waits like `Barrier::wait`, expires
    /// into a [`CommError::Timeout`] naming the missing ranks.
    ///
    /// Every barrier frame is an attribution point: the rank whose
    /// arrival released the generation is the straggler everyone else
    /// waited for, so each waiting rank charges the wait to it in its
    /// blame ledger (the releaser does not blame itself), and with
    /// tracing on the wait becomes a span named after `op` carrying
    /// the blamed peer.
    fn barrier_wait(&self, op: &'static str) -> Result<(), CommError> {
        let w = &*self.world;
        let tracer = &w.tracers[self.rank];
        let span_start = tracer.start();
        let t0 = Instant::now();
        let last = w
            .barrier
            .wait(self.rank, w.timeout)
            .map_err(|missing| {
                w.barrier_timeout(self.rank, op, missing)
            })?;
        let mut src = -1;
        if last != self.rank {
            let waited = t0.elapsed().as_secs_f64();
            w.record_blame(self.rank, last, waited);
            src = w.world_ranks[last] as i32;
        }
        tracer.span(
            op,
            span_start,
            SpanCtx { tier: w.obs_tier(), src, ..SpanCtx::NONE },
        );
        Ok(())
    }
}

impl Transport for Communicator {
    type Sub = Communicator;

    fn rank(&self) -> usize {
        self.rank
    }

    fn m_ranks(&self) -> usize {
        self.world.m
    }

    fn quota(&self) -> usize {
        self.world.quota.load(Ordering::Relaxed)
    }

    fn split(
        &self,
        color: u64,
        key: u64,
    ) -> Result<Communicator, CommError> {
        let w = &*self.world;
        // barrier-framed register protocol (cold path).  Frame start:
        // nobody can deposit into `split_slots` while a straggler of the
        // previous collective is still inside it.
        self.barrier_wait("split")?;
        w.split_slots
            .lock()
            .map_err(|_| {
                w.poisoned(
                    self.rank,
                    "holding the split register".to_string(),
                )
            })?[self.rank] = (color, key);
        self.barrier_wait("split")?;
        // every contribution is visible; rank 0 materializes one
        // sub-world per color (they must be *shared*, so a single rank
        // creates them) and publishes each rank's handle + sub-rank
        if self.rank == 0 {
            let slots = w
                .split_slots
                .lock()
                .map_err(|_| {
                    w.poisoned(
                        self.rank,
                        "holding the split register".to_string(),
                    )
                })?
                .clone();
            let mut groups: std::collections::BTreeMap<u64, Vec<usize>> =
                std::collections::BTreeMap::new();
            for (rank, &(c, _)) in slots.iter().enumerate() {
                groups.entry(c).or_default().push(rank);
            }
            let mut results = w.split_result.lock().map_err(|_| {
                w.poisoned(
                    self.rank,
                    "holding the split result register".to_string(),
                )
            })?;
            let mut children = w.children.lock().map_err(|_| {
                w.poisoned(
                    self.rank,
                    "holding the child-world registry".to_string(),
                )
            })?;
            for mut members in groups.into_values() {
                members.sort_by_key(|&r| (slots[r].1, r));
                // sub-worlds attribute against absolute (root-world)
                // ranks and record into the same shared trace buffer
                let abs_ranks: Vec<usize> =
                    members.iter().map(|&r| w.world_ranks[r]).collect();
                let sub = WorldBuilder::new(members.len())
                    .quota(w.quota.load(Ordering::Relaxed))
                    .depth(w.depth)
                    .timeout(w.timeout)
                    .tier("local")
                    .trace(w.trace.clone())
                    .world_ranks(abs_ranks, w.root_m)
                    .build();
                children.push(sub.clone());
                for (sub_rank, &r) in members.iter().enumerate() {
                    results[r] = Some((sub.clone(), sub_rank));
                }
            }
        }
        self.barrier_wait("split")?;
        // each rank takes exactly its own entry; re-entry into the next
        // collective's first barrier implies every entry was taken, so
        // the register is reusable without a fourth barrier
        let (sub, sub_rank) = w
            .split_result
            .lock()
            .map_err(|_| {
                w.poisoned(
                    self.rank,
                    "holding the split result register".to_string(),
                )
            })?[self.rank]
            .take()
            .expect("split result not published");
        Ok(sub.communicator(sub_rank))
    }

    fn alltoall_into(
        &self,
        send: &mut [Vec<SpikeMsg>],
        recv: &mut Vec<Vec<SpikeMsg>>,
    ) -> Result<ExchangeTiming, CommError> {
        assert_eq!(send.len(), self.world.m, "send buffer per rank required");
        let w = &*self.world;
        let tracer = &w.tracers[self.rank];
        let span_start = tracer.start();

        // --- synchronization: explicit barrier in front of the collective
        let t0 = Instant::now();
        self.barrier_wait("alltoall (sync barrier)")?;
        let t1 = Instant::now();
        let sync_secs = (t1 - t0).as_secs_f64();
        w.stats
            .sync_nanos
            .fetch_add((sync_secs * 1e9) as u64, Ordering::Relaxed);

        // --- overflow detection (two-round resize protocol)
        let quota = w.quota.load(Ordering::Relaxed);
        let my_max = send.iter().map(|v| v.len()).max().unwrap_or(0);
        if my_max > quota {
            w.overflow.store(true, Ordering::Relaxed);
        }
        w.stats
            .max_send_per_pair
            .fetch_max(my_max, Ordering::Relaxed);
        self.barrier_wait("alltoall (overflow vote)")?;
        // after the barrier every rank observes the same flag; the reset
        // happens strictly between two further barriers so no rank can
        // read a half-updated flag (all ranks take the same branch)
        let need_resize = w.overflow.load(Ordering::Relaxed);
        if need_resize {
            // every rank grows its buffers until the largest message fits,
            // then a secondary exchange round follows (paper §4.1)
            self.barrier_wait("alltoall (resize round)")?;
            if self.rank == 0 {
                let mut q = w.quota.load(Ordering::Relaxed);
                let need = w.stats.max_send_per_pair.load(Ordering::Relaxed);
                while q < need {
                    q *= 2;
                }
                w.quota.store(q, Ordering::Relaxed);
                w.overflow.store(false, Ordering::Relaxed);
                w.stats.resize_rounds.fetch_add(1, Ordering::Relaxed);
            }
            self.barrier_wait("alltoall (resize round)")?;
        }

        // --- data exchange: write own column, then read own row.  Both
        // sides *swap* with the mailbox slot, so the sender's drained
        // buffer and the receiver's previous buffer circulate instead of
        // being dropped and reallocated (see module docs).
        let mut bytes = 0usize;
        for (dest, buf) in send.iter_mut().enumerate() {
            bytes += buf.len() * SPIKE_WIRE_BYTES;
            let mut slot =
                w.mailboxes[dest][self.rank].lock().map_err(|_| {
                    w.poisoned(
                        self.rank,
                        format!(
                            "holding mailbox slot (dest={dest}, src={})",
                            self.rank
                        ),
                    )
                })?;
            debug_assert!(slot.is_empty(), "mailbox not drained");
            std::mem::swap(&mut *slot, buf);
        }
        w.stats
            .bytes_sent
            .fetch_add(bytes as u64, Ordering::Relaxed);
        self.barrier_wait("alltoall (deposit)")?;
        recv.resize_with(w.m, Vec::new);
        for (src, out) in recv.iter_mut().enumerate() {
            out.clear();
            let mut slot =
                w.mailboxes[self.rank][src].lock().map_err(|_| {
                    w.poisoned(
                        self.rank,
                        format!(
                            "holding mailbox slot (dest={}, src={src})",
                            self.rank
                        ),
                    )
                })?;
            std::mem::swap(&mut *slot, out);
        }
        w.stats.alltoall_calls.fetch_add(1, Ordering::Relaxed);
        // final barrier so nobody races ahead into the next call's writes
        self.barrier_wait("alltoall (drain)")?;
        let data_secs = t1.elapsed().as_secs_f64();
        tracer.span(
            "alltoall",
            span_start,
            SpanCtx::tier(w.obs_tier()),
        );
        Ok(ExchangeTiming { sync_secs, data_secs })
    }

    fn local_swap_into(
        &self,
        send: &mut Vec<SpikeMsg>,
        recv: &mut Vec<SpikeMsg>,
    ) {
        self.world.stats.local_swaps.fetch_add(1, Ordering::Relaxed);
        recv.clear();
        std::mem::swap(send, recv);
    }

    fn allreduce_min_u64(&self, v: u64) -> Result<u64, CommError> {
        let w = &*self.world;
        let tracer = &w.tracers[self.rank];
        let span_start = tracer.start();
        // barrier-framed register protocol: no rank can still be reading
        // the previous reduction when rank 0 resets (it could not have
        // reached this call's first barrier otherwise), and no rank can
        // read before every contribution landed
        self.barrier_wait("allreduce_min")?;
        if self.rank == 0 {
            w.reduce_slot.store(u64::MAX, Ordering::Relaxed);
        }
        self.barrier_wait("allreduce_min")?;
        w.reduce_slot.fetch_min(v, Ordering::Relaxed);
        self.barrier_wait("allreduce_min")?;
        let out = w.reduce_slot.load(Ordering::Relaxed);
        tracer.span(
            "allreduce_min",
            span_start,
            SpanCtx::tier(w.obs_tier()),
        );
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn msg(source: Gid, cycle: u32) -> SpikeMsg {
        SpikeMsg { source, cycle }
    }

    /// Run `f(rank, comm)` on m rank threads, collect results by rank.
    fn run_ranks<F, R>(m: usize, quota: usize, f: F) -> Vec<R>
    where
        F: Fn(usize, Communicator) -> R + Send + Sync,
        R: Send,
    {
        let world = WorldBuilder::new(m).quota(quota).build();
        thread::scope(|s| {
            let handles: Vec<_> = (0..m)
                .map(|rank| {
                    let comm = world.communicator(rank);
                    let f = &f;
                    s.spawn(move || f(rank, comm))
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }

    #[test]
    fn alltoall_routes_messages() {
        let results = run_ranks(4, 64, |rank, comm| {
            // rank r sends spike (source=100*r + d) to each dest d
            let mut send: Vec<Vec<SpikeMsg>> = (0..4)
                .map(|d| vec![msg((100 * rank + d) as Gid, 7)])
                .collect();
            let (recv, _) = comm.alltoall(&mut send).unwrap();
            recv
        });
        for (rank, recv) in results.iter().enumerate() {
            assert_eq!(recv.len(), 4);
            for (src, buf) in recv.iter().enumerate() {
                assert_eq!(buf.len(), 1);
                assert_eq!(buf[0].source, (100 * src + rank) as Gid);
                assert_eq!(buf[0].cycle, 7);
            }
        }
    }

    #[test]
    fn alltoall_preserves_per_source_order() {
        let results = run_ranks(2, 64, |rank, comm| {
            let mut send: Vec<Vec<SpikeMsg>> = (0..2)
                .map(|_| (0..10).map(|i| msg(rank as Gid, i)).collect())
                .collect();
            let (recv, _) = comm.alltoall(&mut send).unwrap();
            recv
        });
        for recv in &results {
            // per source rank, cycles ascend
            for (src, buf) in recv.iter().enumerate() {
                let cycles: Vec<u32> = buf.iter().map(|m| m.cycle).collect();
                assert_eq!(cycles, (0..10).collect::<Vec<_>>());
                assert!(buf.iter().all(|m| m.source == src as Gid));
            }
        }
    }

    #[test]
    fn repeated_rounds_do_not_leak() {
        let results = run_ranks(3, 64, |rank, comm| {
            let mut total = 0usize;
            for round in 0..5u32 {
                let mut send: Vec<Vec<SpikeMsg>> = (0..3)
                    .map(|_| vec![msg(rank as Gid, round)])
                    .collect();
                let (recv, _) = comm.alltoall(&mut send).unwrap();
                assert!(recv
                    .iter()
                    .flatten()
                    .all(|m| m.cycle == round));
                total += recv.iter().map(|b| b.len()).sum::<usize>();
            }
            total
        });
        assert!(results.iter().all(|&t| t == 15));
    }

    #[test]
    fn overflow_triggers_resize_round() {
        let world = WorldBuilder::new(2).quota(4).build();
        let w2 = world.clone();
        thread::scope(|s| {
            for rank in 0..2 {
                let comm = world.communicator(rank);
                s.spawn(move || {
                    // rank 0 sends 10 spikes/pair, above the quota of 4
                    let n = if rank == 0 { 10 } else { 1 };
                    let mut send: Vec<Vec<SpikeMsg>> = (0..2)
                        .map(|_| (0..n).map(|i| msg(rank as Gid, i)).collect())
                        .collect();
                    let (recv, _) = comm.alltoall(&mut send).unwrap();
                    let n: usize = recv.iter().map(|b| b.len()).sum();
                    assert_eq!(n, 10 + 1);
                });
            }
        });
        let snap = w2.stats().snapshot();
        assert_eq!(snap.resize_rounds, 1);
        assert_eq!(
            snap.max_send_per_pair, 10,
            "largest per-pair send not tracked"
        );
        assert!(w2.current_quota() >= 10);
    }

    #[test]
    fn local_swap_returns_buffer_without_barrier() {
        let world = WorldBuilder::new(1).quota(4).build();
        let comm = world.communicator(0);
        let mut send = vec![msg(1, 2), msg(3, 4)];
        let recv = comm.local_swap(&mut send);
        assert_eq!(recv, vec![msg(1, 2), msg(3, 4)]);
        assert!(send.is_empty());
        let snap = world.stats().snapshot();
        assert_eq!(snap.alltoall_calls, 0);
        assert_eq!(snap.local_swaps, 1);
        // local swaps bypass the global exchange: no per-pair maximum
        assert_eq!(snap.max_send_per_pair, 0);
    }

    #[test]
    fn stats_count_bytes() {
        let world = WorldBuilder::new(2).quota(64).build();
        thread::scope(|s| {
            for rank in 0..2 {
                let comm = world.communicator(rank);
                s.spawn(move || {
                    let mut send: Vec<Vec<SpikeMsg>> = (0..2)
                        .map(|_| vec![msg(rank as Gid, 0); 3])
                        .collect();
                    comm.alltoall(&mut send).unwrap();
                });
            }
        });
        let snap = world.stats().snapshot();
        assert_eq!(snap.alltoall_calls, 2);
        // 2 ranks x 2 dests x 3 spikes x 8 bytes
        assert_eq!(snap.bytes_sent, 96);
        assert_eq!(snap.max_send_per_pair, 3);
        // no split-phase traffic in a blocking-only run
        assert_eq!(snap.overlapped_exchanges, 0);
        assert_eq!(snap.hidden_secs, 0.0);
        // and no watchdog fired (none armed)
        assert_eq!(snap.timeouts, 0);
    }

    #[test]
    fn recycled_buffers_many_rounds_stress() {
        // One pair of send/recv buffer sets per rank, recycled over 50
        // rounds of varying fan-out, with one round (20) deliberately
        // overflowing the quota of 4 to trigger the two-round resize
        // protocol mid-stream.  No spike may leak across rounds.
        const M: usize = 3;
        let per_round = |round: u32| -> usize {
            if round == 20 {
                9
            } else {
                1 + (round as usize % 3)
            }
        };
        let world = WorldBuilder::new(M).quota(4).build();
        let w2 = world.clone();
        let results = thread::scope(|s| {
            let handles: Vec<_> = (0..M)
                .map(|rank| {
                    let comm = world.communicator(rank);
                    s.spawn(move || {
                        let mut send: Vec<Vec<SpikeMsg>> =
                            (0..M).map(|_| Vec::new()).collect();
                        let mut recv: Vec<Vec<SpikeMsg>> = Vec::new();
                        let mut total = 0usize;
                        for round in 0..50u32 {
                            let n = per_round(round);
                            for buf in &mut send {
                                for i in 0..n {
                                    buf.push(msg(
                                        (1000 * rank + i) as Gid,
                                        round,
                                    ));
                                }
                            }
                            comm.alltoall_into(&mut send, &mut recv)
                                .unwrap();
                            assert!(
                                send.iter().all(|b| b.is_empty()),
                                "send not drained in round {round}"
                            );
                            for (src, buf) in recv.iter().enumerate() {
                                assert_eq!(
                                    buf.len(),
                                    n,
                                    "round {round} from rank {src}"
                                );
                                assert!(
                                    buf.iter().all(|m| m.cycle == round),
                                    "stale spikes leaked into round {round}"
                                );
                                assert!(buf
                                    .iter()
                                    .all(|m| m.source / 1000
                                        == src as Gid));
                            }
                            total +=
                                recv.iter().map(|b| b.len()).sum::<usize>();
                        }
                        total
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect::<Vec<_>>()
        });
        let expect: usize =
            (0..50u32).map(|r| per_round(r) * M).sum();
        assert!(results.iter().all(|&t| t == expect), "{results:?}");
        let snap = w2.stats().snapshot();
        assert_eq!(snap.alltoall_calls, 50 * M as u64);
        assert_eq!(
            snap.resize_rounds, 1,
            "overflow round must resize exactly once"
        );
        assert_eq!(
            snap.max_send_per_pair, 9,
            "per-pair maximum is the overflow round"
        );
        assert!(w2.current_quota() >= 9);
    }

    #[test]
    fn alltoall_into_reuses_buffer_capacity() {
        // With swap-based recycling, buffer capacity circulates between
        // the send buffer, the mailbox slot and the receive buffer; once
        // all three are warm no round allocates, so capacities stay put.
        let world = WorldBuilder::new(1).quota(64).build();
        let comm = world.communicator(0);
        let mut send = vec![Vec::new()];
        let mut recv: Vec<Vec<SpikeMsg>> = Vec::new();
        let mut fill_and_exchange = |send: &mut Vec<Vec<SpikeMsg>>,
                                     recv: &mut Vec<Vec<SpikeMsg>>,
                                     round: u32| {
            for i in 0..32 {
                send[0].push(msg(i, round));
            }
            comm.alltoall_into(send, recv).unwrap();
            assert_eq!(recv[0].len(), 32);
            assert!(recv[0].iter().all(|m| m.cycle == round));
        };
        for round in 0..10 {
            fill_and_exchange(&mut send, &mut recv, round);
        }
        let warm = (send[0].capacity(), recv[0].capacity());
        assert!(warm.0 >= 32 && warm.1 >= 32, "{warm:?}");
        for round in 10..40 {
            fill_and_exchange(&mut send, &mut recv, round);
        }
        assert_eq!(
            (send[0].capacity(), recv[0].capacity()),
            warm,
            "buffer recycling regressed to per-round allocation"
        );
    }

    #[test]
    fn local_swap_into_recycles_capacity() {
        let world = WorldBuilder::new(1).quota(4).build();
        let comm = world.communicator(0);
        let mut send = Vec::new();
        let mut recv = Vec::new();
        for round in 0..20u32 {
            for i in 0..16 {
                send.push(msg(i, round));
            }
            comm.local_swap_into(&mut send, &mut recv);
            assert_eq!(recv.len(), 16);
            assert!(recv.iter().all(|m| m.cycle == round));
            assert!(send.is_empty());
        }
        // the two buffers ping-pong; both hold capacity after warm-up
        assert!(send.capacity() >= 16 && recv.capacity() >= 16);
    }

    #[test]
    fn allreduce_min_agrees_across_ranks_and_rounds() {
        let results = run_ranks(4, 64, |rank, comm| {
            // round 1: min of (10 + rank); round 2: min of (100 - rank).
            // Back-to-back calls exercise the register-reset framing.
            let a = comm.allreduce_min_u64(10 + rank as u64).unwrap();
            let b = comm.allreduce_min_u64(100 - rank as u64).unwrap();
            (a, b)
        });
        assert!(results.iter().all(|&(a, b)| a == 10 && b == 97));
    }

    #[test]
    fn allreduce_min_does_not_touch_spike_stats() {
        let world = WorldBuilder::new(2).quota(64).build();
        thread::scope(|s| {
            for rank in 0..2 {
                let comm = world.communicator(rank);
                s.spawn(move || comm.allreduce_min_u64(rank as u64).unwrap());
            }
        });
        let snap = world.stats().snapshot();
        assert_eq!(snap.alltoall_calls, 0);
        assert_eq!(snap.bytes_sent, 0);
    }

    #[test]
    fn split_isolates_disjoint_groups() {
        // colors [0,0,1,1]: two groups of two; intra-group alltoalls
        // carry group-tagged payloads and must never leak across groups,
        // while the parent world's own counters stay untouched (tier
        // attribution)
        let world = WorldBuilder::new(4).quota(64).build();
        let results = thread::scope(|s| {
            let handles: Vec<_> = (0..4)
                .map(|rank| {
                    let comm = world.communicator(rank);
                    s.spawn(move || {
                        let color = (rank / 2) as u64;
                        let local = comm.split(color, rank as u64).unwrap();
                        assert_eq!(local.m_ranks(), 2);
                        assert_eq!(local.rank(), rank % 2);
                        let mut send: Vec<Vec<SpikeMsg>> = (0..2)
                            .map(|_| {
                                vec![msg((100 * rank) as Gid, color as u32)]
                            })
                            .collect();
                        let (recv, _) = local.alltoall(&mut send).unwrap();
                        recv
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect::<Vec<_>>()
        });
        for (rank, recv) in results.iter().enumerate() {
            let group = rank / 2;
            assert_eq!(recv.len(), 2);
            for (src_local, buf) in recv.iter().enumerate() {
                assert_eq!(buf.len(), 1);
                // the source is the group-mate, never a foreign rank
                assert_eq!(
                    buf[0].source,
                    (100 * (group * 2 + src_local)) as Gid
                );
                assert_eq!(buf[0].cycle, group as u32, "cross-group leak");
            }
        }
        let tiers = world.tiered_stats();
        assert_eq!(tiers.global.alltoall_calls, 0);
        assert_eq!(tiers.global.bytes_sent, 0);
        assert_eq!(tiers.local.alltoall_calls, 4);
        assert_eq!(
            tiers.local.bytes_sent,
            4 * 2 * SPIKE_WIRE_BYTES as u64
        );
        assert_eq!(tiers.combined().alltoall_calls, 4);
    }

    #[test]
    fn split_stats_attributed_per_tier() {
        // each rank exchanges on both tiers: parent counters carry the
        // global traffic, children the local tier, and the combined view
        // sums both for flat consumers
        let world = WorldBuilder::new(2).quota(64).build();
        thread::scope(|s| {
            for rank in 0..2 {
                let comm = world.communicator(rank);
                s.spawn(move || {
                    let local = comm.split(0, rank as u64).unwrap();
                    let mut send: Vec<Vec<SpikeMsg>> =
                        (0..2).map(|_| vec![msg(rank as Gid, 1)]).collect();
                    local.alltoall(&mut send).unwrap();
                    let mut lsend = vec![msg(rank as Gid, 2)];
                    let mut lrecv = Vec::new();
                    local.local_swap_into(&mut lsend, &mut lrecv);
                    let mut send: Vec<Vec<SpikeMsg>> = (0..2)
                        .map(|_| vec![msg(rank as Gid, 3); 2])
                        .collect();
                    comm.alltoall(&mut send).unwrap();
                });
            }
        });
        let tiers = world.tiered_stats();
        assert_eq!(tiers.local.alltoall_calls, 2);
        assert_eq!(tiers.local.local_swaps, 2);
        assert_eq!(
            tiers.local.bytes_sent,
            2 * 2 * SPIKE_WIRE_BYTES as u64
        );
        assert_eq!(tiers.global.alltoall_calls, 2);
        assert_eq!(tiers.global.local_swaps, 0);
        assert_eq!(
            tiers.global.bytes_sent,
            2 * 2 * 2 * SPIKE_WIRE_BYTES as u64
        );
        let combined = tiers.combined();
        assert_eq!(combined.alltoall_calls, 4);
        assert_eq!(combined.local_swaps, 2);
        assert_eq!(
            combined.bytes_sent,
            tiers.local.bytes_sent + tiers.global.bytes_sent
        );
        assert!(combined.sync_secs >= tiers.global.sync_secs);
    }

    #[test]
    fn split_orders_ranks_by_key_then_rank() {
        // MPI_Comm_split semantics: descending keys reverse the
        // sub-ranks
        let results = run_ranks(3, 64, |rank, comm| {
            let local = comm.split(7, (10 - rank) as u64).unwrap();
            (local.rank(), local.m_ranks())
        });
        assert_eq!(results, vec![(2, 3), (1, 3), (0, 3)]);
    }

    #[test]
    fn split_singleton_groups_degenerate() {
        // every rank its own color: 1-rank sub-worlds whose collectives
        // are self-delivery — the degenerate form the engine uses at
        // ranks_per_area = 1
        let world = WorldBuilder::new(3).quota(64).build();
        thread::scope(|s| {
            for rank in 0..3 {
                let comm = world.communicator(rank);
                s.spawn(move || {
                    let local = comm.split(rank as u64, 0).unwrap();
                    assert_eq!(local.m_ranks(), 1);
                    assert_eq!(local.rank(), 0);
                    let mut send = vec![vec![msg(rank as Gid, 5)]];
                    let (recv, _) = local.alltoall(&mut send).unwrap();
                    assert_eq!(recv[0], vec![msg(rank as Gid, 5)]);
                    let mut lsend = vec![msg(rank as Gid, 6)];
                    let recv = local.local_swap(&mut lsend);
                    assert_eq!(recv, vec![msg(rank as Gid, 6)]);
                });
            }
        });
        let tiers = world.tiered_stats();
        assert_eq!(tiers.local.alltoall_calls, 3);
        assert_eq!(tiers.local.local_swaps, 3);
        assert_eq!(tiers.global.alltoall_calls, 0);
        assert_eq!(tiers.global.local_swaps, 0);
    }

    #[test]
    fn repeated_and_nested_splits() {
        // the barrier-framed register survives back-to-back splits, and
        // a sub-communicator can itself be split (grandchildren roll up
        // recursively into the parent's local tier)
        let world = WorldBuilder::new(4).quota(64).build();
        thread::scope(|s| {
            for rank in 0..4 {
                let comm = world.communicator(rank);
                s.spawn(move || {
                    let a = comm
                        .split((rank % 2) as u64, rank as u64)
                        .unwrap();
                    assert_eq!(a.m_ranks(), 2);
                    let b = comm
                        .split((rank / 2) as u64, rank as u64)
                        .unwrap();
                    assert_eq!(b.m_ranks(), 2);
                    let c = b.split(b.rank() as u64, 0).unwrap();
                    assert_eq!(c.m_ranks(), 1);
                    let mut send = vec![vec![msg(rank as Gid, 9)]];
                    let (recv, _) = c.alltoall(&mut send).unwrap();
                    assert_eq!(recv[0].len(), 1);
                });
            }
        });
        assert_eq!(world.local_stats().alltoall_calls, 4);
        assert_eq!(world.stats().snapshot().alltoall_calls, 0);
    }

    #[test]
    fn split_inherits_grown_quota() {
        // the resize protocol grows the parent quota before the split;
        // the sub-world must start from the grown value (no secondary
        // resize on the local tier for the same message size)
        let world = WorldBuilder::new(2).quota(4).build();
        thread::scope(|s| {
            for rank in 0..2 {
                let comm = world.communicator(rank);
                s.spawn(move || {
                    let mut send: Vec<Vec<SpikeMsg>> = (0..2)
                        .map(|_| {
                            (0..10).map(|i| msg(rank as Gid, i)).collect()
                        })
                        .collect();
                    comm.alltoall(&mut send).unwrap();
                    let local = comm.split(0, rank as u64).unwrap();
                    assert_eq!(local.quota(), comm.quota());
                    let mut send: Vec<Vec<SpikeMsg>> = (0..2)
                        .map(|_| {
                            (0..10).map(|i| msg(rank as Gid, i)).collect()
                        })
                        .collect();
                    local.alltoall(&mut send).unwrap();
                });
            }
        });
        assert!(world.current_quota() >= 10);
        let tiers = world.tiered_stats();
        assert_eq!(tiers.global.resize_rounds, 1);
        assert_eq!(
            tiers.local.resize_rounds, 0,
            "sub-world must inherit the grown quota"
        );
    }

    #[test]
    fn timing_fields_populated() {
        let results = run_ranks(2, 64, |rank, comm| {
            // rank 1 works longer before the barrier -> rank 0 waits
            if rank == 1 {
                std::hint::black_box(
                    (0..2_000_000u64).map(|x| x.wrapping_mul(7)).sum::<u64>(),
                );
            }
            let mut send: Vec<Vec<SpikeMsg>> =
                (0..2).map(|_| Vec::new()).collect();
            let (_, timing) = comm.alltoall(&mut send).unwrap();
            timing
        });
        for t in &results {
            assert!(t.sync_secs >= 0.0);
            assert!(t.data_secs >= 0.0);
        }
    }

    #[test]
    fn barrier_timeout_names_missing_ranks() {
        // rank 1 never shows up at the collective: the armed watchdog
        // must fire with the missing rank and tier in the diagnostic
        // instead of hanging forever
        let world = WorldBuilder::new(2)
            .quota(4)
            .timeout(Some(Duration::from_millis(50)))
            .build();
        let comm = world.communicator(0);
        let mut send: Vec<Vec<SpikeMsg>> =
            (0..2).map(|_| Vec::new()).collect();
        let mut recv = Vec::new();
        let err = comm
            .alltoall_into(&mut send, &mut recv)
            .expect_err("watchdog did not fire");
        match &err {
            CommError::Timeout { tier, missing, present, .. } => {
                assert_eq!(*tier, "global");
                assert_eq!(missing, &vec![1]);
                assert_eq!(present, &vec![0]);
            }
            other => panic!("unexpected error variant: {other}"),
        }
        let msg = err.to_string();
        assert!(msg.contains("global"), "{msg}");
        assert!(msg.contains("missing ranks [1]"), "{msg}");
        assert_eq!(world.stats().snapshot().timeouts, 1);
    }

    #[test]
    fn no_timeout_means_wait_forever_semantics_preserved() {
        // without a deadline the world behaves exactly as before: a
        // staggered arrival completes fine and counts no timeouts
        let results = run_ranks(3, 64, |rank, comm| {
            if rank == 2 {
                thread::sleep(Duration::from_millis(20));
            }
            comm.allreduce_min_u64(rank as u64).unwrap()
        });
        assert!(results.iter().all(|&v| v == 0));
    }

    #[test]
    fn split_timeout_fires_on_missing_rank() {
        let world = WorldBuilder::new(2)
            .quota(4)
            .timeout(Some(Duration::from_millis(50)))
            .build();
        let comm = world.communicator(0);
        let err = comm.split(0, 0).expect_err("split watchdog");
        let msg = err.to_string();
        assert!(msg.contains("split"), "{msg}");
        assert!(msg.contains("missing ranks [1]"), "{msg}");
    }

    #[test]
    fn barrier_blames_the_last_arriver() {
        // rank 2 computes longest before every exchange: the other
        // ranks' ledgers must name it, and it must blame nobody
        let world = WorldBuilder::new(3).quota(64).build();
        thread::scope(|s| {
            for rank in 0..3 {
                let comm = world.communicator(rank);
                s.spawn(move || {
                    for _ in 0..5 {
                        if rank == 2 {
                            thread::sleep(Duration::from_millis(5));
                        }
                        let mut send: Vec<Vec<SpikeMsg>> =
                            (0..3).map(|_| Vec::new()).collect();
                        comm.alltoall(&mut send).unwrap();
                    }
                });
            }
        });
        let blame = world.blame_report();
        for waiter in [0usize, 1] {
            let (top, waits, late) = blame.global[waiter].top().unwrap();
            assert_eq!(top, 2, "rank {waiter} should blame rank 2");
            assert!(waits >= 5, "expected >=5 blamed waits, got {waits}");
            assert!(late > 0.0);
        }
        // the straggler itself never waits for anyone consistently;
        // in particular it must not blame itself
        assert_eq!(blame.global[2].waits[2], 0);
        // local tier untouched (no split happened)
        assert!(blame.local.iter().all(|b| b.is_empty()));
    }

    #[test]
    fn sub_world_blame_lands_in_local_tier_with_absolute_ranks() {
        let world = WorldBuilder::new(4).quota(64).build();
        thread::scope(|s| {
            for rank in 0..4 {
                let comm = world.communicator(rank);
                s.spawn(move || {
                    // groups {0,1} and {2,3}; rank 3 straggles in its
                    // group's local collectives
                    let local =
                        comm.split((rank / 2) as u64, rank as u64).unwrap();
                    for _ in 0..4 {
                        if rank == 3 {
                            thread::sleep(Duration::from_millis(5));
                        }
                        let mut send: Vec<Vec<SpikeMsg>> =
                            (0..2).map(|_| Vec::new()).collect();
                        local.alltoall(&mut send).unwrap();
                    }
                });
            }
        });
        let blame = world.blame_report();
        // rank 2 waited for rank 3 on the local tier, in absolute ranks
        let (top, waits, _) = blame.local[2].top().unwrap();
        assert_eq!(top, 3);
        assert!(waits >= 4);
        // the {0,1} group has no injected straggler; whatever noise it
        // recorded must stay within the group (ranks 0/1 never blame 2/3)
        for waiter in [0usize, 1] {
            assert_eq!(blame.local[waiter].waits[2], 0);
            assert_eq!(blame.local[waiter].waits[3], 0);
        }
    }

    #[test]
    fn traced_alltoall_records_nested_spans() {
        use crate::obs::TraceBuf;
        let buf = TraceBuf::new(2);
        let world =
            WorldBuilder::new(2).quota(64).trace(Some(buf.clone())).build();
        thread::scope(|s| {
            for rank in 0..2 {
                let comm = world.communicator(rank);
                s.spawn(move || {
                    let mut send: Vec<Vec<SpikeMsg>> =
                        (0..2).map(|_| vec![msg(rank as Gid, 1)]).collect();
                    comm.alltoall(&mut send).unwrap();
                });
            }
        });
        let spans = buf.drain();
        for pid in 0..2u32 {
            let mine: Vec<_> =
                spans.iter().filter(|s| s.pid == pid).collect();
            let parent = mine
                .iter()
                .find(|s| s.name == "alltoall")
                .expect("missing alltoall span");
            assert_eq!(parent.ctx.tier, Tier::Global);
            // barrier frames nest inside the collective span
            let barriers: Vec<_> = mine
                .iter()
                .filter(|s| s.name.starts_with("alltoall ("))
                .collect();
            assert!(barriers.len() >= 3, "got {}", barriers.len());
            for b in barriers {
                assert!(b.ts_us >= parent.ts_us - 1e-3);
                assert!(
                    b.ts_us + b.dur_us
                        <= parent.ts_us + parent.dur_us + 1e-3,
                    "barrier span leaks out of the collective span"
                );
            }
        }
    }

    #[test]
    fn split_propagates_trace_to_sub_worlds() {
        use crate::obs::TraceBuf;
        let buf = TraceBuf::new(4);
        let world =
            WorldBuilder::new(4).quota(64).trace(Some(buf.clone())).build();
        thread::scope(|s| {
            for rank in 0..4 {
                let comm = world.communicator(rank);
                s.spawn(move || {
                    let local =
                        comm.split((rank / 2) as u64, rank as u64).unwrap();
                    let mut send: Vec<Vec<SpikeMsg>> =
                        (0..2).map(|_| Vec::new()).collect();
                    local.alltoall(&mut send).unwrap();
                });
            }
        });
        let spans = buf.drain();
        let local_alltoalls: Vec<_> = spans
            .iter()
            .filter(|s| s.name == "alltoall" && s.ctx.tier == Tier::Local)
            .collect();
        assert_eq!(local_alltoalls.len(), 4);
        // pids are absolute root-world ranks, not sub-world ranks
        let mut pids: Vec<u32> =
            local_alltoalls.iter().map(|s| s.pid).collect();
        pids.sort_unstable();
        assert_eq!(pids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn untraced_world_records_no_spans_but_still_blames() {
        let world = WorldBuilder::new(2).quota(64).build();
        thread::scope(|s| {
            for rank in 0..2 {
                let comm = world.communicator(rank);
                s.spawn(move || {
                    if rank == 1 {
                        thread::sleep(Duration::from_millis(5));
                    }
                    comm.allreduce_min_u64(rank as u64).unwrap();
                });
            }
        });
        let blame = world.blame_report();
        assert!(blame.global[0].waits[1] >= 1);
    }
}
