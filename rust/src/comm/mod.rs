//! Simulated MPI layer: collective spike exchange between ranks that live
//! as OS threads in one address space.
//!
//! Semantics follow the paper's communication scheme (§4.1):
//!
//! * [`Transport::alltoall_into`] — the global exchange.  An explicit
//!   barrier in front of the collective separates *synchronization*
//!   (waiting for the slowest rank) from the *data exchange* proper,
//!   exactly like the instrumentation NEST uses (§4.1).  Spike buffers
//!   grow via the two-round resize protocol: if any rank exceeds the
//!   current quota, all ranks double their buffers and a secondary
//!   exchange round follows.
//! * [`Transport::local_swap_into`] — the structure-aware local pathway:
//!   a rank-local swap of send and receive buffers, no synchronization.
//! * [`SplitTransport::alltoall_start`] / [`PendingExchange::complete`] —
//!   the **split-phase** form of the global exchange ([`nonblocking`]):
//!   the post side deposits into a ring of epoch-stamped mailbox slots
//!   without waiting, and the completion side rendezvous with each
//!   sender's deposit only when the receiver actually needs the data —
//!   or earlier, source by source, through the incremental
//!   [`Pending::try_complete_source`] fast path.  The ring holds up to a
//!   configurable depth of exchanges in flight per rank
//!   ([`WorldBuilder::depth`]); the slack between post and completion —
//!   bounded by the inter-area delay of the spikes on the wire — is
//!   latency-hiding budget: compute of the following cycles runs while
//!   peers catch up.  See the [`nonblocking`] module docs for the ring
//!   protocol, the split-phase quota-resize and the hidden-latency
//!   accounting.
//!
//! # Hierarchical communicators ([`Transport::split`])
//!
//! The paper's hybrid architecture maps every area onto a *group* of
//! compute nodes: the group exchanges its short-range spikes over a
//! **local communicator** every min-delay interval, while the global
//! exchange across areas runs only once per epoch.  [`Transport::split`]
//! is the primitive that builds this hierarchy (the `MPI_Comm_split`
//! shape): a collective call in which every rank passes a `color` and a
//! `key`; ranks sharing a color form one sub-communicator, ranked by
//! `(key, rank)`.  A sub-communicator is a full [`Transport`] (and, for
//! the shared-memory world, a full [`SplitTransport`]) with its **own**
//! barrier, mailboxes, quota, split-phase slot rings and [`CommStats`] —
//! collectives on different sub-communicators never synchronize with
//! each other, and statistics stay attributable per tier
//! ([`World::tiered_stats`] aggregates the children as the *local* tier
//! next to the parent's *global* tier).  Splitting is a cold-path setup
//! operation; the per-cycle hot paths are unchanged.
//!
//! # The [`Transport`] abstraction
//!
//! The engine talks to the communication layer exclusively through the
//! [`Transport`] trait, so the shared-memory [`World`] of this module is
//! one implementation among possible others (a real MPI binding, an
//! RDMA fabric, a loopback test double).  [`Communicator`] — the
//! per-rank handle into a [`World`] — is the first implementor; because
//! [`Transport::split`] yields the implementor's own communicator type
//! ([`Transport::Sub`]), every backend exposes one coherent two-tier
//! API.
//!
//! # Buffer-recycling contract
//!
//! The hot-path entry points take *caller-owned* buffers and never
//! allocate in steady state:
//!
//! * [`Transport::alltoall_into`] drains every `send[d]` into the wire
//!   (leaving it empty but with its capacity intact for refilling) and
//!   overwrites `recv[s]` with the spikes received from source rank `s`.
//!   Internally the shared-memory world *swaps* vectors through the
//!   per-pair mailbox on both the write and the read side, so buffer
//!   capacity circulates sender → mailbox → receiver → sender and after
//!   a warm-up round no exchange allocates.
//! * [`Transport::local_swap_into`] swaps `send` and `recv` (clearing
//!   `recv` first): the received spikes land in `recv`, and `send` comes
//!   back empty with the capacity of the previous receive buffer.
//!
//! Callers must not assume a buffer keeps its identity across calls —
//! only that contents are delivered exactly once and capacity is
//! conserved by the layer as a whole.
//!
//! The transport is shared-memory mailboxes; the *timing* of a real
//! interconnect is modelled separately by `vcluster::interconnect` (the
//! hardware substitution of DESIGN.md §2).

pub mod nonblocking;

pub use nonblocking::{
    CompletionTiming, Pending, PendingExchange, SplitTransport,
};

use crate::network::Gid;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Barrier, Mutex};
use std::time::Instant;

/// One spike on the wire: source neuron and emission cycle.  The paper's
/// spikes carry only the source id; we add the cycle so that lumped
/// epoch-wise delivery of the structure-aware scheme stays explicit (and
/// assertable).  Wire size is accounted as 8 bytes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpikeMsg {
    pub source: Gid,
    pub cycle: u32,
}

pub const SPIKE_WIRE_BYTES: usize = 8;

/// Aggregate communication statistics across all ranks of one
/// communicator.  Every [`World`] — including the sub-worlds produced by
/// [`Transport::split`] — owns its own instance, so exchanges stay
/// attributable to the communicator (and therefore the tier) that
/// carried them.
#[derive(Debug, Default)]
pub struct CommStats {
    pub alltoall_calls: AtomicU64,
    pub local_swaps: AtomicU64,
    pub bytes_sent: AtomicU64,
    pub resize_rounds: AtomicU64,
    pub max_send_per_pair: AtomicUsize,
    /// Barrier wait in front of blocking collectives — the
    /// synchronization share of [`Transport::alltoall_into`].
    pub sync_nanos: AtomicU64,
    /// Split-phase exchanges completed (counted per rank, like
    /// `alltoall_calls`, which also counts them).
    pub overlapped_exchanges: AtomicU64,
    /// Post-side time of split-phase exchanges (depositing; never waits).
    pub post_nanos: AtomicU64,
    /// Completion-side time blocked waiting for missing deposits — the
    /// un-hidden residue of the peers' synchronization skew.
    pub complete_wait_nanos: AtomicU64,
    /// Peer skew that elapsed between post and completion while the rank
    /// was computing — synchronization time moved off the critical path.
    pub hidden_nanos: AtomicU64,
    /// Sources drained *early* through the incremental completion fast
    /// path ([`Pending::try_complete_source`]) — deposits consumed during
    /// the in-flight window instead of at the deadline rendezvous.
    pub early_drained_sources: AtomicU64,
}

/// Point-in-time view of [`CommStats`], with durations in seconds.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CommStatsSnapshot {
    pub alltoall_calls: u64,
    pub local_swaps: u64,
    pub bytes_sent: u64,
    pub resize_rounds: u64,
    pub max_send_per_pair: u64,
    pub overlapped_exchanges: u64,
    pub early_drained_sources: u64,
    /// Barrier wait of blocking collectives (see
    /// [`CommStats::sync_nanos`]).
    pub sync_secs: f64,
    pub post_secs: f64,
    pub complete_wait_secs: f64,
    pub hidden_secs: f64,
}

impl CommStatsSnapshot {
    /// Field-wise combination of two tiers' snapshots: counters and
    /// durations add, the per-pair maximum takes the larger tier.
    pub fn merged(&self, other: &CommStatsSnapshot) -> CommStatsSnapshot {
        CommStatsSnapshot {
            alltoall_calls: self.alltoall_calls + other.alltoall_calls,
            local_swaps: self.local_swaps + other.local_swaps,
            bytes_sent: self.bytes_sent + other.bytes_sent,
            resize_rounds: self.resize_rounds + other.resize_rounds,
            max_send_per_pair: self
                .max_send_per_pair
                .max(other.max_send_per_pair),
            overlapped_exchanges: self.overlapped_exchanges
                + other.overlapped_exchanges,
            early_drained_sources: self.early_drained_sources
                + other.early_drained_sources,
            sync_secs: self.sync_secs + other.sync_secs,
            post_secs: self.post_secs + other.post_secs,
            complete_wait_secs: self.complete_wait_secs
                + other.complete_wait_secs,
            hidden_secs: self.hidden_secs + other.hidden_secs,
        }
    }
}

/// Per-tier communication statistics of a hierarchical run: the parent
/// communicator's traffic (`global`) next to the aggregate of every
/// sub-communicator split off it (`local`).  [`TieredCommStats::combined`]
/// is the flat single-communicator view kept for existing consumers.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TieredCommStats {
    pub global: CommStatsSnapshot,
    pub local: CommStatsSnapshot,
}

impl TieredCommStats {
    pub fn combined(&self) -> CommStatsSnapshot {
        self.global.merged(&self.local)
    }
}

impl CommStats {
    pub fn snapshot(&self) -> CommStatsSnapshot {
        CommStatsSnapshot {
            alltoall_calls: self.alltoall_calls.load(Ordering::Relaxed),
            local_swaps: self.local_swaps.load(Ordering::Relaxed),
            bytes_sent: self.bytes_sent.load(Ordering::Relaxed),
            resize_rounds: self.resize_rounds.load(Ordering::Relaxed),
            max_send_per_pair: self.max_send_per_pair.load(Ordering::Relaxed)
                as u64,
            overlapped_exchanges: self
                .overlapped_exchanges
                .load(Ordering::Relaxed),
            early_drained_sources: self
                .early_drained_sources
                .load(Ordering::Relaxed),
            sync_secs: self.sync_nanos.load(Ordering::Relaxed) as f64 / 1e9,
            post_secs: self.post_nanos.load(Ordering::Relaxed) as f64 / 1e9,
            complete_wait_secs: self.complete_wait_nanos.load(Ordering::Relaxed)
                as f64
                / 1e9,
            hidden_secs: self.hidden_nanos.load(Ordering::Relaxed) as f64 / 1e9,
        }
    }
}

struct WorldInner {
    m: usize,
    barrier: Barrier,
    /// mailboxes[dest][src]
    mailboxes: Vec<Vec<Mutex<Vec<SpikeMsg>>>>,
    /// Current buffer quota in spikes per rank pair (grows on overflow).
    quota: AtomicUsize,
    overflow: AtomicBool,
    /// Split-phase pipeline depth (sub-worlds inherit it on split).
    depth: usize,
    /// Scratch register of [`Transport::allreduce_min_u64`].
    reduce_slot: AtomicU64,
    /// Per-rank `(color, key)` contributions of the in-flight
    /// [`Transport::split`] collective (barrier-framed, cold path).
    split_slots: Mutex<Vec<(u64, u64)>>,
    /// Published outcome of the split: each rank's sub-world and its
    /// rank within it, deposited by rank 0 and taken by the owner.
    split_result: Mutex<Vec<Option<(World, usize)>>>,
    /// Sub-worlds created by [`Transport::split`], kept for per-tier
    /// statistics aggregation ([`World::local_stats`]).
    children: Mutex<Vec<World>>,
    /// Split-phase mailbox state (epoch-stamped ring buffers).
    nb: nonblocking::NbWorld,
    stats: CommStats,
}

/// Shared communication world; build once via [`WorldBuilder`], then
/// [`World::communicator`] per rank thread.
#[derive(Clone)]
pub struct World {
    inner: Arc<WorldInner>,
}

/// The one constructor of [`World`]: number of ranks plus the two tuning
/// knobs that used to be spread over a constructor pair.
///
/// * `quota` — starting spike-buffer size per rank pair (NEST starts
///   small and grows via the two-round resize protocol; tests exercise
///   it with tiny quotas).  Default 1024.
/// * `depth` — split-phase pipeline depth: the mailbox ring holds up to
///   this many exchanges in flight per rank (`2·depth` epoch-stamped
///   slots per (dest, src) pair — see the [`nonblocking`] module docs
///   for why `2·depth` suffices).  Default 1.
///
/// Sub-worlds created by [`Transport::split`] inherit the parent's depth
/// and its *current* quota.
#[derive(Clone, Copy, Debug)]
pub struct WorldBuilder {
    m: usize,
    quota: usize,
    depth: usize,
}

impl WorldBuilder {
    pub fn new(m: usize) -> WorldBuilder {
        WorldBuilder { m, quota: 1024, depth: 1 }
    }

    pub fn quota(mut self, quota: usize) -> WorldBuilder {
        self.quota = quota;
        self
    }

    pub fn depth(mut self, depth: usize) -> WorldBuilder {
        self.depth = depth;
        self
    }

    pub fn build(self) -> World {
        let WorldBuilder { m, quota, depth } = self;
        assert!(m >= 1);
        assert!(depth >= 1, "pipeline depth must be >= 1");
        let mailboxes = (0..m)
            .map(|_| (0..m).map(|_| Mutex::new(Vec::new())).collect())
            .collect();
        World {
            inner: Arc::new(WorldInner {
                m,
                barrier: Barrier::new(m),
                mailboxes,
                quota: AtomicUsize::new(quota.max(1)),
                overflow: AtomicBool::new(false),
                depth,
                reduce_slot: AtomicU64::new(u64::MAX),
                split_slots: Mutex::new(vec![(0, 0); m]),
                split_result: Mutex::new((0..m).map(|_| None).collect()),
                children: Mutex::new(Vec::new()),
                nb: nonblocking::NbWorld::new(m, depth),
                stats: CommStats::default(),
            }),
        }
    }
}

impl World {
    pub fn communicator(&self, rank: usize) -> Communicator {
        assert!(rank < self.inner.m);
        Communicator { world: self.inner.clone(), rank }
    }

    pub fn m_ranks(&self) -> usize {
        self.inner.m
    }

    pub fn stats(&self) -> &CommStats {
        &self.inner.stats
    }

    /// Aggregate statistics of every sub-communicator split off this
    /// world (recursively) — the *local* tier of a hierarchical run.
    /// Empty-default when no split ever happened.
    pub fn local_stats(&self) -> CommStatsSnapshot {
        let children = self.inner.children.lock().unwrap();
        children.iter().fold(CommStatsSnapshot::default(), |acc, c| {
            acc.merged(&c.stats().snapshot()).merged(&c.local_stats())
        })
    }

    /// Per-tier view: this world's own traffic as the *global* tier,
    /// the aggregated sub-communicators as the *local* tier.
    pub fn tiered_stats(&self) -> TieredCommStats {
        TieredCommStats {
            global: self.stats().snapshot(),
            local: self.local_stats(),
        }
    }

    pub fn current_quota(&self) -> usize {
        self.inner.quota.load(Ordering::Relaxed)
    }
}

/// Per-rank handle into the [`World`].
pub struct Communicator {
    world: Arc<WorldInner>,
    rank: usize,
}

/// Per-rank view of a communication fabric: the collective global
/// exchange and the rank-local pathway, with recycled buffers (see the
/// module docs for the buffer-recycling contract).
pub trait Transport {
    /// Communicator type produced by [`Transport::split`].  The
    /// shared-memory world splits into further shared-memory worlds; an
    /// MPI binding would split into MPI sub-communicators.
    type Sub: Transport;

    /// This rank's id within the world.
    fn rank(&self) -> usize;

    /// Number of ranks in the world.
    fn m_ranks(&self) -> usize;

    /// Collective communicator split, the `MPI_Comm_split` shape: every
    /// rank calls `split` concurrently; ranks passing the same `color`
    /// form one sub-communicator, with ranks assigned in ascending
    /// `(key, rank)` order.  The sub-communicator is fully independent
    /// of its parent — own barrier, own mailboxes and quota, own
    /// statistics — so collectives on disjoint groups never synchronize
    /// with each other.  Cold path (setup only): the engine splits once
    /// to build the per-area-group local tier.
    fn split(&self, color: u64, key: u64) -> Self::Sub;

    /// Collective all-to-all spike exchange.  `send[d]` is the buffer
    /// destined for rank `d` (must have length M) and is drained by the
    /// call; `recv` is resized to M slots and `recv[s]` is overwritten
    /// with the spikes from source rank `s` (per-source order
    /// preserved).  Returns the timing split into synchronization and
    /// data-exchange parts.
    ///
    /// All ranks must call this the same number of times (collective
    /// semantics); mismatch deadlocks, as real MPI would.
    fn alltoall_into(
        &self,
        send: &mut [Vec<SpikeMsg>],
        recv: &mut Vec<Vec<SpikeMsg>>,
    ) -> ExchangeTiming;

    /// Rank-local exchange of the structure-aware short-range pathway:
    /// `recv` is cleared and swapped with `send`, so the sent spikes
    /// come back in `recv` and `send` is left empty (capacity
    /// recycled).  No synchronization with other ranks.
    fn local_swap_into(
        &self,
        send: &mut Vec<SpikeMsg>,
        recv: &mut Vec<SpikeMsg>,
    );

    /// Control-plane collective: the minimum of `v` over all ranks (an
    /// `MPI_Allreduce(MIN)`).  Cold path — used to agree on run-wide
    /// parameters derived from rank-local state (e.g. the sustainable
    /// split-phase pipeline depth), so it deliberately stays off the
    /// spike-statistics counters.  Collective semantics: every rank must
    /// call it the same number of times.
    fn allreduce_min_u64(&self, v: u64) -> u64;

    /// Allocating convenience wrapper around [`Transport::alltoall_into`]
    /// for cold paths (setup exchanges, tests).
    fn alltoall(
        &self,
        send: &mut [Vec<SpikeMsg>],
    ) -> (Vec<Vec<SpikeMsg>>, ExchangeTiming) {
        let mut recv = Vec::new();
        let timing = self.alltoall_into(send, &mut recv);
        (recv, timing)
    }

    /// Allocating convenience wrapper around
    /// [`Transport::local_swap_into`].
    fn local_swap(&self, send: &mut Vec<SpikeMsg>) -> Vec<SpikeMsg> {
        let mut recv = Vec::new();
        self.local_swap_into(send, &mut recv);
        recv
    }
}

/// Timing of one collective call, in seconds of real wall clock.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExchangeTiming {
    /// Waiting at the barrier in front of the collective.
    pub sync_secs: f64,
    /// The data exchange itself (write + release + read).
    pub data_secs: f64,
}

impl Transport for Communicator {
    type Sub = Communicator;

    fn rank(&self) -> usize {
        self.rank
    }

    fn m_ranks(&self) -> usize {
        self.world.m
    }

    fn split(&self, color: u64, key: u64) -> Communicator {
        let w = &*self.world;
        // barrier-framed register protocol (cold path).  Frame start:
        // nobody can deposit into `split_slots` while a straggler of the
        // previous collective is still inside it.
        w.barrier.wait();
        w.split_slots.lock().unwrap()[self.rank] = (color, key);
        w.barrier.wait();
        // every contribution is visible; rank 0 materializes one
        // sub-world per color (they must be *shared*, so a single rank
        // creates them) and publishes each rank's handle + sub-rank
        if self.rank == 0 {
            let slots = w.split_slots.lock().unwrap().clone();
            let mut groups: std::collections::BTreeMap<u64, Vec<usize>> =
                std::collections::BTreeMap::new();
            for (rank, &(c, _)) in slots.iter().enumerate() {
                groups.entry(c).or_default().push(rank);
            }
            let mut results = w.split_result.lock().unwrap();
            let mut children = w.children.lock().unwrap();
            for mut members in groups.into_values() {
                members.sort_by_key(|&r| (slots[r].1, r));
                let sub = WorldBuilder::new(members.len())
                    .quota(w.quota.load(Ordering::Relaxed))
                    .depth(w.depth)
                    .build();
                children.push(sub.clone());
                for (sub_rank, &r) in members.iter().enumerate() {
                    results[r] = Some((sub.clone(), sub_rank));
                }
            }
        }
        w.barrier.wait();
        // each rank takes exactly its own entry; re-entry into the next
        // collective's first barrier implies every entry was taken, so
        // the register is reusable without a fourth barrier
        let (sub, sub_rank) = w.split_result.lock().unwrap()[self.rank]
            .take()
            .expect("split result not published");
        sub.communicator(sub_rank)
    }

    fn alltoall_into(
        &self,
        send: &mut [Vec<SpikeMsg>],
        recv: &mut Vec<Vec<SpikeMsg>>,
    ) -> ExchangeTiming {
        assert_eq!(send.len(), self.world.m, "send buffer per rank required");
        let w = &*self.world;

        // --- synchronization: explicit barrier in front of the collective
        let t0 = Instant::now();
        w.barrier.wait();
        let t1 = Instant::now();
        let sync_secs = (t1 - t0).as_secs_f64();
        w.stats
            .sync_nanos
            .fetch_add((sync_secs * 1e9) as u64, Ordering::Relaxed);

        // --- overflow detection (two-round resize protocol)
        let quota = w.quota.load(Ordering::Relaxed);
        let my_max = send.iter().map(|v| v.len()).max().unwrap_or(0);
        if my_max > quota {
            w.overflow.store(true, Ordering::Relaxed);
        }
        w.stats
            .max_send_per_pair
            .fetch_max(my_max, Ordering::Relaxed);
        w.barrier.wait();
        // after the barrier every rank observes the same flag; the reset
        // happens strictly between two further barriers so no rank can
        // read a half-updated flag (all ranks take the same branch)
        let need_resize = w.overflow.load(Ordering::Relaxed);
        if need_resize {
            // every rank grows its buffers until the largest message fits,
            // then a secondary exchange round follows (paper §4.1)
            w.barrier.wait();
            if self.rank == 0 {
                let mut q = w.quota.load(Ordering::Relaxed);
                let need = w.stats.max_send_per_pair.load(Ordering::Relaxed);
                while q < need {
                    q *= 2;
                }
                w.quota.store(q, Ordering::Relaxed);
                w.overflow.store(false, Ordering::Relaxed);
                w.stats.resize_rounds.fetch_add(1, Ordering::Relaxed);
            }
            w.barrier.wait();
        }

        // --- data exchange: write own column, then read own row.  Both
        // sides *swap* with the mailbox slot, so the sender's drained
        // buffer and the receiver's previous buffer circulate instead of
        // being dropped and reallocated (see module docs).
        let mut bytes = 0usize;
        for (dest, buf) in send.iter_mut().enumerate() {
            bytes += buf.len() * SPIKE_WIRE_BYTES;
            let mut slot = w.mailboxes[dest][self.rank].lock().unwrap();
            debug_assert!(slot.is_empty(), "mailbox not drained");
            std::mem::swap(&mut *slot, buf);
        }
        w.stats
            .bytes_sent
            .fetch_add(bytes as u64, Ordering::Relaxed);
        w.barrier.wait();
        recv.resize_with(w.m, Vec::new);
        for (src, out) in recv.iter_mut().enumerate() {
            out.clear();
            let mut slot = w.mailboxes[self.rank][src].lock().unwrap();
            std::mem::swap(&mut *slot, out);
        }
        w.stats.alltoall_calls.fetch_add(1, Ordering::Relaxed);
        // final barrier so nobody races ahead into the next call's writes
        w.barrier.wait();
        let data_secs = t1.elapsed().as_secs_f64();
        ExchangeTiming { sync_secs, data_secs }
    }

    fn local_swap_into(
        &self,
        send: &mut Vec<SpikeMsg>,
        recv: &mut Vec<SpikeMsg>,
    ) {
        self.world.stats.local_swaps.fetch_add(1, Ordering::Relaxed);
        recv.clear();
        std::mem::swap(send, recv);
    }

    fn allreduce_min_u64(&self, v: u64) -> u64 {
        let w = &*self.world;
        // barrier-framed register protocol: no rank can still be reading
        // the previous reduction when rank 0 resets (it could not have
        // reached this call's first barrier otherwise), and no rank can
        // read before every contribution landed
        w.barrier.wait();
        if self.rank == 0 {
            w.reduce_slot.store(u64::MAX, Ordering::Relaxed);
        }
        w.barrier.wait();
        w.reduce_slot.fetch_min(v, Ordering::Relaxed);
        w.barrier.wait();
        w.reduce_slot.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn msg(source: Gid, cycle: u32) -> SpikeMsg {
        SpikeMsg { source, cycle }
    }

    /// Run `f(rank, comm)` on m rank threads, collect results by rank.
    fn run_ranks<F, R>(m: usize, quota: usize, f: F) -> Vec<R>
    where
        F: Fn(usize, Communicator) -> R + Send + Sync,
        R: Send,
    {
        let world = WorldBuilder::new(m).quota(quota).build();
        thread::scope(|s| {
            let handles: Vec<_> = (0..m)
                .map(|rank| {
                    let comm = world.communicator(rank);
                    let f = &f;
                    s.spawn(move || f(rank, comm))
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }

    #[test]
    fn alltoall_routes_messages() {
        let results = run_ranks(4, 64, |rank, comm| {
            // rank r sends spike (source=100*r + d) to each dest d
            let mut send: Vec<Vec<SpikeMsg>> = (0..4)
                .map(|d| vec![msg((100 * rank + d) as Gid, 7)])
                .collect();
            let (recv, _) = comm.alltoall(&mut send);
            recv
        });
        for (rank, recv) in results.iter().enumerate() {
            assert_eq!(recv.len(), 4);
            for (src, buf) in recv.iter().enumerate() {
                assert_eq!(buf.len(), 1);
                assert_eq!(buf[0].source, (100 * src + rank) as Gid);
                assert_eq!(buf[0].cycle, 7);
            }
        }
    }

    #[test]
    fn alltoall_preserves_per_source_order() {
        let results = run_ranks(2, 64, |rank, comm| {
            let mut send: Vec<Vec<SpikeMsg>> = (0..2)
                .map(|_| (0..10).map(|i| msg(rank as Gid, i)).collect())
                .collect();
            let (recv, _) = comm.alltoall(&mut send);
            recv
        });
        for recv in &results {
            // per source rank, cycles ascend
            for (src, buf) in recv.iter().enumerate() {
                let cycles: Vec<u32> = buf.iter().map(|m| m.cycle).collect();
                assert_eq!(cycles, (0..10).collect::<Vec<_>>());
                assert!(buf.iter().all(|m| m.source == src as Gid));
            }
        }
    }

    #[test]
    fn repeated_rounds_do_not_leak() {
        let results = run_ranks(3, 64, |rank, comm| {
            let mut total = 0usize;
            for round in 0..5u32 {
                let mut send: Vec<Vec<SpikeMsg>> = (0..3)
                    .map(|_| vec![msg(rank as Gid, round)])
                    .collect();
                let (recv, _) = comm.alltoall(&mut send);
                assert!(recv
                    .iter()
                    .flatten()
                    .all(|m| m.cycle == round));
                total += recv.iter().map(|b| b.len()).sum::<usize>();
            }
            total
        });
        assert!(results.iter().all(|&t| t == 15));
    }

    #[test]
    fn overflow_triggers_resize_round() {
        let world = WorldBuilder::new(2).quota(4).build();
        let w2 = world.clone();
        thread::scope(|s| {
            for rank in 0..2 {
                let comm = world.communicator(rank);
                s.spawn(move || {
                    // rank 0 sends 10 spikes/pair, above the quota of 4
                    let n = if rank == 0 { 10 } else { 1 };
                    let mut send: Vec<Vec<SpikeMsg>> = (0..2)
                        .map(|_| (0..n).map(|i| msg(rank as Gid, i)).collect())
                        .collect();
                    let (recv, _) = comm.alltoall(&mut send);
                    let n: usize = recv.iter().map(|b| b.len()).sum();
                    assert_eq!(n, 10 + 1);
                });
            }
        });
        let snap = w2.stats().snapshot();
        assert_eq!(snap.resize_rounds, 1);
        assert_eq!(
            snap.max_send_per_pair, 10,
            "largest per-pair send not tracked"
        );
        assert!(w2.current_quota() >= 10);
    }

    #[test]
    fn local_swap_returns_buffer_without_barrier() {
        let world = WorldBuilder::new(1).quota(4).build();
        let comm = world.communicator(0);
        let mut send = vec![msg(1, 2), msg(3, 4)];
        let recv = comm.local_swap(&mut send);
        assert_eq!(recv, vec![msg(1, 2), msg(3, 4)]);
        assert!(send.is_empty());
        let snap = world.stats().snapshot();
        assert_eq!(snap.alltoall_calls, 0);
        assert_eq!(snap.local_swaps, 1);
        // local swaps bypass the global exchange: no per-pair maximum
        assert_eq!(snap.max_send_per_pair, 0);
    }

    #[test]
    fn stats_count_bytes() {
        let world = WorldBuilder::new(2).quota(64).build();
        thread::scope(|s| {
            for rank in 0..2 {
                let comm = world.communicator(rank);
                s.spawn(move || {
                    let mut send: Vec<Vec<SpikeMsg>> = (0..2)
                        .map(|_| vec![msg(rank as Gid, 0); 3])
                        .collect();
                    comm.alltoall(&mut send);
                });
            }
        });
        let snap = world.stats().snapshot();
        assert_eq!(snap.alltoall_calls, 2);
        // 2 ranks x 2 dests x 3 spikes x 8 bytes
        assert_eq!(snap.bytes_sent, 96);
        assert_eq!(snap.max_send_per_pair, 3);
        // no split-phase traffic in a blocking-only run
        assert_eq!(snap.overlapped_exchanges, 0);
        assert_eq!(snap.hidden_secs, 0.0);
    }

    #[test]
    fn recycled_buffers_many_rounds_stress() {
        // One pair of send/recv buffer sets per rank, recycled over 50
        // rounds of varying fan-out, with one round (20) deliberately
        // overflowing the quota of 4 to trigger the two-round resize
        // protocol mid-stream.  No spike may leak across rounds.
        const M: usize = 3;
        let per_round = |round: u32| -> usize {
            if round == 20 {
                9
            } else {
                1 + (round as usize % 3)
            }
        };
        let world = WorldBuilder::new(M).quota(4).build();
        let w2 = world.clone();
        let results = thread::scope(|s| {
            let handles: Vec<_> = (0..M)
                .map(|rank| {
                    let comm = world.communicator(rank);
                    s.spawn(move || {
                        let mut send: Vec<Vec<SpikeMsg>> =
                            (0..M).map(|_| Vec::new()).collect();
                        let mut recv: Vec<Vec<SpikeMsg>> = Vec::new();
                        let mut total = 0usize;
                        for round in 0..50u32 {
                            let n = per_round(round);
                            for buf in &mut send {
                                for i in 0..n {
                                    buf.push(msg(
                                        (1000 * rank + i) as Gid,
                                        round,
                                    ));
                                }
                            }
                            comm.alltoall_into(&mut send, &mut recv);
                            assert!(
                                send.iter().all(|b| b.is_empty()),
                                "send not drained in round {round}"
                            );
                            for (src, buf) in recv.iter().enumerate() {
                                assert_eq!(
                                    buf.len(),
                                    n,
                                    "round {round} from rank {src}"
                                );
                                assert!(
                                    buf.iter().all(|m| m.cycle == round),
                                    "stale spikes leaked into round {round}"
                                );
                                assert!(buf
                                    .iter()
                                    .all(|m| m.source / 1000
                                        == src as Gid));
                            }
                            total +=
                                recv.iter().map(|b| b.len()).sum::<usize>();
                        }
                        total
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect::<Vec<_>>()
        });
        let expect: usize =
            (0..50u32).map(|r| per_round(r) * M).sum();
        assert!(results.iter().all(|&t| t == expect), "{results:?}");
        let snap = w2.stats().snapshot();
        assert_eq!(snap.alltoall_calls, 50 * M as u64);
        assert_eq!(
            snap.resize_rounds, 1,
            "overflow round must resize exactly once"
        );
        assert_eq!(
            snap.max_send_per_pair, 9,
            "per-pair maximum is the overflow round"
        );
        assert!(w2.current_quota() >= 9);
    }

    #[test]
    fn alltoall_into_reuses_buffer_capacity() {
        // With swap-based recycling, buffer capacity circulates between
        // the send buffer, the mailbox slot and the receive buffer; once
        // all three are warm no round allocates, so capacities stay put.
        let world = WorldBuilder::new(1).quota(64).build();
        let comm = world.communicator(0);
        let mut send = vec![Vec::new()];
        let mut recv: Vec<Vec<SpikeMsg>> = Vec::new();
        let mut fill_and_exchange = |send: &mut Vec<Vec<SpikeMsg>>,
                                     recv: &mut Vec<Vec<SpikeMsg>>,
                                     round: u32| {
            for i in 0..32 {
                send[0].push(msg(i, round));
            }
            comm.alltoall_into(send, recv);
            assert_eq!(recv[0].len(), 32);
            assert!(recv[0].iter().all(|m| m.cycle == round));
        };
        for round in 0..10 {
            fill_and_exchange(&mut send, &mut recv, round);
        }
        let warm = (send[0].capacity(), recv[0].capacity());
        assert!(warm.0 >= 32 && warm.1 >= 32, "{warm:?}");
        for round in 10..40 {
            fill_and_exchange(&mut send, &mut recv, round);
        }
        assert_eq!(
            (send[0].capacity(), recv[0].capacity()),
            warm,
            "buffer recycling regressed to per-round allocation"
        );
    }

    #[test]
    fn local_swap_into_recycles_capacity() {
        let world = WorldBuilder::new(1).quota(4).build();
        let comm = world.communicator(0);
        let mut send = Vec::new();
        let mut recv = Vec::new();
        for round in 0..20u32 {
            for i in 0..16 {
                send.push(msg(i, round));
            }
            comm.local_swap_into(&mut send, &mut recv);
            assert_eq!(recv.len(), 16);
            assert!(recv.iter().all(|m| m.cycle == round));
            assert!(send.is_empty());
        }
        // the two buffers ping-pong; both hold capacity after warm-up
        assert!(send.capacity() >= 16 && recv.capacity() >= 16);
    }

    #[test]
    fn allreduce_min_agrees_across_ranks_and_rounds() {
        let results = run_ranks(4, 64, |rank, comm| {
            // round 1: min of (10 + rank); round 2: min of (100 - rank).
            // Back-to-back calls exercise the register-reset framing.
            let a = comm.allreduce_min_u64(10 + rank as u64);
            let b = comm.allreduce_min_u64(100 - rank as u64);
            (a, b)
        });
        assert!(results.iter().all(|&(a, b)| a == 10 && b == 97));
    }

    #[test]
    fn allreduce_min_does_not_touch_spike_stats() {
        let world = WorldBuilder::new(2).quota(64).build();
        thread::scope(|s| {
            for rank in 0..2 {
                let comm = world.communicator(rank);
                s.spawn(move || comm.allreduce_min_u64(rank as u64));
            }
        });
        let snap = world.stats().snapshot();
        assert_eq!(snap.alltoall_calls, 0);
        assert_eq!(snap.bytes_sent, 0);
    }

    #[test]
    fn split_isolates_disjoint_groups() {
        // colors [0,0,1,1]: two groups of two; intra-group alltoalls
        // carry group-tagged payloads and must never leak across groups,
        // while the parent world's own counters stay untouched (tier
        // attribution)
        let world = WorldBuilder::new(4).quota(64).build();
        let results = thread::scope(|s| {
            let handles: Vec<_> = (0..4)
                .map(|rank| {
                    let comm = world.communicator(rank);
                    s.spawn(move || {
                        let color = (rank / 2) as u64;
                        let local = comm.split(color, rank as u64);
                        assert_eq!(local.m_ranks(), 2);
                        assert_eq!(local.rank(), rank % 2);
                        let mut send: Vec<Vec<SpikeMsg>> = (0..2)
                            .map(|_| {
                                vec![msg((100 * rank) as Gid, color as u32)]
                            })
                            .collect();
                        let (recv, _) = local.alltoall(&mut send);
                        recv
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect::<Vec<_>>()
        });
        for (rank, recv) in results.iter().enumerate() {
            let group = rank / 2;
            assert_eq!(recv.len(), 2);
            for (src_local, buf) in recv.iter().enumerate() {
                assert_eq!(buf.len(), 1);
                // the source is the group-mate, never a foreign rank
                assert_eq!(
                    buf[0].source,
                    (100 * (group * 2 + src_local)) as Gid
                );
                assert_eq!(buf[0].cycle, group as u32, "cross-group leak");
            }
        }
        let tiers = world.tiered_stats();
        assert_eq!(tiers.global.alltoall_calls, 0);
        assert_eq!(tiers.global.bytes_sent, 0);
        assert_eq!(tiers.local.alltoall_calls, 4);
        assert_eq!(
            tiers.local.bytes_sent,
            4 * 2 * SPIKE_WIRE_BYTES as u64
        );
        assert_eq!(tiers.combined().alltoall_calls, 4);
    }

    #[test]
    fn split_stats_attributed_per_tier() {
        // each rank exchanges on both tiers: parent counters carry the
        // global traffic, children the local tier, and the combined view
        // sums both for flat consumers
        let world = WorldBuilder::new(2).quota(64).build();
        thread::scope(|s| {
            for rank in 0..2 {
                let comm = world.communicator(rank);
                s.spawn(move || {
                    let local = comm.split(0, rank as u64);
                    let mut send: Vec<Vec<SpikeMsg>> =
                        (0..2).map(|_| vec![msg(rank as Gid, 1)]).collect();
                    local.alltoall(&mut send);
                    let mut lsend = vec![msg(rank as Gid, 2)];
                    let mut lrecv = Vec::new();
                    local.local_swap_into(&mut lsend, &mut lrecv);
                    let mut send: Vec<Vec<SpikeMsg>> = (0..2)
                        .map(|_| vec![msg(rank as Gid, 3); 2])
                        .collect();
                    comm.alltoall(&mut send);
                });
            }
        });
        let tiers = world.tiered_stats();
        assert_eq!(tiers.local.alltoall_calls, 2);
        assert_eq!(tiers.local.local_swaps, 2);
        assert_eq!(
            tiers.local.bytes_sent,
            2 * 2 * SPIKE_WIRE_BYTES as u64
        );
        assert_eq!(tiers.global.alltoall_calls, 2);
        assert_eq!(tiers.global.local_swaps, 0);
        assert_eq!(
            tiers.global.bytes_sent,
            2 * 2 * 2 * SPIKE_WIRE_BYTES as u64
        );
        let combined = tiers.combined();
        assert_eq!(combined.alltoall_calls, 4);
        assert_eq!(combined.local_swaps, 2);
        assert_eq!(
            combined.bytes_sent,
            tiers.local.bytes_sent + tiers.global.bytes_sent
        );
        assert!(combined.sync_secs >= tiers.global.sync_secs);
    }

    #[test]
    fn split_orders_ranks_by_key_then_rank() {
        // MPI_Comm_split semantics: descending keys reverse the
        // sub-ranks
        let results = run_ranks(3, 64, |rank, comm| {
            let local = comm.split(7, (10 - rank) as u64);
            (local.rank(), local.m_ranks())
        });
        assert_eq!(results, vec![(2, 3), (1, 3), (0, 3)]);
    }

    #[test]
    fn split_singleton_groups_degenerate() {
        // every rank its own color: 1-rank sub-worlds whose collectives
        // are self-delivery — the degenerate form the engine uses at
        // ranks_per_area = 1
        let world = WorldBuilder::new(3).quota(64).build();
        thread::scope(|s| {
            for rank in 0..3 {
                let comm = world.communicator(rank);
                s.spawn(move || {
                    let local = comm.split(rank as u64, 0);
                    assert_eq!(local.m_ranks(), 1);
                    assert_eq!(local.rank(), 0);
                    let mut send = vec![vec![msg(rank as Gid, 5)]];
                    let (recv, _) = local.alltoall(&mut send);
                    assert_eq!(recv[0], vec![msg(rank as Gid, 5)]);
                    let mut lsend = vec![msg(rank as Gid, 6)];
                    let recv = local.local_swap(&mut lsend);
                    assert_eq!(recv, vec![msg(rank as Gid, 6)]);
                });
            }
        });
        let tiers = world.tiered_stats();
        assert_eq!(tiers.local.alltoall_calls, 3);
        assert_eq!(tiers.local.local_swaps, 3);
        assert_eq!(tiers.global.alltoall_calls, 0);
        assert_eq!(tiers.global.local_swaps, 0);
    }

    #[test]
    fn repeated_and_nested_splits() {
        // the barrier-framed register survives back-to-back splits, and
        // a sub-communicator can itself be split (grandchildren roll up
        // recursively into the parent's local tier)
        let world = WorldBuilder::new(4).quota(64).build();
        thread::scope(|s| {
            for rank in 0..4 {
                let comm = world.communicator(rank);
                s.spawn(move || {
                    let a = comm.split((rank % 2) as u64, rank as u64);
                    assert_eq!(a.m_ranks(), 2);
                    let b = comm.split((rank / 2) as u64, rank as u64);
                    assert_eq!(b.m_ranks(), 2);
                    let c = b.split(b.rank() as u64, 0);
                    assert_eq!(c.m_ranks(), 1);
                    let mut send = vec![vec![msg(rank as Gid, 9)]];
                    let (recv, _) = c.alltoall(&mut send);
                    assert_eq!(recv[0].len(), 1);
                });
            }
        });
        assert_eq!(world.local_stats().alltoall_calls, 4);
        assert_eq!(world.stats().snapshot().alltoall_calls, 0);
    }

    #[test]
    fn split_inherits_grown_quota() {
        // the resize protocol grows the parent quota before the split;
        // the sub-world must start from the grown value (no secondary
        // resize on the local tier for the same message size)
        let world = WorldBuilder::new(2).quota(4).build();
        thread::scope(|s| {
            for rank in 0..2 {
                let comm = world.communicator(rank);
                s.spawn(move || {
                    let mut send: Vec<Vec<SpikeMsg>> = (0..2)
                        .map(|_| {
                            (0..10).map(|i| msg(rank as Gid, i)).collect()
                        })
                        .collect();
                    comm.alltoall(&mut send);
                    let local = comm.split(0, rank as u64);
                    let mut send: Vec<Vec<SpikeMsg>> = (0..2)
                        .map(|_| {
                            (0..10).map(|i| msg(rank as Gid, i)).collect()
                        })
                        .collect();
                    local.alltoall(&mut send);
                });
            }
        });
        assert!(world.current_quota() >= 10);
        let tiers = world.tiered_stats();
        assert_eq!(tiers.global.resize_rounds, 1);
        assert_eq!(
            tiers.local.resize_rounds, 0,
            "sub-world must inherit the grown quota"
        );
    }

    #[test]
    fn timing_fields_populated() {
        let results = run_ranks(2, 64, |rank, comm| {
            // rank 1 works longer before the barrier -> rank 0 waits
            if rank == 1 {
                std::hint::black_box(
                    (0..2_000_000u64).map(|x| x.wrapping_mul(7)).sum::<u64>(),
                );
            }
            let mut send: Vec<Vec<SpikeMsg>> =
                (0..2).map(|_| Vec::new()).collect();
            let (_, timing) = comm.alltoall(&mut send);
            timing
        });
        for t in &results {
            assert!(t.sync_secs >= 0.0);
            assert!(t.data_secs >= 0.0);
        }
    }
}
