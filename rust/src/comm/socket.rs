//! Multi-process transport: one OS process per rank over Unix-domain
//! sockets — the pure-Rust stand-in for an MPI backend (no MPI
//! toolchain required).
//!
//! [`SocketComm`] implements the full [`Transport`] + [`SplitTransport`]
//! surface of the shared-memory [`super::World`]:
//!
//! * the blocking [`Transport::alltoall_into`] with an explicit barrier
//!   frame separating synchronization from the data exchange, exactly
//!   like the shared-memory protocol;
//! * the split-phase [`SplitTransport::alltoall_start`] /
//!   [`Pending::complete`] pipeline with epoch-stamped rounds and the
//!   incremental [`Pending::try_complete_source`] fast path;
//! * collective [`Transport::split`] sub-communicators and
//!   [`Transport::allreduce_min_u64`];
//! * the quota-resize protocol (advisory over sockets — no shared
//!   buffers to grow — but tracked deterministically so `quota()` and
//!   the resize statistics agree with the shared-memory world);
//! * typed [`CommError::Timeout`] / [`CommError::Poisoned`] so the
//!   engine's comm watchdogs and fault injection keep working: a dead
//!   peer process closes its sockets, the reader thread observes the
//!   EOF, and every wait still needing that peer fails *immediately*
//!   with a watchdog diagnostic naming it — no need to sit out the
//!   full deadline.
//!
//! # Wire format
//!
//! Every frame is a little-endian header followed by a raw payload:
//!
//! ```text
//! comm: u64 | kind: u8 | seq: u64 | arg: u64 | len: u32 | payload
//! ```
//!
//! `comm` routes the frame to a communicator (sub-communicators from
//! `split` share the socket mesh under ids derived deterministically on
//! every member — see [`child_comm_id`]); `seq` is the per-communicator
//! per-kind sequence number (barrier generation, reduce round, exchange
//! epoch); `arg` carries the kind-specific scalar (reduce value, the
//! sender's per-destination maximum for data frames — the input of the
//! deterministic quota settle — or the split color).  Spike payloads
//! are [`SPIKE_WIRE_BYTES`] bytes per spike: `source: u32 | cycle: u32`.
//!
//! # Rendezvous
//!
//! Rank `r` binds `<dir>/rank<r>.sock`, dials every lower rank
//! (retrying until the peer's listener appears) and accepts every
//! higher rank; an 8-byte hello carrying the absolute rank identifies
//! each accepted connection.  One detached reader thread per peer
//! demultiplexes incoming frames into per-communicator inboxes keyed by
//! `(kind, seq)`; a frame for a communicator this process has not
//! created yet simply creates the inbox — `split` needs no extra
//! synchronization for early-arriving sub-communicator traffic.
//!
//! # Slot-ring safety over sockets
//!
//! The shared-memory world recycles `2·depth` preallocated ring slots
//! per (dest, src) pair; posting exchange `k` is safe because the slot
//! occupant `k − 2·depth` is provably history.  Over sockets the ring
//! becomes seq-keyed inbox entries, and the same flight bound does the
//! work: a rank posts at most `depth` exchanges ahead of its oldest
//! incomplete round, so at most `2·depth` rounds per source can be
//! resident before the receiver drains them — the memory bound carries
//! over even though no slot is ever literally reused.  Stream order
//! (per-connection FIFO) preserves the per-source spike order the
//! deterministic merge relies on.

use super::{
    CommError, CommStats, CompletionTiming, ExchangeTiming, Pending,
    SpikeMsg, SplitTransport, TieredCommStats, Transport,
    SPIKE_WIRE_BYTES,
};
use crate::obs::blame::{Blame, TieredBlame};
use anyhow::{bail, Context as _, Result as AnyResult};
use std::collections::HashMap;
use std::io::{ErrorKind, Read, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

const KIND_BARRIER: u8 = 1;
const KIND_REDUCE: u8 = 2;
const KIND_DATA: u8 = 3;
const KIND_NB_DATA: u8 = 4;
const KIND_SPLIT: u8 = 5;

/// `comm u64 | kind u8 | seq u64 | arg u64 | len u32`.
const HEADER_BYTES: usize = 29;

/// Communicator id of the root world ("nsimroot" in ASCII); children
/// derive theirs via [`child_comm_id`].
const ROOT_COMM_ID: u64 = 0x6e73_696d_726f_6f74;

/// Deterministic sub-communicator id: FNV-1a over (parent id, split
/// sequence number, color).  Every member of the group computes the
/// same id from the same collective inputs, so frames route without a
/// registration round-trip.
fn child_comm_id(parent: u64, seq: u64, color: u64) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in parent
        .to_le_bytes()
        .into_iter()
        .chain(seq.to_le_bytes())
        .chain(color.to_le_bytes())
    {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// One received data frame, parked until the owning collective drains
/// it.  `arrived` feeds the hidden-latency accounting (the socket
/// analogue of the mailbox deposit timestamp).
struct DataFrame {
    /// Absolute (mesh) rank of the sender.
    src: usize,
    /// The sender's per-destination maximum this round (quota input).
    max_per_pair: u64,
    spikes: Vec<SpikeMsg>,
    arrived: Instant,
}

/// Per-communicator inbox: frames keyed by `(kind, seq)`, each entry in
/// arrival order (the last element of a completed gather is the
/// straggler the blame ledger charges).
#[derive(Default)]
struct Inbox {
    barrier: HashMap<u64, Vec<usize>>,
    reduce: HashMap<u64, Vec<(usize, u64)>>,
    data: HashMap<u64, Vec<DataFrame>>,
    nb: HashMap<u64, Vec<DataFrame>>,
    /// `(abs rank, color, key)` registrations of a split round.
    split: HashMap<u64, Vec<(usize, u64, u64)>>,
}

struct DemuxState {
    /// Peers whose connection hit EOF or an I/O error — a dead process.
    dead: Vec<bool>,
    comms: HashMap<u64, Inbox>,
}

/// The piece the reader threads share: deliberately *not* the whole
/// [`Mesh`], so dropping the mesh (which shuts the sockets down) is
/// what terminates the readers rather than the other way around.
struct DemuxShared {
    state: Mutex<DemuxState>,
    cv: Condvar,
}

fn decode_spikes(payload: &[u8]) -> Vec<SpikeMsg> {
    let mut v = Vec::with_capacity(payload.len() / SPIKE_WIRE_BYTES);
    for c in payload.chunks_exact(SPIKE_WIRE_BYTES) {
        v.push(SpikeMsg {
            source: u32::from_le_bytes(c[0..4].try_into().unwrap()),
            cycle: u32::from_le_bytes(c[4..8].try_into().unwrap()),
        });
    }
    v
}

/// Per-peer reader: demultiplex frames into the inboxes until the
/// connection dies, then mark the peer dead and wake every waiter so
/// pending gathers can fail with a diagnostic naming it.
fn reader_loop(
    shared: Arc<DemuxShared>,
    mut stream: UnixStream,
    peer: usize,
) {
    loop {
        let mut hdr = [0u8; HEADER_BYTES];
        if stream.read_exact(&mut hdr).is_err() {
            break;
        }
        let comm = u64::from_le_bytes(hdr[0..8].try_into().unwrap());
        let kind = hdr[8];
        let seq = u64::from_le_bytes(hdr[9..17].try_into().unwrap());
        let arg = u64::from_le_bytes(hdr[17..25].try_into().unwrap());
        let len = u32::from_le_bytes(hdr[25..29].try_into().unwrap());
        let mut payload = vec![0u8; len as usize];
        if len > 0 && stream.read_exact(&mut payload).is_err() {
            break;
        }
        let mut st = shared
            .state
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let inbox = st.comms.entry(comm).or_default();
        match kind {
            KIND_BARRIER => {
                inbox.barrier.entry(seq).or_default().push(peer)
            }
            KIND_REDUCE => {
                inbox.reduce.entry(seq).or_default().push((peer, arg))
            }
            KIND_DATA | KIND_NB_DATA => {
                let frame = DataFrame {
                    src: peer,
                    max_per_pair: arg,
                    spikes: decode_spikes(&payload),
                    arrived: Instant::now(),
                };
                let map = if kind == KIND_DATA {
                    &mut inbox.data
                } else {
                    &mut inbox.nb
                };
                map.entry(seq).or_default().push(frame);
            }
            KIND_SPLIT if payload.len() >= 8 => {
                let key =
                    u64::from_le_bytes(payload[0..8].try_into().unwrap());
                inbox.split.entry(seq).or_default().push((peer, arg, key));
            }
            // unknown kinds are skipped (forward compatibility)
            _ => {}
        }
        drop(st);
        shared.cv.notify_all();
    }
    let mut st = shared.state.lock().unwrap_or_else(|e| e.into_inner());
    st.dead[peer] = true;
    drop(st);
    shared.cv.notify_all();
}

/// The process-wide socket fabric: one connection per peer plus the
/// frame demultiplexer, shared by the root communicator and every
/// sub-communicator split off it.  Per-tier statistics and blame
/// ledgers live here so the engine can collect them after the run.
struct Mesh {
    m: usize,
    rank: usize,
    /// Write side of each peer connection (`None` at our own index).
    links: Vec<Option<Mutex<UnixStream>>>,
    shared: Arc<DemuxShared>,
    timeout: Option<Duration>,
    depth: usize,
    stats_global: CommStats,
    stats_local: CommStats,
    blame_global: Mutex<Blame>,
    blame_local: Mutex<Blame>,
    sock_path: PathBuf,
}

impl Drop for Mesh {
    fn drop(&mut self) {
        // closing both directions is what terminates our reader threads
        // (they hold only the DemuxShared, never the mesh) and tells
        // the peers we are gone
        for link in self.links.iter().flatten() {
            let s = match link.lock() {
                Ok(g) => g,
                Err(e) => e.into_inner(),
            };
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
        let _ = std::fs::remove_file(&self.sock_path);
    }
}

impl Mesh {
    fn stats(&self, tier: &str) -> &CommStats {
        if tier == "global" {
            &self.stats_global
        } else {
            &self.stats_local
        }
    }

    fn blame(&self, tier: &str) -> &Mutex<Blame> {
        if tier == "global" {
            &self.blame_global
        } else {
            &self.blame_local
        }
    }

    /// Write one frame to `dest`.  A write error means the peer died;
    /// record it and let the next gather that needs the peer surface
    /// the typed watchdog error (a send itself never fails a run).
    fn send_frame(
        &self,
        dest: usize,
        comm: u64,
        kind: u8,
        seq: u64,
        arg: u64,
        payload: &[u8],
    ) {
        let link = self.links[dest]
            .as_ref()
            .expect("send_frame to self has no link");
        let mut buf = Vec::with_capacity(HEADER_BYTES + payload.len());
        buf.extend_from_slice(&comm.to_le_bytes());
        buf.push(kind);
        buf.extend_from_slice(&seq.to_le_bytes());
        buf.extend_from_slice(&arg.to_le_bytes());
        buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        buf.extend_from_slice(payload);
        let failed = {
            let mut s = link.lock().unwrap_or_else(|e| e.into_inner());
            s.write_all(&buf).is_err()
        };
        if failed {
            let mut st = self
                .shared
                .state
                .lock()
                .unwrap_or_else(|e| e.into_inner());
            st.dead[dest] = true;
            drop(st);
            self.shared.cv.notify_all();
        }
    }

    fn send_spikes(
        &self,
        dest: usize,
        comm: u64,
        kind: u8,
        seq: u64,
        arg: u64,
        spikes: &[SpikeMsg],
    ) {
        let mut payload =
            Vec::with_capacity(spikes.len() * SPIKE_WIRE_BYTES);
        for sp in spikes {
            payload.extend_from_slice(&sp.source.to_le_bytes());
            payload.extend_from_slice(&sp.cycle.to_le_bytes());
        }
        self.send_frame(dest, comm, kind, seq, arg, &payload);
    }
}

/// Shared state of one communicator (the root or a `split` child): the
/// member list in sub-rank order, the deterministic quota mirror and
/// the per-kind sequence counters.  [`SocketComm`] and every
/// [`SocketPending`] it posts hold this behind an `Arc`.
struct CommInner {
    mesh: Arc<Mesh>,
    id: u64,
    tier: &'static str,
    /// Absolute (mesh) rank of each member, in sub-rank order.
    members: Vec<usize>,
    /// This process's rank within `members`.
    rank: usize,
    quota: AtomicUsize,
    barrier_seq: AtomicU64,
    reduce_seq: AtomicU64,
    data_seq: AtomicU64,
    nb_seq: AtomicU64,
    split_seq: AtomicU64,
    /// Split-phase rounds posted but not completed (flight-bound check).
    outstanding: AtomicUsize,
}

/// Outcome of a frame gather: the taken value, how long the wait took
/// and whether it ever actually blocked (only a blocked wait blames a
/// straggler — the releaser of an already-complete gather waited for
/// nobody).
struct Gathered<R> {
    value: R,
    waited: f64,
    blocked: bool,
}

impl CommInner {
    fn my_abs(&self) -> usize {
        self.members[self.rank]
    }

    fn local_of(&self, abs: usize) -> usize {
        self.members
            .iter()
            .position(|&a| a == abs)
            .expect("frame from a rank outside this communicator")
    }

    fn stats(&self) -> &CommStats {
        self.mesh.stats(self.tier)
    }

    fn poisoned(&self) -> CommError {
        CommError::Poisoned {
            tier: self.tier,
            rank: self.rank,
            context: "holding the socket frame demultiplexer".to_string(),
        }
    }

    fn record_blame(&self, blamed_abs: usize, waited: f64) {
        let mut b = self
            .mesh
            .blame(self.tier)
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        b.record(blamed_abs, waited);
    }

    /// Block until `take` yields a value from this communicator's
    /// inbox.  `arrived` reports which peers (absolute ranks) have
    /// already contributed, for the watchdog diagnostic; a needed peer
    /// marked dead fails the wait immediately — EOF is definitive, no
    /// point sitting out the deadline.
    fn gather<R>(
        &self,
        op: &'static str,
        epoch: Option<u64>,
        ring_slot: Option<usize>,
        mut take: impl FnMut(&mut Inbox) -> Option<R>,
        mut arrived: impl FnMut(&Inbox) -> Vec<usize>,
    ) -> Result<Gathered<R>, CommError> {
        let t0 = Instant::now();
        let mesh = &*self.mesh;
        let mut blocked = false;
        let mut st = mesh
            .shared
            .state
            .lock()
            .map_err(|_| self.poisoned())?;
        loop {
            {
                let inbox = st.comms.entry(self.id).or_default();
                if let Some(value) = take(inbox) {
                    return Ok(Gathered {
                        value,
                        waited: t0.elapsed().as_secs_f64(),
                        blocked,
                    });
                }
            }
            let present_abs = {
                let inbox = st.comms.entry(self.id).or_default();
                arrived(inbox)
            };
            let missing: Vec<usize> = self
                .members
                .iter()
                .copied()
                .filter(|&a| {
                    a != self.my_abs() && !present_abs.contains(&a)
                })
                .collect();
            let dead_hit = missing.iter().any(|&a| st.dead[a]);
            let expired = mesh
                .timeout
                .map(|t| t0.elapsed() >= t)
                .unwrap_or(false);
            if dead_hit || expired {
                self.stats().timeouts.fetch_add(1, Ordering::Relaxed);
                return Err(CommError::Timeout {
                    tier: self.tier,
                    op,
                    rank: self.rank,
                    epoch,
                    ring_slot,
                    waited: t0.elapsed(),
                    missing: missing
                        .iter()
                        .map(|&a| self.local_of(a))
                        .collect(),
                    present: present_abs
                        .iter()
                        .map(|&a| self.local_of(a))
                        .collect(),
                });
            }
            blocked = true;
            st = match mesh.timeout {
                Some(t) => {
                    let left = t.saturating_sub(t0.elapsed());
                    mesh.shared
                        .cv
                        .wait_timeout(st, left)
                        .map_err(|_| self.poisoned())?
                        .0
                }
                None => mesh
                    .shared
                    .cv
                    .wait(st)
                    .map_err(|_| self.poisoned())?,
            };
        }
    }

    /// Barrier frame: send a token to every member, wait for all of
    /// theirs.  Returns the wait time; the last arriver is charged in
    /// the blame ledger like the shared-memory barrier's releaser.
    fn barrier(&self, op: &'static str) -> Result<f64, CommError> {
        let need = self.members.len() - 1;
        if need == 0 {
            return Ok(0.0);
        }
        let seq = self.barrier_seq.fetch_add(1, Ordering::Relaxed);
        for &peer in &self.members {
            if peer != self.my_abs() {
                self.mesh
                    .send_frame(peer, self.id, KIND_BARRIER, seq, 0, &[]);
            }
        }
        let g = self.gather(
            op,
            None,
            None,
            |inbox| {
                if inbox.barrier.get(&seq).is_some_and(|v| v.len() == need)
                {
                    inbox.barrier.remove(&seq)
                } else {
                    None
                }
            },
            |inbox| inbox.barrier.get(&seq).cloned().unwrap_or_default(),
        )?;
        if g.blocked {
            if let Some(&last) = g.value.last() {
                self.record_blame(last, g.waited);
            }
        }
        Ok(g.waited)
    }

    fn allreduce_min(&self, v: u64) -> Result<u64, CommError> {
        let need = self.members.len() - 1;
        if need == 0 {
            return Ok(v);
        }
        let seq = self.reduce_seq.fetch_add(1, Ordering::Relaxed);
        for &peer in &self.members {
            if peer != self.my_abs() {
                self.mesh
                    .send_frame(peer, self.id, KIND_REDUCE, seq, v, &[]);
            }
        }
        let g = self.gather(
            "allreduce-min",
            None,
            None,
            |inbox| {
                if inbox.reduce.get(&seq).is_some_and(|e| e.len() == need)
                {
                    inbox.reduce.remove(&seq)
                } else {
                    None
                }
            },
            |inbox| {
                inbox
                    .reduce
                    .get(&seq)
                    .map(|e| e.iter().map(|&(r, _)| r).collect())
                    .unwrap_or_default()
            },
        )?;
        Ok(g.value.iter().map(|&(_, x)| x).fold(v, u64::min))
    }

    /// Deterministic quota settle: every member gathered the same
    /// per-round maxima, so every member doubles to the same value —
    /// keeping `quota()` (and a future checkpoint of it) consistent
    /// across processes without a second protocol round.
    fn settle_quota(&self, round_max: usize) {
        let q = self.quota.load(Ordering::Relaxed);
        if round_max > q {
            let mut grown = q.max(1);
            while grown < round_max {
                grown *= 2;
            }
            self.quota.store(grown, Ordering::Relaxed);
            self.stats().resize_rounds.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn alltoall_into(
        &self,
        send: &mut [Vec<SpikeMsg>],
        recv: &mut Vec<Vec<SpikeMsg>>,
    ) -> Result<ExchangeTiming, CommError> {
        let m = self.members.len();
        assert_eq!(
            send.len(),
            m,
            "alltoall send must carry one buffer per rank"
        );
        let stats = self.stats();
        // barrier frame in front of the collective: separates the
        // synchronization share (waiting for the slowest member) from
        // the data exchange proper, like the shared-memory protocol
        let sync_secs = self.barrier("alltoall (sync barrier)")?;
        stats
            .sync_nanos
            .fetch_add((sync_secs * 1e9) as u64, Ordering::Relaxed);
        let t_data = Instant::now();
        let my_max = send.iter().map(Vec::len).max().unwrap_or(0);
        let seq = self.data_seq.fetch_add(1, Ordering::Relaxed);
        let mut bytes = 0u64;
        for (d, buf) in send.iter_mut().enumerate() {
            if d == self.rank {
                continue;
            }
            bytes += (buf.len() * SPIKE_WIRE_BYTES) as u64;
            self.mesh.send_spikes(
                self.members[d],
                self.id,
                KIND_DATA,
                seq,
                my_max as u64,
                buf,
            );
            buf.clear();
        }
        recv.resize_with(m, Vec::new);
        // self-delivery: swap, conserving both buffers' capacity
        recv[self.rank].clear();
        std::mem::swap(&mut send[self.rank], &mut recv[self.rank]);
        let mut round_max = my_max as u64;
        if m > 1 {
            let need = m - 1;
            let g = self.gather(
                "alltoall (data)",
                Some(seq),
                None,
                |inbox| {
                    if inbox
                        .data
                        .get(&seq)
                        .is_some_and(|f| f.len() == need)
                    {
                        inbox.data.remove(&seq)
                    } else {
                        None
                    }
                },
                |inbox| {
                    inbox
                        .data
                        .get(&seq)
                        .map(|f| f.iter().map(|fr| fr.src).collect())
                        .unwrap_or_default()
                },
            )?;
            for frame in g.value {
                round_max = round_max.max(frame.max_per_pair);
                recv[self.local_of(frame.src)] = frame.spikes;
            }
        }
        self.settle_quota(round_max as usize);
        stats.alltoall_calls.fetch_add(1, Ordering::Relaxed);
        stats.bytes_sent.fetch_add(bytes, Ordering::Relaxed);
        stats
            .max_send_per_pair
            .fetch_max(my_max, Ordering::Relaxed);
        Ok(ExchangeTiming {
            sync_secs,
            data_secs: t_data.elapsed().as_secs_f64(),
        })
    }
}

/// Builds the socket mesh for one rank and hands back the root
/// communicator.  The socket analogue of
/// [`super::WorldBuilder`] — except every process builds only its own
/// rank's endpoint and the constructor blocks until the full mesh is
/// connected (the rendezvous).
pub struct SocketWorldBuilder {
    m: usize,
    rank: usize,
    dir: PathBuf,
    quota: usize,
    depth: usize,
    timeout: Option<Duration>,
    rendezvous_timeout: Duration,
}

impl SocketWorldBuilder {
    pub fn new(m: usize, rank: usize, dir: &Path) -> SocketWorldBuilder {
        SocketWorldBuilder {
            m,
            rank,
            dir: dir.to_path_buf(),
            quota: 1024,
            depth: 1,
            timeout: None,
            rendezvous_timeout: Duration::from_secs(30),
        }
    }

    /// Initial spike-buffer quota per rank pair (advisory over
    /// sockets, but tracked so statistics match the shared world).
    pub fn quota(mut self, quota: usize) -> SocketWorldBuilder {
        self.quota = quota.max(1);
        self
    }

    /// Split-phase pipeline depth (ring of `2·depth` rounds in flight).
    pub fn depth(mut self, depth: usize) -> SocketWorldBuilder {
        self.depth = depth.max(1);
        self
    }

    /// Watchdog deadline for every blocking rendezvous; `None` waits
    /// forever (EOF from a dead peer still fails fast).
    pub fn timeout(
        mut self,
        timeout: Option<Duration>,
    ) -> SocketWorldBuilder {
        self.timeout = timeout;
        self
    }

    /// How long to keep dialing peers whose listener has not appeared
    /// yet before giving up on the mesh (default 30 s).
    pub fn rendezvous_timeout(
        mut self,
        timeout: Duration,
    ) -> SocketWorldBuilder {
        self.rendezvous_timeout = timeout;
        self
    }

    /// Bind, dial, accept: block until all `m - 1` peer connections
    /// exist, then return the root communicator.
    pub fn connect(self) -> AnyResult<SocketComm> {
        anyhow::ensure!(self.m >= 1, "socket mesh needs at least 1 rank");
        anyhow::ensure!(
            self.rank < self.m,
            "socket rank {} out of range for {} ranks",
            self.rank,
            self.m
        );
        std::fs::create_dir_all(&self.dir).with_context(|| {
            format!("creating socket dir {}", self.dir.display())
        })?;
        let sock_path = self.dir.join(format!("rank{}.sock", self.rank));
        let _ = std::fs::remove_file(&sock_path);
        let listener = UnixListener::bind(&sock_path).with_context(|| {
            format!("binding {}", sock_path.display())
        })?;
        let shared = Arc::new(DemuxShared {
            state: Mutex::new(DemuxState {
                dead: vec![false; self.m],
                comms: HashMap::new(),
            }),
            cv: Condvar::new(),
        });
        let mut links: Vec<Option<Mutex<UnixStream>>> =
            (0..self.m).map(|_| None).collect();
        let deadline = Instant::now() + self.rendezvous_timeout;
        // dial every lower rank, retrying until its listener appears
        for peer in 0..self.rank {
            let path = self.dir.join(format!("rank{peer}.sock"));
            let mut stream = loop {
                match UnixStream::connect(&path) {
                    Ok(s) => break s,
                    Err(e) => {
                        if Instant::now() >= deadline {
                            bail!(
                                "socket rendezvous: rank {} could not \
                                 reach rank {peer} at {} within {:?} \
                                 ({e})",
                                self.rank,
                                path.display(),
                                self.rendezvous_timeout
                            );
                        }
                        std::thread::sleep(Duration::from_millis(2));
                    }
                }
            };
            stream
                .write_all(&(self.rank as u64).to_le_bytes())
                .with_context(|| format!("hello to rank {peer}"))?;
            let reader = stream.try_clone().context("cloning stream")?;
            let sh = shared.clone();
            std::thread::spawn(move || reader_loop(sh, reader, peer));
            links[peer] = Some(Mutex::new(stream));
        }
        // accept every higher rank; the 8-byte hello says who it is
        listener.set_nonblocking(true)?;
        let mut pending = self.m - 1 - self.rank;
        while pending > 0 {
            match listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false)?;
                    let mut hello = [0u8; 8];
                    (&stream)
                        .read_exact(&mut hello)
                        .context("reading peer hello")?;
                    let peer = u64::from_le_bytes(hello) as usize;
                    anyhow::ensure!(
                        peer > self.rank
                            && peer < self.m
                            && links[peer].is_none(),
                        "socket rendezvous: unexpected hello from rank \
                         {peer}"
                    );
                    let reader =
                        stream.try_clone().context("cloning stream")?;
                    let sh = shared.clone();
                    std::thread::spawn(move || {
                        reader_loop(sh, reader, peer)
                    });
                    links[peer] = Some(Mutex::new(stream));
                    pending -= 1;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        bail!(
                            "socket rendezvous: rank {} still waiting \
                             for {pending} peer connection(s) after \
                             {:?}",
                            self.rank,
                            self.rendezvous_timeout
                        );
                    }
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(e) => {
                    return Err(e)
                        .context("accepting a peer connection")
                }
            }
        }
        let mesh = Arc::new(Mesh {
            m: self.m,
            rank: self.rank,
            links,
            shared,
            timeout: self.timeout,
            depth: self.depth,
            stats_global: CommStats::default(),
            stats_local: CommStats::default(),
            blame_global: Mutex::new(Blame::sized(self.m)),
            blame_local: Mutex::new(Blame::sized(self.m)),
            sock_path,
        });
        Ok(SocketComm {
            inner: Arc::new(CommInner {
                mesh,
                id: ROOT_COMM_ID,
                tier: "global",
                members: (0..self.m).collect(),
                rank: self.rank,
                quota: AtomicUsize::new(self.quota),
                barrier_seq: AtomicU64::new(0),
                reduce_seq: AtomicU64::new(0),
                data_seq: AtomicU64::new(0),
                nb_seq: AtomicU64::new(0),
                split_seq: AtomicU64::new(0),
                outstanding: AtomicUsize::new(0),
            }),
        })
    }
}

/// This process's handle into one socket communicator — the root world
/// of the mesh or a sub-communicator from [`Transport::split`].
pub struct SocketComm {
    inner: Arc<CommInner>,
}

impl SocketComm {
    /// Per-tier statistics of this *process* (the shared-memory world
    /// aggregates over all ranks; over sockets each process reports its
    /// own share and the launcher's consumers sum if they need a
    /// cluster view).
    pub fn tiered_stats(&self) -> TieredCommStats {
        let mesh = &*self.inner.mesh;
        TieredCommStats {
            global: mesh.stats_global.snapshot(),
            local: mesh.stats_local.snapshot(),
        }
    }

    /// This process's straggler ledgers in root-mesh absolute ranks,
    /// shaped like [`super::World::blame_report`] with only our own
    /// rank's row filled.
    pub fn blame_report(&self) -> TieredBlame {
        let mesh = &*self.inner.mesh;
        let mut t = TieredBlame::sized(mesh.m);
        t.global[mesh.rank] = mesh
            .blame_global
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .clone();
        t.local[mesh.rank] = mesh
            .blame_local
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .clone();
        t
    }
}

impl Transport for SocketComm {
    type Sub = SocketComm;

    fn rank(&self) -> usize {
        self.inner.rank
    }

    fn m_ranks(&self) -> usize {
        self.inner.members.len()
    }

    fn quota(&self) -> usize {
        self.inner.quota.load(Ordering::Relaxed)
    }

    fn split(
        &self,
        color: u64,
        key: u64,
    ) -> Result<SocketComm, CommError> {
        let inner = &*self.inner;
        let m = inner.members.len();
        let seq = inner.split_seq.fetch_add(1, Ordering::Relaxed);
        for &peer in &inner.members {
            if peer != inner.my_abs() {
                inner.mesh.send_frame(
                    peer,
                    inner.id,
                    KIND_SPLIT,
                    seq,
                    color,
                    &key.to_le_bytes(),
                );
            }
        }
        let mut all: Vec<(usize, u64, u64)> = if m > 1 {
            let need = m - 1;
            inner
                .gather(
                    "split",
                    None,
                    None,
                    |inbox| {
                        if inbox
                            .split
                            .get(&seq)
                            .is_some_and(|e| e.len() == need)
                        {
                            inbox.split.remove(&seq)
                        } else {
                            None
                        }
                    },
                    |inbox| {
                        inbox
                            .split
                            .get(&seq)
                            .map(|e| {
                                e.iter().map(|&(r, _, _)| r).collect()
                            })
                            .unwrap_or_default()
                    },
                )?
                .value
        } else {
            Vec::new()
        };
        all.push((inner.my_abs(), color, key));
        // deterministic grouping, the MPI_Comm_split shape: members of
        // our color ordered by (key, parent-local rank) — every member
        // of the group computes the identical list
        let mut group: Vec<(u64, usize, usize)> = all
            .iter()
            .filter(|&&(_, c, _)| c == color)
            .map(|&(abs, _, k)| (k, inner.local_of(abs), abs))
            .collect();
        group.sort_unstable();
        let members: Vec<usize> =
            group.iter().map(|&(_, _, abs)| abs).collect();
        let rank = members
            .iter()
            .position(|&a| a == inner.my_abs())
            .expect("split group must contain the caller");
        Ok(SocketComm {
            inner: Arc::new(CommInner {
                mesh: inner.mesh.clone(),
                id: child_comm_id(inner.id, seq, color),
                tier: "local",
                members,
                rank,
                quota: AtomicUsize::new(
                    inner.quota.load(Ordering::Relaxed),
                ),
                barrier_seq: AtomicU64::new(0),
                reduce_seq: AtomicU64::new(0),
                data_seq: AtomicU64::new(0),
                nb_seq: AtomicU64::new(0),
                split_seq: AtomicU64::new(0),
                outstanding: AtomicUsize::new(0),
            }),
        })
    }

    fn alltoall_into(
        &self,
        send: &mut [Vec<SpikeMsg>],
        recv: &mut Vec<Vec<SpikeMsg>>,
    ) -> Result<ExchangeTiming, CommError> {
        self.inner.alltoall_into(send, recv)
    }

    fn local_swap_into(
        &self,
        send: &mut Vec<SpikeMsg>,
        recv: &mut Vec<SpikeMsg>,
    ) {
        recv.clear();
        std::mem::swap(send, recv);
        self.inner
            .stats()
            .local_swaps
            .fetch_add(1, Ordering::Relaxed);
    }

    fn allreduce_min_u64(&self, v: u64) -> Result<u64, CommError> {
        self.inner.allreduce_min(v)
    }
}

impl SplitTransport for SocketComm {
    type Pending = SocketPending;

    fn alltoall_start(
        &self,
        send: &mut [Vec<SpikeMsg>],
    ) -> Result<SocketPending, CommError> {
        let inner = &self.inner;
        let m = inner.members.len();
        assert_eq!(
            send.len(),
            m,
            "alltoall send must carry one buffer per rank"
        );
        let ring = 2 * inner.mesh.depth;
        debug_assert!(
            inner.outstanding.load(Ordering::Relaxed) < ring,
            "posting past the {ring}-round flight bound"
        );
        inner.outstanding.fetch_add(1, Ordering::Relaxed);
        let t0 = Instant::now();
        let my_max = send.iter().map(Vec::len).max().unwrap_or(0) as u64;
        let seq = inner.nb_seq.fetch_add(1, Ordering::Relaxed);
        let stats = inner.stats();
        let mut bytes = 0u64;
        for (d, buf) in send.iter_mut().enumerate() {
            if d == inner.rank {
                // self-deposit straight into our own inbox
                let spikes = std::mem::take(buf);
                let mut st = inner
                    .mesh
                    .shared
                    .state
                    .lock()
                    .map_err(|_| inner.poisoned())?;
                st.comms
                    .entry(inner.id)
                    .or_default()
                    .nb
                    .entry(seq)
                    .or_default()
                    .push(DataFrame {
                        src: inner.my_abs(),
                        max_per_pair: my_max,
                        spikes,
                        arrived: Instant::now(),
                    });
                continue;
            }
            bytes += (buf.len() * SPIKE_WIRE_BYTES) as u64;
            inner.mesh.send_spikes(
                inner.members[d],
                inner.id,
                KIND_NB_DATA,
                seq,
                my_max,
                buf,
            );
            buf.clear();
        }
        let post_secs = t0.elapsed().as_secs_f64();
        stats
            .post_nanos
            .fetch_add((post_secs * 1e9) as u64, Ordering::Relaxed);
        stats.bytes_sent.fetch_add(bytes, Ordering::Relaxed);
        stats
            .max_send_per_pair
            .fetch_max(my_max as usize, Ordering::Relaxed);
        Ok(SocketPending {
            inner: self.inner.clone(),
            seq,
            posted_at: t0,
            post_secs,
            last_arrival: t0,
            drained: vec![false; m],
            round_max: my_max,
            completed: false,
        })
    }
}

/// Handle to an in-flight socket exchange — the [`Pending`] of the
/// socket backend.  Same contract as the shared-memory
/// [`super::PendingExchange`]: complete exactly once, abandon on the
/// error path.
#[must_use = "an unfinished exchange deadlocks its peers; call complete()"]
pub struct SocketPending {
    inner: Arc<CommInner>,
    seq: u64,
    posted_at: Instant,
    post_secs: f64,
    /// Latest deposit arrival observed (early drains included) — the
    /// hidden-latency accounting input.
    last_arrival: Instant,
    drained: Vec<bool>,
    round_max: u64,
    completed: bool,
}

impl Drop for SocketPending {
    fn drop(&mut self) {
        if !self.completed && !std::thread::panicking() {
            debug_assert!(
                false,
                "SocketPending (rank {}, seq {}) dropped without \
                 complete(); peers would deadlock at their rendezvous",
                self.inner.rank, self.seq
            );
        }
    }
}

impl SocketPending {
    fn ring_slot(&self) -> usize {
        (self.seq % (2 * self.inner.mesh.depth) as u64) as usize
    }

    /// Drain every frame of this round currently parked in the inbox
    /// into `recv`; returns the absolute rank of the latest-arriving
    /// frame drained, if any.
    fn drain_available(
        &mut self,
        recv: &mut [Vec<SpikeMsg>],
    ) -> Result<Option<usize>, CommError> {
        let inner = self.inner.clone();
        let mut st = inner
            .mesh
            .shared
            .state
            .lock()
            .map_err(|_| inner.poisoned())?;
        let inbox = st.comms.entry(inner.id).or_default();
        let mut latest: Option<(Instant, usize)> = None;
        if let Some(frames) = inbox.nb.get_mut(&self.seq) {
            while let Some(frame) = frames.pop() {
                let local = inner.local_of(frame.src);
                debug_assert!(!self.drained[local]);
                recv[local] = frame.spikes;
                self.drained[local] = true;
                self.round_max = self.round_max.max(frame.max_per_pair);
                if frame.arrived > self.last_arrival {
                    self.last_arrival = frame.arrived;
                }
                if latest.is_none_or(|(t, _)| frame.arrived > t) {
                    latest = Some((frame.arrived, frame.src));
                }
            }
            inbox.nb.remove(&self.seq);
        }
        Ok(latest.map(|(_, src)| src))
    }
}

impl Pending for SocketPending {
    fn post_secs(&self) -> f64 {
        self.post_secs
    }

    fn try_complete_source(
        &mut self,
        src: usize,
        out: &mut Vec<SpikeMsg>,
    ) -> Result<bool, CommError> {
        if self.drained[src] {
            return Ok(true);
        }
        let inner = self.inner.clone();
        let abs = inner.members[src];
        let mut st = inner
            .mesh
            .shared
            .state
            .lock()
            .map_err(|_| inner.poisoned())?;
        let inbox = st.comms.entry(inner.id).or_default();
        let Some(frames) = inbox.nb.get_mut(&self.seq) else {
            return Ok(false);
        };
        let Some(i) = frames.iter().position(|f| f.src == abs) else {
            return Ok(false);
        };
        let frame = frames.swap_remove(i);
        if frames.is_empty() {
            inbox.nb.remove(&self.seq);
        }
        drop(st);
        *out = frame.spikes;
        self.drained[src] = true;
        self.round_max = self.round_max.max(frame.max_per_pair);
        if frame.arrived > self.last_arrival {
            self.last_arrival = frame.arrived;
        }
        inner
            .stats()
            .early_drained_sources
            .fetch_add(1, Ordering::Relaxed);
        Ok(true)
    }

    fn complete(
        mut self,
        recv: &mut Vec<Vec<SpikeMsg>>,
    ) -> Result<CompletionTiming, CommError> {
        let inner = self.inner.clone();
        let mesh = &*inner.mesh;
        let m = inner.members.len();
        let t_enter = Instant::now();
        recv.resize_with(m, Vec::new);
        let mut wait_secs = 0.0;
        let mut drain_secs = 0.0;
        let mut last_blamed: Option<usize> = None;
        loop {
            let td = Instant::now();
            let drained_src = self.drain_available(recv).map_err(|e| {
                self.completed = true;
                e
            })?;
            drain_secs += td.elapsed().as_secs_f64();
            if let Some(src) = drained_src {
                if wait_secs > 0.0 && src != inner.my_abs() {
                    last_blamed = Some(src);
                }
            }
            if self.drained.iter().all(|&d| d) {
                break;
            }
            // blocked: wait watchdogged for more deposits; a dead peer
            // whose deposit is missing fails immediately
            let tw = Instant::now();
            let mut st = mesh.shared.state.lock().map_err(|_| {
                self.completed = true;
                inner.poisoned()
            })?;
            let has_new = st
                .comms
                .entry(inner.id)
                .or_default()
                .nb
                .get(&self.seq)
                .is_some_and(|f| !f.is_empty());
            if !has_new {
                let missing: Vec<usize> = (0..m)
                    .filter(|&s| !self.drained[s])
                    .collect();
                let dead_hit = missing
                    .iter()
                    .any(|&s| st.dead[inner.members[s]]);
                let expired = mesh
                    .timeout
                    .map(|t| t_enter.elapsed() >= t)
                    .unwrap_or(false);
                if dead_hit || expired {
                    drop(st);
                    self.completed = true;
                    inner
                        .stats()
                        .timeouts
                        .fetch_add(1, Ordering::Relaxed);
                    let present: Vec<usize> = (0..m)
                        .filter(|&s| self.drained[s])
                        .collect();
                    return Err(CommError::Timeout {
                        tier: inner.tier,
                        op: "split-phase complete",
                        rank: inner.rank,
                        epoch: Some(self.seq),
                        ring_slot: Some(self.ring_slot()),
                        waited: t_enter.elapsed(),
                        missing,
                        present,
                    });
                }
                let _unused = match mesh.timeout {
                    Some(t) => {
                        let left = t.saturating_sub(t_enter.elapsed());
                        mesh.shared
                            .cv
                            .wait_timeout(st, left)
                            .map_err(|_| {
                                self.completed = true;
                                inner.poisoned()
                            })?
                            .0
                    }
                    None => {
                        mesh.shared.cv.wait(st).map_err(|_| {
                            self.completed = true;
                            inner.poisoned()
                        })?
                    }
                };
            }
            wait_secs += tw.elapsed().as_secs_f64();
        }
        let stats = inner.stats();
        stats.alltoall_calls.fetch_add(1, Ordering::Relaxed);
        stats
            .overlapped_exchanges
            .fetch_add(1, Ordering::Relaxed);
        stats
            .complete_wait_nanos
            .fetch_add((wait_secs * 1e9) as u64, Ordering::Relaxed);
        // hidden latency: peer skew that elapsed between post and the
        // completion entry while this rank was computing
        let hidden_end = if self.last_arrival < t_enter {
            self.last_arrival
        } else {
            t_enter
        };
        let hidden = hidden_end
            .saturating_duration_since(self.posted_at)
            .as_secs_f64();
        stats
            .hidden_nanos
            .fetch_add((hidden * 1e9) as u64, Ordering::Relaxed);
        inner.settle_quota(self.round_max as usize);
        inner.outstanding.fetch_sub(1, Ordering::Relaxed);
        if let Some(src) = last_blamed {
            if wait_secs > 0.0 {
                inner.record_blame(src, wait_secs);
            }
        }
        self.completed = true;
        Ok(CompletionTiming { wait_secs, drain_secs })
    }

    fn abandon(mut self) {
        self.completed = true;
        self.inner.outstanding.fetch_sub(1, Ordering::Relaxed);
    }
}
