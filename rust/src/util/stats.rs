//! Descriptive statistics, the Gaussian special functions, order
//! statistics and time-series helpers used by the theory module and the
//! virtual cluster.
//!
//! Everything here operates on plain `&[f64]`; no external crates.

/// Arithmetic mean; 0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance; 0 for fewer than two samples.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Coefficient of variation `sigma / mu` (0 if the mean is 0).
pub fn cv(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        0.0
    } else {
        std_dev(xs) / m
    }
}

/// Linear-interpolated quantile, `q` in `[0, 1]`.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "quantile of empty slice");
    assert!((0.0..=1.0).contains(&q), "q={q} out of range");
    let mut s: Vec<f64> = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q * (s.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (pos - lo as f64) * (s[hi] - s[lo])
    }
}

/// Maximum (NaN-free input assumed).
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Minimum (NaN-free input assumed).
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

/// Error function, Abramowitz & Stegun 7.1.26 (|err| <= 1.5e-7).
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.327_591_1 * x);
    let y = 1.0
        - (((((1.061_405_429 * t - 1.453_152_027) * t) + 1.421_413_741) * t
            - 0.284_496_736)
            * t
            + 0.254_829_592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Standard normal CDF.
pub fn norm_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// Standard normal PDF.
pub fn norm_pdf(x: f64) -> f64 {
    (-0.5 * x * x).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Inverse standard normal CDF (Acklam's rational approximation,
/// |rel err| < 1.15e-9).
pub fn norm_ppf(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "norm_ppf domain: p={p}");
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;
    if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5])
            * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r
                + 1.0)
    } else {
        -norm_ppf(1.0 - p)
    }
}

/// Blom's approximation of the expected maximum of `n` iid standard
/// normals, expressed in standard deviations from the mean — the paper's
/// `xi_M` (eq 8/9).
pub fn blom_xi(n: usize) -> f64 {
    assert!(n >= 1);
    if n == 1 {
        return 0.0;
    }
    const ALPHA: f64 = 0.375;
    norm_ppf((n as f64 - ALPHA) / (n as f64 - 2.0 * ALPHA + 1.0))
}

/// Probability that the maximum of `m` iid draws falls in the upper-tail
/// region that a single draw hits with probability `p_tail` (paper eq 12).
pub fn p_max_in_tail(p_tail: f64, m: usize) -> f64 {
    1.0 - (1.0 - p_tail).powi(m as i32)
}

/// Gaussian kernel density estimate at `grid` points with Silverman's
/// rule-of-thumb bandwidth.
pub fn kde(xs: &[f64], grid: &[f64]) -> Vec<f64> {
    assert!(!xs.is_empty());
    let n = xs.len() as f64;
    let sd = std_dev(xs);
    let iqr = quantile(xs, 0.75) - quantile(xs, 0.25);
    let spread = if iqr > 0.0 { sd.min(iqr / 1.34) } else { sd };
    let h = (0.9 * spread * n.powf(-0.2)).max(1e-12);
    grid.iter()
        .map(|&g| {
            xs.iter().map(|&x| norm_pdf((g - x) / h)).sum::<f64>() / (n * h)
        })
        .collect()
}

/// Histogram over `nbins` equal bins spanning `[lo, hi]`; returns
/// (bin_centers, counts).
pub fn histogram(xs: &[f64], lo: f64, hi: f64, nbins: usize) -> (Vec<f64>, Vec<usize>) {
    assert!(nbins > 0 && hi > lo);
    let w = (hi - lo) / nbins as f64;
    let mut counts = vec![0usize; nbins];
    for &x in xs {
        if x >= lo && x <= hi {
            let mut b = ((x - lo) / w) as usize;
            if b == nbins {
                b -= 1;
            }
            counts[b] += 1;
        }
    }
    let centers = (0..nbins).map(|i| lo + (i as f64 + 0.5) * w).collect();
    (centers, counts)
}

/// Lag-k autocorrelation coefficient.
pub fn autocorr(xs: &[f64], lag: usize) -> f64 {
    if xs.len() <= lag + 1 {
        return 0.0;
    }
    let m = mean(xs);
    let denom: f64 = xs.iter().map(|x| (x - m) * (x - m)).sum();
    if denom == 0.0 {
        return 0.0;
    }
    let num: f64 = xs[..xs.len() - lag]
        .iter()
        .zip(&xs[lag..])
        .map(|(a, b)| (a - m) * (b - m))
        .sum();
    num / denom
}

/// Fit an AR(1) process `x_t - mu = phi (x_{t-1} - mu) + eps`; returns
/// `(mu, phi, sigma_eps)`.
pub fn fit_ar1(xs: &[f64]) -> (f64, f64, f64) {
    let mu = mean(xs);
    let phi = autocorr(xs, 1);
    let var = variance(xs);
    let sigma_eps = (var * (1.0 - phi * phi)).max(0.0).sqrt();
    (mu, phi, sigma_eps)
}

/// Sum of `chunk`-sized consecutive groups — the paper's "lumped" cycle
/// times (eq 5).  Trailing partial chunks are dropped.
pub fn lump_sums(xs: &[f64], chunk: usize) -> Vec<f64> {
    assert!(chunk > 0);
    xs.chunks_exact(chunk).map(|c| c.iter().sum()).collect()
}

/// Streaming (Welford) accumulator of count / mean / variance / extrema —
/// the bounded-memory form of [`mean`] / [`variance`] for sample streams
/// too long to store (the observability layer's per-cycle compute
/// intervals, which previously accumulated as unbounded `Vec<f64>`s).
///
/// Merging two accumulators (Chan et al.'s parallel update) gives the
/// same moments as one pass over the concatenated stream, so per-rank
/// recorders can be pooled into a run-wide fit.
#[derive(Clone, Copy, Debug)]
pub struct Moments {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Default for Moments {
    fn default() -> Moments {
        Moments {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

impl Moments {
    pub fn new() -> Moments {
        Moments::default()
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Combine with another accumulator; equivalent to having pushed
    /// both streams into one.
    pub fn merge(&mut self, other: &Moments) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = *other;
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        self.mean += d * other.n as f64 / n as f64;
        self.m2 += other.m2
            + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn n(&self) -> u64 {
        self.n
    }

    /// Arithmetic mean; 0 before the first sample (like [`mean`]).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Population variance; 0 for fewer than two samples (like
    /// [`variance`]).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Coefficient of variation `sigma / mu` (0 if the mean is 0, like
    /// [`cv`]).
    pub fn cv(&self) -> f64 {
        if self.mean() == 0.0 {
            0.0
        } else {
            self.std_dev() / self.mean()
        }
    }

    /// Smallest sample; 0 before the first sample.
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Largest sample; 0 before the first sample.
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

/// Number of bins of the fixed log₂ histogram used for streaming
/// duration distributions.
pub const LOG2_HIST_BINS: usize = 64;

/// Lower edge of the first log₂ histogram bin, in the sample's own unit
/// (seconds throughout this repo): bin `i` covers
/// `[LOG2_HIST_LO·2^i, LOG2_HIST_LO·2^(i+1))`, so 64 bins span 1 ns to
/// ~18 × 10⁹ s — every duration a run can produce, in constant memory.
pub const LOG2_HIST_LO: f64 = 1e-9;

/// Bin index of `x` in the fixed log₂ histogram; values at or below the
/// first edge land in bin 0, values beyond the last edge in the last bin.
#[inline]
pub fn log2_bin(x: f64) -> usize {
    if !(x > LOG2_HIST_LO) {
        return 0;
    }
    let i = (x / LOG2_HIST_LO).log2().floor() as usize;
    i.min(LOG2_HIST_BINS - 1)
}

/// Lower edge of log₂ histogram bin `i`, in the sample unit.
#[inline]
pub fn log2_bin_lo(i: usize) -> f64 {
    LOG2_HIST_LO * (i as f64).exp2()
}

/// Quantile estimate from log₂ histogram `counts`, `q` in `[0, 1]`:
/// the geometric midpoint of the bin holding the q-th sample.  0 for an
/// empty histogram.  Resolution is one octave — adequate for the "is
/// the tail two bins or ten bins out" questions the interval
/// distributions answer; exact quantiles need the raw samples
/// (`--record-cycle-times`).
pub fn log2_hist_quantile(counts: &[u64], q: f64) -> f64 {
    assert!((0.0..=1.0).contains(&q), "q={q} out of range");
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
    let mut cum = 0u64;
    for (i, &c) in counts.iter().enumerate() {
        cum += c;
        if cum >= rank {
            return log2_bin_lo(i) * std::f64::consts::SQRT_2;
        }
    }
    log2_bin_lo(counts.len() - 1) * std::f64::consts::SQRT_2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn mean_var_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
        assert!((cv(&xs) - 1.25f64.sqrt() / 2.5).abs() < 1e-12);
    }

    #[test]
    fn quantile_interpolates() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        assert_eq!(quantile(&xs, 0.0), 0.0);
        assert_eq!(quantile(&xs, 1.0), 3.0);
        assert!((quantile(&xs, 0.5) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn erf_reference_values() {
        // A&S 7.1.26 has |err| <= 1.5e-7
        assert!((erf(0.0)).abs() < 1.5e-7);
        assert!((erf(1.0) - 0.842_700_79).abs() < 1e-6);
        assert!((erf(-1.0) + 0.842_700_79).abs() < 1e-6);
        assert!((erf(2.0) - 0.995_322_27).abs() < 1e-6);
    }

    #[test]
    fn norm_cdf_ppf_roundtrip() {
        for &p in &[0.001, 0.01, 0.1, 0.35, 0.5, 0.72, 0.9, 0.99, 0.999] {
            let x = norm_ppf(p);
            assert!((norm_cdf(x) - p).abs() < 1e-6, "p={p} x={x}");
        }
    }

    #[test]
    fn blom_xi_monotone_and_reference() {
        // E[max of 2 std normals] = 1/sqrt(pi) ≈ 0.5642
        assert!((blom_xi(2) - 0.5642).abs() < 0.03);
        let xs: Vec<f64> = [2, 4, 16, 64, 128, 1024]
            .iter()
            .map(|&n| blom_xi(n))
            .collect();
        assert!(xs.windows(2).all(|w| w[0] < w[1]), "{xs:?}");
        // for n=128 the expected max is around 2.55 sigma
        assert!((blom_xi(128) - 2.55).abs() < 0.1, "{}", blom_xi(128));
    }

    #[test]
    fn blom_matches_monte_carlo() {
        let mut r = Pcg64::seed_from_u64(1);
        for &m in &[8usize, 32, 128] {
            let trials = 4000;
            let mc: f64 = (0..trials)
                .map(|_| (0..m).map(|_| r.normal()).fold(f64::MIN, f64::max))
                .sum::<f64>()
                / trials as f64;
            assert!(
                (mc - blom_xi(m)).abs() < 0.05,
                "m={m} mc={mc} blom={}",
                blom_xi(m)
            );
        }
    }

    #[test]
    fn p_max_tail_example_from_paper() {
        // paper: M=128, upper 3.5% of cycle times -> ~99% of maxima
        let p = p_max_in_tail(0.035, 128);
        assert!(p > 0.98 && p < 0.999, "p={p}");
    }

    #[test]
    fn kde_integrates_to_one() {
        let mut r = Pcg64::seed_from_u64(2);
        let xs: Vec<f64> = (0..2000).map(|_| r.normal()).collect();
        let grid: Vec<f64> = (-400..=400).map(|i| i as f64 * 0.01).collect();
        let dens = kde(&xs, &grid);
        let integral: f64 = dens.iter().sum::<f64>() * 0.01;
        assert!((integral - 1.0).abs() < 0.02, "integral={integral}");
    }

    #[test]
    fn histogram_counts_all_inside() {
        let xs = [0.1, 0.2, 0.5, 0.9];
        let (centers, counts) = histogram(&xs, 0.0, 1.0, 2);
        assert_eq!(centers.len(), 2);
        assert_eq!(counts.iter().sum::<usize>(), 4);
        // 0.5 falls into the second half-open bin
        assert_eq!(counts[0], 2);
        assert_eq!(counts[1], 2);
    }

    #[test]
    fn ar1_fit_recovers_phi() {
        let mut r = Pcg64::seed_from_u64(3);
        let phi = 0.8;
        let mut x = 0.0;
        let xs: Vec<f64> = (0..200_000)
            .map(|_| {
                x = phi * x + r.normal();
                x
            })
            .collect();
        let (mu, phi_hat, sig) = fit_ar1(&xs);
        assert!(mu.abs() < 0.05, "mu={mu}");
        assert!((phi_hat - phi).abs() < 0.02, "phi={phi_hat}");
        assert!((sig - 1.0).abs() < 0.05, "sig={sig}");
    }

    #[test]
    fn lump_sums_matches_clt_scaling() {
        let mut r = Pcg64::seed_from_u64(4);
        let xs: Vec<f64> = (0..100_000).map(|_| r.normal_ms(10.0, 1.0)).collect();
        let lumped = lump_sums(&xs, 10);
        assert!((mean(&lumped) - 100.0).abs() < 0.2);
        // std should scale by sqrt(10), so CV by 1/sqrt(10)
        let ratio = cv(&lumped) / cv(&xs);
        assert!((ratio - 1.0 / 10f64.sqrt()).abs() < 0.02, "ratio={ratio}");
    }

    #[test]
    fn lump_sums_drops_partial_chunk() {
        assert_eq!(lump_sums(&[1.0, 2.0, 3.0, 4.0, 5.0], 2), vec![3.0, 7.0]);
    }

    #[test]
    fn moments_match_batch_statistics() {
        let mut r = Pcg64::seed_from_u64(5);
        let xs: Vec<f64> = (0..5000).map(|_| r.normal_ms(3.0, 0.5)).collect();
        let mut m = Moments::new();
        for &x in &xs {
            m.push(x);
        }
        assert_eq!(m.n(), xs.len() as u64);
        assert!((m.mean() - mean(&xs)).abs() < 1e-9);
        assert!((m.variance() - variance(&xs)).abs() < 1e-9);
        assert!((m.cv() - cv(&xs)).abs() < 1e-9);
        assert_eq!(m.min(), min(&xs));
        assert_eq!(m.max(), max(&xs));
    }

    #[test]
    fn moments_merge_equals_single_pass() {
        let mut r = Pcg64::seed_from_u64(6);
        let xs: Vec<f64> = (0..999).map(|_| r.normal_ms(1.0, 2.0)).collect();
        let mut whole = Moments::new();
        for &x in &xs {
            whole.push(x);
        }
        let (a, b) = xs.split_at(137);
        let mut left = Moments::new();
        let mut right = Moments::new();
        a.iter().for_each(|&x| left.push(x));
        b.iter().for_each(|&x| right.push(x));
        left.merge(&right);
        assert_eq!(left.n(), whole.n());
        assert!((left.mean() - whole.mean()).abs() < 1e-9);
        assert!((left.variance() - whole.variance()).abs() < 1e-9);
        // merging an empty accumulator is the identity, both ways
        let mut empty = Moments::new();
        empty.merge(&whole);
        assert!((empty.mean() - whole.mean()).abs() < 1e-12);
        whole.merge(&Moments::new());
        assert!((left.mean() - whole.mean()).abs() < 1e-9);
    }

    #[test]
    fn moments_empty_defaults_are_finite() {
        let m = Moments::new();
        assert_eq!(m.n(), 0);
        assert_eq!(m.mean(), 0.0);
        assert_eq!(m.variance(), 0.0);
        assert_eq!(m.cv(), 0.0);
        assert_eq!(m.min(), 0.0);
        assert_eq!(m.max(), 0.0);
    }

    #[test]
    fn log2_bins_cover_and_order() {
        // edges are octaves from 1 ns; indices are monotone in x and
        // saturate at the ends instead of panicking
        assert_eq!(log2_bin(0.0), 0);
        assert_eq!(log2_bin(-1.0), 0);
        assert_eq!(log2_bin(1e-9), 0);
        assert_eq!(log2_bin(3e-9), 1);
        assert_eq!(log2_bin(f64::MAX), LOG2_HIST_BINS - 1);
        let samples = [1e-8, 1e-6, 1e-4, 1e-2, 1.0, 100.0];
        let bins: Vec<usize> = samples.iter().map(|&x| log2_bin(x)).collect();
        assert!(bins.windows(2).all(|w| w[0] < w[1]), "{bins:?}");
        for &x in &samples {
            let b = log2_bin(x);
            assert!(log2_bin_lo(b) <= x && x < log2_bin_lo(b + 1));
        }
    }

    #[test]
    fn log2_hist_quantile_brackets_true_quantile() {
        // the estimate is the geometric midpoint of the right bin, so it
        // is within one octave of the exact sample quantile
        let mut r = Pcg64::seed_from_u64(7);
        let xs: Vec<f64> =
            (0..4000).map(|_| 1.6e-3 * (1.0 + 0.06 * r.normal())).collect();
        let mut counts = vec![0u64; LOG2_HIST_BINS];
        for &x in &xs {
            counts[log2_bin(x)] += 1;
        }
        for &q in &[0.5, 0.9, 0.99] {
            let est = log2_hist_quantile(&counts, q);
            let exact = quantile(&xs, q);
            assert!(
                est > exact / 2.0 && est < exact * 2.0,
                "q={q} est={est} exact={exact}"
            );
        }
        assert_eq!(log2_hist_quantile(&vec![0u64; LOG2_HIST_BINS], 0.5), 0.0);
    }
}
