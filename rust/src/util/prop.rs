//! Miniature property-testing harness (no `proptest` offline).
//!
//! [`check`] runs a property over `n` generated cases, each driven by a
//! deterministically-derived RNG; on failure it re-reports the failing
//! case index and seed so the case can be replayed exactly.  Shrinking is
//! intentionally out of scope — generators here produce small cases to
//! begin with.

use crate::util::rng::Pcg64;

/// Run `prop` over `n` cases.  `gen` builds a case from the per-case RNG.
/// The property returns `Err(reason)` to fail.
///
/// Panics with the case index, master seed and reason on the first
/// failure, so `PROP_SEED=<seed> cargo test` style replaying is trivial.
pub fn check<T, G, P>(name: &str, n: usize, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Pcg64) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let master_seed = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    for case in 0..n {
        let mut rng = Pcg64::new(master_seed, case as u64);
        let input = gen(&mut rng);
        if let Err(reason) = prop(&input) {
            panic!(
                "property '{name}' failed at case {case} \
                 (PROP_SEED={master_seed}): {reason}\ninput: {input:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check(
            "tautology",
            25,
            |rng| rng.below(100),
            |_| {
                count += 1;
                Ok(())
            },
        );
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_context() {
        check(
            "fails",
            10,
            |rng| rng.below(100),
            |&x| {
                if x < 1000 {
                    Err(format!("x={x}"))
                } else {
                    Ok(())
                }
            },
        );
    }

    #[test]
    fn cases_are_deterministic() {
        let mut first: Vec<u64> = Vec::new();
        check("collect1", 5, |r| r.next_u64(), |&x| {
            first.push(x);
            Ok(())
        });
        let mut second: Vec<u64> = Vec::new();
        check("collect2", 5, |r| r.next_u64(), |&x| {
            second.push(x);
            Ok(())
        });
        assert_eq!(first, second);
    }
}
