//! Phase timers mirroring NEST's internal high-resolution timers (§4.1).
//!
//! The functional engine measures real wall-clock time per phase; the
//! virtual cluster accounts simulated time through the same interface so
//! downstream reporting (real-time factors, phase breakdowns) is shared.

use std::fmt;
use std::time::Instant;

/// The simulation phases instrumented by the paper (Fig 1/7 legend).
///
/// `Communicate` is split as in §4.1: `Synchronize` is the waiting time at
/// the barrier in front of the collective; `DataExchange` is the
/// `MPI_Alltoall` itself.  `CollocateLocal`/`DeliverLocal` do not exist in
/// the paper's accounting and are folded into the main phases.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Phase {
    Deliver,
    Update,
    Collocate,
    Synchronize,
    DataExchange,
}

impl Phase {
    pub const ALL: [Phase; 5] = [
        Phase::Deliver,
        Phase::Update,
        Phase::Collocate,
        Phase::Synchronize,
        Phase::DataExchange,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Phase::Deliver => "deliver",
            Phase::Update => "update",
            Phase::Collocate => "collocate",
            Phase::Synchronize => "synchronize",
            Phase::DataExchange => "data-exchange",
        }
    }
}

impl fmt::Display for Phase {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Accumulated seconds per phase (real or simulated).
#[derive(Clone, Debug, Default)]
pub struct PhaseTimes {
    secs: [f64; 5],
}

impl PhaseTimes {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn add(&mut self, phase: Phase, secs: f64) {
        self.secs[phase as usize] += secs;
    }

    #[inline]
    pub fn get(&self, phase: Phase) -> f64 {
        self.secs[phase as usize]
    }

    /// Total across phases.
    pub fn total(&self) -> f64 {
        self.secs.iter().sum()
    }

    /// Cycle time in the paper's sense (eq 18): deliver + update +
    /// collocate, excluding communication.
    pub fn cycle_time(&self) -> f64 {
        self.get(Phase::Deliver) + self.get(Phase::Update)
            + self.get(Phase::Collocate)
    }

    /// Communication in the paper's sense: synchronize + data exchange.
    pub fn communicate(&self) -> f64 {
        self.get(Phase::Synchronize) + self.get(Phase::DataExchange)
    }

    /// Element-wise sum.
    pub fn merge(&mut self, other: &PhaseTimes) {
        for i in 0..5 {
            self.secs[i] += other.secs[i];
        }
    }

    /// Element-wise mean over several ranks' accumulators — the paper
    /// averages cumulative phase durations across MPI processes.
    pub fn mean_of(others: &[PhaseTimes]) -> PhaseTimes {
        let mut out = PhaseTimes::new();
        if others.is_empty() {
            return out;
        }
        for o in others {
            out.merge(o);
        }
        for s in &mut out.secs {
            *s /= others.len() as f64;
        }
        out
    }

    /// Element-wise max over several ranks' accumulators — the
    /// slowest-rank profile that the barrier in front of the collective
    /// makes everyone wait for (the paper's central bottleneck).
    pub fn max_of(others: &[PhaseTimes]) -> PhaseTimes {
        let mut out = PhaseTimes::new();
        for o in others {
            for i in 0..5 {
                out.secs[i] = out.secs[i].max(o.secs[i]);
            }
        }
        out
    }

    /// Real-time factor: wall-clock / model time.
    pub fn rtf(&self, t_model_secs: f64) -> f64 {
        self.total() / t_model_secs
    }
}

/// Wall-clock stopwatch that charges elapsed time to a [`PhaseTimes`].
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    /// Elapsed seconds since start (or last lap) and reset.
    pub fn lap(&mut self) -> f64 {
        let now = Instant::now();
        let secs = now.duration_since(self.start).as_secs_f64();
        self.start = now;
        secs
    }

    /// Charge the elapsed lap to `phase`.
    pub fn charge(&mut self, times: &mut PhaseTimes, phase: Phase) -> f64 {
        let secs = self.lap();
        times.add(phase, secs);
        secs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_accounting() {
        let mut t = PhaseTimes::new();
        t.add(Phase::Deliver, 1.0);
        t.add(Phase::Update, 2.0);
        t.add(Phase::Collocate, 0.5);
        t.add(Phase::Synchronize, 0.25);
        t.add(Phase::DataExchange, 0.25);
        assert_eq!(t.cycle_time(), 3.5);
        assert_eq!(t.communicate(), 0.5);
        assert_eq!(t.total(), 4.0);
        assert_eq!(t.rtf(2.0), 2.0);
    }

    #[test]
    fn mean_of_ranks() {
        let mut a = PhaseTimes::new();
        a.add(Phase::Update, 2.0);
        let mut b = PhaseTimes::new();
        b.add(Phase::Update, 4.0);
        let m = PhaseTimes::mean_of(&[a, b]);
        assert_eq!(m.get(Phase::Update), 3.0);
    }

    #[test]
    fn max_of_ranks_is_elementwise() {
        let mut a = PhaseTimes::new();
        a.add(Phase::Update, 2.0);
        a.add(Phase::Deliver, 5.0);
        let mut b = PhaseTimes::new();
        b.add(Phase::Update, 4.0);
        b.add(Phase::Deliver, 1.0);
        let m = PhaseTimes::max_of(&[a, b]);
        assert_eq!(m.get(Phase::Update), 4.0);
        assert_eq!(m.get(Phase::Deliver), 5.0);
        assert_eq!(PhaseTimes::max_of(&[]).total(), 0.0);
    }

    #[test]
    fn stopwatch_measures_positive_time() {
        let mut sw = Stopwatch::start();
        let mut t = PhaseTimes::new();
        std::hint::black_box((0..10_000).sum::<u64>());
        let secs = sw.charge(&mut t, Phase::Update);
        assert!(secs >= 0.0);
        assert_eq!(t.get(Phase::Update), secs);
    }

    #[test]
    fn all_phases_enumerated() {
        assert_eq!(Phase::ALL.len(), 5);
        let names: std::collections::HashSet<_> =
            Phase::ALL.iter().map(|p| p.name()).collect();
        assert_eq!(names.len(), 5);
    }
}
