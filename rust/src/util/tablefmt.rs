//! Fixed-width text tables for figure/bench output.
//!
//! Every figure harness prints its rows through this module so results are
//! grep-able and diff-able; the same rows are also emitted as JSON into
//! `results/`.

/// A simple column-aligned table builder.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width mismatch: {cells:?}"
        );
        self.rows.push(cells);
        self
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    out.push_str("  ");
                }
                // right-align numbers-ish columns, left-align first column
                if i == 0 {
                    out.push_str(&format!("{:<w$}", c, w = widths[i]));
                } else {
                    out.push_str(&format!("{:>w$}", c, w = widths[i]));
                }
            }
            out.push('\n');
        };
        fmt_row(&self.headers, &mut out);
        let total: usize =
            widths.iter().sum::<usize>() + 2 * (ncols.saturating_sub(1));
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            fmt_row(row, &mut out);
        }
        out
    }
}

/// Format a float with a sensible number of significant digits for tables.
pub fn fnum(x: f64) -> String {
    if x == 0.0 {
        "0".into()
    } else if x.abs() >= 100.0 {
        format!("{x:.1}")
    } else if x.abs() >= 1.0 {
        format!("{x:.2}")
    } else if x.abs() >= 0.001 {
        format!("{x:.4}")
    } else {
        format!("{x:.3e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(vec!["alpha".into(), "1.0".into()]);
        t.row(vec!["b".into(), "12345.6".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("name"));
        assert!(lines[2].ends_with("1.0"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn fnum_ranges() {
        assert_eq!(fnum(0.0), "0");
        assert_eq!(fnum(123.456), "123.5");
        assert_eq!(fnum(1.234), "1.23");
        assert_eq!(fnum(0.0123), "0.0123");
        assert!(fnum(1e-6).contains('e'));
    }
}
