//! Minimal command-line argument parser (no `clap` offline).
//!
//! Grammar: `nsim <subcommand> [positional ...] [--key value | --key=value
//! | --flag]`.  Typed accessors with defaults; unknown-option detection is
//! the caller's responsibility via [`Args::finish`].

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    consumed: std::cell::RefCell<std::collections::BTreeSet<String>>,
}

#[derive(Debug)]
pub enum CliError {
    MissingValue(String),
    BadValue { key: String, value: String, why: String },
    Unknown(String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::MissingValue(key) => {
                write!(f, "missing value for option --{key}")
            }
            CliError::BadValue { key, value, why } => {
                write!(f, "invalid value for --{key}: {value:?} ({why})")
            }
            CliError::Unknown(opts) => write!(f, "unknown option(s): {opts}"),
        }
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse a raw argument list (without the program name).
    pub fn parse<I, S>(raw: I) -> Result<Args, CliError>
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut positional = Vec::new();
        let mut options = BTreeMap::new();
        let mut it = raw.into_iter().map(Into::into).peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    options.insert(k.to_string(), v.to_string());
                } else {
                    // flag or space-separated value
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            options.insert(body.to_string(), it.next().unwrap());
                        }
                        _ => {
                            options.insert(body.to_string(), "true".into());
                        }
                    }
                }
            } else {
                positional.push(arg);
            }
        }
        Ok(Args {
            positional,
            options,
            consumed: Default::default(),
        })
    }

    /// From `std::env::args()` (skips argv[0]).
    pub fn from_env() -> Result<Args, CliError> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    fn raw(&self, key: &str) -> Option<&str> {
        let v = self.options.get(key).map(|s| s.as_str());
        if v.is_some() {
            self.consumed.borrow_mut().insert(key.to_string());
        }
        v
    }

    pub fn str_opt(&self, key: &str) -> Option<String> {
        self.raw(key).map(|s| s.to_string())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.raw(key).unwrap_or(default).to_string()
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.raw(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64, CliError> {
        match self.raw(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e: std::num::ParseFloatError| {
                CliError::BadValue {
                    key: key.into(),
                    value: v.into(),
                    why: e.to_string(),
                }
            }),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize, CliError> {
        match self.raw(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e: std::num::ParseIntError| {
                CliError::BadValue {
                    key: key.into(),
                    value: v.into(),
                    why: e.to_string(),
                }
            }),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64, CliError> {
        match self.raw(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e: std::num::ParseIntError| {
                CliError::BadValue {
                    key: key.into(),
                    value: v.into(),
                    why: e.to_string(),
                }
            }),
        }
    }

    /// Optional f64 — `None` when the option is absent (unlike
    /// [`Args::f64_or`] there is no default to fall back on, e.g. the
    /// watchdog deadline where absence means "disabled").
    pub fn f64_opt(&self, key: &str) -> Result<Option<f64>, CliError> {
        match self.raw(key) {
            None => Ok(None),
            Some(v) => v.parse().map(Some).map_err(
                |e: std::num::ParseFloatError| CliError::BadValue {
                    key: key.into(),
                    value: v.into(),
                    why: e.to_string(),
                },
            ),
        }
    }

    /// Comma-separated usize list, e.g. `--ranks 16,32,64`.
    pub fn usize_list_or(
        &self,
        key: &str,
        default: &[usize],
    ) -> Result<Vec<usize>, CliError> {
        match self.raw(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim().parse().map_err(|e: std::num::ParseIntError| {
                        CliError::BadValue {
                            key: key.into(),
                            value: v.into(),
                            why: e.to_string(),
                        }
                    })
                })
                .collect(),
        }
    }

    /// Error if any provided option was never consumed by an accessor.
    pub fn finish(&self) -> Result<(), CliError> {
        let consumed = self.consumed.borrow();
        let unknown: Vec<_> = self
            .options
            .keys()
            .filter(|k| !consumed.contains(*k))
            .cloned()
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(CliError::Unknown(unknown.join(", ")))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(raw: &[&str]) -> Args {
        Args::parse(raw.iter().copied()).unwrap()
    }

    #[test]
    fn positional_and_subcommand() {
        let a = args(&["figure", "fig7a"]);
        assert_eq!(a.subcommand(), Some("figure"));
        assert_eq!(a.positional[1], "fig7a");
    }

    #[test]
    fn key_value_both_syntaxes() {
        let a = args(&["run", "--ranks", "32", "--seed=654"]);
        assert_eq!(a.usize_or("ranks", 0).unwrap(), 32);
        assert_eq!(a.u64_or("seed", 0).unwrap(), 654);
    }

    #[test]
    fn flags() {
        let a = args(&["run", "--verbose", "--ranks", "8"]);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.usize_or("ranks", 0).unwrap(), 8);
    }

    #[test]
    fn defaults() {
        let a = args(&["run"]);
        assert_eq!(a.f64_or("t-model", 10.0).unwrap(), 10.0);
        assert_eq!(a.str_or("strategy", "conventional"), "conventional");
    }

    #[test]
    fn optional_f64() {
        let a = args(&["run", "--comm-timeout", "2.5"]);
        assert_eq!(a.f64_opt("comm-timeout").unwrap(), Some(2.5));
        assert_eq!(a.f64_opt("absent").unwrap(), None);
        let a = args(&["run", "--comm-timeout", "soon"]);
        assert!(a.f64_opt("comm-timeout").is_err());
    }

    #[test]
    fn bad_value_errors() {
        let a = args(&["run", "--ranks", "many"]);
        assert!(a.usize_or("ranks", 0).is_err());
    }

    #[test]
    fn list_parsing() {
        let a = args(&["run", "--ms", "16,32, 64"]);
        assert_eq!(a.usize_list_or("ms", &[]).unwrap(), vec![16, 32, 64]);
    }

    #[test]
    fn unknown_options_detected() {
        let a = args(&["run", "--bogus", "1", "--ranks", "2"]);
        let _ = a.usize_or("ranks", 0);
        let err = a.finish().unwrap_err();
        assert!(err.to_string().contains("bogus"));
    }
}
