//! PCG64 pseudo-random number generator plus the distributions used by the
//! network generator and the virtual cluster.
//!
//! Implements the PCG XSL-RR 128/64 generator (O'Neill 2014) — the same
//! family NEST exposes — with deterministic seeding and jump-free
//! substreams via odd stream increments.  No external crates.

/// PCG XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128, // odd
    /// Cached second normal of the last Marsaglia-polar pair (perf: the
    /// virtual cluster draws several normals per rank-cycle).
    spare_normal: Option<f64>,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Seed with a 64-bit seed and a stream id (distinct streams are
    /// statistically independent).
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Self { state: 0, inc, spare_normal: None };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(inc);
        rng
    }

    /// Seed with stream 0.
    pub fn seed_from_u64(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53-bit precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Unbiased uniform integer in `[0, n)` (Lemire rejection).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Standard normal via Marsaglia polar method with the pair's second
    /// value cached.
    pub fn normal(&mut self) -> f64 {
        if let Some(x) = self.spare_normal.take() {
            return x;
        }
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.spare_normal = Some(v * f);
                return u * f;
            }
        }
    }

    /// Normal with the given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Normal truncated below at `lo` (resampling; `lo` must be within a
    /// few sigma of the mean to terminate quickly).
    pub fn normal_truncated_low(&mut self, mean: f64, std: f64, lo: f64) -> f64 {
        if std <= 0.0 {
            return mean.max(lo);
        }
        for _ in 0..10_000 {
            let x = self.normal_ms(mean, std);
            if x >= lo {
                return x;
            }
        }
        lo // pathological parameterization: clamp
    }

    /// Poisson-distributed count (Knuth for small lambda, normal
    /// approximation above 30 — adequate for spike-count modelling).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 30.0 {
            let x = self.normal_ms(lambda, lambda.sqrt());
            return x.max(0.0).round() as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.uniform();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (Floyd's algorithm).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} distinct from {n}");
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in n - k..n {
            let t = self.below_usize(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::seed_from_u64(42);
        let mut b = Pcg64::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(42, 0);
        let mut b = Pcg64::new(42, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Pcg64::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Pcg64::seed_from_u64(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_ish() {
        let mut r = Pcg64::seed_from_u64(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seed_from_u64(5);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn truncated_normal_respects_floor() {
        let mut r = Pcg64::seed_from_u64(9);
        for _ in 0..10_000 {
            let x = r.normal_truncated_low(1.0, 2.0, 0.5);
            assert!(x >= 0.5);
        }
    }

    #[test]
    fn poisson_mean_matches_lambda() {
        let mut r = Pcg64::seed_from_u64(13);
        for &lam in &[0.5, 3.0, 12.0, 80.0] {
            let n = 20_000;
            let mean: f64 =
                (0..n).map(|_| r.poisson(lam) as f64).sum::<f64>() / n as f64;
            assert!(
                (mean - lam).abs() < lam.max(1.0) * 0.05,
                "lambda={lam} mean={mean}"
            );
        }
    }

    #[test]
    fn sample_distinct_is_distinct_and_in_range() {
        let mut r = Pcg64::seed_from_u64(17);
        let got = r.sample_distinct(100, 30);
        let set: std::collections::HashSet<_> = got.iter().collect();
        assert_eq!(set.len(), 30);
        assert!(got.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seed_from_u64(23);
        let mut xs: Vec<u32> = (0..1000).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..1000).collect::<Vec<_>>());
        assert_ne!(xs, (0..1000).collect::<Vec<_>>());
    }
}
