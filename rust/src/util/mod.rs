//! Hand-rolled substrate utilities.
//!
//! The offline crate registry ships no `rand`, `serde`, `clap` or
//! `criterion`, so this module provides the minimal, well-tested
//! equivalents the rest of the crate builds on: a PCG64 RNG with the
//! distributions the paper needs, descriptive statistics + order
//! statistics, a small JSON reader/writer, phase timers, an argument
//! parser and a fixed-width table formatter.

pub mod rng;
pub mod stats;
pub mod json;
pub mod timers;
pub mod cli;
pub mod tablefmt;
pub mod prop;
