//! Minimal JSON reader/writer (RFC 8259 subset, no external crates).
//!
//! Used for the artifact manifest, config files and machine-readable
//! results.  Supports the full value grammar; numbers are f64; strings
//! handle the standard escapes incl. `\uXXXX` (BMP only — sufficient for
//! our ASCII configs).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.  Objects use a BTreeMap for deterministic serialization.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    /// Non-negative integer access (epoch counters, checkpoint periods).
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|x| *x >= 0.0).map(|x| x as u64)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build an array of numbers.
    pub fn nums(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, ParseError> {
        Err(ParseError { pos: self.pos, msg: msg.into() })
    }

    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(format!("expected '{}'", b as char))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            Some(b) => self.err(format!("unexpected byte '{}'", b as char)),
            None => self.err("unexpected end of input"),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            self.err(format!("invalid literal, expected {lit}"))
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(arr));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return self.err("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(
                                &self.bytes[self.pos + 1..self.pos + 5],
                            )
                            .map_err(|_| ParseError {
                                pos: self.pos,
                                msg: "bad \\u escape".into(),
                            })?;
                            let cp = u32::from_str_radix(hex, 16).map_err(
                                |_| ParseError {
                                    pos: self.pos,
                                    msg: "bad \\u escape".into(),
                                },
                            )?;
                            out.push(
                                char::from_u32(cp).unwrap_or('\u{fffd}'),
                            );
                            self.pos += 4;
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let s = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| ParseError {
                            pos: self.pos,
                            msg: "invalid utf-8".into(),
                        })?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b) if b.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b) if b.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b) if b.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| ParseError { pos: start, msg: e.to_string() })
    }
}

/// Parse a JSON document (trailing whitespace allowed, nothing else).
pub fn parse(text: &str) -> Result<Json, ParseError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_value(v: &Json, indent: usize, cur: usize, out: &mut String) {
    let pad = |n: usize, out: &mut String| {
        if indent > 0 {
            out.push('\n');
            out.push_str(&" ".repeat(n));
        }
    };
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(x) => {
            if x.fract() == 0.0 && x.abs() < 1e15 {
                out.push_str(&format!("{}", *x as i64));
            } else {
                out.push_str(&format!("{x}"));
            }
        }
        Json::Str(s) => escape_into(s, out),
        Json::Arr(a) => {
            if a.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                pad(cur + indent, out);
                write_value(item, indent, cur + indent, out);
            }
            pad(cur, out);
            out.push(']');
        }
        Json::Obj(o) => {
            if o.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                pad(cur + indent, out);
                escape_into(k, out);
                out.push(':');
                if indent > 0 {
                    out.push(' ');
                }
                write_value(val, indent, cur + indent, out);
            }
            pad(cur, out);
            out.push('}');
        }
    }
}

/// Serialize compactly.
pub fn to_string(v: &Json) -> String {
    let mut s = String::new();
    write_value(v, 0, 0, &mut s);
    s
}

/// Serialize with 2-space indentation.
pub fn to_string_pretty(v: &Json) -> String {
    let mut s = String::new();
    write_value(v, 2, 0, &mut s);
    s
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&to_string(self))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("3.25").unwrap(), Json::Num(3.25));
        assert_eq!(parse("-2e3").unwrap(), Json::Num(-2000.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1], Json::Num(2.0));
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"m":[1,2.5,true,null,"s"],"n":{"x":-1}}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&to_string(&v)).unwrap(), v);
        assert_eq!(parse(&to_string_pretty(&v)).unwrap(), v);
    }

    #[test]
    fn escapes_control_chars() {
        let v = Json::Str("a\"b\\c\nd\u{1}".into());
        let s = to_string(&v);
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn u64_accessor_rejects_negatives() {
        assert_eq!(parse("7").unwrap().as_u64(), Some(7));
        assert_eq!(parse("0").unwrap().as_u64(), Some(0));
        assert_eq!(parse("-3").unwrap().as_u64(), None);
        assert_eq!(parse("\"7\"").unwrap().as_u64(), None);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(to_string(&Json::Num(7.0)), "7");
        assert_eq!(to_string(&Json::Num(7.5)), "7.5");
    }

    #[test]
    fn manifest_like_document() {
        let text = std::fs::read_to_string(
            concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json"),
        );
        if let Ok(text) = text {
            let v = parse(&text).unwrap();
            assert!(v.get("artifacts").unwrap().as_arr().unwrap().len() >= 1);
        }
    }
}
