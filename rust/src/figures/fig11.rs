//! Fig 11: strong-scaling comparison of the MAM and the MAM-benchmark
//! (both 32 areas, conventional strategy).

use super::common::{mean_phase_rtf, phase_row_cells, phase_row_json, PHASE_HEADERS, SEEDS};
use super::{FigOptions, FigureOutput};
use crate::config::Strategy;
use crate::models;
use crate::util::json::Json;
use crate::util::tablefmt::Table;
use crate::vcluster::MachineProfile;
use anyhow::Result;

pub fn fig11(opts: &FigOptions) -> Result<FigureOutput> {
    let machine = MachineProfile::supermuc_ng();
    let mam = models::mam(1.0, 0.1)?;
    let mamb = models::mam_benchmark(32, 1.0, 0.1)?;
    let mut table = Table::new(&PHASE_HEADERS);
    let mut rows = Vec::new();
    for (name, spec) in [("MAM", &mam), ("MAM-benchmark", &mamb)] {
        for &m in &[16usize, 32, 64, 128] {
            let (phases, total) = mean_phase_rtf(
                &machine,
                spec,
                Strategy::Conventional,
                m,
                opts.t_model_ms,
                &SEEDS,
            )?;
            table.row(phase_row_cells(name, m, &phases, total));
            rows.push(phase_row_json(name, m, &phases, total));
        }
    }
    Ok(FigureOutput {
        name: "fig11",
        title: "strong scaling: MAM vs MAM-benchmark (conventional, 32 areas)"
            .into(),
        table: table.render(),
        json: Json::obj(vec![("rows", Json::Arr(rows))]),
    })
}
