//! Fig 7: (a) MAM-benchmark weak scaling, conventional vs structure-aware;
//! (b) measured cycle-time distributions at M=128.

use super::common::{
    mean_phase_rtf, phase_row_cells, phase_row_json, vc_run, PHASE_HEADERS,
    SEEDS,
};
use super::{FigOptions, FigureOutput};
use crate::config::Strategy;
use crate::models;
use crate::util::json::Json;
use crate::util::stats;
use crate::util::tablefmt::{fnum, Table};
use crate::vcluster::MachineProfile;
use anyhow::Result;

const MS: [usize; 4] = [16, 32, 64, 128];

/// Fig 7a: weak scaling (areas = M), per-phase RTFs for both strategies.
pub fn fig7a(opts: &FigOptions) -> Result<FigureOutput> {
    let machine = MachineProfile::supermuc_ng();
    let mut table = Table::new(&PHASE_HEADERS);
    let mut rows = Vec::new();
    let mut summary = Vec::new();
    for strategy in [Strategy::Conventional, Strategy::StructureAware] {
        for &m in &MS {
            let spec = models::mam_benchmark(m, 1.0, 1.0)?;
            let (phases, total) = mean_phase_rtf(
                &machine,
                &spec,
                strategy,
                m,
                opts.t_model_ms,
                &SEEDS,
            )?;
            let label = strategy.name();
            table.row(phase_row_cells(label, m, &phases, total));
            rows.push(phase_row_json(label, m, &phases, total));
            summary.push((strategy, m, phases, total));
        }
    }
    // headline numbers at M=128
    let conv128 = summary
        .iter()
        .find(|(s, m, _, _)| *s == Strategy::Conventional && *m == 128)
        .unwrap();
    let stru128 = summary
        .iter()
        .find(|(s, m, _, _)| *s == Strategy::StructureAware && *m == 128)
        .unwrap();
    let runtime_red = 1.0 - stru128.3 / conv128.3;
    let deliver_red = 1.0 - stru128.2[0] / conv128.2[0];
    let sync_red = 1.0 - stru128.2[3] / conv128.2[3];
    let data_red = 1.0 - stru128.2[4] / conv128.2[4];
    let footer = format!(
        "M=128: runtime -{:.0}%, deliver -{:.0}%, sync -{:.0}%, \
         data-exchange -{:.0}%  (paper: -30%, -25%, -48%, -76%)",
        100.0 * runtime_red,
        100.0 * deliver_red,
        100.0 * sync_red,
        100.0 * data_red
    );
    Ok(FigureOutput {
        name: "fig7a",
        title: "MAM-benchmark weak scaling, conventional vs structure-aware"
            .into(),
        table: format!("{}\n{footer}", table.render()),
        json: Json::obj(vec![
            ("rows", Json::Arr(rows)),
            ("runtime_reduction_m128", runtime_red.into()),
            ("deliver_reduction_m128", deliver_red.into()),
            ("sync_reduction_m128", sync_red.into()),
            ("data_reduction_m128", data_red.into()),
        ]),
    })
}

/// Fig 7b: distributions of (lumped) cycle times and per-cycle maxima at
/// M=128, seed 654.
pub fn fig7b(opts: &FigOptions) -> Result<FigureOutput> {
    let machine = MachineProfile::supermuc_ng();
    let spec = models::mam_benchmark(128, 1.0, 1.0)?;
    let mut table = Table::new(&[
        "strategy",
        "mean [ms]",
        "CV",
        "q96.5 [ms]",
        "max [ms]",
        "maxima>q96.5",
    ]);
    let mut json_rows = Vec::new();
    let mut cvs = Vec::new();
    for strategy in [Strategy::Conventional, Strategy::StructureAware] {
        let res = vc_run(
            &machine,
            &spec,
            strategy,
            128,
            opts.t_model_ms,
            654,
            true,
        )?;
        let d = if strategy.dual_pathways() { 10 } else { 1 };
        // lumped cycle times across all ranks
        let mut all: Vec<f64> = Vec::new();
        for row in &res.cycle_times {
            all.extend(stats::lump_sums(row, d));
        }
        let mean = stats::mean(&all);
        let cv = stats::cv(&all);
        let q = stats::quantile(&all, 0.965);
        let maxima = &res.epoch_maxima;
        let above =
            maxima.iter().filter(|&&x| x >= q).count() as f64
                / maxima.len() as f64;
        table.row(vec![
            strategy.name().into(),
            fnum(mean * 1e3),
            fnum(cv),
            fnum(q * 1e3),
            fnum(stats::max(&all) * 1e3),
            format!("{:.0}%", above * 100.0),
        ]);
        json_rows.push(Json::obj(vec![
            ("strategy", strategy.name().into()),
            ("mean_ms", (mean * 1e3).into()),
            ("cv", cv.into()),
            ("q965_ms", (q * 1e3).into()),
            ("max_ms", (stats::max(&all) * 1e3).into()),
            ("maxima_above_q", above.into()),
        ]));
        cvs.push(cv);
    }
    let cv_ratio = cvs[1] / cvs[0];
    let footer = format!(
        "CV ratio struct/conv = {:.2} (paper: 0.71; iid theory eq 7: {:.2}) \
         — serial correlations prevent the full 1/sqrt(D) gain",
        cv_ratio,
        1.0 / 10f64.sqrt()
    );
    Ok(FigureOutput {
        name: "fig7b",
        title: "cycle-time distributions at M=128 (lumped for struct)".into(),
        table: format!("{}\n{footer}", table.render()),
        json: Json::obj(vec![
            ("rows", Json::Arr(json_rows)),
            ("cv_ratio", cv_ratio.into()),
        ]),
    })
}
