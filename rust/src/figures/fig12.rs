//! Fig 12: temporal evolution of per-rank cycle times — the serial
//! correlations that break the iid assumption of the sync theory.

use super::common::vc_run;
use super::{FigOptions, FigureOutput};
use crate::config::Strategy;
use crate::models;
use crate::util::json::Json;
use crate::util::stats;
use crate::util::tablefmt::{fnum, Table};
use crate::vcluster::MachineProfile;
use anyhow::Result;

pub fn fig12(opts: &FigOptions) -> Result<FigureOutput> {
    let machine = MachineProfile::supermuc_ng();
    let spec = models::mam_benchmark(128, 1.0, 1.0)?;
    let mut table = Table::new(&[
        "strategy",
        "ac lag1",
        "ac lag100",
        "ac lag1000",
        "rank-mean CV",
        "AR(1) phi",
    ]);
    let mut json_rows = Vec::new();
    for strategy in [Strategy::Conventional, Strategy::StructureAware] {
        let res = vc_run(
            &machine,
            &spec,
            strategy,
            128,
            opts.t_model_ms,
            654,
            true,
        )?;
        // pool autocorrelation over a handful of ranks
        let probe: Vec<usize> = vec![0, 31, 64, 97, 127];
        let mut ac1 = 0.0;
        let mut ac100 = 0.0;
        let mut ac1000 = 0.0;
        let mut phi = 0.0;
        for &r in &probe {
            let row = &res.cycle_times[r];
            ac1 += stats::autocorr(row, 1);
            ac100 += stats::autocorr(row, 100);
            ac1000 += stats::autocorr(row, 1000.min(row.len() / 2));
            phi += stats::fit_ar1(row).1;
        }
        let n = probe.len() as f64;
        (ac1, ac100, ac1000, phi) = (ac1 / n, ac100 / n, ac1000 / n, phi / n);
        // spread of per-rank mean cycle times (systematically faster /
        // slower processes)
        let rank_means: Vec<f64> = res
            .cycle_times
            .iter()
            .map(|row| stats::mean(row))
            .collect();
        let rm_cv = stats::cv(&rank_means);
        table.row(vec![
            strategy.name().into(),
            fnum(ac1),
            fnum(ac100),
            fnum(ac1000),
            fnum(rm_cv),
            fnum(phi),
        ]);
        // downsampled example series for plotting (rank 0)
        let row0 = &res.cycle_times[0];
        let stride = (row0.len() / 500).max(1);
        let series: Vec<f64> =
            row0.iter().step_by(stride).map(|&x| x * 1e3).collect();
        json_rows.push(Json::obj(vec![
            ("strategy", strategy.name().into()),
            ("ac_lag1", ac1.into()),
            ("ac_lag100", ac100.into()),
            ("ac_lag1000", ac1000.into()),
            ("rank_mean_cv", rm_cv.into()),
            ("ar1_phi", phi.into()),
            ("rank0_series_ms", Json::nums(&series)),
        ]));
    }
    let footer = "persistent positive autocorrelation over >=1000 cycles \
                  explains why the measured CV ratio (0.71) exceeds the \
                  iid prediction (0.32)";
    Ok(FigureOutput {
        name: "fig12",
        title: "temporal structure of per-rank cycle times (M=128)".into(),
        table: format!("{}\n{footer}", table.render()),
        json: Json::obj(vec![("rows", Json::Arr(json_rows))]),
    })
}
