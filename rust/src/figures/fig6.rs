//! Fig 6: the theoretical analyses — (a) cycle-time distributions and
//! per-cycle maxima under lumping, (b) irregular-access fractions of the
//! spike-delivery model.

use super::FigureOutput;
use crate::theory::delivery::{
    f_irr_conventional, f_irr_structure, DeliveryScenario,
};
use crate::theory::sync::{maxima_tail_coverage, CycleTimeModel};
use crate::util::json::Json;
use crate::util::stats;
use crate::util::tablefmt::{fnum, Table};
use anyhow::Result;

/// Fig 6a: N(mu, sigma) cycle times, lumped D=10; expected maxima for
/// M in {64, 128} and the upper-3.5 % quantile markers.
pub fn fig6a() -> Result<FigureOutput> {
    // parameterized like the measured MAM-benchmark distribution
    let model = CycleTimeModel::paper_default();
    let lumped = model.lumped(10);
    let mut table = Table::new(&[
        "distribution",
        "mu [ms]",
        "sigma [ms]",
        "CV",
        "E[max] M=64 [ms]",
        "E[max] M=128 [ms]",
        "q96.5 [ms]",
    ]);
    for (name, m) in [("conventional", model), ("structure-aware D=10", lumped)]
    {
        let q = m.mu + stats::norm_ppf(0.965) * m.sigma;
        table.row(vec![
            name.into(),
            fnum(m.mu * 1e3),
            fnum(m.sigma * 1e3),
            fnum(m.cv()),
            fnum(m.expected_max(64) * 1e3),
            fnum(m.expected_max(128) * 1e3),
            fnum(q * 1e3),
        ]);
    }
    let coverage = maxima_tail_coverage(0.035, 128);
    let footer = format!(
        "eq 12 at M=128: upper 3.5% of cycle times cover {:.1}% of \
         per-cycle maxima; CV ratio = {:.3} (eq 7: 1/sqrt(10) = {:.3})",
        coverage * 100.0,
        lumped.cv() / model.cv(),
        1.0 / 10f64.sqrt()
    );
    Ok(FigureOutput {
        name: "fig6a",
        title: "theoretical cycle-time distributions and maxima".into(),
        table: format!("{}\n{footer}", table.render()),
        json: Json::obj(vec![
            ("cv_conv", model.cv().into()),
            ("cv_struct", lumped.cv().into()),
            ("maxima_tail_coverage", coverage.into()),
            ("e_max_128_conv_ms", (model.expected_max(128) * 1e3).into()),
            ("e_max_128_struct_ms", (lumped.expected_max(128) * 1e3).into()),
        ]),
    })
}

/// Fig 6b: predicted fraction of irregular memory access vs number of MPI
/// processes, conventional vs structure-aware, T_M in {48, 128}.
pub fn fig6b() -> Result<FigureOutput> {
    let sc = DeliveryScenario::default();
    let ms = [8usize, 16, 32, 64, 128, 256];
    let mut table = Table::new(&[
        "M",
        "conv T=48",
        "struct T=48",
        "conv T=128",
        "struct T=128",
        "reduction T=48",
        "reduction T=128",
    ]);
    let mut rows = Vec::new();
    for &m in &ms {
        let c48 = f_irr_conventional(&sc, m, 48);
        let s48 = f_irr_structure(&sc, m, 48);
        let c128 = f_irr_conventional(&sc, m, 128);
        let s128 = f_irr_structure(&sc, m, 128);
        table.row(vec![
            m.to_string(),
            fnum(c48),
            fnum(s48),
            fnum(c128),
            fnum(s128),
            format!("{:.0}%", 100.0 * (1.0 - s48 / c48)),
            format!("{:.0}%", 100.0 * (1.0 - s128 / c128)),
        ]);
        rows.push(Json::obj(vec![
            ("m", m.into()),
            ("conv_t48", c48.into()),
            ("struct_t48", s48.into()),
            ("conv_t128", c128.into()),
            ("struct_t128", s128.into()),
        ]));
    }
    Ok(FigureOutput {
        name: "fig6b",
        title: "predicted fraction of irregular synapse accesses (eqs 13-17)"
            .into(),
        table: table.render(),
        json: Json::obj(vec![("rows", Json::Arr(rows))]),
    })
}
