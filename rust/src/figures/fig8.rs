//! Fig 8: robustness of the structure-aware scheme to heterogeneity —
//! (a) area-size variability, (b) spike-rate variability, (c) the delay
//! ratio D.

use super::common::{phase_row_cells, phase_row_json, PHASE_HEADERS};
use super::{FigOptions, FigureOutput};
use crate::config::Strategy;
use crate::models;
use crate::util::json::Json;
use crate::util::tablefmt::Table;
use crate::util::timers::Phase;
use crate::vcluster::{run_cluster, MachineProfile, VcOptions, Workload};
use anyhow::Result;

const M: usize = 64;
/// Sampling seeds for the heterogeneity draws (three per point, as in the
/// paper).
const SAMPLE_SEEDS: [u64; 3] = [1, 2, 3];

fn run_het(
    opts: &FigOptions,
    cv_size: f64,
    cv_rate: f64,
    d_min_inter: f64,
) -> Result<([f64; 5], f64)> {
    let machine = MachineProfile::supermuc_ng();
    let mut acc = [0.0f64; 5];
    let mut total = 0.0;
    for &ss in &SAMPLE_SEEDS {
        let spec = models::mam_benchmark_heterogeneous(
            M,
            1.0,
            d_min_inter,
            cv_size,
            cv_rate,
            ss,
        )?;
        let w = Workload::derive(
            &spec,
            Strategy::StructureAware,
            M,
            machine.t_m,
        )?;
        let res = run_cluster(
            &machine,
            &w,
            &VcOptions {
                t_model_ms: opts.t_model_ms,
                h_ms: spec.h_ms,
                seed: opts.seed + ss,
                record_cycle_times: false,
            },
        )?;
        let t_model_s = opts.t_model_ms / 1000.0;
        for (i, p) in Phase::ALL.iter().enumerate() {
            acc[i] += res.mean_times.get(*p) / t_model_s;
        }
        total += res.rtf();
    }
    let n = SAMPLE_SEEDS.len() as f64;
    for a in &mut acc {
        *a /= n;
    }
    Ok((acc, total / n))
}

/// Fig 8a: RTF vs CV of area size (fixed mean 130k, D=10).
pub fn fig8a(opts: &FigOptions) -> Result<FigureOutput> {
    let cvs = [0.0, 0.05, 0.1, 0.2, 0.3];
    let mut table = Table::new(&PHASE_HEADERS);
    let mut rows = Vec::new();
    for &cv in &cvs {
        let (phases, total) = run_het(opts, cv, 0.0, 1.0)?;
        let label = format!("CV(size)={cv}");
        table.row(phase_row_cells(&label, M, &phases, total));
        rows.push(phase_row_json(&label, M, &phases, total));
    }
    Ok(FigureOutput {
        name: "fig8a",
        title: "structure-aware RTF vs area-size variability (M=64)".into(),
        table: table.render(),
        json: Json::obj(vec![("rows", Json::Arr(rows))]),
    })
}

/// Fig 8b: RTF vs CV of per-area spike rate (fixed mean 2.5 /s, D=10).
pub fn fig8b(opts: &FigOptions) -> Result<FigureOutput> {
    let cvs = [0.0, 0.1, 0.25, 0.5, 1.0];
    let mut table = Table::new(&PHASE_HEADERS);
    let mut rows = Vec::new();
    for &cv in &cvs {
        let (phases, total) = run_het(opts, 0.0, cv, 1.0)?;
        let label = format!("CV(rate)={cv}");
        table.row(phase_row_cells(&label, M, &phases, total));
        rows.push(phase_row_json(&label, M, &phases, total));
    }
    Ok(FigureOutput {
        name: "fig8b",
        title: "structure-aware RTF vs spike-rate variability (M=64)".into(),
        table: table.render(),
        json: Json::obj(vec![("rows", Json::Arr(rows))]),
    })
}

/// Fig 8c: RTF vs the delay ratio D (d_min fixed at 0.1 ms).
pub fn fig8c(opts: &FigOptions) -> Result<FigureOutput> {
    let ds = [1u32, 2, 5, 10, 20, 50];
    let mut table = Table::new(&PHASE_HEADERS);
    let mut rows = Vec::new();
    let mut comm_rtfs = Vec::new();
    for &d in &ds {
        let (phases, total) = run_het(opts, 0.0, 0.0, 0.1 * d as f64)?;
        let label = format!("D={d}");
        table.row(phase_row_cells(&label, M, &phases, total));
        rows.push(phase_row_json(&label, M, &phases, total));
        comm_rtfs.push(phases[3] + phases[4]);
    }
    let footer = format!(
        "communication RTF by D: {} — rapid gain up to D~5-10, then \
         saturation (eq 11)",
        comm_rtfs
            .iter()
            .map(|c| format!("{c:.2}"))
            .collect::<Vec<_>>()
            .join(", ")
    );
    Ok(FigureOutput {
        name: "fig8c",
        title: "structure-aware RTF vs delay ratio D (M=64)".into(),
        table: format!("{}\n{footer}", table.render()),
        json: Json::obj(vec![
            ("rows", Json::Arr(rows)),
            ("comm_rtfs", Json::nums(&comm_rtfs)),
        ]),
    })
}
