//! Fig 5: graphical intuition — the same per-cycle workload under
//! per-cycle barriers vs one barrier per D cycles.

use super::{FigOptions, FigureOutput};
use crate::theory::illustration;
use crate::util::json::Json;
use crate::util::tablefmt::{fnum, Table};
use anyhow::Result;

pub fn fig5(opts: &FigOptions) -> Result<FigureOutput> {
    // the paper's illustration setting: S=10 cycles, M=32, D=10
    let ill = illustration::generate(32, 10, 10, opts.seed);
    let (wall_c, wall_s, sync_c, sync_s) = ill.evaluate();

    // plus a long-run version so the ratio is statistically meaningful
    let long = illustration::generate(32, 100_000, 10, opts.seed);
    let (lwall_c, lwall_s, lsync_c, lsync_s) = long.evaluate();

    let mut table =
        Table::new(&["setting", "strategy", "wall [ms]", "sync [ms]", "sync ratio"]);
    table.row(vec![
        "S=10".into(),
        "conventional".into(),
        fnum(wall_c * 1e3),
        fnum(sync_c * 1e3),
        "1.00".into(),
    ]);
    table.row(vec![
        "S=10".into(),
        "structure-aware".into(),
        fnum(wall_s * 1e3),
        fnum(sync_s * 1e3),
        fnum(sync_s / sync_c),
    ]);
    table.row(vec![
        "S=100k".into(),
        "conventional".into(),
        fnum(lwall_c * 1e3),
        fnum(lsync_c * 1e3),
        "1.00".into(),
    ]);
    table.row(vec![
        "S=100k".into(),
        "structure-aware".into(),
        fnum(lwall_s * 1e3),
        fnum(lsync_s * 1e3),
        fnum(lsync_s / lsync_c),
    ]);
    let footer = format!(
        "theory (eq 11): sync ratio = 1/sqrt(10) = {:.3}",
        1.0 / 10f64.sqrt()
    );
    Ok(FigureOutput {
        name: "fig5",
        title: "synthetic illustration: fewer barriers level out variation"
            .into(),
        table: format!("{}\n{footer}", table.render()),
        json: Json::obj(vec![
            ("short_sync_ratio", (sync_s / sync_c).into()),
            ("long_sync_ratio", (lsync_s / lsync_c).into()),
            ("theory_ratio", (1.0 / 10f64.sqrt()).into()),
        ]),
    })
}
