//! Fig 9: the real-world MAM at M=32 under all three strategies on both
//! machine profiles.

use super::common::{mean_phase_rtf, phase_row_cells, phase_row_json, PHASE_HEADERS, SEEDS};
use super::{FigOptions, FigureOutput};
use crate::config::Strategy;
use crate::models;
use crate::util::json::Json;
use crate::util::tablefmt::Table;
use crate::vcluster::MachineProfile;
use anyhow::Result;

pub fn fig9(opts: &FigOptions) -> Result<FigureOutput> {
    let spec = models::mam(1.0, 1.0)?;
    let mut table = Table::new(&PHASE_HEADERS);
    let mut rows = Vec::new();
    let mut totals = std::collections::BTreeMap::new();
    for machine in [MachineProfile::supermuc_ng(), MachineProfile::jureca_dc()]
    {
        for strategy in [
            Strategy::Conventional,
            Strategy::Intermediate,
            Strategy::StructureAware,
        ] {
            let (phases, total) = mean_phase_rtf(
                &machine,
                &spec,
                strategy,
                32,
                opts.t_model_ms,
                &SEEDS,
            )?;
            let label = format!("{}/{}", machine.name, strategy.name());
            table.row(phase_row_cells(&label, 32, &phases, total));
            rows.push(phase_row_json(&label, 32, &phases, total));
            totals.insert(label, total);
        }
    }
    let speedup_jureca = 1.0
        - totals["JURECA-DC/structure-aware"]
            / totals["JURECA-DC/conventional"];
    let speedup_smng = 1.0
        - totals["SuperMUC-NG/structure-aware"]
            / totals["SuperMUC-NG/conventional"];
    let footer = format!(
        "net structure-aware speed-up: JURECA-DC {:.0}% (paper: 42%), \
         SuperMUC-NG {:.0}% (paper: ~parity)",
        100.0 * speedup_jureca,
        100.0 * speedup_smng
    );
    Ok(FigureOutput {
        name: "fig9",
        title: "real-world MAM, M=32: conventional / intermediate / \
                structure-aware on two machines"
            .into(),
        table: format!("{}\n{footer}", table.render()),
        json: Json::obj(vec![
            ("rows", Json::Arr(rows)),
            ("speedup_jureca", speedup_jureca.into()),
            ("speedup_supermuc", speedup_smng.into()),
        ]),
    })
}
