//! Shared helpers for the figure harnesses.

use crate::config::Strategy;
use crate::network::ModelSpec;
use crate::util::json::Json;
use crate::util::timers::{Phase, PhaseTimes};
use crate::vcluster::{run_cluster, MachineProfile, VcOptions, VcResult, Workload};
use anyhow::Result;

/// The paper's three benchmark seeds (§4.2).
pub const SEEDS: [u64; 3] = [12, 654, 91856];

/// Run the virtual cluster for (spec, strategy, m) on `machine`.
pub fn vc_run(
    machine: &MachineProfile,
    spec: &ModelSpec,
    strategy: Strategy,
    m: usize,
    t_model_ms: f64,
    seed: u64,
    record_cycle_times: bool,
) -> Result<VcResult> {
    let w = Workload::derive(spec, strategy, m, machine.t_m)?;
    run_cluster(
        machine,
        &w,
        &VcOptions {
            t_model_ms,
            h_ms: spec.h_ms,
            seed,
            record_cycle_times,
        },
    )
}

/// Mean RTF per phase over seeds; returns (phase RTFs in Phase::ALL
/// order, total RTF).
pub fn mean_phase_rtf(
    machine: &MachineProfile,
    spec: &ModelSpec,
    strategy: Strategy,
    m: usize,
    t_model_ms: f64,
    seeds: &[u64],
) -> Result<([f64; 5], f64)> {
    let mut acc = PhaseTimes::new();
    for &seed in seeds {
        let res = vc_run(machine, spec, strategy, m, t_model_ms, seed, false)?;
        acc.merge(&res.mean_times);
    }
    let t_model_s = t_model_ms / 1000.0 * seeds.len() as f64;
    let mut out = [0.0f64; 5];
    for (i, p) in Phase::ALL.iter().enumerate() {
        out[i] = acc.get(*p) / t_model_s;
    }
    Ok((out, acc.total() / t_model_s))
}

/// JSON row for a phase breakdown.
pub fn phase_row_json(label: &str, m: usize, phases: &[f64; 5], total: f64) -> Json {
    Json::obj(vec![
        ("label", label.into()),
        ("m", m.into()),
        ("deliver", phases[0].into()),
        ("update", phases[1].into()),
        ("collocate", phases[2].into()),
        ("synchronize", phases[3].into()),
        ("data_exchange", phases[4].into()),
        ("rtf", total.into()),
    ])
}

/// Standard table header for phase breakdowns.
pub const PHASE_HEADERS: [&str; 8] = [
    "config",
    "M",
    "deliver",
    "update",
    "collocate",
    "synchronize",
    "data-exch",
    "RTF",
];

/// Render a phase row into table cells.
pub fn phase_row_cells(
    label: &str,
    m: usize,
    phases: &[f64; 5],
    total: f64,
) -> Vec<String> {
    use crate::util::tablefmt::fnum;
    vec![
        label.to_string(),
        m.to_string(),
        fnum(phases[0]),
        fnum(phases[1]),
        fnum(phases[2]),
        fnum(phases[3]),
        fnum(phases[4]),
        fnum(total),
    ]
}
