//! Figure harnesses: regenerate every figure of the paper's evaluation
//! as text tables (stdout) + JSON rows (`results/<fig>.json`).
//!
//! Each harness returns a [`FigureOutput`] so benches and tests can check
//! the numbers without re-parsing stdout.

pub mod common;
pub mod fig1;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod fig11;
pub mod fig12;

use crate::util::json::Json;
use anyhow::Result;

/// A rendered figure: human-readable table plus machine-readable rows.
pub struct FigureOutput {
    pub name: &'static str,
    pub title: String,
    pub table: String,
    pub json: Json,
}

impl FigureOutput {
    /// Print to stdout and write `results/<name>.json`.
    pub fn emit(&self, results_dir: &str) -> Result<()> {
        println!("== {} — {} ==", self.name, self.title);
        println!("{}", self.table);
        std::fs::create_dir_all(results_dir)?;
        let path = format!("{results_dir}/{}.json", self.name);
        std::fs::write(&path, crate::util::json::to_string_pretty(&self.json))?;
        println!("[written {path}]\n");
        Ok(())
    }
}

/// Quick-mode scaling: figure harnesses accept a `t_model_ms` so CI runs
/// stay fast while the full paper protocol (10 s) remains available.
#[derive(Clone, Copy, Debug)]
pub struct FigOptions {
    pub t_model_ms: f64,
    pub seed: u64,
}

impl Default for FigOptions {
    fn default() -> Self {
        Self { t_model_ms: 1_000.0, seed: 654 }
    }
}

/// Run a figure by name.
pub fn run_figure(name: &str, opts: &FigOptions) -> Result<FigureOutput> {
    match name {
        "fig1a" => fig1::fig1a(opts),
        "fig1b" => fig1::fig1b(opts),
        "fig4" => fig4::fig4(),
        "fig5" => fig5::fig5(opts),
        "fig6a" => fig6::fig6a(),
        "fig6b" => fig6::fig6b(),
        "fig7a" => fig7::fig7a(opts),
        "fig7b" => fig7::fig7b(opts),
        "fig8a" => fig8::fig8a(opts),
        "fig8b" => fig8::fig8b(opts),
        "fig8c" => fig8::fig8c(opts),
        "fig9" => fig9::fig9(opts),
        "fig11" => fig11::fig11(opts),
        "fig12" => fig12::fig12(opts),
        other => anyhow::bail!(
            "unknown figure {other:?}; available: fig1a fig1b fig4 fig5 \
             fig6a fig6b fig7a fig7b fig8a fig8b fig8c fig9 fig11 fig12"
        ),
    }
}

/// All figure names in paper order.
pub const ALL_FIGURES: [&str; 14] = [
    "fig1a", "fig1b", "fig4", "fig5", "fig6a", "fig6b", "fig7a", "fig7b",
    "fig8a", "fig8b", "fig8c", "fig9", "fig11", "fig12",
];
