//! Fig 1: strong scaling of the MAM (conventional strategy) and the
//! decomposition of communication time into synchronization vs pure MPI
//! data exchange.

use super::common::{
    mean_phase_rtf, phase_row_cells, phase_row_json, vc_run, PHASE_HEADERS,
    SEEDS,
};
use super::{FigOptions, FigureOutput};
use crate::config::Strategy;
use crate::models;
use crate::util::json::Json;
use crate::util::tablefmt::{fnum, Table};
use crate::vcluster::MachineProfile;
use anyhow::Result;

const MS: [usize; 4] = [16, 32, 64, 128];

/// Fig 1a: per-phase real-time factors of the MAM under strong scaling,
/// conventional strategy, SuperMUC-NG.
pub fn fig1a(opts: &FigOptions) -> Result<FigureOutput> {
    let machine = MachineProfile::supermuc_ng();
    let spec = models::mam(1.0, 0.1)?; // no inter-area cutoff exploited
    let mut table = Table::new(&PHASE_HEADERS);
    let mut rows = Vec::new();
    for &m in &MS {
        let (phases, total) = mean_phase_rtf(
            &machine,
            &spec,
            Strategy::Conventional,
            m,
            opts.t_model_ms,
            &SEEDS,
        )?;
        table.row(phase_row_cells("MAM/conv", m, &phases, total));
        rows.push(phase_row_json("MAM/conv", m, &phases, total));
    }
    Ok(FigureOutput {
        name: "fig1a",
        title: "MAM strong scaling, conventional strategy (per-phase RTF)"
            .into(),
        table: table.render(),
        json: Json::obj(vec![("rows", Json::Arr(rows))]),
    })
}

/// Fig 1b: communication RTF vs the pure-MPI estimate from the Alltoall
/// benchmark (the dashed line) — exposing synchronization as the gap.
pub fn fig1b(opts: &FigOptions) -> Result<FigureOutput> {
    let machine = MachineProfile::supermuc_ng();
    let spec = models::mam(1.0, 0.1)?;
    let mut table = Table::new(&[
        "M",
        "comm RTF",
        "pure-MPI RTF",
        "sync share",
        "bytes/pair",
    ]);
    let mut rows = Vec::new();
    for &m in &MS {
        let res = vc_run(
            &machine,
            &spec,
            Strategy::Conventional,
            m,
            opts.t_model_ms,
            opts.seed,
            false,
        )?;
        use crate::util::timers::Phase;
        let t_model_s = opts.t_model_ms / 1000.0;
        let comm_rtf = (res.mean_times.get(Phase::Synchronize)
            + res.mean_times.get(Phase::DataExchange))
            / t_model_s;
        let data_rtf = res.data_rtf();
        let sync_share = 1.0 - data_rtf / comm_rtf;
        table.row(vec![
            m.to_string(),
            fnum(comm_rtf),
            fnum(data_rtf),
            format!("{:.0}%", 100.0 * sync_share),
            fnum(res.bytes_per_pair),
        ]);
        rows.push(Json::obj(vec![
            ("m", m.into()),
            ("comm_rtf", comm_rtf.into()),
            ("pure_mpi_rtf", data_rtf.into()),
            ("sync_share", sync_share.into()),
            ("bytes_per_pair", res.bytes_per_pair.into()),
        ]));
    }
    Ok(FigureOutput {
        name: "fig1b",
        title:
            "communication RTF vs pure MPI data exchange (sync dominates)"
                .into(),
        table: table.render(),
        json: Json::obj(vec![("rows", Json::Arr(rows))]),
    })
}
