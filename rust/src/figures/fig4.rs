//! Fig 4: `MPI_Alltoall` time vs message size for increasing numbers of
//! MPI processes, including the algorithm-switch jumps, plus the typical
//! MAM-benchmark buffer sizes under both strategies.

use super::FigureOutput;
use crate::util::json::Json;
use crate::util::tablefmt::{fnum, Table};
use crate::vcluster::MachineProfile;
use anyhow::Result;

pub fn fig4() -> Result<FigureOutput> {
    let machine = MachineProfile::supermuc_ng();
    let ms = [16usize, 32, 64, 128];
    let sizes: Vec<f64> = (6..=16).map(|e| (1u64 << e) as f64).collect();

    let mut table = Table::new(&["bytes/pair", "M=16", "M=32", "M=64", "M=128"]);
    let mut rows = Vec::new();
    for &s in &sizes {
        let times: Vec<f64> =
            ms.iter().map(|&m| machine.alltoall.time(m, s)).collect();
        table.row(
            std::iter::once(format!("{}", s as u64))
                .chain(times.iter().map(|&t| fnum(t * 1e6)))
                .collect(),
        );
        rows.push(Json::obj(vec![
            ("bytes", s.into()),
            ("time_us", Json::nums(&times.iter().map(|t| t * 1e6).collect::<Vec<_>>())),
        ]));
    }
    // typical buffer sizes of the MAM-benchmark (dashed lines of Fig 4):
    // conventional ~317 B/pair, structure-aware ~3170 B/pair at M=128
    let conv = machine.alltoall.time(128, 317.0);
    let stru = machine.alltoall.time(128, 3170.0);
    let reduction = 1.0 - (stru / 10.0) / conv;
    let footer = format!(
        "typical MAM buffers at M=128: conv 317 B -> {:.1} us/call, \
         struct 3170 B -> {:.1} us/call ({:.0}% data-time reduction at D=10)",
        conv * 1e6,
        stru * 1e6,
        100.0 * reduction
    );
    Ok(FigureOutput {
        name: "fig4",
        title: "MPI_Alltoall time vs message size (us per call)".into(),
        table: format!("{}\n{footer}", table.render()),
        json: Json::obj(vec![
            ("rows", Json::Arr(rows)),
            ("conv_buffer_time_us", (conv * 1e6).into()),
            ("struct_buffer_time_us", (stru * 1e6).into()),
            ("data_reduction_at_d10", reduction.into()),
        ]),
    })
}
