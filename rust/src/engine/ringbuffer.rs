//! Delayed synaptic-input ring buffers.
//!
//! One buffer per (rank, thread): `n_slots` rows of `n_neurons` f64
//! accumulators indexed by absolute simulation step modulo `n_slots`.
//! Accumulation is f64 so that sums of the bundled models' binary-fraction
//! weights are exact and therefore order-independent — the property the
//! strategy-equivalence test relies on (DESIGN.md §6).
//!
//! The engine constructs rings through [`RingBuffer::with_horizon`],
//! which takes the computed write-ahead horizon next to the slot count
//! and *asserts* `n_slots > horizon` — so a sizing bug fails at rank
//! construction instead of surfacing as a silent wrap-around collision
//! only the downstream delivery-deadline `debug_assert` might catch.
//! Delivery writes whole delay buckets per spike via
//! [`RingBuffer::accumulate_row`]: one call touches a single slot row
//! sequentially (the cache-friendly write pattern of the delay-bucketed
//! connection tables, see `tables`).

/// Ring buffer of per-neuron delayed inputs.
#[derive(Clone, Debug)]
pub struct RingBuffer {
    slots: Vec<f64>,
    n_neurons: usize,
    n_slots: usize,
}

impl RingBuffer {
    /// `n_slots` must exceed the largest write-ahead distance
    /// (max local delay + communication epoch).  Callers that know the
    /// horizon should use [`RingBuffer::with_horizon`], which enforces
    /// the invariant instead of documenting it.
    pub fn new(n_neurons: usize, n_slots: usize) -> RingBuffer {
        assert!(n_slots >= 1, "ring buffer needs at least one slot");
        RingBuffer {
            slots: vec![0.0; n_neurons * n_slots],
            n_neurons,
            n_slots,
        }
    }

    /// As [`RingBuffer::new`], asserting the documented sizing invariant
    /// against the caller's computed write-ahead `horizon` (the largest
    /// `arrive - consume_step` distance any delivery can produce): a
    /// write `horizon` steps ahead of the consume cursor must land on a
    /// row that is not still pending, i.e. `n_slots > horizon`.
    pub fn with_horizon(
        n_neurons: usize,
        n_slots: usize,
        horizon: usize,
    ) -> RingBuffer {
        assert!(
            n_slots > horizon,
            "ring buffer too small: {n_slots} slots cannot hold a \
             write-ahead horizon of {horizon} steps without wrap-around \
             collisions"
        );
        RingBuffer::new(n_neurons, n_slots)
    }

    pub fn n_slots(&self) -> usize {
        self.n_slots
    }

    pub fn n_neurons(&self) -> usize {
        self.n_neurons
    }

    /// The raw accumulator matrix (`n_slots × n_neurons`, row-major) —
    /// the buffer's entire dynamic state.  Indexing is a pure
    /// `step % n_slots` with no cursor, so checkpointing the slots and
    /// resuming at the same absolute step reproduces delivery exactly.
    pub fn slots(&self) -> &[f64] {
        &self.slots
    }

    /// Overwrite the accumulator matrix from a checkpoint (shape must
    /// match the deterministic rebuild that produced `self`).
    pub fn load_slots(&mut self, data: &[f64]) -> Result<(), String> {
        if data.len() != self.slots.len() {
            return Err(format!(
                "ring-buffer snapshot has {} accumulators but this \
                 run's buffer holds {} ({} neurons × {} slots)",
                data.len(),
                self.slots.len(),
                self.n_neurons,
                self.n_slots,
            ));
        }
        self.slots.copy_from_slice(data);
        Ok(())
    }

    /// Add `weight` to the input of `neuron` arriving at absolute `step`.
    #[inline]
    pub fn add(&mut self, step: u64, neuron: u32, weight: f32) {
        let slot = (step % self.n_slots as u64) as usize;
        self.slots[slot * self.n_neurons + neuron as usize] += weight as f64;
    }

    /// Accumulate one delay bucket: add `weights[i]` to `targets[i]`'s
    /// input arriving at absolute `step`, for all `i`.  All writes hit
    /// the single slot row of `step`, so the row base is computed once
    /// and the walk stays within one `n_neurons`-sized row — the write
    /// pattern the delay-bucketed connection layout exists for.
    #[inline]
    pub fn accumulate_row(
        &mut self,
        step: u64,
        targets: &[u32],
        weights: &[f32],
    ) {
        debug_assert_eq!(targets.len(), weights.len());
        let slot = (step % self.n_slots as u64) as usize;
        let row = &mut self.slots[slot * self.n_neurons..][..self.n_neurons];
        for (&t, &w) in targets.iter().zip(weights) {
            row[t as usize] += w as f64;
        }
    }

    /// Read out the input row for `step` into `out` (as f32, matching the
    /// kernel's input dtype) and clear it for reuse.  Called once per
    /// (thread, step) on the update hot path — worth inlining into the
    /// per-worker cycle loop.
    #[inline]
    pub fn take_row(&mut self, step: u64, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.n_neurons);
        let slot = (step % self.n_slots as u64) as usize;
        let row = &mut self.slots[slot * self.n_neurons..][..self.n_neurons];
        for (o, r) in out.iter_mut().zip(row.iter_mut()) {
            *o = *r as f32;
            *r = 0.0;
        }
    }

    /// Sum of all pending input (diagnostics / leak detection in tests).
    pub fn pending_total(&self) -> f64 {
        self.slots.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_then_take() {
        let mut rb = RingBuffer::new(3, 8);
        rb.add(5, 0, 1.0);
        rb.add(5, 2, 0.5);
        rb.add(5, 2, 0.25);
        let mut row = vec![0.0f32; 3];
        rb.take_row(5, &mut row);
        assert_eq!(row, vec![1.0, 0.0, 0.75]);
        // cleared after take
        rb.take_row(5, &mut row);
        assert_eq!(row, vec![0.0, 0.0, 0.0]);
    }

    #[test]
    fn wraps_modulo_slots() {
        let mut rb = RingBuffer::new(1, 4);
        rb.add(2, 0, 1.0);
        rb.add(6, 0, 2.0); // same slot as step 2 (6 % 4 == 2)
        let mut row = vec![0.0f32; 1];
        rb.take_row(6, &mut row);
        assert_eq!(row[0], 3.0); // collision by design if capacity too small
    }

    #[test]
    fn distinct_slots_do_not_interfere() {
        let mut rb = RingBuffer::new(2, 16);
        for step in 0..16u64 {
            rb.add(step, 0, step as f32);
        }
        let mut row = vec![0.0f32; 2];
        for step in 0..16u64 {
            rb.take_row(step, &mut row);
            assert_eq!(row[0], step as f32);
            assert_eq!(row[1], 0.0);
        }
        assert_eq!(rb.pending_total(), 0.0);
    }

    #[test]
    fn accumulate_row_matches_individual_adds() {
        let targets = [0u32, 3, 1, 3];
        let weights = [0.25f32, -0.625, 0.125, 0.5];
        let mut batched = RingBuffer::new(4, 8);
        batched.accumulate_row(5, &targets, &weights);
        let mut single = RingBuffer::new(4, 8);
        for (&t, &w) in targets.iter().zip(&weights) {
            single.add(5, t, w);
        }
        let (mut a, mut b) = (vec![0.0f32; 4], vec![0.0f32; 4]);
        batched.take_row(5, &mut a);
        single.take_row(5, &mut b);
        assert_eq!(a, b);
        assert_eq!(a, vec![0.25, 0.125, 0.0, -0.125]);
    }

    #[test]
    fn with_horizon_accepts_sufficient_slots() {
        let rb = RingBuffer::with_horizon(2, 8, 7);
        assert_eq!(rb.n_slots(), 8);
    }

    #[test]
    #[should_panic(expected = "ring buffer too small")]
    fn with_horizon_rejects_insufficient_slots() {
        let _ = RingBuffer::with_horizon(2, 4, 4);
    }

    #[test]
    fn slots_roundtrip_through_checkpoint_accessors() {
        let mut a = RingBuffer::new(3, 4);
        a.add(5, 1, 0.25);
        a.add(2, 0, -0.5);
        let mut b = RingBuffer::new(3, 4);
        b.load_slots(a.slots()).unwrap();
        let (mut ra, mut rb) = (vec![0.0f32; 3], vec![0.0f32; 3]);
        for step in [2u64, 5] {
            a.take_row(step, &mut ra);
            b.take_row(step, &mut rb);
            assert_eq!(ra, rb);
        }
        assert!(b.load_slots(&[0.0; 2]).is_err());
    }

    #[test]
    fn f64_accumulation_is_order_independent_for_binary_weights() {
        let weights = [0.125f32, -0.625, 0.125, 0.125, -0.625, 0.125];
        let mut fwd = RingBuffer::new(1, 2);
        for &w in &weights {
            fwd.add(0, 0, w);
        }
        let mut rev = RingBuffer::new(1, 2);
        for &w in weights.iter().rev() {
            rev.add(0, 0, w);
        }
        let (mut a, mut b) = (vec![0.0f32], vec![0.0f32]);
        fwd.take_row(0, &mut a);
        rev.take_row(0, &mut b);
        assert_eq!(a, b);
        assert_eq!(a[0], -0.75);
    }
}
