//! The functional distributed simulation engine.
//!
//! [`simulate`] spawns one OS thread per (simulated) MPI rank, builds the
//! rank-local data structures collectively (including the target-table
//! exchange of the preparation phase) and iterates the cycle loop of
//! paper Fig 3.  Results are merged into a [`SimResult`] containing phase
//! breakdowns, recorded spikes and per-cycle times.
//!
//! Within a rank, virtual threads run on the persistent phase-barrier
//! worker runtime by default ([`crate::config::ExecMode::Pooled`]):
//! workers are spawned once per run and advance through deliver →
//! update → collocate in lock-step over a reusable barrier.  The
//! receive side is fully parallel — workers cooperatively sort and
//! bucket the incoming per-sender spike runs through a T×T grid, then
//! each worker k-way merges its own column back into the canonical
//! delivery order (`engine::receive`); the coordinator never sorts or
//! scans a spike.  See `engine::rank` for the full protocol and the
//! bit-identity argument; `ExecMode::Sequential` is the reference
//! schedule (same bucket/merge code on one OS thread) and
//! `ExecMode::PooledChannels` the legacy PR 1 channel pool with the old
//! coordinator-sorted broadcast delivery, kept as the A/B baseline.
//!
//! Communication follows the paper's **hierarchical two-tier
//! architecture**: the engine builds one global [`crate::comm::World`]
//! and, for
//! dual-pathway strategies, splits one **local communicator per area
//! group** off it ([`Transport::split`], colored by
//! `Placement::group_of_rank`).  With `--ranks-per-area 1` (default)
//! every group is a singleton and the local tier is the intra-rank
//! buffer swap — bit-identical to the pre-hierarchical engine.  With
//! `ranks_per_area > 1` an area spans a group of ranks that exchange its
//! short-range spikes over their sub-communicator every cycle, while the
//! long-range exchange across areas stays on the global communicator
//! once per epoch.  [`SimResult::comm_tiers`] reports both tiers'
//! statistics; [`SimResult::comm_stats`] keeps the combined flat view.
//!
//! The epoch-boundary global exchange runs blocking or split-phase
//! ([`crate::config::CommMode`]): under `CommMode::Overlap` each rank
//! posts the exchange without waiting and completes it cycles later,
//! just before its delivery deadline, keeping up to `comm_depth`
//! exchange rounds in flight (`--comm-depth`; validated collectively
//! against the realized delay slack) and draining early-arrived peers
//! incrementally during the in-flight window — see `engine::rank` for
//! the deadline schedule and `comm::nonblocking` for the ring protocol.
//! All modes, depths and group sizes produce bit-identical spike trains
//! in every exec mode.
//!
//! **Fault tolerance**: with `--checkpoint-every N` the engine snapshots
//! the full dynamic state every N epochs through `engine::checkpoint`
//! (collective assembly, atomic write) and `--restore <path>` resumes a
//! run from such a snapshot bit-identically; `--comm-timeout` arms the
//! transport watchdog so a dead or stalled rank surfaces as a
//! structured [`crate::comm::CommError`] naming the tier, operation and
//! missing peers instead of a hang; and the deterministic fault plan
//! (`--straggler`, `--delay-deposit`, `--kill-at`) injects compute
//! stragglers, held-back deposits and rank kills for the recovery
//! tests and experiments.

pub mod checkpoint;
pub mod neuron;
pub mod rank;
pub mod receive;
pub mod ringbuffer;
pub mod update;

use crate::comm::{
    CommStatsSnapshot, SplitTransport, TieredCommStats, Transport,
    WorldBuilder,
};
use crate::config::{
    CommMode, RunConfig, Strategy, TransportKind, UpdatePath,
};
use crate::network::{Gid, ModelSpec};
use crate::obs::blame::TieredBlame;
use crate::obs::intervals::TierIntervalSummary;
use crate::obs::{SpanEvent, TraceBuf, Tracer};
use crate::placement::Placement;
use crate::util::timers::PhaseTimes;
use anyhow::{Context, Result};
use checkpoint::{CkptCtx, Fingerprint, Snapshot};
use rank::{CkptSched, RankResult, RankState, RunOpts};
use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::Duration;
use update::Updater;

/// Mid-run progress snapshot handed to [`SimHooks::progress`]: how far
/// the run is and the interval statistics accumulated *so far* (the
/// recorders are streaming, so the snapshot is O(1) to take).  Emitted
/// by rank 0 only, at epoch boundaries — all ranks pass the boundary
/// together, so rank 0's cycle counter speaks for the whole run.
#[derive(Debug, Clone)]
pub struct Progress {
    /// Cycles completed (the boundary just passed).
    pub cycle: u64,
    /// Total cycles of the run.
    pub s_cycles: u64,
    /// Streaming compute-interval statistics up to `cycle`.
    pub intervals: TierIntervalSummary,
}

/// Progress callback: invoked on rank 0's coordinator thread, so it
/// must be cheap and must not block on the ranks it is reporting about.
pub type ProgressFn = dyn Fn(Progress) + Send + Sync;

/// Optional runtime hooks for long-running callers (the serving layer):
/// cooperative cancellation and periodic progress reports.  The default
/// (no hooks) adds **zero** collectives and zero per-cycle branches
/// beyond one `Option` check, so plain CLI runs are unchanged.
#[derive(Clone, Default)]
pub struct SimHooks {
    /// Raise to request cancellation.  The ranks agree on the flag
    /// collectively at an epoch boundary (an `allreduce_min` over
    /// "have I seen it?"), so every rank unwinds from the *same* cycle
    /// and no rank is left blocked in a collective — the run fails
    /// with a typed [`Cancelled`] error.
    pub cancel: Option<Arc<AtomicBool>>,
    /// Progress callback, fired by rank 0 every
    /// `progress_every_epochs` epochs.
    pub progress: Option<Arc<ProgressFn>>,
    /// Epoch period of the progress callback (0 is treated as 1).
    pub progress_every_epochs: u64,
}

/// Typed error a cancelled run unwinds with: every rank raises it at
/// the same (epoch-boundary) cycle, so callers can downcast the
/// simulation error to distinguish "asked to stop" from real failures.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Cancelled {
    /// The cycle the ranks agreed to stop at.
    pub cycle: u64,
}

impl std::fmt::Display for Cancelled {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "simulation cancelled at cycle {}", self.cycle)
    }
}

impl std::error::Error for Cancelled {}

/// Outcome of a functional simulation.
pub struct SimResult {
    pub strategy: Strategy,
    pub m_ranks: usize,
    /// Per-rank phase times.
    pub rank_times: Vec<PhaseTimes>,
    /// Mean phase times across ranks (the paper's reporting convention).
    pub mean_times: PhaseTimes,
    /// Element-wise slowest-rank phase times — the wait-for-the-slowest
    /// profile the communication restructuring attacks.
    pub max_times: PhaseTimes,
    /// All recorded spikes sorted by (step, gid) — empty unless
    /// `record_spikes`.
    pub spikes: Vec<(u64, Gid)>,
    /// Per-rank per-cycle (deliver+update+collocate) times — empty unless
    /// `record_cycle_times`.
    pub cycle_times: Vec<Vec<f64>>,
    /// Simulated cycles.
    pub s_cycles: u64,
    /// Simulated model time in ms.
    pub t_model_ms: f64,
    /// Per-rank neuron counts.
    pub rank_neurons: Vec<usize>,
    /// Per-rank synapse counts (short, long pathway).
    pub rank_conns: Vec<(usize, usize)>,
    /// Combined (both tiers) communication statistics of the run — the
    /// flat single-communicator view kept for existing consumers.
    pub comm_stats: CommStatsSnapshot,
    /// Per-tier communication statistics: the global (inter-area)
    /// communicator next to the aggregated per-area-group local
    /// communicators.
    pub comm_tiers: TieredCommStats,
    /// Split-phase pipeline depth the run actually used: the configured
    /// `comm_depth` under `CommMode::Overlap` (validated against the
    /// realized delay slack of every rank), 1 under
    /// `CommMode::Blocking`.
    pub effective_comm_depth: u64,
    /// Residual ring-buffer mass per rank per virtual thread after the
    /// last cycle — delivered input the run never consumed.  Exactly 0.0
    /// everywhere when all delays fit inside the simulated horizon
    /// (which the conservation test arranges); bit-identical across
    /// exec/comm modes regardless.
    pub ring_pending: Vec<Vec<f64>>,
    /// Cycles per communication epoch of this run (1 unless the
    /// strategy uses dual pathways).
    pub epoch_cycles: u64,
    /// Per-rank streaming compute-interval statistics per tier — the
    /// bounded always-on replacement for `cycle_times`.
    pub intervals: Vec<TierIntervalSummary>,
    /// Straggler-attribution ledgers: who each rank waited for, per
    /// tier, in absolute (root-world) rank numbers.
    pub blame: TieredBlame,
    /// Recorded trace spans — empty unless `cfg.trace`.
    pub spans: Vec<SpanEvent>,
}

impl SimResult {
    /// Wall-clock real-time factor, averaged across ranks.
    pub fn rtf(&self) -> f64 {
        self.mean_times.rtf(self.t_model_ms / 1000.0)
    }

    /// Total spike count.
    pub fn n_spikes(&self) -> usize {
        self.spikes.len()
    }

    /// Mean firing rate in spikes/s per neuron.
    pub fn mean_rate_hz(&self, n_neurons: usize) -> f64 {
        if n_neurons == 0 || self.t_model_ms <= 0.0 {
            return 0.0;
        }
        self.spikes.len() as f64 / n_neurons as f64
            / (self.t_model_ms / 1000.0)
    }
}

/// Build the placement implied by the strategy (including the
/// area→rank-group mapping when `ranks_per_area > 1`).
pub fn placement_for(
    spec: &ModelSpec,
    cfg: &RunConfig,
) -> Result<Placement> {
    if cfg.strategy.structure_aware_placement() {
        Placement::area_aligned_grouped(
            spec,
            cfg.m_ranks,
            cfg.threads_per_rank,
            cfg.ranks_per_area,
        )
    } else {
        Ok(Placement::round_robin(cfg.m_ranks, cfg.threads_per_rank))
    }
}

/// The cycle shape a run derives from model and config:
/// `(s_cycles, epoch_cycles, steps_per_cycle)`, with the
/// partial-tail-epoch guard applied.  Every backend — in-process and
/// multi-process — derives the shape through this one function, so all
/// processes of a socket run agree on it by construction.
pub fn run_shape(
    spec: &ModelSpec,
    cfg: &RunConfig,
) -> Result<(u64, u64, u64)> {
    let steps_per_cycle = spec.d_min_steps() as u64;
    let total_steps =
        (cfg.t_model_ms / spec.h_ms).round().max(1.0) as u64;
    let s_cycles = total_steps / steps_per_cycle;
    anyhow::ensure!(
        s_cycles >= 1,
        "t_model shorter than one simulation cycle"
    );
    let epoch_cycles = if cfg.strategy.dual_pathways() {
        (spec.delay_ratio() as u64).max(1)
    } else {
        1
    };
    // Guard the partial tail epoch: under the structure-aware strategy
    // the global exchange only runs at epoch boundaries, so spikes
    // collocated into the send buffers during a trailing partial epoch
    // would silently never be exchanged.  Reject such runs instead.
    if cfg.strategy.dual_pathways() {
        anyhow::ensure!(
            s_cycles % epoch_cycles == 0,
            "run length of {s_cycles} cycles is not a multiple of the \
             structure-aware communication epoch ({epoch_cycles} cycles): \
             long-range spikes of the trailing partial epoch would never \
             be exchanged; pick t_model as a multiple of {} ms",
            epoch_cycles as f64 * steps_per_cycle as f64 * spec.h_ms,
        );
    }
    Ok((s_cycles, epoch_cycles, steps_per_cycle))
}

/// Identity of the simulated state: a snapshot only restores into a
/// run that rebuilds the exact same deterministic structures.  Both
/// backends derive it through this one function so a snapshot written
/// by the in-process engine resumes in a socket-rank process and vice
/// versa.
fn fingerprint_for(
    spec: &ModelSpec,
    cfg: &RunConfig,
    epoch_cycles: u64,
    steps_per_cycle: u64,
) -> Fingerprint {
    Fingerprint {
        model: spec.name.clone(),
        n_neurons: spec.total_neurons(),
        m_ranks: cfg.m_ranks as u32,
        threads_per_rank: cfg.threads_per_rank as u32,
        ranks_per_area: cfg.ranks_per_area as u32,
        strategy: cfg.strategy.name().to_string(),
        seed: cfg.seed,
        epoch_cycles,
        steps_per_cycle,
        record_spikes: cfg.record_spikes,
    }
}

/// Load and verify the snapshot named by `cfg.restore` (if any): the
/// fingerprint must match this run's, the snapshot cycle must leave
/// something to resume, and the part count must equal the rank count.
fn load_restore_snapshot(
    cfg: &RunConfig,
    fingerprint: &Fingerprint,
    s_cycles: u64,
) -> Result<Option<Snapshot>> {
    let Some(path) = &cfg.restore else {
        return Ok(None);
    };
    let snap = Snapshot::read_verified(path)?;
    snap.fingerprint.check_matches(fingerprint)?;
    anyhow::ensure!(
        snap.cycle < s_cycles,
        "snapshot was taken at cycle {} but this run simulates \
         only {s_cycles} cycles — nothing left to resume",
        snap.cycle,
    );
    anyhow::ensure!(
        snap.parts.len() == cfg.m_ranks,
        "snapshot holds {} rank parts but this run uses {} ranks",
        snap.parts.len(),
        cfg.m_ranks,
    );
    Ok(Some(snap))
}

/// One rank's share of a run, generic over the transport: split the
/// local communicator (dual pathways), build the rank state
/// collectively, validate the pipeline depth against the realized delay
/// slack, restore from a snapshot part if resuming, and run the cycle
/// loop.  The in-process engine calls this once per rank thread; the
/// socket backend calls it once per *process*.
#[allow(clippy::too_many_arguments)]
fn run_rank<T: SplitTransport>(
    spec: &ModelSpec,
    cfg: &RunConfig,
    placement: &Placement,
    r: usize,
    comm: &T,
    updater: &Updater,
    snapshot: Option<&Snapshot>,
    ckpt: Option<CkptSched<'_>>,
    tracer: Tracer,
    s_cycles: u64,
    start_cycle: u64,
    hooks: &SimHooks,
) -> Result<RankResult> {
    // hierarchical communicators: dual-pathway runs split one local
    // communicator per area group off the global world (collective:
    // every rank calls split exactly once, colored by its group)
    let local_comm = if cfg.strategy.dual_pathways() {
        Some(
            comm.split(placement.group_of_rank(r) as u64, r as u64)
                .context("splitting the local communicator")?,
        )
    } else {
        None
    };
    let mut state = RankState::build(
        spec,
        placement,
        cfg.strategy,
        cfg.comm,
        cfg.comm_depth,
        cfg.seed,
        comm,
        cfg.record_spikes,
    )?;
    // a pipeline deeper than the *realized* delay slack would force
    // completing an exchange in the very cycle that needs its spikes;
    // reduce the rank-local bound collectively so every rank takes the
    // same accept/reject branch (no rank left at a barrier)
    if cfg.comm == CommMode::Overlap && cfg.comm_depth > 1 {
        let sustainable = comm
            .allreduce_min_u64(state.max_sustainable_depth())
            .context("depth-validation reduction")?;
        anyhow::ensure!(
            cfg.comm_depth as u64 <= sustainable,
            "comm depth {} exceeds the realized delay \
             slack: the most constrained rank can keep at \
             most {} exchange(s) in flight before the \
             causality deadline forces completion; lower \
             --comm-depth to {} or pick a model whose \
             remote delays exceed the min-delay cutoff by \
             more cycles",
            cfg.comm_depth,
            sustainable,
            sustainable,
        );
    }
    if let Some(snap) = snapshot {
        state
            .restore_part(&snap.parts[r])
            .with_context(|| format!("restoring rank {r} state"))?;
    }
    state.run(
        comm,
        local_comm.as_ref(),
        updater,
        RunOpts {
            s_cycles,
            start_cycle,
            record_cycle_times: cfg.record_cycle_times,
            exec: cfg.exec,
            faults: cfg.faults.for_rank(r),
            ckpt,
            tracer,
            hooks,
        },
    )
}

/// Run the functional engine on `spec` with `cfg`.
///
/// `updater_factory` builds the update executor once; `None` selects it
/// from `cfg.update_path` (Native, or the XLA path via the runtime).
pub fn simulate(spec: &ModelSpec, cfg: &RunConfig) -> Result<SimResult> {
    simulate_hooked(spec, cfg, &SimHooks::default())
}

/// As [`simulate`], with runtime hooks (cancellation + progress) for
/// long-running callers such as the serving layer.
pub fn simulate_hooked(
    spec: &ModelSpec,
    cfg: &RunConfig,
    hooks: &SimHooks,
) -> Result<SimResult> {
    let updater = match cfg.update_path {
        UpdatePath::Native => Updater::Native,
        UpdatePath::Xla => crate::runtime::updater::xla_updater(spec)
            .context("building XLA updater (run `make artifacts`?)")?,
    };
    simulate_with_hooks(spec, cfg, &updater, hooks)
}

/// As [`simulate`], with an explicit update executor.
pub fn simulate_with(
    spec: &ModelSpec,
    cfg: &RunConfig,
    updater: &Updater,
) -> Result<SimResult> {
    simulate_with_hooks(spec, cfg, updater, &SimHooks::default())
}

/// The in-process backend: explicit update executor *and* hooks.
pub fn simulate_with_hooks(
    spec: &ModelSpec,
    cfg: &RunConfig,
    updater: &Updater,
    hooks: &SimHooks,
) -> Result<SimResult> {
    cfg.validate()?;
    anyhow::ensure!(
        cfg.transport == TransportKind::Shmem,
        "simulate() runs the in-process shared-memory backend; a \
         socket-transport config must go through simulate_socket (one \
         process per rank, usually via `nsim launch`)"
    );
    let placement = placement_for(spec, cfg)?;
    let (s_cycles, epoch_cycles, steps_per_cycle) =
        run_shape(spec, cfg)?;

    // identity of the simulated state: a snapshot only restores into a
    // run that rebuilds the exact same deterministic structures
    let fingerprint =
        fingerprint_for(spec, cfg, epoch_cycles, steps_per_cycle);
    let snapshot = load_restore_snapshot(cfg, &fingerprint, s_cycles)?;
    let start_cycle = snapshot.as_ref().map_or(0, |s| s.cycle);
    // resume from the grown quota so the transport's mailbox capacity
    // (and hence its growth trajectory) continues where it left off
    let quota = snapshot
        .as_ref()
        .map_or(cfg.comm_quota, |s| s.quota as usize);
    let ckpt_ctx = (cfg.checkpoint_every > 0).then(|| {
        CkptCtx::new(
            cfg.m_ranks,
            fingerprint.clone(),
            cfg.checkpoint_path.clone(),
        )
    });

    let trace_buf = cfg.trace.then(|| TraceBuf::with_mode(cfg.m_ranks, cfg.trace_mode));
    let world = WorldBuilder::new(cfg.m_ranks)
        .quota(quota)
        .depth(cfg.comm_depth)
        .timeout(cfg.comm_timeout.map(Duration::from_secs_f64))
        .trace(trace_buf.clone())
        .build();
    let results: Result<Vec<RankResult>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..cfg.m_ranks)
            .map(|r| {
                let comm = world.communicator(r);
                let placement = &placement;
                let updater = &updater;
                let snapshot = &snapshot;
                let ckpt_ctx = &ckpt_ctx;
                let trace_buf = &trace_buf;
                scope.spawn(move || -> Result<RankResult> {
                    run_rank(
                        spec,
                        cfg,
                        placement,
                        r,
                        &comm,
                        updater,
                        snapshot.as_ref(),
                        ckpt_ctx.as_ref().map(|ctx| CkptSched {
                            ctx,
                            every_epochs: cfg.checkpoint_every,
                        }),
                        trace_buf
                            .as_ref()
                            .map_or_else(Tracer::off, |b| Tracer::new(b, r)),
                        s_cycles,
                        start_cycle,
                        hooks,
                    )
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("rank thread panicked"))
            .collect()
    });
    let results = results?;

    let mut rank_times = vec![PhaseTimes::new(); cfg.m_ranks];
    let mut cycle_times = vec![Vec::new(); cfg.m_ranks];
    let mut rank_neurons = vec![0usize; cfg.m_ranks];
    let mut rank_conns = vec![(0usize, 0usize); cfg.m_ranks];
    let mut ring_pending = vec![Vec::new(); cfg.m_ranks];
    let mut intervals =
        vec![TierIntervalSummary::default(); cfg.m_ranks];
    let mut spikes = Vec::new();
    for r in results {
        rank_times[r.rank] = r.phase_times;
        cycle_times[r.rank] = r.cycle_times;
        rank_neurons[r.rank] = r.n_neurons;
        rank_conns[r.rank] = (r.n_conns_short, r.n_conns_long);
        ring_pending[r.rank] = r.ring_pending;
        intervals[r.rank] = r.intervals;
        spikes.extend(r.spikes);
    }
    spikes.sort_unstable();
    let mean_times = PhaseTimes::mean_of(&rank_times);
    let max_times = PhaseTimes::max_of(&rank_times);
    let comm_tiers = world.tiered_stats();
    let blame = world.blame_report();
    let spans = trace_buf.as_ref().map_or_else(Vec::new, |b| b.drain());

    Ok(SimResult {
        strategy: cfg.strategy,
        m_ranks: cfg.m_ranks,
        rank_times,
        mean_times,
        max_times,
        spikes,
        cycle_times,
        s_cycles,
        t_model_ms: cfg.t_model_ms,
        rank_neurons,
        rank_conns,
        comm_stats: comm_tiers.combined(),
        comm_tiers,
        effective_comm_depth: match cfg.comm {
            CommMode::Blocking => 1,
            CommMode::Overlap => cfg.comm_depth as u64,
        },
        ring_pending,
        epoch_cycles,
        intervals,
        blame,
        spans,
    })
}

/// Run **one rank** of a multi-process simulation over the socket
/// transport: rendezvous with the peer processes through `dir`, run the
/// same collective protocol as the in-process engine, and return this
/// process's view of the result.
///
/// Every process derives the run shape from the same `(spec, cfg)`
/// through [`run_shape`] and runs the identical [`run_rank`] body the
/// in-process backend uses, so the merged spike trains are bit-identical
/// to [`simulate`] by construction (asserted by the cross-process
/// equivalence tests).  Per-rank vectors of the returned [`SimResult`]
/// are filled only at `rank` — aggregation across processes is the
/// launcher's job (`nsim launch` merges the per-rank spike files);
/// `mean_times`/`max_times` are this process's own phase profile.
#[cfg(unix)]
pub fn simulate_socket(
    spec: &ModelSpec,
    cfg: &RunConfig,
    rank: usize,
    dir: &std::path::Path,
) -> Result<SimResult> {
    use crate::comm::socket::SocketWorldBuilder;

    cfg.validate()?;
    anyhow::ensure!(
        cfg.transport == TransportKind::Socket,
        "simulate_socket requires --transport socket"
    );
    anyhow::ensure!(
        rank < cfg.m_ranks,
        "socket rank {rank} out of range for {} ranks",
        cfg.m_ranks
    );
    let updater = match cfg.update_path {
        UpdatePath::Native => Updater::Native,
        UpdatePath::Xla => crate::runtime::updater::xla_updater(spec)
            .context("building XLA updater (run `make artifacts`?)")?,
    };
    let placement = placement_for(spec, cfg)?;
    let (s_cycles, epoch_cycles, steps_per_cycle) =
        run_shape(spec, cfg)?;
    // restore works over the socket transport: every process reads the
    // (shared-filesystem) snapshot and restores its own rank part —
    // no collective is needed beyond what a cold start already does.
    // Only *writing* checkpoints stays shmem-only (the snapshot
    // collectives assemble parts through a shared CkptCtx), which
    // RunConfig::validate still rejects.
    let fingerprint =
        fingerprint_for(spec, cfg, epoch_cycles, steps_per_cycle);
    let snapshot = load_restore_snapshot(cfg, &fingerprint, s_cycles)?;
    let start_cycle = snapshot.as_ref().map_or(0, |s| s.cycle);
    let quota = snapshot
        .as_ref()
        .map_or(cfg.comm_quota, |s| s.quota as usize);
    let trace_buf = cfg.trace.then(|| TraceBuf::with_mode(cfg.m_ranks, cfg.trace_mode));
    let comm = SocketWorldBuilder::new(cfg.m_ranks, rank, dir)
        .quota(quota)
        .depth(cfg.comm_depth)
        .timeout(cfg.comm_timeout.map(Duration::from_secs_f64))
        .connect()
        .context("connecting the socket mesh")?;
    let res = run_rank(
        spec,
        cfg,
        &placement,
        rank,
        &comm,
        &updater,
        snapshot.as_ref(),
        None,
        trace_buf
            .as_ref()
            .map_or_else(Tracer::off, |b| Tracer::new(b, rank)),
        s_cycles,
        start_cycle,
        &SimHooks::default(),
    )?;

    let mut rank_times = vec![PhaseTimes::new(); cfg.m_ranks];
    let mut cycle_times = vec![Vec::new(); cfg.m_ranks];
    let mut rank_neurons = vec![0usize; cfg.m_ranks];
    let mut rank_conns = vec![(0usize, 0usize); cfg.m_ranks];
    let mut ring_pending = vec![Vec::new(); cfg.m_ranks];
    let mut intervals =
        vec![TierIntervalSummary::default(); cfg.m_ranks];
    rank_times[rank] = res.phase_times.clone();
    cycle_times[rank] = res.cycle_times;
    rank_neurons[rank] = res.n_neurons;
    rank_conns[rank] = (res.n_conns_short, res.n_conns_long);
    ring_pending[rank] = res.ring_pending;
    intervals[rank] = res.intervals;
    let mut spikes = res.spikes;
    spikes.sort_unstable();
    let comm_tiers = comm.tiered_stats();
    let blame = comm.blame_report();
    let spans = trace_buf.as_ref().map_or_else(Vec::new, |b| b.drain());

    Ok(SimResult {
        strategy: cfg.strategy,
        m_ranks: cfg.m_ranks,
        mean_times: res.phase_times.clone(),
        max_times: res.phase_times,
        rank_times,
        spikes,
        cycle_times,
        s_cycles,
        t_model_ms: cfg.t_model_ms,
        rank_neurons,
        rank_conns,
        comm_stats: comm_tiers.combined(),
        comm_tiers,
        effective_comm_depth: match cfg.comm {
            CommMode::Blocking => 1,
            CommMode::Overlap => cfg.comm_depth as u64,
        },
        ring_pending,
        epoch_cycles,
        intervals,
        blame,
        spans,
    })
}
