//! Versioned checkpoint/restore of the full engine state.
//!
//! A checkpoint is taken at an **epoch boundary** with the split-phase
//! pipeline drained to depth 0 (the run driver in `engine::rank` forces
//! every in-flight exchange to complete before snapshotting), so the
//! only dynamic state left is per-virtual-thread neuron state, the
//! ring-buffer contents, the received-but-undelivered runs, the spikes
//! recorded so far and the grown communicator quota.  Everything else —
//! connection tables, target tables, placements, neuron parameters —
//! is rebuilt deterministically from `(spec, seed, config)`, which the
//! snapshot pins through its [`Fingerprint`].
//!
//! # File format (version 1)
//!
//! ```text
//! magic  "NSIMCKPT"                                  8 bytes
//! version u32 LE                                     4 bytes
//! payload_len u64 LE                                 8 bytes
//! checksum u64 LE  (FNV-1a over the payload)         8 bytes
//! payload:
//!   fingerprint | cycle u64 | quota u64 | n_parts u32
//!   n_parts × (part_len u64, part bytes)             one part per rank
//! ```
//!
//! All integers are little-endian; the per-rank part bytes are produced
//! by `RankState::serialize_part` and are themselves length-framed, so
//! the container stays ignorant of engine internals.  Readers verify
//! magic, version, payload length (truncation) and checksum
//! (corruption) before any field is interpreted; writers go through a
//! temporary file + `rename` so a crash mid-write never leaves a
//! half-written file under the checkpoint path.
//!
//! The engine has no runtime RNG stream — spike-train stochasticity
//! comes from GID-keyed hashes (`engine::neuron`) and the build-time
//! network draw, both functions of the seed — so pinning `seed` in the
//! fingerprint *is* the RNG-stream snapshot.

use anyhow::{bail, ensure, Context, Result};
use std::sync::Mutex;

/// File magic of every engine checkpoint.
pub const MAGIC: [u8; 8] = *b"NSIMCKPT";
/// Current snapshot format version.
pub const VERSION: u32 = 1;

/// FNV-1a 64-bit over `bytes` — the corruption check of the header.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Little-endian byte sink for snapshot serialization.
#[derive(Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> ByteWriter {
        ByteWriter::default()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Length-prefixed UTF-8 string.
    pub fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Length-prefixed raw byte block.
    pub fn bytes(&mut self, b: &[u8]) {
        self.u64(b.len() as u64);
        self.buf.extend_from_slice(b);
    }
}

/// Little-endian cursor over snapshot bytes; every read is bounds
/// checked so a truncated or lying length field surfaces as a clean
/// error instead of a panic.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> ByteReader<'a> {
        ByteReader { buf, pos: 0 }
    }

    pub fn is_done(&self) -> bool {
        self.pos == self.buf.len()
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            n <= self.buf.len() - self.pos,
            "checkpoint truncated: wanted {n} bytes at offset {} but \
             only {} remain",
            self.pos,
            self.buf.len() - self.pos,
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// A `u64` length field that must fit the platform's `usize`.
    pub fn read_len(&mut self) -> Result<usize> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| {
            anyhow::anyhow!("checkpoint length field {v} overflows usize")
        })
    }

    pub fn str(&mut self) -> Result<String> {
        let n = self.read_len()?;
        let raw = self.take(n)?;
        String::from_utf8(raw.to_vec())
            .context("checkpoint string field is not UTF-8")
    }

    pub fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.read_len()?;
        Ok(self.take(n)?.to_vec())
    }
}

/// The run identity a snapshot was taken under.  Restore refuses to
/// resume when any field differs — the serialized state is only
/// meaningful against the identical deterministic rebuild.  Execution
/// knobs that do *not* change the simulated state (exec mode, comm
/// mode, pipeline depth, timeouts) are deliberately absent: restoring
/// under a different runtime is exactly the cross-mode equivalence the
/// tests pin.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Fingerprint {
    pub model: String,
    pub n_neurons: u32,
    pub m_ranks: u32,
    pub threads_per_rank: u32,
    pub ranks_per_area: u32,
    pub strategy: String,
    pub seed: u64,
    pub epoch_cycles: u64,
    pub steps_per_cycle: u64,
    pub record_spikes: bool,
}

impl Fingerprint {
    fn write(&self, w: &mut ByteWriter) {
        w.str(&self.model);
        w.u32(self.n_neurons);
        w.u32(self.m_ranks);
        w.u32(self.threads_per_rank);
        w.u32(self.ranks_per_area);
        w.str(&self.strategy);
        w.u64(self.seed);
        w.u64(self.epoch_cycles);
        w.u64(self.steps_per_cycle);
        w.u8(self.record_spikes as u8);
    }

    fn read(r: &mut ByteReader<'_>) -> Result<Fingerprint> {
        Ok(Fingerprint {
            model: r.str()?,
            n_neurons: r.u32()?,
            m_ranks: r.u32()?,
            threads_per_rank: r.u32()?,
            ranks_per_area: r.u32()?,
            strategy: r.str()?,
            seed: r.u64()?,
            epoch_cycles: r.u64()?,
            steps_per_cycle: r.u64()?,
            record_spikes: r.u8()? != 0,
        })
    }

    /// Field-by-field comparison against the fingerprint of the run
    /// attempting the restore, with one named mismatch per error so the
    /// operator knows exactly which knob diverged.
    pub fn check_matches(&self, run: &Fingerprint) -> Result<()> {
        macro_rules! field {
            ($name:literal, $f:ident) => {
                ensure!(
                    self.$f == run.$f,
                    "checkpoint does not match this run: {} is {:?} in \
                     the snapshot but {:?} here",
                    $name,
                    self.$f,
                    run.$f,
                );
            };
        }
        field!("model", model);
        field!("total neuron count", n_neurons);
        field!("--ranks", m_ranks);
        field!("--threads (threads per rank)", threads_per_rank);
        field!("--ranks-per-area", ranks_per_area);
        field!("--strategy", strategy);
        field!("--seed", seed);
        field!("communication epoch (cycles)", epoch_cycles);
        field!("steps per cycle", steps_per_cycle);
        field!("--record-spikes", record_spikes);
        Ok(())
    }
}

/// One materialized checkpoint: the fingerprint, the epoch-boundary
/// cycle it was taken at, the communicator quota grown so far, and one
/// opaque state part per rank.
pub struct Snapshot {
    pub fingerprint: Fingerprint,
    pub cycle: u64,
    pub quota: u64,
    pub parts: Vec<Vec<u8>>,
}

impl Snapshot {
    /// Serialize to the on-disk container (header + checksummed
    /// payload).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut pw = ByteWriter::new();
        self.fingerprint.write(&mut pw);
        pw.u64(self.cycle);
        pw.u64(self.quota);
        pw.u32(self.parts.len() as u32);
        for part in &self.parts {
            pw.bytes(part);
        }
        let payload = pw.into_bytes();
        let mut out = Vec::with_capacity(28 + payload.len());
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        out.extend_from_slice(&fnv1a(&payload).to_le_bytes());
        out.extend_from_slice(&payload);
        out
    }

    /// Parse and verify the on-disk container: magic, version, length
    /// (truncation) and checksum (corruption) are all checked before
    /// any payload field is interpreted.
    pub fn from_bytes(raw: &[u8]) -> Result<Snapshot> {
        ensure!(
            raw.len() >= 28,
            "not a checkpoint: file is {} bytes, shorter than the \
             28-byte header",
            raw.len(),
        );
        ensure!(
            raw[..8] == MAGIC,
            "not a checkpoint: bad magic {:?} (expected {:?})",
            &raw[..8],
            std::str::from_utf8(&MAGIC).unwrap(),
        );
        let version = u32::from_le_bytes(raw[8..12].try_into().unwrap());
        ensure!(
            version == VERSION,
            "unsupported checkpoint version {version} (this build reads \
             version {VERSION})",
        );
        let payload_len =
            u64::from_le_bytes(raw[12..20].try_into().unwrap());
        let checksum = u64::from_le_bytes(raw[20..28].try_into().unwrap());
        let payload = &raw[28..];
        ensure!(
            payload.len() as u64 == payload_len,
            "checkpoint truncated or padded: header declares a {} byte \
             payload but {} bytes follow it",
            payload_len,
            payload.len(),
        );
        let actual = fnv1a(payload);
        ensure!(
            actual == checksum,
            "checkpoint corrupted: checksum mismatch (header {checksum:#018x}, \
             payload hashes to {actual:#018x})",
        );
        let mut r = ByteReader::new(payload);
        let fingerprint = Fingerprint::read(&mut r)?;
        let cycle = r.u64()?;
        let quota = r.u64()?;
        let n_parts = r.u32()? as usize;
        let mut parts = Vec::with_capacity(n_parts);
        for _ in 0..n_parts {
            parts.push(r.bytes()?);
        }
        ensure!(
            r.is_done(),
            "checkpoint payload has trailing garbage after the last \
             rank part",
        );
        Ok(Snapshot { fingerprint, cycle, quota, parts })
    }

    /// Write atomically: serialize to `<path>.tmp`, fsync, then rename
    /// over `path`, so readers only ever observe a complete snapshot.
    pub fn write_atomic(&self, path: &str) -> Result<()> {
        use std::io::Write as _;
        let tmp = format!("{path}.tmp");
        let bytes = self.to_bytes();
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating checkpoint {tmp:?}"))?;
        f.write_all(&bytes)
            .with_context(|| format!("writing checkpoint {tmp:?}"))?;
        f.sync_all()
            .with_context(|| format!("syncing checkpoint {tmp:?}"))?;
        drop(f);
        std::fs::rename(&tmp, path).with_context(|| {
            format!("renaming checkpoint {tmp:?} into place at {path:?}")
        })?;
        Ok(())
    }

    /// Read a snapshot back, verifying the container end to end.
    pub fn read_verified(path: &str) -> Result<Snapshot> {
        let raw = std::fs::read(path)
            .with_context(|| format!("reading checkpoint {path:?}"))?;
        Snapshot::from_bytes(&raw)
            .with_context(|| format!("parsing checkpoint {path:?}"))
    }
}

/// The collective rendezvous of a checkpoint write: every rank deposits
/// its serialized part, a barrier (an `allreduce_min` in the engine)
/// guarantees all parts landed, rank 0 assembles and writes the file,
/// a second barrier publishes the outcome, and every rank then checks
/// for a write error so a full disk fails the whole run instead of
/// only rank 0.
pub struct CkptCtx {
    path: String,
    fingerprint: Fingerprint,
    parts: Mutex<Vec<Option<Vec<u8>>>>,
    error: Mutex<Option<String>>,
}

impl CkptCtx {
    pub fn new(
        m_ranks: usize,
        fingerprint: Fingerprint,
        path: String,
    ) -> CkptCtx {
        CkptCtx {
            path,
            fingerprint,
            parts: Mutex::new(vec![None; m_ranks]),
            error: Mutex::new(None),
        }
    }

    /// Deposit `rank`'s serialized state for the checkpoint being
    /// assembled.
    pub fn deposit(&self, rank: usize, part: Vec<u8>) {
        let mut parts = self.parts.lock().expect("checkpoint ctx poisoned");
        debug_assert!(
            parts[rank].is_none(),
            "rank {rank} deposited two checkpoint parts in one round"
        );
        parts[rank] = Some(part);
    }

    /// Assemble all deposited parts into a [`Snapshot`] at `cycle` and
    /// write it atomically (rank 0 only, after the post-deposit
    /// barrier).  Failures are recorded for [`CkptCtx::check`] rather
    /// than returned, because every rank — not just the writer — must
    /// observe them after the publish barrier.
    pub fn assemble_and_write(&self, cycle: u64, quota: u64) {
        let parts: Vec<Vec<u8>> = {
            let mut guard =
                self.parts.lock().expect("checkpoint ctx poisoned");
            guard
                .iter_mut()
                .map(|p| {
                    p.take().expect(
                        "checkpoint part missing after the deposit barrier",
                    )
                })
                .collect()
        };
        let snap = Snapshot {
            fingerprint: self.fingerprint.clone(),
            cycle,
            quota,
            parts,
        };
        if let Err(e) = snap.write_atomic(&self.path) {
            *self.error.lock().expect("checkpoint ctx poisoned") =
                Some(format!("{e:#}"));
        }
    }

    /// The outcome of the last write, observed by every rank after the
    /// publish barrier.
    pub fn check(&self) -> Result<()> {
        if let Some(e) =
            self.error.lock().expect("checkpoint ctx poisoned").clone()
        {
            bail!("checkpoint write failed: {e}");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fp() -> Fingerprint {
        Fingerprint {
            model: "test-net".into(),
            n_neurons: 240,
            m_ranks: 4,
            threads_per_rank: 2,
            ranks_per_area: 1,
            strategy: "structure-aware".into(),
            seed: 12,
            epoch_cycles: 5,
            steps_per_cycle: 4,
            record_spikes: true,
        }
    }

    fn snap() -> Snapshot {
        Snapshot {
            fingerprint: fp(),
            cycle: 40,
            quota: 256,
            parts: vec![vec![1, 2, 3], vec![], vec![255; 9], vec![7]],
        }
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let s = snap();
        let back = Snapshot::from_bytes(&s.to_bytes()).unwrap();
        assert_eq!(back.fingerprint, s.fingerprint);
        assert_eq!(back.cycle, 40);
        assert_eq!(back.quota, 256);
        assert_eq!(back.parts, s.parts);
    }

    #[test]
    fn truncation_detected_not_panicked() {
        let bytes = snap().to_bytes();
        for cut in [0, 5, 27, 28, bytes.len() - 1] {
            let err = Snapshot::from_bytes(&bytes[..cut])
                .expect_err("truncated snapshot accepted");
            let msg = format!("{err:#}");
            assert!(
                msg.contains("truncated")
                    || msg.contains("shorter than the 28-byte header"),
                "unhelpful truncation error: {msg}"
            );
        }
    }

    #[test]
    fn corruption_detected_by_checksum() {
        let mut bytes = snap().to_bytes();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x40;
        let err = Snapshot::from_bytes(&bytes)
            .expect_err("corrupted snapshot accepted");
        assert!(format!("{err:#}").contains("checksum"));
    }

    #[test]
    fn wrong_magic_and_version_rejected() {
        let mut bytes = snap().to_bytes();
        bytes[0] = b'X';
        let err = Snapshot::from_bytes(&bytes).unwrap_err();
        assert!(format!("{err:#}").contains("bad magic"));

        let mut bytes = snap().to_bytes();
        bytes[8] = 99;
        let err = Snapshot::from_bytes(&bytes).unwrap_err();
        assert!(format!("{err:#}").contains("version 99"));
    }

    #[test]
    fn fingerprint_mismatches_name_the_field() {
        let a = fp();
        let mut b = fp();
        b.threads_per_rank = 4;
        let err = a.check_matches(&b).unwrap_err();
        assert!(format!("{err:#}").contains("--threads"));

        let mut c = fp();
        c.seed = 13;
        let err = a.check_matches(&c).unwrap_err();
        assert!(format!("{err:#}").contains("--seed"));
        a.check_matches(&fp()).unwrap();
    }

    #[test]
    fn atomic_write_then_read_back() {
        let dir = std::env::temp_dir();
        let path = dir
            .join(format!("nsim_ckpt_test_{}.ckpt", std::process::id()))
            .to_string_lossy()
            .into_owned();
        let s = snap();
        s.write_atomic(&path).unwrap();
        assert!(
            !std::path::Path::new(&format!("{path}.tmp")).exists(),
            "temporary file left behind"
        );
        let back = Snapshot::read_verified(&path).unwrap();
        assert_eq!(back.parts, s.parts);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn ckpt_ctx_collects_parts_and_reports_write_errors() {
        let ctx = CkptCtx::new(
            2,
            fp(),
            "/nonexistent-dir-zzz/nsim.ckpt".into(),
        );
        ctx.deposit(0, vec![1]);
        ctx.deposit(1, vec![2]);
        ctx.assemble_and_write(10, 64);
        let err = ctx.check().expect_err("write into missing dir succeeded");
        assert!(format!("{err:#}").contains("checkpoint write failed"));
    }
}
