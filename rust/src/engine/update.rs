//! Pluggable update-phase execution: native Rust arithmetic or any
//! external executor (the PJRT/XLA path lives in `runtime::updater`).

use crate::engine::neuron::NeuronBlock;

/// A function advancing a [`NeuronBlock`] one step given summed synaptic
/// input, appending spiking local indices.
pub type StepFn =
    Box<dyn Fn(&mut NeuronBlock, &[f32], &mut Vec<u32>) + Send + Sync>;

/// Update-phase executor shared by all rank threads *and* every worker
/// of the intra-rank pool — it must stay `Send + Sync` (enforced below),
/// which is why [`StepFn`] carries those bounds.
pub enum Updater {
    /// In-process f32 arithmetic (mirrors the L1 kernel op order).
    Native,
    /// External executor, e.g. the AOT-compiled XLA artifact via PJRT.
    Custom(StepFn),
}

// The engine shares one `&Updater` across all rank threads and pool
// workers; fail at compile time if a refactor ever loses the bounds.
const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<Updater>();
};

impl Updater {
    #[inline]
    pub fn step(
        &self,
        block: &mut NeuronBlock,
        syn: &[f32],
        spikes_out: &mut Vec<u32>,
    ) {
        match self {
            Updater::Native => block.step_native(syn, spikes_out),
            Updater::Custom(f) => f(block, syn, spikes_out),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::spec::{LifParams, NeuronKind};

    #[test]
    fn custom_updater_is_called() {
        let updater = Updater::Custom(Box::new(|_, _, out| out.push(42)));
        let mut block = NeuronBlock::build(&[0], 0.1, |_| {
            NeuronKind::Lif(LifParams::default())
        });
        let mut out = Vec::new();
        updater.step(&mut block, &[0.0], &mut out);
        assert_eq!(out, vec![42]);
    }

    #[test]
    fn native_matches_block_step() {
        let updater = Updater::Native;
        let mut a = NeuronBlock::build(&[0, 1], 0.1, |_| {
            NeuronKind::Lif(LifParams::default())
        });
        let mut b = a.clone();
        let (mut oa, mut ob) = (Vec::new(), Vec::new());
        updater.step(&mut a, &[20.0, 0.0], &mut oa);
        b.step_native(&[20.0, 0.0], &mut ob);
        assert_eq!(oa, ob);
    }
}
