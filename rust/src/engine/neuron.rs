//! Native neuron-state propagation, arithmetically identical to the L1
//! Pallas kernels (`python/compile/kernels/`).
//!
//! The operation order matches the kernel exactly — `p22*v + drive + syn`
//! in f32, `where`-style selects — so that the Native and Xla update paths
//! produce bit-identical trajectories (verified by the runtime parity
//! test).  Per-neuron external drive is folded into the synaptic input
//! (the kernel's scalar `drive` parameter stays 0), which lets one AOT
//! artifact serve areas with heterogeneous `i_e`.

use crate::network::spec::{LifParams, NeuronKind};
use crate::network::Gid;

/// Scalar LIF parameters shared by a thread block (f32, as in the kernel).
#[derive(Clone, Copy, Debug)]
pub struct LifScalars {
    pub p22: f32,
    pub theta: f32,
    pub v_reset: f32,
    pub ref_steps: f32,
}

impl LifScalars {
    pub fn from_params(p: &LifParams, h_ms: f64) -> LifScalars {
        LifScalars {
            p22: p.p22(h_ms),
            theta: p.theta_mv as f32,
            v_reset: p.v_reset_mv as f32,
            ref_steps: p.ref_steps(h_ms),
        }
    }
}

/// State of all neurons of one (rank, thread) partition.
#[derive(Clone, Debug)]
pub enum NeuronBlock {
    Lif {
        scalars: LifScalars,
        /// Per-neuron constant drive per step, added to the synaptic input.
        drive: Vec<f32>,
        v: Vec<f32>,
        refr: Vec<f32>,
    },
    IgnoreAndFire {
        phase: Vec<f32>,
        interval: Vec<f32>,
    },
}

/// Deterministic, placement-independent initial phase for ignore-and-fire
/// neurons: a hash of the GID modulo the interval.  Spreads spikes evenly
/// over the interval so aggregate rate is constant per cycle.
pub fn ianf_phase(gid: Gid, interval_steps: u32) -> f32 {
    // splitmix64 finalizer
    let mut z = gid as u64 ^ 0x9e37_79b9_7f4a_7c15;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^= z >> 31;
    (z % interval_steps.max(1) as u64) as f32
}

/// Deterministic membrane jitter in `[0, 1)` from the GID (splitmix64),
/// placement-independent.  Multi-area models initialize `V_m` randomly to
/// avoid an artificial synchronous onset volley.
pub fn vm_jitter(gid: Gid) -> f32 {
    let mut z = (gid as u64).wrapping_add(0x1234_5678_9abc_def0)
        ^ 0x9e37_79b9_7f4a_7c15;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^= z >> 31;
    (z >> 40) as f32 / (1u64 << 24) as f32
}

impl NeuronBlock {
    /// Initialize LIF membranes with GID-derived jitter spanning
    /// `[0, frac * theta)` — placement-independent (keyed by GID), so the
    /// strategy-equivalence invariant is preserved.  No-op for
    /// ignore-and-fire blocks (their phase is already GID-jittered).
    pub fn init_membrane_jitter(&mut self, gids: &[Gid], frac: f32) {
        if let NeuronBlock::Lif { scalars, v, .. } = self {
            debug_assert_eq!(gids.len(), v.len());
            let span = frac * scalars.theta;
            for (vi, &g) in v.iter_mut().zip(gids) {
                *vi = vm_jitter(g) * span;
            }
        }
    }

    /// Build the block for `gids`, taking per-area parameters from
    /// `area_params(gid) -> NeuronKind` (must be homogeneous in kind).
    pub fn build(
        gids: &[Gid],
        h_ms: f64,
        kind_of: impl Fn(Gid) -> NeuronKind,
    ) -> NeuronBlock {
        if gids.is_empty() {
            // kind is irrelevant for an empty block
            return NeuronBlock::Lif {
                scalars: LifScalars::from_params(&LifParams::default(), h_ms),
                drive: vec![],
                v: vec![],
                refr: vec![],
            };
        }
        match kind_of(gids[0]) {
            NeuronKind::Lif(_) => {
                let mut drive = Vec::with_capacity(gids.len());
                let mut scalars = None;
                for &g in gids {
                    match kind_of(g) {
                        NeuronKind::Lif(p) => {
                            let s = LifScalars::from_params(&p, h_ms);
                            let sc = scalars.get_or_insert(s);
                            assert!(
                                sc.p22 == s.p22
                                    && sc.theta == s.theta
                                    && sc.v_reset == s.v_reset
                                    && sc.ref_steps == s.ref_steps,
                                "intrinsic LIF parameters must be \
                                 homogeneous across areas (as in the MAM)"
                            );
                            drive.push(p.drive(h_ms));
                        }
                        _ => panic!("mixed neuron kinds in one model"),
                    }
                }
                NeuronBlock::Lif {
                    scalars: scalars.unwrap(),
                    drive,
                    v: vec![0.0; gids.len()],
                    refr: vec![0.0; gids.len()],
                }
            }
            NeuronKind::IgnoreAndFire { .. } => {
                let mut phase = Vec::with_capacity(gids.len());
                let mut interval = Vec::with_capacity(gids.len());
                for &g in gids {
                    match kind_of(g) {
                        NeuronKind::IgnoreAndFire { interval_steps } => {
                            interval.push(interval_steps as f32);
                            phase.push(ianf_phase(g, interval_steps));
                        }
                        _ => panic!("mixed neuron kinds in one model"),
                    }
                }
                NeuronBlock::IgnoreAndFire { phase, interval }
            }
        }
    }

    pub fn len(&self) -> usize {
        match self {
            NeuronBlock::Lif { v, .. } => v.len(),
            NeuronBlock::IgnoreAndFire { phase, .. } => phase.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Advance all neurons one resolution step.  `syn[i]` is the summed
    /// delta input for neuron `i` this step; indices of spiking neurons
    /// are appended to `spikes_out`.
    ///
    /// Mirrors `_lif_kernel` / `_ianf_kernel` op-for-op.
    pub fn step_native(&mut self, syn: &[f32], spikes_out: &mut Vec<u32>) {
        match self {
            NeuronBlock::Lif { scalars, drive, v, refr } => {
                let LifScalars { p22, theta, v_reset, ref_steps } = *scalars;
                debug_assert_eq!(syn.len(), v.len());
                // zipped iteration elides bounds checks in the hot loop
                for (i, (((vi, ri), &s), &d)) in v
                    .iter_mut()
                    .zip(refr.iter_mut())
                    .zip(syn.iter())
                    .zip(drive.iter())
                    .enumerate()
                {
                    let input = s + d;
                    let is_ref = *ri > 0.0;
                    let v_int = p22 * *vi + 0.0f32 + input;
                    let v_new = if is_ref { v_reset } else { v_int };
                    let spike = !is_ref && v_new >= theta;
                    *vi = if spike { v_reset } else { v_new };
                    *ri = if spike {
                        ref_steps
                    } else {
                        (*ri - 1.0).max(0.0)
                    };
                    if spike {
                        spikes_out.push(i as u32);
                    }
                }
            }
            NeuronBlock::IgnoreAndFire { phase, interval } => {
                for i in 0..phase.len() {
                    let ph = phase[i] + 1.0;
                    let spike = ph >= interval[i];
                    phase[i] = if spike { 0.0 } else { ph };
                    if spike {
                        spikes_out.push(i as u32);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::spec::LifParams;

    fn lif_block(n: usize, i_e: f64) -> NeuronBlock {
        let gids: Vec<Gid> = (0..n as Gid).collect();
        let params = LifParams { i_e_pa: i_e, ..Default::default() };
        NeuronBlock::build(&gids, 0.1, |_| NeuronKind::Lif(params))
    }

    #[test]
    fn lif_decays_without_input() {
        let mut b = lif_block(1, 0.0);
        if let NeuronBlock::Lif { v, .. } = &mut b {
            v[0] = 10.0;
        }
        let mut spk = Vec::new();
        b.step_native(&[0.0], &mut spk);
        if let NeuronBlock::Lif { v, .. } = &b {
            let want = 10.0f32 * (-0.01f64).exp() as f32;
            assert!((v[0] - want).abs() < 1e-5);
        }
        assert!(spk.is_empty());
    }

    #[test]
    fn lif_spikes_and_goes_refractory() {
        let mut b = lif_block(2, 0.0);
        let mut spk = Vec::new();
        b.step_native(&[20.0, 1.0], &mut spk);
        assert_eq!(spk, vec![0]);
        if let NeuronBlock::Lif { v, refr, .. } = &b {
            assert_eq!(v[0], 0.0);
            assert_eq!(refr[0], 20.0);
            assert!(refr[1] == 0.0);
        }
        // refractory: massive input ignored, no spike
        spk.clear();
        b.step_native(&[100.0, 0.0], &mut spk);
        assert!(spk.is_empty());
        if let NeuronBlock::Lif { v, refr, .. } = &b {
            assert_eq!(v[0], 0.0);
            assert_eq!(refr[0], 19.0);
        }
    }

    #[test]
    fn tonic_rate_matches_f_i_inverse() {
        // drive calibrated for 10 Hz must produce ~10 Hz over 1 s
        let params = LifParams::default();
        let i_e = params.i_e_for_rate(10.0);
        let mut b = lif_block(1, 0.0);
        if let NeuronBlock::Lif { drive, .. } = &mut b {
            let p = LifParams { i_e_pa: i_e, ..Default::default() };
            drive[0] = p.drive(0.1);
        }
        let mut count = 0;
        let mut spk = Vec::new();
        for _ in 0..10_000 {
            spk.clear();
            b.step_native(&[0.0], &mut spk);
            count += spk.len();
        }
        assert!((9..=11).contains(&count), "rate {count}/s");
    }

    #[test]
    fn ianf_fires_at_interval_with_gid_phase() {
        let gids: Vec<Gid> = (0..100).collect();
        let mut b = NeuronBlock::build(&gids, 0.1, |_| {
            NeuronKind::IgnoreAndFire { interval_steps: 10 }
        });
        let syn = vec![0.0; 100];
        let mut per_step = Vec::new();
        for _ in 0..100 {
            let mut spk = Vec::new();
            b.step_native(&syn, &mut spk);
            per_step.push(spk.len());
        }
        let total: usize = per_step.iter().sum();
        assert_eq!(total, 100 * 10); // each neuron 10 times in 100 steps
        // phases spread: no step gets all 100 spikes
        assert!(per_step.iter().all(|&n| n < 40), "{per_step:?}");
    }

    #[test]
    fn ianf_phase_is_deterministic_and_in_range() {
        for gid in 0..1000u32 {
            let p = ianf_phase(gid, 4000);
            assert_eq!(p, ianf_phase(gid, 4000));
            assert!(p >= 0.0 && p < 4000.0);
        }
    }

    #[test]
    fn empty_block_is_noop() {
        let mut b = NeuronBlock::build(&[], 0.1, |_| {
            NeuronKind::Lif(LifParams::default())
        });
        let mut spk = Vec::new();
        b.step_native(&[], &mut spk);
        assert!(spk.is_empty());
        assert!(b.is_empty());
    }

    #[test]
    #[should_panic(expected = "homogeneous")]
    fn heterogeneous_intrinsic_params_rejected() {
        let gids: Vec<Gid> = vec![0, 1];
        NeuronBlock::build(&gids, 0.1, |g| {
            NeuronKind::Lif(LifParams {
                tau_m_ms: if g == 0 { 10.0 } else { 20.0 },
                ..Default::default()
            })
        });
    }
}
