//! Per-rank simulation state and the cycle loop (paper Fig 3).
//!
//! Each rank owns its thread partitions (NEST's virtual threads), the
//! dual connection/source/target tables, spike registers, MPI buffers and
//! ring buffers.  `run()` iterates deliver → update → collocate →
//! communicate for `S` cycles, with the communicate step depending on the
//! strategy: global exchange every cycle (conventional/intermediate) or
//! the **two-tier hybrid schedule** (structure-aware) — a local-tier
//! exchange every cycle plus the global exchange every D-th cycle.
//!
//! # The parallel receive side
//!
//! Received spikes arrive as **runs** — the transport's per-sender
//! buffers, absorbed into a per-pathway [`RunSet`] by the communicate
//! step.  Delivery never flattens them into one batch: spike compression
//! makes the canonical `(source, cycle)` key globally unique across a
//! deliver phase, so sorting each run independently and k-way merging
//! the sorted runs reproduces *the* canonical order bit-exactly (see
//! `engine::receive`).  The work parallelizes across the receive side
//! (arXiv 2109.11358) instead of serializing on the coordinator:
//!
//! 1. **bucket** (parallel over *producers*): each worker sorts its
//!    share of the runs, merges them into its canonical substream, and
//!    scatters every spike through [`SourceShards`] into per-(producer,
//!    consumer) grid cells — already resolved to a connection-*group*
//!    index, so the consumer never searches its table;
//! 2. **merge** (parallel over *consumers*): each worker k-way merges
//!    its own grid column back into the canonical order and accumulates
//!    whole delay buckets into its ring buffer via
//!    [`RingBuffer::accumulate_row`] (the cache-aware connection layout
//!    of arXiv 2109.12855, see `tables`).
//!
//! The sequential path runs the same bucket/merge code on one OS
//! thread; the legacy channel pool keeps the old coordinator-sorted
//! broadcast delivery as an A/B arm.  All paths produce bit-identical
//! spike trains: the merged per-thread delivery sequence equals the
//! canonical subsequence the old full-batch scan produced, every
//! virtual thread owns its ring buffer and neuron block exclusively,
//! and collocation output is concatenated in virtual-thread order.
//! Delay-bucketed accumulation reorders f64 adds within a (source,
//! step) group only — exact for the asserted binary-fraction weights
//! (DESIGN.md §6), hence order-independent.
//!
//! # The phase-barrier worker protocol
//!
//! The barrier runtime spawns one worker OS thread per virtual thread
//! *once per run*; workers then advance through the cycle phases in
//! lock-step with the coordinator (the rank's OS thread) over a single
//! reusable [`std::sync::Barrier`] of size `T + 1`, with zero channel
//! traffic and no steady-state *spike buffer* allocation (the bucket
//! and merge phases each build one pointer-sized scratch vector of
//! borrowed views per worker per cycle).  Each worker owns its
//! [`ThreadState`] outright, shares one [`Mutex`]-guarded slot with the
//! coordinator, and shares the T×T bucket grid with its siblings; the
//! barriers partition time so no lock is ever contended — the bucket
//! phase locks grid *row* `w` (disjoint across producers), the merge
//! phase locks grid *column* `t` (disjoint across consumers), and a
//! barrier separates the phases.  Per cycle:
//!
//! 1. coordinator: distribute the received runs round-robin over the
//!    worker slots, then `wait()` (**runs ready**);
//! 2. workers: sort + merge own runs, scatter into grid row (bucket
//!    phase), `wait()` (**buckets ready**);
//! 3. workers: k-way merge own grid column into the ring buffer (merge
//!    phase), `wait()` (**deliver done** — coordinator charges the
//!    deliver phase);
//! 4. workers: advance neurons one cycle, `wait()` (**update done** —
//!    coordinator charges the update phase);
//! 5. workers: collocate spike registers into the slot's output
//!    buffers, `wait()` (**collocate done**); coordinator drains the
//!    slots in virtual-thread order (the determinism barrier), reclaims
//!    the cleared run buffers into the [`RunSet`] pools, charges
//!    collocate and runs the communicate step while workers park at the
//!    next cycle's *runs ready* barrier.
//!
//! The *runs ready* barrier doubles as the stop gate: the coordinator
//! raises an [`AtomicBool`] before releasing it when the run segment
//! ends (its natural end, a checkpoint boundary, an injected kill, or
//! a comm error unwinding the run), and workers hand their
//! [`ThreadState`] and recorded spikes back through the scoped-thread
//! join handles — so the rank can checkpoint the state between
//! segments and resume the same workers for the next one.
//!
//! # Overlapped communication ([`crate::config::CommMode::Overlap`])
//!
//! Under the split-phase comm mode the epoch-boundary global exchange is
//! *posted* ([`crate::comm::SplitTransport::alltoall_start`]) at the end
//! of the boundary cycle without waiting for any peer, the rank keeps
//! running local cycles, and the exchange is *completed* just before the
//! first cycle whose delivery deadline needs the spikes.  The deadline
//! is sound by construction: every spike in the exchange was emitted no
//! earlier than the first cycle of the posting epoch and travels a
//! connection of at least `min_remote_delay_steps` — the rank's
//! *realized* minimum incoming delay over the tables the exchange
//! delivers through (floored by the model's `d_min_inter` cutoff, but
//! typically several cycles above it, which is the latency-hiding
//! budget).
//!
//! ## The depth-D deadline schedule
//!
//! With `comm_depth = D` (`--comm-depth`) the rank keeps up to `D`
//! exchanges in flight across consecutive epoch boundaries; each
//! exchange completes at the earlier of its causality deadline and the
//! `D`-th boundary after its post (the transport's mailbox-ring bound):
//!
//! ```text
//! cycle    0    1    2    3    4    5    ...        (epoch = 1 cycle,
//! post     e0   e1   e2   e3   e4   e5              realized min remote
//!          ├────┼────┼────╮                          delay = 3 cycles,
//!          │    ├────┼────┼────╮                     depth D = 3)
//!          │    │    ├────┼────┼────╮
//! complete ▼    ▼    e0   e1   e2   e3 ...
//!                    ▲ deliver of cycle 3 consumes e0's spikes:
//!                      deadline(e_k) = k + min(slack, D) cycles
//! ```
//!
//! Every cycle, `RankState::service_exchanges` first runs the
//! **incremental per-source completion** fast path: a condvar-free
//! try-drain of every (in-flight exchange, source) pair, consuming
//! deposits that already landed while the exchange stays pending.  At
//! the deadline, the final rendezvous then waits only for peers whose
//! deposit is still missing.  Deadlines of consecutive exchanges are
//! strictly increasing (the clamp window translates by one epoch per
//! post), so completions are FIFO and the in-flight count never exceeds
//! the lesser of `D` and the realized slack — which is why depths
//! beyond the collectively-reduced [`RankState::max_sustainable_depth`]
//! are rejected at startup rather than silently under-delivered.
//!
//! Each in-flight exchange owns a recycled per-source receive-buffer
//! set (checked out of `recv_pool` at post, returned at completion), so
//! early drains of a younger exchange never clobber an older one's
//! spikes and no *spike buffer* is allocated in steady state at any
//! depth (the transport's per-post drained-flag vector — M bytes — is
//! the one steady-state allocation of the overlapped path).  Posting
//! swaps each send buffer against an empty recycled vector, so the
//! rank's single send set is immediately reusable while the deposited
//! data rides its ring slot.  Because every delivered spike still lands
//! in the ring buffer strictly before the first row that could contain
//! it is read — the causality `debug_assert` in `deliver_conns` checks
//! exactly this deadline, and [`RingBuffer::with_horizon`] asserts the
//! ring can hold the full write-ahead window at construction — spike
//! trains are bit-identical to the blocking mode in every exec mode at
//! every depth.

use crate::comm::{
    CommError, Pending, SpikeMsg, SplitTransport, Transport,
};
use crate::config::{CommMode, ExecMode, RankFaults, Strategy};
use crate::engine::checkpoint::{ByteReader, ByteWriter, CkptCtx};
use crate::engine::neuron::NeuronBlock;
use crate::engine::{Cancelled, Progress, SimHooks};
use crate::engine::receive::{
    bucket_runs, merge_routed, sort_canonical, sort_run, RoutedSpike, RunSet,
};
use crate::engine::ringbuffer::RingBuffer;
use crate::engine::update::Updater;
use crate::network::{incoming_connections, Gid, ModelSpec};
use crate::obs::intervals::{TierIntervalSummary, TierIntervals};
use crate::obs::{SpanCtx, Tracer};
use crate::placement::Placement;
use crate::tables::{
    mask_test, ConnSlice, ConnTable, LocalConn, Pathways, SourceShards,
    TargetTable,
};
use crate::util::timers::{Phase, PhaseTimes, Stopwatch};
use anyhow::{bail, ensure, Context, Result};
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Barrier, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// One virtual thread's worth of state.
pub struct ThreadState {
    /// Ascending thread-local GIDs; index in this vec = local index.
    pub gids: Vec<Gid>,
    pub block: NeuronBlock,
    pub ring: RingBuffer,
    pub conn: Pathways<ConnTable>,
    pub targets: Pathways<TargetTable>,
    /// Per-neuron has-targets bitmasks (one bit per local neuron, built
    /// once from `targets` after the target-table exchange), so the
    /// update hot loop tests membership without touching the per-neuron
    /// rank vectors.
    has_targets: Pathways<Vec<u64>>,
    /// Scratch: per-step synaptic input row.
    syn_buf: Vec<f32>,
    /// Scratch: spiking local indices of the current step.
    spike_idx: Vec<u32>,
    /// Spike registers (local index, emission step), split by pathway.
    register: Pathways<Vec<(u32, u64)>>,
}

/// Accumulate one spike's connection group into `ring`, one delay
/// bucket at a time: every bucket is a single
/// [`RingBuffer::accumulate_row`] call, so the writes walk one slot row
/// sequentially (the access pattern the delay-bucketed [`ConnTable`]
/// layout exists for).  The causality `debug_assert` is the delivery
/// deadline check the overlapped comm mode relies on.
#[inline]
fn deliver_conns(
    ring: &mut RingBuffer,
    conns: ConnSlice<'_>,
    source: Gid,
    cycle: u32,
    first_step: u64,
) {
    for (delay, targets, weights) in conns.delay_runs() {
        let arrive = cycle as u64 + delay as u64;
        debug_assert!(
            arrive >= first_step,
            "spike from {source} missed its delivery deadline: arrives \
             at step {arrive} < current step {first_step} (its \
             ring-buffer row was already consumed)"
        );
        ring.accumulate_row(arrive, targets, weights);
    }
}

impl ThreadState {
    /// Deliver a `(source, step)`-sorted spike batch through this
    /// thread's tables of the given pathway into its ring buffer, with
    /// a per-spike table lookup — the legacy broadcast delivery of the
    /// channel runtime, kept as the A/B baseline the parallel receive
    /// path is measured against.
    fn deliver_sorted(
        &mut self,
        long_range: bool,
        batch: &[SpikeMsg],
        first_step: u64,
    ) {
        let ThreadState { conn, ring, .. } = self;
        let table = conn.get(long_range);
        for msg in batch {
            deliver_conns(
                ring,
                table.lookup(msg.source),
                msg.source,
                msg.cycle,
                first_step,
            );
        }
    }

    /// Deliver one routed spike: the connection group was already
    /// resolved by [`SourceShards`] during bucketing, so this is a
    /// direct CSR row access — no search on the hot path.
    #[inline]
    fn deliver_routed(
        &mut self,
        long_range: bool,
        sp: RoutedSpike,
        first_step: u64,
    ) {
        let ThreadState { conn, ring, .. } = self;
        deliver_conns(
            ring,
            conn.get(long_range).group(sp.group as usize),
            sp.source,
            sp.cycle,
            first_step,
        );
    }

    /// Advance this thread's neurons through one cycle of `steps`
    /// resolution steps, recording emitted spikes into `spikes_out` and
    /// filling the pathway registers for the collocate phase.
    fn update_cycle(
        &mut self,
        updater: &Updater,
        first_step: u64,
        steps: u64,
        dual: bool,
        record_spikes: bool,
        spikes_out: &mut Vec<(u64, Gid)>,
    ) {
        for step in first_step..first_step + steps {
            self.ring.take_row(step, &mut self.syn_buf);
            self.spike_idx.clear();
            updater.step(&mut self.block, &self.syn_buf, &mut self.spike_idx);
            for &idx in &self.spike_idx {
                if record_spikes {
                    spikes_out.push((step, self.gids[idx as usize]));
                }
                if dual {
                    if mask_test(&self.has_targets.short, idx as usize) {
                        self.register.short.push((idx, step));
                    }
                    if mask_test(&self.has_targets.long, idx as usize) {
                        self.register.long.push((idx, step));
                    }
                } else if mask_test(&self.has_targets.short, idx as usize) {
                    self.register.short.push((idx, step));
                }
            }
        }
    }

    /// Drain this thread's spike registers into send buffers: the local
    /// pathway into the per-group-member buffers `local_out` (one per
    /// rank of this rank's area group; member `i` is global rank
    /// `group_start + i`), the global pathway into `global_out[d]` per
    /// destination rank (spike compression: one entry per target rank).
    /// A singleton group (`local_out.len() == 1`) skips the per-member
    /// routing — every short-range target is on this rank by
    /// construction, the pre-hierarchical behavior.  Register order —
    /// (step, local index) within the cycle — is preserved, so
    /// concatenating per-thread output in thread order reproduces the
    /// sequential collocation exactly.
    fn collocate_into(
        &mut self,
        dual: bool,
        group_start: u16,
        local_out: &mut [Vec<SpikeMsg>],
        global_out: &mut [Vec<SpikeMsg>],
    ) {
        if dual {
            if local_out.len() == 1 {
                // short-range spikes into the (sole) local exchange
                // buffer: the whole area lives on this rank
                for &(idx, step) in &self.register.short {
                    local_out[0].push(SpikeMsg {
                        source: self.gids[idx as usize],
                        cycle: step as u32,
                    });
                }
            } else {
                // the area spans a rank group: route per group member
                // through the short-pathway target tables (the same
                // spike compression as the global pathway)
                for &(idx, step) in &self.register.short {
                    let gid = self.gids[idx as usize];
                    for &r in self.targets.short.ranks(idx as usize) {
                        debug_assert!(
                            r >= group_start
                                && ((r - group_start) as usize)
                                    < local_out.len(),
                            "short-range target rank {r} outside the \
                             area group starting at {group_start}"
                        );
                        local_out[(r - group_start) as usize].push(
                            SpikeMsg { source: gid, cycle: step as u32 },
                        );
                    }
                }
            }
            self.register.short.clear();
            // long-range spikes accumulate in the global MPI buffers
            // across the epoch
            for &(idx, step) in &self.register.long {
                let gid = self.gids[idx as usize];
                for &r in self.targets.long.ranks(idx as usize) {
                    global_out[r as usize].push(SpikeMsg {
                        source: gid,
                        cycle: step as u32,
                    });
                }
            }
            self.register.long.clear();
        } else {
            for &(idx, step) in &self.register.short {
                let gid = self.gids[idx as usize];
                for &r in self.targets.short.ranks(idx as usize) {
                    global_out[r as usize].push(SpikeMsg {
                        source: gid,
                        cycle: step as u32,
                    });
                }
            }
            self.register.short.clear();
        }
    }
}

/// Measurements returned by a rank after the run.
pub struct RankResult {
    pub rank: usize,
    pub phase_times: PhaseTimes,
    /// (deliver+update+collocate) wall seconds per cycle (paper eq 18).
    pub cycle_times: Vec<f64>,
    /// Recorded spikes (emission step, gid).  Within a rank the order is
    /// execution-dependent (per virtual thread in pooled mode); callers
    /// sort globally by (step, gid) as `engine::simulate` does.
    pub spikes: Vec<(u64, Gid)>,
    /// Synapses hosted by this rank, by pathway.
    pub n_conns_short: usize,
    pub n_conns_long: usize,
    /// Local neurons (real, not ghosts).
    pub n_neurons: usize,
    /// Residual [`RingBuffer::pending_total`] per virtual thread after
    /// the last cycle — input that was delivered but never consumed.
    /// Exactly 0.0 when every delay fits inside the simulated horizon;
    /// the conservation test pins delays to make that so, and the
    /// equivalence tests assert the vector is bit-identical across exec
    /// and comm modes either way.
    pub ring_pending: Vec<f64>,
    /// Streaming compute-interval statistics per communication tier
    /// (always on — the bounded replacement for `cycle_times`).
    pub intervals: TierIntervalSummary,
}

/// Per-rank observability state threaded through the run: the span
/// tracer (a no-op when tracing is off) and the streaming
/// compute-interval recorders (always on — fixed size, no steady-state
/// allocation).  Lives on the rank's coordinator OS thread only, so no
/// synchronization beyond the tracer's own per-rank sink.
struct RankObs {
    tracer: Tracer,
    intervals: TierIntervals,
    /// Caller-supplied runtime hooks (cancellation token + progress
    /// callback) — default (none) for plain CLI runs.
    hooks: SimHooks,
    /// Total cycles of the run, for the progress payload.
    s_cycles: u64,
}

impl RankObs {
    /// Fire the mid-run progress hook: rank 0 only, at the configured
    /// epoch period, right after the interval recorder absorbed the
    /// boundary cycle.  The interval summary is a non-consuming O(1)
    /// snapshot of the streaming recorders.
    fn maybe_progress(&self, rank: usize, s: u64, epoch_cycles: u64) {
        let Some(cb) = self.hooks.progress.as_ref() else {
            return;
        };
        if rank != 0 {
            return;
        }
        let every =
            self.hooks.progress_every_epochs.max(1) * epoch_cycles;
        if (s + 1) % every == 0 {
            cb(Progress {
                cycle: s + 1,
                s_cycles: self.s_cycles,
                intervals: self.intervals.summary(),
            });
        }
    }
}

/// Collective cancellation check at the top of an epoch-boundary
/// cycle.  Every rank reaches this collective at the same cycle (the
/// token is either present on all ranks or on none), and all of them
/// unwind together only once the *minimum* over "have I seen the
/// flag?" is 1 — i.e. the last rank has observed it.  An asymmetric
/// exit would leave the other ranks blocked in the cycle's collectives;
/// agreeing first means the error path below is exactly the one the
/// comm-error unwind already exercises.
fn check_cancel<T: Transport>(
    hooks: &SimHooks,
    comm: &T,
    s: u64,
    epoch_cycles: u64,
) -> Result<()> {
    let Some(flag) = hooks.cancel.as_ref() else {
        return Ok(());
    };
    if s % epoch_cycles != 0 {
        return Ok(());
    }
    let seen = flag.load(Ordering::Relaxed) as u64;
    let all = comm
        .allreduce_min_u64(seen)
        .context("cancellation agreement reduction")?;
    if all == 1 {
        return Err(Cancelled { cycle: s }.into());
    }
    Ok(())
}

/// The rank-side view of the engine's checkpoint schedule: the shared
/// collection context and the epoch period.  `None` in
/// [`RunOpts::ckpt`] disables checkpointing entirely.
pub struct CkptSched<'a> {
    pub ctx: &'a CkptCtx,
    pub every_epochs: u64,
}

/// Everything [`RankState::run`] needs beyond the communicators: the
/// cycle range, the exec mode, this rank's injected faults and the
/// checkpoint schedule.  `start_cycle > 0` means the state was restored
/// from a snapshot taken at that cycle.
pub struct RunOpts<'a> {
    pub s_cycles: u64,
    pub start_cycle: u64,
    pub record_cycle_times: bool,
    pub exec: ExecMode,
    pub faults: RankFaults,
    pub ckpt: Option<CkptSched<'a>>,
    /// Span tracer for this rank ([`Tracer::off`] when `--trace` is
    /// absent — one branch per span site, no clock reads).
    pub tracer: Tracer,
    /// Runtime hooks (cancellation + progress); the default no-hook
    /// value adds no collectives and no per-cycle work.
    pub hooks: &'a SimHooks,
}

/// Apply the injected compute-straggler factor for `epoch`: sleep so
/// the cycle's update phase appears inflated by the configured factor.
/// Purely a timing perturbation — neuron state is untouched, so spike
/// trains are bit-identical with and without the injection (which the
/// fault-tolerance tests assert).  Returns the extra seconds, charged
/// to the update phase like real compute would be.
fn straggle(
    faults: &RankFaults,
    epoch: u64,
    update_secs: f64,
    phase_times: &mut PhaseTimes,
    sw: &mut Stopwatch,
) -> f64 {
    let factor = faults.straggle_factor(epoch);
    if factor <= 1.0 || update_secs <= 0.0 {
        return 0.0;
    }
    std::thread::sleep(Duration::from_secs_f64(
        (factor - 1.0) * update_secs,
    ));
    // lap the caller's stopwatch over the sleep so the injected time
    // lands in the update phase, not the next phase it would charge
    let extra = sw.lap();
    phase_times.add(Phase::Update, extra);
    extra
}

/// Commands from the rank's coordinator to one pool worker.  Buffers
/// travel with the command and come back with the reply, so the pool is
/// allocation-free in steady state.
enum Cmd {
    Deliver {
        long_range: bool,
        batch: Arc<Vec<SpikeMsg>>,
        first_step: u64,
    },
    Update {
        first_step: u64,
        steps: u64,
        dual: bool,
        record_spikes: bool,
    },
    Collocate {
        dual: bool,
        /// One buffer per rank of this rank's area group.
        local: Vec<Vec<SpikeMsg>>,
        global: Vec<Vec<SpikeMsg>>,
    },
    Finish,
}

enum Reply {
    Done,
    Collocated {
        local: Vec<Vec<SpikeMsg>>,
        global: Vec<Vec<SpikeMsg>>,
    },
    Finished {
        spikes: Vec<(u64, Gid)>,
        /// The worker's thread state, handed back so the rank can
        /// checkpoint between segments and reuse the state for the
        /// next one (boxed: the state dwarfs the other variants).
        state: Box<ThreadState>,
    },
}

/// Body of one pool worker: owns its [`ThreadState`] exclusively and
/// serves phase commands until `Finish`.
fn worker_loop(
    mut th: ThreadState,
    updater: &Updater,
    group_start: u16,
    rx: Receiver<Cmd>,
    tx: Sender<Reply>,
) {
    let mut spikes: Vec<(u64, Gid)> = Vec::new();
    while let Ok(cmd) = rx.recv() {
        match cmd {
            Cmd::Deliver { long_range, batch, first_step } => {
                th.deliver_sorted(long_range, &batch, first_step);
                // release the shared batch before signalling so the
                // coordinator can reclaim the buffer via Arc::try_unwrap
                drop(batch);
                if tx.send(Reply::Done).is_err() {
                    return;
                }
            }
            Cmd::Update { first_step, steps, dual, record_spikes } => {
                th.update_cycle(
                    updater,
                    first_step,
                    steps,
                    dual,
                    record_spikes,
                    &mut spikes,
                );
                if tx.send(Reply::Done).is_err() {
                    return;
                }
            }
            Cmd::Collocate { dual, mut local, mut global } => {
                th.collocate_into(dual, group_start, &mut local, &mut global);
                if tx.send(Reply::Collocated { local, global }).is_err() {
                    return;
                }
            }
            Cmd::Finish => {
                let _ = tx.send(Reply::Finished {
                    spikes,
                    state: Box::new(th),
                });
                return;
            }
        }
    }
}

fn expect_done(rx: &Receiver<Reply>) {
    match rx.recv().expect("pool worker died") {
        Reply::Done => {}
        _ => unreachable!("unexpected pool worker reply"),
    }
}

/// Sort `buf` canonically, broadcast it to all workers for delivery, and
/// reclaim the buffer for the next round once every worker is done —
/// the legacy coordinator-sorted delivery (the "old" arm of the
/// delivery A/B; the barrier runtime replaces it with the cooperative
/// bucket/merge protocol).
fn pooled_deliver(
    buf: &mut Vec<SpikeMsg>,
    long_range: bool,
    first_step: u64,
    cmd_txs: &[Sender<Cmd>],
    reply_rxs: &[Receiver<Reply>],
) {
    if buf.is_empty() {
        return;
    }
    let mut batch = std::mem::take(buf);
    sort_canonical(&mut batch);
    let shared = Arc::new(batch);
    for tx in cmd_txs {
        tx.send(Cmd::Deliver {
            long_range,
            batch: shared.clone(),
            first_step,
        })
        .expect("pool worker died");
    }
    for rx in reply_rxs {
        expect_done(rx);
    }
    // all workers dropped their clones after replying; recycle the
    // allocation (fall back to a fresh buffer if anything still holds on)
    if let Ok(mut v) = Arc::try_unwrap(shared) {
        v.clear();
        *buf = v;
    }
}

/// Coordinator↔worker hand-off slot of the barrier runtime.  The mutex
/// is never contended: the barriers partition time so the coordinator
/// touches it only between *collocate done* and the next *runs ready*,
/// and the owning worker only in between.
struct WorkerSlot {
    data: Mutex<SlotData>,
}

/// The buffers exchanged through one [`WorkerSlot`], all recycled across
/// cycles (cleared, never dropped).
#[derive(Default)]
struct SlotData {
    /// Coordinator → worker: this worker's share of the received
    /// short-pathway runs (each run canonically sortable on its own).
    /// The worker clears them during the bucket phase; the coordinator
    /// reclaims the cleared buffers into the [`RunSet`] pool.
    runs_short: Vec<Vec<SpikeMsg>>,
    /// Coordinator → worker: share of the long-pathway runs.
    runs_long: Vec<Vec<SpikeMsg>>,
    /// Worker → coordinator: local-pathway collocation output, one
    /// buffer per rank of the area group (a single buffer for the
    /// degenerate one-rank group).
    local_out: Vec<Vec<SpikeMsg>>,
    /// Worker → coordinator: per-destination-rank collocation output.
    global_out: Vec<Vec<SpikeMsg>>,
}

/// One producer→consumer cell of the T×T bucket grid: the routed
/// spikes producer `w` scattered for consumer `t`, per pathway, each
/// in canonical order (a merge of canonically sorted runs scattered in
/// order stays sorted).  Buffers are recycled across cycles.  The
/// mutexes are never contended — the bucket phase locks whole rows
/// (disjoint per producer), the merge phase whole columns (disjoint
/// per consumer), and a barrier separates the phases.
#[derive(Default)]
struct BucketCell {
    short: Vec<RoutedSpike>,
    long: Vec<RoutedSpike>,
}

/// Aborts the process if dropped while panicking.  [`Barrier`] has no
/// poisoning: a worker that panicked between waits would leave the
/// coordinator (and every sibling) blocked forever, turning a bug into
/// a silent hang.  Aborting instead keeps failures loud, matching the
/// "pool worker died" behaviour of the channel runtime.
struct AbortOnPanic;

impl Drop for AbortOnPanic {
    fn drop(&mut self) {
        if std::thread::panicking() {
            eprintln!(
                "barrier worker panicked; aborting to avoid deadlocking \
                 the phase barrier"
            );
            std::process::abort();
        }
    }
}

/// Body of one persistent barrier-runtime worker (see the module docs
/// for the phase protocol).  Owns [`ThreadState`] number `me` for one
/// run segment; participates in the cooperative bucket/merge receive
/// as producer `me` (grid row) and consumer `me` (grid column).  The
/// worker does not count cycles itself: each iteration starts at the
/// *runs ready* barrier, which doubles as the stop gate — the
/// coordinator raises `stop` before releasing that barrier when the
/// segment is over *or* a comm error is unwinding the run, so workers
/// always exit cleanly instead of deadlocking the phase barrier.
/// Returns the thread state (for checkpointing / the next segment)
/// and the spikes recorded during the segment.
#[allow(clippy::too_many_arguments)]
fn barrier_worker(
    me: usize,
    mut th: ThreadState,
    updater: &Updater,
    slot: &WorkerSlot,
    grid: &[Vec<Mutex<BucketCell>>],
    shards: &Pathways<SourceShards>,
    barrier: &Barrier,
    stop: &AtomicBool,
    start: u64,
    end: u64,
    steps: u64,
    dual: bool,
    group_start: u16,
    record_spikes: bool,
) -> (ThreadState, Vec<(u64, Gid)>) {
    let _abort_guard = AbortOnPanic;
    let mut spikes: Vec<(u64, Gid)> = Vec::new();
    let mut heads: Vec<usize> = Vec::new();
    let mut s = start;
    loop {
        barrier.wait(); // runs ready (doubles as the stop gate)
        if stop.load(Ordering::Acquire) {
            break;
        }
        debug_assert!(s < end, "coordinator released a cycle past the end");
        let first_step = s * steps;
        let mut guard = slot.data.lock().unwrap();
        let d = &mut *guard;
        // ---- bucket phase: sort + merge own runs, scatter into grid
        // row `me` (locking the row; rows are disjoint across workers)
        {
            let mut row: Vec<MutexGuard<'_, BucketCell>> =
                grid[me].iter().map(|c| c.lock().unwrap()).collect();
            bucket_runs(
                &shards.short,
                &mut d.runs_short,
                &mut heads,
                |t, sp| row[t as usize].short.push(sp),
            );
            bucket_runs(
                shards.get(dual),
                &mut d.runs_long,
                &mut heads,
                |t, sp| row[t as usize].long.push(sp),
            );
        }
        barrier.wait(); // buckets ready
        // ---- merge phase: k-way merge grid column `me` into the ring
        // (locking the column; columns are disjoint across workers)
        {
            let mut col: Vec<MutexGuard<'_, BucketCell>> =
                grid.iter().map(|p| p[me].lock().unwrap()).collect();
            {
                let views: Vec<&[RoutedSpike]> =
                    col.iter().map(|c| c.short.as_slice()).collect();
                merge_routed(&views, &mut heads, |sp| {
                    th.deliver_routed(false, sp, first_step)
                });
            }
            {
                let views: Vec<&[RoutedSpike]> =
                    col.iter().map(|c| c.long.as_slice()).collect();
                merge_routed(&views, &mut heads, |sp| {
                    th.deliver_routed(dual, sp, first_step)
                });
            }
            for c in col.iter_mut() {
                c.short.clear();
                c.long.clear();
            }
        }
        barrier.wait(); // deliver done
        th.update_cycle(
            updater,
            first_step,
            steps,
            dual,
            record_spikes,
            &mut spikes,
        );
        barrier.wait(); // update done
        th.collocate_into(
            dual,
            group_start,
            &mut d.local_out,
            &mut d.global_out,
        );
        drop(guard);
        barrier.wait(); // collocate done
        s += 1;
    }
    (th, spikes)
}

/// One in-flight split-phase exchange, the cycle before whose deliver
/// phase it must be completed, and the per-source receive buffers it
/// drains into — owned per exchange so the incremental fast path can
/// fill them while older exchanges are still pending (buffer sets are
/// recycled through `RankState::recv_pool`).
struct InFlight<P: Pending> {
    pending: P,
    deadline_cycle: u64,
    recv: Vec<Vec<SpikeMsg>>,
}

/// Full per-rank state.
pub struct RankState {
    rank: usize,
    strategy: Strategy,
    /// Blocking or split-phase (overlapped) global exchange.
    comm_mode: CommMode,
    /// Split-phase pipeline depth: up to this many exchanges in flight
    /// per rank (1 under `CommMode::Blocking`).
    comm_depth: u64,
    /// Cycles between global exchanges (1 unless structure-aware).
    epoch_cycles: u64,
    steps_per_cycle: u64,
    /// Realized minimum delay (steps) over the connections that the
    /// global exchange delivers through — the long-range tables under
    /// dual pathways, all tables otherwise.  Lower-bounds how early any
    /// exchanged spike can arrive and therefore how long an overlapped
    /// exchange may stay in flight.  `u16::MAX` when the rank hosts no
    /// such connection (the deadline then clamps to the next boundary).
    min_remote_delay_steps: u64,
    /// First global rank of this rank's area group (contiguous ranks).
    group_start: usize,
    /// Ranks in this rank's area group; 1 = singleton group (one area
    /// per rank, local tier degenerates to the intra-rank swap).
    group_size: usize,
    threads: Vec<ThreadState>,
    /// Source → (owning thread, connection group) routing index per
    /// pathway; carries the rank's one dense source index per pathway.
    shards: Pathways<SourceShards>,
    /// gid -> (thread, local index) for neurons hosted here.
    local_index: HashMap<Gid, (u16, u32)>,
    global_send: Vec<Vec<SpikeMsg>>,
    local_send: Vec<SpikeMsg>,
    /// Per-group-member send buffers of the local tier (used instead of
    /// `local_send` when the group spans more than one rank).
    local_send_group: Vec<Vec<SpikeMsg>>,
    /// The received-but-undelivered runs per pathway — the one delivery
    /// input all exec modes and both comm modes share.
    recv: Pathways<RunSet>,
    /// Recycled intermediate of the singleton local tier's buffer swap
    /// (the swap target, absorbed into `recv.short` as one run).
    local_swap: Vec<SpikeMsg>,
    /// Recycled per-source transport buffers of the global exchange.
    recv_global: Vec<Vec<SpikeMsg>>,
    /// Recycled per-source transport buffers of the local-tier group
    /// alltoall.
    recv_local_group: Vec<Vec<SpikeMsg>>,
    /// Recycled per-exchange receive-buffer sets of the overlapped path
    /// (one set checked out per posted exchange, returned at its
    /// completion — no steady-state allocation at any pipeline depth).
    recv_pool: Vec<Vec<Vec<SpikeMsg>>>,
    /// Per-thread routed-spike buckets of the sequential receive path
    /// (the barrier runtime uses the shared grid instead).
    seq_buckets: Pathways<Vec<Vec<RoutedSpike>>>,
    /// Scratch head indices for the k-way merges (sequential path).
    merge_heads: Vec<usize>,
    record_spikes: bool,
    spikes: Vec<(u64, Gid)>,
}

impl RankState {
    /// Build tables and state for `rank`.  Collective: performs the
    /// target-table construction exchange, so *all* ranks must call this
    /// concurrently (as NEST's preparation phase does, §4.1.2).
    #[allow(clippy::too_many_arguments)]
    pub fn build<T: Transport>(
        spec: &ModelSpec,
        placement: &Placement,
        strategy: Strategy,
        comm_mode: CommMode,
        comm_depth: usize,
        seed: u64,
        comm: &T,
        record_spikes: bool,
    ) -> Result<RankState> {
        let rank = comm.rank();
        let m = comm.m_ranks();
        let t_m = placement.threads_per_rank();
        let dual = strategy.dual_pathways();
        let steps_per_cycle = spec.d_min_steps() as u64;
        let epoch_cycles =
            if dual { spec.delay_ratio() as u64 } else { 1 }.max(1);

        // --- thread partitions and local index
        let mut threads = Vec::with_capacity(t_m);
        let mut local_index: HashMap<Gid, (u16, u32)> = HashMap::new();
        for th in 0..t_m {
            let gids = placement.local_gids(spec, rank, th);
            for (i, &g) in gids.iter().enumerate() {
                local_index.insert(g, (th as u16, i as u32));
            }
            threads.push(gids);
        }

        // --- connection tables + target-table notifications
        // notification (dest rank) -> set of (source, long_range)
        let mut notify: Vec<std::collections::HashSet<(Gid, bool)>> =
            vec![Default::default(); m];
        let mut built_threads = Vec::with_capacity(t_m);
        let mut min_remote_delay: u16 = u16::MAX;
        for gids in threads {
            let mut entries_short: Vec<(Gid, LocalConn)> = Vec::new();
            let mut entries_long: Vec<(Gid, LocalConn)> = Vec::new();
            let mut max_delay: u16 = 1;
            for (idx, &target) in gids.iter().enumerate() {
                for c in incoming_connections(spec, seed, target) {
                    let long_range = dual && !c.intra;
                    let lc = LocalConn {
                        target_local: idx as u32,
                        weight: c.weight,
                        delay_steps: c.delay_steps,
                    };
                    max_delay = max_delay.max(c.delay_steps);
                    // connections fed by the *global* exchange bound the
                    // overlap deadline: the long pathway under dual
                    // strategies, every connection otherwise
                    if !dual || long_range {
                        min_remote_delay = min_remote_delay.min(c.delay_steps);
                    }
                    if long_range {
                        entries_long.push((c.source, lc));
                    } else {
                        entries_short.push((c.source, lc));
                    }
                    let src_rank = placement.rank_of(spec, c.source);
                    notify[src_rank].insert((c.source, long_range));
                }
            }
            let conn = Pathways {
                short: ConnTable::build(entries_short),
                long: ConnTable::build(entries_long),
            };
            // write-ahead horizon: the largest `arrive - first_step` any
            // delivery can produce — max delay plus the epoch of lumped
            // delivery (+1 slack for the boundary cycle's own steps).
            // This also covers the in-flight window of overlapped
            // exchanges at *any* pipeline depth: delaying completion
            // only *advances* the read cursor past already-consumed
            // rows, so the write-ahead distance at delivery time shrinks
            // (never grows) relative to delivering at the boundary.
            // `with_horizon` asserts the sizing instead of trusting it;
            // the deadline debug_assert in `deliver_conns` catches the
            // other direction (a row consumed before its spike lands).
            let horizon = max_delay as usize
                + (epoch_cycles * steps_per_cycle) as usize
                + 1;
            let ring = RingBuffer::with_horizon(gids.len(), horizon + 1, horizon);
            let mut block = NeuronBlock::build(&gids, spec.h_ms, |g| {
                spec.areas[spec.area_of(g)].neuron
            });
            // desynchronize the onset (NEST models randomize V_m); keyed
            // by GID so all placements/strategies see the same state
            block.init_membrane_jitter(&gids, 0.95);
            let syn_len = gids.len();
            built_threads.push(ThreadState {
                gids,
                block,
                ring,
                conn,
                targets: Pathways {
                    short: TargetTable::new(syn_len),
                    long: TargetTable::new(syn_len),
                },
                has_targets: Pathways::default(),
                syn_buf: vec![0.0; syn_len],
                spike_idx: Vec::new(),
                register: Pathways::default(),
            });
        }
        let mut threads = built_threads;

        // --- collective target-table construction: tell each source's
        // host rank that we have targets of it (pathway encoded in
        // cycle).  The batch goes through the one canonical sort helper
        // (`sort_run`): (source, pathway) keys are unique per dest —
        // they come out of a set — which `sort_run` debug_asserts, so
        // the unstable sort cannot reorder equals differently than the
        // stable sort it replaced.
        let mut send: Vec<Vec<SpikeMsg>> = notify
            .into_iter()
            .map(|set| {
                let mut v: Vec<SpikeMsg> = set
                    .into_iter()
                    .map(|(source, long)| SpikeMsg {
                        source,
                        cycle: long as u32,
                    })
                    .collect();
                sort_run(&mut v);
                v
            })
            .collect();
        let (recv, _) = comm
            .alltoall(&mut send)
            .context("target-table construction exchange")?;
        for (src_rank, buf) in recv.iter().enumerate() {
            for msg in buf {
                let (th, idx) = local_index[&msg.source];
                threads[th as usize]
                    .targets
                    .get_mut(msg.cycle == 1)
                    .add(idx as usize, src_rank as u16);
            }
        }

        // target tables are final: freeze the per-neuron has-targets
        // bitmasks the update hot loop consults
        for th in threads.iter_mut() {
            th.has_targets = Pathways {
                short: th.targets.short.has_targets_mask(),
                long: th.targets.long.has_targets_mask(),
            };
        }

        // rank-level source → (thread, group) routing index for the
        // parallel receive path (one per pathway, merged from the
        // per-thread CSRs; holds the rank's one dense index per pathway)
        let shards = Pathways {
            short: SourceShards::build(threads.iter().map(|t| &t.conn.short)),
            long: SourceShards::build(threads.iter().map(|t| &t.conn.long)),
        };

        let group = placement.group_ranks(rank);
        let (group_start, group_size) = (group.start, group.len());

        let n_threads = threads.len();
        Ok(RankState {
            rank,
            strategy,
            comm_mode,
            comm_depth: match comm_mode {
                CommMode::Blocking => 1,
                CommMode::Overlap => (comm_depth as u64).max(1),
            },
            epoch_cycles,
            steps_per_cycle,
            min_remote_delay_steps: min_remote_delay as u64,
            group_start,
            group_size,
            threads,
            shards,
            local_index,
            global_send: (0..m).map(|_| Vec::new()).collect(),
            local_send: Vec::new(),
            local_send_group: (0..group_size).map(|_| Vec::new()).collect(),
            recv: Pathways::default(),
            local_swap: Vec::new(),
            recv_global: Vec::new(),
            recv_local_group: Vec::new(),
            recv_pool: Vec::new(),
            seq_buckets: Pathways {
                short: (0..n_threads).map(|_| Vec::new()).collect(),
                long: (0..n_threads).map(|_| Vec::new()).collect(),
            },
            merge_heads: Vec::new(),
            record_spikes,
            spikes: Vec::new(),
        })
    }

    pub fn n_local_neurons(&self) -> usize {
        self.local_index.len()
    }

    /// The sequential receive path: for each pathway slot, sort + merge
    /// the pending runs, scatter into per-thread routed buckets, then
    /// deliver each thread's bucket in canonical order — the same
    /// bucket/merge code the barrier workers run cooperatively, on one
    /// OS thread (the reference schedule for bit-identity).
    fn deliver_runs_sequential(&mut self, dual: bool, first_step: u64) {
        let shards = &self.shards;
        let heads = &mut self.merge_heads;
        let threads = &mut self.threads;
        let recv = &mut self.recv;
        let buckets = &mut self.seq_buckets;
        for long_slot in [false, true] {
            let set = recv.get_mut(long_slot);
            if set.is_empty() {
                continue;
            }
            let bs = buckets.get_mut(long_slot);
            let sh = if long_slot { shards.get(dual) } else { &shards.short };
            bucket_runs(sh, set.runs_mut(), heads, |t, sp| {
                bs[t as usize].push(sp)
            });
            set.reclaim();
            let long_range = long_slot && dual;
            for (t, th) in threads.iter_mut().enumerate() {
                for &sp in &bs[t] {
                    th.deliver_routed(long_range, sp, first_step);
                }
                bs[t].clear();
            }
        }
    }

    /// Cycle before whose deliver phase an exchange posted at the end of
    /// cycle `post_cycle` must complete.  The exchange carries spikes
    /// emitted no earlier than the first cycle of the posting epoch, so
    /// none can arrive before `first_emission + min_remote_delay`;
    /// completion is clamped to the `comm_depth`-th following boundary
    /// so at most `comm_depth` exchanges are ever in flight (matching
    /// the transport's mailbox ring).  Because the clamp window shifts
    /// by exactly one epoch per posted exchange, deadlines of
    /// consecutive exchanges are strictly increasing — the pipeline
    /// completes in FIFO order, one exchange per epoch in steady state.
    fn overlap_deadline(&self, post_cycle: u64) -> u64 {
        let d = self.epoch_cycles;
        let steps = self.steps_per_cycle;
        let first_emission_step = (post_cycle + 1 - d) * steps;
        let earliest_arrival = first_emission_step
            .saturating_add(self.min_remote_delay_steps);
        (earliest_arrival / steps)
            .clamp(post_cycle + 1, post_cycle + self.comm_depth * d)
    }

    /// Largest split-phase pipeline depth this rank can sustain without
    /// the causality deadline forcing a completion in the very cycle
    /// that needs the spikes: how many epoch boundaries fit between an
    /// exchange's post and the arrival cycle of its earliest possible
    /// spike.  Depends on the *realized* minimum remote delay, so it is
    /// rank-local; the engine reduces it over all ranks (collectively)
    /// before accepting a `comm_depth > 1` run.
    pub fn max_sustainable_depth(&self) -> u64 {
        let slack_cycles =
            self.min_remote_delay_steps / self.steps_per_cycle;
        let window = (slack_cycles + 1).saturating_sub(self.epoch_cycles);
        // ceil(window / epoch), floored at depth 1 (plain overlap)
        ((window + self.epoch_cycles - 1) / self.epoch_cycles).max(1)
    }

    /// Service the in-flight exchange pipeline at the start of cycle
    /// `s`: first the incremental fast path — drain every source whose
    /// deposit already landed, across *all* in-flight exchanges, without
    /// blocking — then complete (FIFO) every exchange whose delivery
    /// deadline has arrived (or all of them with `force`, for the final
    /// exchanges whose spikes fall beyond the simulated horizon),
    /// absorbing their per-source buffers as runs into `recv.long`
    /// exactly as the blocking path does.  Completion-side wait is
    /// charged to `Synchronize`, drains to `DataExchange`.  A watchdog
    /// timeout (or poisoned transport) surfaces as a [`CommError`]; the
    /// caller must then tear the remaining pipeline down through
    /// [`RankState::abandon_inflight`] before unwinding.
    fn service_exchanges<P: Pending>(
        &mut self,
        inflight: &mut VecDeque<InFlight<P>>,
        s: u64,
        force: bool,
        phase_times: &mut PhaseTimes,
    ) -> Result<(), CommError> {
        if inflight.is_empty() {
            return Ok(());
        }
        // incremental per-source completion: a condvar-free try-drain
        // over every pending (exchange, source) pair, so the deadline
        // rendezvous below only ever waits for genuinely late peers
        let t0 = Instant::now();
        for f in inflight.iter_mut() {
            let InFlight { pending, recv, .. } = f;
            for (src, out) in recv.iter_mut().enumerate() {
                pending.try_complete_source(src, out)?;
            }
        }
        phase_times.add(Phase::DataExchange, t0.elapsed().as_secs_f64());

        while inflight
            .front()
            .is_some_and(|f| force || f.deadline_cycle <= s)
        {
            let InFlight { pending, mut recv, .. } =
                inflight.pop_front().unwrap();
            let timing = pending.complete(&mut recv)?;
            phase_times.add(Phase::Synchronize, timing.wait_secs);
            phase_times.add(Phase::DataExchange, timing.drain_secs);
            // absorb as runs (two pipelined exchanges may reach their
            // deadlines before the same deliver phase transiently at
            // startup — the RunSet simply accumulates both); recv.long
            // is the one delivery input both comm modes share
            for buf in &mut recv {
                self.recv.long.push_run(buf);
            }
            self.recv_pool.push(recv);
        }
        Ok(())
    }

    /// Error-path teardown of the split-phase pipeline: consume every
    /// still-pending exchange without completing it (see
    /// [`Pending::abandon`]) and reclaim the receive-buffer sets, so a
    /// typed [`CommError`] can propagate as a clean `Err` instead of
    /// tripping the leak check in the pending handle's `Drop`.
    fn abandon_inflight<P: Pending>(
        &mut self,
        inflight: &mut VecDeque<InFlight<P>>,
    ) {
        for f in inflight.drain(..) {
            f.pending.abandon();
            self.recv_pool.push(f.recv);
        }
    }

    /// Merge one worker's per-group-member collocation output into this
    /// rank's local-tier send buffers — the one place the
    /// singleton/grouped branch lives for both pooled runtimes, so the
    /// exec modes cannot diverge on the local-tier merge.
    fn merge_local_out(&mut self, local_out: &mut [Vec<SpikeMsg>]) {
        if self.group_size > 1 {
            for (mbr, part) in local_out.iter_mut().enumerate() {
                self.local_send_group[mbr].append(part);
            }
        } else {
            self.local_send.append(&mut local_out[0]);
        }
    }

    /// Collocate every virtual thread's registers into this rank's send
    /// buffers, in virtual-thread order (the sequential reference the
    /// pooled paths reproduce).
    fn collocate_all(&mut self, dual: bool) {
        let group_start = self.group_start as u16;
        if self.group_size > 1 {
            for th in &mut self.threads {
                th.collocate_into(
                    dual,
                    group_start,
                    &mut self.local_send_group,
                    &mut self.global_send,
                );
            }
        } else {
            for th in &mut self.threads {
                th.collocate_into(
                    dual,
                    group_start,
                    std::slice::from_mut(&mut self.local_send),
                    &mut self.global_send,
                );
            }
        }
    }

    /// The communicate step of one cycle, the two-tier hybrid schedule:
    /// the local tier every cycle under dual strategies — an intra-group
    /// alltoall on the area's sub-communicator when the group spans
    /// several ranks, the intra-rank buffer swap for a singleton group —
    /// and the global exchange every `epoch_cycles`-th cycle on the
    /// global communicator — blocking, or posted split-phase into the
    /// in-flight pipeline and completed later by `service_exchanges`.
    /// Every received per-sender buffer becomes one [`RunSet`] run via
    /// the swap of `push_run`, so transport capacity keeps circulating
    /// (no flattening copy, no per-cycle allocation).
    fn communicate<T: SplitTransport>(
        &mut self,
        comm: &T,
        local: Option<&T::Sub>,
        s: u64,
        dual: bool,
        faults: &RankFaults,
        phase_times: &mut PhaseTimes,
        inflight: &mut VecDeque<InFlight<T::Pending>>,
    ) -> Result<(), CommError> {
        if dual {
            let local = local.expect(
                "dual-pathway strategies need a local communicator \
                 (Transport::split per area group)",
            );
            if self.group_size > 1 {
                // real intra-group alltoall every min-delay interval:
                // the area spans several ranks, so short-range spikes
                // cross rank boundaries inside the group
                let timing = local.alltoall_into(
                    &mut self.local_send_group,
                    &mut self.recv_local_group,
                )?;
                phase_times.add(Phase::Synchronize, timing.sync_secs);
                phase_times.add(Phase::DataExchange, timing.data_secs);
                // absorb each group member's buffer as one run — the
                // per-member canonical runs the parallel merge consumes
                for buf in &mut self.recv_local_group {
                    self.recv.short.push_run(buf);
                }
            } else {
                // singleton group: the local tier degenerates to the
                // intra-rank buffer swap (no synchronization)
                local.local_swap_into(
                    &mut self.local_send,
                    &mut self.local_swap,
                );
                self.recv.short.push_run(&mut self.local_swap);
            }
        }
        if (s + 1) % self.epoch_cycles == 0 {
            // fault injection: hold this rank's deposits back from the
            // epoch-boundary exchange (timing-only — spike trains are
            // unchanged; peers beyond the watchdog budget time out)
            let delay_ms = faults.deposit_delay_ms(s / self.epoch_cycles);
            if delay_ms > 0.0 {
                std::thread::sleep(Duration::from_secs_f64(delay_ms / 1e3));
            }
            match self.comm_mode {
                CommMode::Blocking => {
                    let timing = comm.alltoall_into(
                        &mut self.global_send,
                        &mut self.recv_global,
                    )?;
                    phase_times.add(Phase::Synchronize, timing.sync_secs);
                    phase_times.add(Phase::DataExchange, timing.data_secs);
                    for buf in &mut self.recv_global {
                        self.recv.long.push_run(buf);
                    }
                }
                CommMode::Overlap => {
                    debug_assert!(
                        (inflight.len() as u64) < self.comm_depth,
                        "pipeline full at post: {} in flight, depth {}",
                        inflight.len(),
                        self.comm_depth
                    );
                    let pending = comm.alltoall_start(&mut self.global_send)?;
                    phase_times.add(Phase::DataExchange, pending.post_secs());
                    let mut recv =
                        self.recv_pool.pop().unwrap_or_default();
                    recv.resize_with(self.global_send.len(), Vec::new);
                    inflight.push_back(InFlight {
                        pending,
                        deadline_cycle: self.overlap_deadline(s),
                        recv,
                    });
                }
            }
        }
        Ok(())
    }

    /// Run the state-propagation loop from `opts.start_cycle` to
    /// `opts.s_cycles`.  `local` is the rank's area-group
    /// sub-communicator (required by dual-pathway strategies, where it
    /// carries the local tier of the hybrid schedule; `None` is fine
    /// otherwise).
    ///
    /// The run is cut into **segments** at every checkpoint boundary
    /// and at this rank's injected kill cycle; inside a segment the
    /// per-exec-mode loops run exactly as before.  Segment ends always
    /// fall on epoch boundaries (checkpoint periods are whole epochs
    /// and kills are specified per epoch), so the split-phase pipeline
    /// can be force-drained at each cut without changing spike trains:
    /// ring rows are keyed by absolute step and f64 accumulation of the
    /// binary-fraction weights is exact, so *when* an exchange's spikes
    /// land does not change what any later cycle reads — the same
    /// argument the blocking/overlap equivalence rests on.
    pub fn run<T: SplitTransport>(
        mut self,
        comm: &T,
        local: Option<&T::Sub>,
        updater: &Updater,
        opts: RunOpts<'_>,
    ) -> Result<RankResult> {
        let mut phase_times = PhaseTimes::new();
        let mut cycle_times =
            Vec::with_capacity(if opts.record_cycle_times {
                (opts.s_cycles - opts.start_cycle) as usize
            } else {
                0
            });
        let mut obs = RankObs {
            tracer: opts.tracer.clone(),
            intervals: TierIntervals::default(),
            hooks: opts.hooks.clone(),
            s_cycles: opts.s_cycles,
        };
        let period = opts
            .ckpt
            .as_ref()
            .map(|c| c.every_epochs.max(1) * self.epoch_cycles);
        let kill_cycle = opts
            .faults
            .kill_epoch
            .map(|e| e.saturating_mul(self.epoch_cycles));

        let mut start = opts.start_cycle;
        loop {
            let mut end = opts.s_cycles;
            if let Some(p) = period {
                end = end.min((start / p + 1) * p);
            }
            if let Some(k) = kill_cycle {
                if k >= start {
                    end = end.min(k);
                }
            }
            match opts.exec {
                // a single virtual thread gains nothing from workers;
                // run in place so `threads_per_rank = 1` is zero-cost
                ExecMode::Pooled if self.threads.len() > 1 => self
                    .seg_barrier(
                        comm,
                        local,
                        start,
                        end,
                        updater,
                        opts.record_cycle_times,
                        &opts.faults,
                        &mut phase_times,
                        &mut cycle_times,
                        &mut obs,
                    )?,
                ExecMode::PooledChannels if self.threads.len() > 1 => self
                    .seg_channels(
                        comm,
                        local,
                        start,
                        end,
                        updater,
                        opts.record_cycle_times,
                        &opts.faults,
                        &mut phase_times,
                        &mut cycle_times,
                        &mut obs,
                    )?,
                _ => self.seg_sequential(
                    comm,
                    local,
                    start,
                    end,
                    updater,
                    opts.record_cycle_times,
                    &opts.faults,
                    &mut phase_times,
                    &mut cycle_times,
                    &mut obs,
                )?,
            }
            if let (Some(p), Some(sched)) = (period, opts.ckpt.as_ref()) {
                // every rank passes every period boundary — the killed
                // rank included: it snapshots first, dies after — so
                // the checkpoint collectives always match up.  The
                // `end > start_cycle` guard keeps a rank killed *at*
                // the restore point from checkpointing stale state.
                if end % p == 0 && end > opts.start_cycle {
                    self.write_checkpoint(comm, sched.ctx, end, &obs.tracer)?;
                }
            }
            if kill_cycle == Some(end) && end < opts.s_cycles {
                bail!(
                    "fault injection: rank {} killed at epoch {} (cycle \
                     {end}); surviving ranks will trip the comm watchdog",
                    self.rank,
                    end / self.epoch_cycles,
                );
            }
            if end >= opts.s_cycles {
                break;
            }
            start = end;
        }

        let (mut n_short, mut n_long, mut n_neurons) = (0usize, 0usize, 0usize);
        let mut ring_pending = Vec::with_capacity(self.threads.len());
        for th in &self.threads {
            n_short += th.conn.short.n_connections();
            n_long += th.conn.long.n_connections();
            n_neurons += th.gids.len();
            ring_pending.push(th.ring.pending_total());
        }
        Ok(RankResult {
            rank: self.rank,
            phase_times,
            cycle_times,
            spikes: self.spikes,
            n_conns_short: n_short,
            n_conns_long: n_long,
            n_neurons,
            ring_pending,
            intervals: obs.intervals.summary(),
        })
    }

    /// Collective checkpoint at cycle `cycle` (a segment boundary, so
    /// the split-phase pipeline is drained to depth 0 and the spike
    /// registers are empty).  Every rank deposits its serialized part
    /// into the shared [`CkptCtx`]; rank 0 assembles and atomically
    /// writes the snapshot between two barrier collectives (allreduce
    /// over a dummy value — the transport's one always-available
    /// barrier), then all ranks check the published outcome so a write
    /// failure surfaces on every rank, not just rank 0.
    fn write_checkpoint<T: Transport>(
        &mut self,
        comm: &T,
        ck: &CkptCtx,
        cycle: u64,
        tracer: &Tracer,
    ) -> Result<()> {
        let span_start = tracer.start();
        let part = self.serialize_part();
        ck.deposit(self.rank, part);
        comm.allreduce_min_u64(0)
            .context("checkpoint deposit barrier")?;
        if self.rank == 0 {
            ck.assemble_and_write(cycle, comm.quota() as u64);
        }
        comm.allreduce_min_u64(0)
            .context("checkpoint publish barrier")?;
        tracer.span("checkpoint", span_start, SpanCtx::cycle(cycle));
        ck.check()
    }

    /// Serialize this rank's dynamic state as one snapshot part: per
    /// virtual thread the neuron state and the ring-buffer accumulators,
    /// then the received-but-undelivered runs per pathway and the
    /// recorded spikes.  Everything else (tables, target masks, GIDs)
    /// is rebuilt deterministically from the model spec at restore.
    fn serialize_part(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.u32(self.threads.len() as u32);
        for th in &self.threads {
            match &th.block {
                NeuronBlock::Lif { v, refr, .. } => {
                    w.u8(0);
                    w.u64(v.len() as u64);
                    for &x in v {
                        w.f32(x);
                    }
                    for &x in refr {
                        w.f32(x);
                    }
                }
                NeuronBlock::IgnoreAndFire { phase, .. } => {
                    w.u8(1);
                    w.u64(phase.len() as u64);
                    for &x in phase {
                        w.f32(x);
                    }
                }
            }
            w.u64(th.ring.n_neurons() as u64);
            w.u64(th.ring.n_slots() as u64);
            for &x in th.ring.slots() {
                w.f64(x);
            }
            debug_assert!(
                th.register.short.is_empty() && th.register.long.is_empty(),
                "spike registers must be drained at a checkpoint boundary"
            );
        }
        for set in [&self.recv.short, &self.recv.long] {
            let runs = set.runs();
            w.u32(runs.len() as u32);
            for run in runs {
                w.u64(run.len() as u64);
                for msg in run {
                    w.u32(msg.source);
                    w.u32(msg.cycle);
                }
            }
        }
        debug_assert!(
            self.global_send.iter().all(|b| b.is_empty()),
            "global send buffers must be empty at a checkpoint boundary"
        );
        w.u64(self.spikes.len() as u64);
        for &(step, gid) in &self.spikes {
            w.u64(step);
            w.u32(gid);
        }
        w.into_bytes()
    }

    /// Restore this rank's dynamic state from a snapshot part written
    /// by [`RankState::serialize_part`] on a matching run (the engine
    /// checks the snapshot fingerprint first; the shape checks here
    /// catch corruption that survived the checksum-verified framing).
    pub fn restore_part(&mut self, part: &[u8]) -> Result<()> {
        let mut r = ByteReader::new(part);
        let n_threads = r.u32()? as usize;
        ensure!(
            n_threads == self.threads.len(),
            "snapshot rank part has {n_threads} virtual threads but \
             this run builds {}",
            self.threads.len(),
        );
        for th in &mut self.threads {
            let tag = r.u8()?;
            let n = r.u64()? as usize;
            ensure!(
                n == th.gids.len(),
                "snapshot thread holds {n} neurons but this run's \
                 thread holds {}",
                th.gids.len(),
            );
            match (tag, &mut th.block) {
                (0, NeuronBlock::Lif { v, refr, .. }) => {
                    for x in v.iter_mut() {
                        *x = r.f32()?;
                    }
                    for x in refr.iter_mut() {
                        *x = r.f32()?;
                    }
                }
                (1, NeuronBlock::IgnoreAndFire { phase, .. }) => {
                    for x in phase.iter_mut() {
                        *x = r.f32()?;
                    }
                }
                (tag, _) => bail!(
                    "snapshot neuron-block tag {tag} does not match \
                     this run's neuron model"
                ),
            }
            let ring_neurons = r.u64()? as usize;
            let ring_slots = r.u64()? as usize;
            ensure!(
                ring_neurons == th.ring.n_neurons()
                    && ring_slots == th.ring.n_slots(),
                "snapshot ring buffer is {ring_neurons} neurons × \
                 {ring_slots} slots but this run builds {} × {}",
                th.ring.n_neurons(),
                th.ring.n_slots(),
            );
            let mut slots = vec![0.0f64; ring_neurons * ring_slots];
            for x in slots.iter_mut() {
                *x = r.f64()?;
            }
            th.ring.load_slots(&slots).map_err(anyhow::Error::msg)?;
        }
        for long_slot in [false, true] {
            let n_runs = r.u32()?;
            for _ in 0..n_runs {
                let len = r.u64()? as usize;
                let mut run = Vec::with_capacity(len);
                for _ in 0..len {
                    let source = r.u32()?;
                    let cycle = r.u32()?;
                    run.push(SpikeMsg { source, cycle });
                }
                self.recv.get_mut(long_slot).push_run(&mut run);
            }
        }
        let n_spikes = r.u64()? as usize;
        self.spikes.reserve(n_spikes);
        for _ in 0..n_spikes {
            let step = r.u64()?;
            let gid = r.u32()?;
            self.spikes.push((step, gid));
        }
        ensure!(
            r.is_done(),
            "rank part has trailing bytes after the recorded spikes"
        );
        Ok(())
    }

    /// Virtual threads iterated in place on the rank's OS thread — the
    /// reference schedule the pooled paths must reproduce bit-exactly —
    /// over the segment of cycles `[start, end)`.
    #[allow(clippy::too_many_arguments)]
    fn seg_sequential<T: SplitTransport>(
        &mut self,
        comm: &T,
        local: Option<&T::Sub>,
        start: u64,
        end: u64,
        updater: &Updater,
        record_cycle_times: bool,
        faults: &RankFaults,
        phase_times: &mut PhaseTimes,
        cycle_times: &mut Vec<f64>,
        obs: &mut RankObs,
    ) -> Result<()> {
        let dual = self.strategy.dual_pathways();
        let mut inflight: VecDeque<InFlight<T::Pending>> = VecDeque::new();
        // on a comm error the remaining pipeline must be abandoned (not
        // dropped) before unwinding, so errors break out to one exit
        // instead of returning early
        let mut outcome: Result<()> = Ok(());

        for s in start..end {
            let first_step = s * self.steps_per_cycle;
            // cooperative cancellation: agree collectively at epoch
            // boundaries, then unwind through the comm-error exit
            if let Err(e) =
                check_cancel(&obs.hooks, comm, s, self.epoch_cycles)
            {
                outcome = Err(e);
                break;
            }
            // drain early deposits and complete due overlapped exchanges
            // before the deliver phase (charged to their own phases, not
            // this cycle's timer)
            if let Err(e) =
                self.service_exchanges(&mut inflight, s, false, phase_times)
            {
                outcome = Err(e.into());
                break;
            }
            let mut sw = Stopwatch::start();
            let mut cycle_secs = 0.0;

            // ---- deliver -------------------------------------------------
            let p0 = obs.tracer.start();
            self.deliver_runs_sequential(dual, first_step);
            cycle_secs += sw.charge(phase_times, Phase::Deliver);
            obs.tracer.span("deliver", p0, SpanCtx::cycle(s));

            // ---- update --------------------------------------------------
            let p0 = obs.tracer.start();
            for th in &mut self.threads {
                th.update_cycle(
                    updater,
                    first_step,
                    self.steps_per_cycle,
                    dual,
                    self.record_spikes,
                    &mut self.spikes,
                );
            }
            let upd = sw.charge(phase_times, Phase::Update);
            obs.tracer.span("update", p0, SpanCtx::cycle(s));
            cycle_secs += upd;
            let p0 = obs.tracer.start();
            let extra = straggle(
                faults,
                s / self.epoch_cycles,
                upd,
                phase_times,
                &mut sw,
            );
            if extra > 0.0 {
                obs.tracer.span("straggle", p0, SpanCtx::cycle(s));
            }
            cycle_secs += extra;

            // ---- collocate -----------------------------------------------
            let p0 = obs.tracer.start();
            self.collocate_all(dual);
            cycle_secs += sw.charge(phase_times, Phase::Collocate);
            obs.tracer.span("collocate", p0, SpanCtx::cycle(s));
            if record_cycle_times {
                cycle_times.push(cycle_secs);
            }
            obs.intervals
                .record_cycle(cycle_secs, (s + 1) % self.epoch_cycles == 0);
            obs.maybe_progress(self.rank, s, self.epoch_cycles);

            // ---- communicate ---------------------------------------------
            if let Err(e) = self.communicate(
                comm,
                local,
                s,
                dual,
                faults,
                phase_times,
                &mut inflight,
            ) {
                outcome = Err(e.into());
                break;
            }
        }
        // drain the pipeline to depth 0 at the segment end: the final
        // posted exchanges either carry spikes beyond the simulated
        // horizon (run end — the blocking path likewise never delivers
        // its last receive) or land in ring rows keyed by absolute
        // step, unchanged by completing early (checkpoint boundary)
        if outcome.is_ok() {
            outcome = self
                .service_exchanges(&mut inflight, end, true, phase_times)
                .map_err(Into::into);
        }
        if outcome.is_err() {
            self.abandon_inflight(&mut inflight);
        }
        outcome
    }

    /// The persistent barrier-synced worker runtime (the default pooled
    /// path; protocol in the module docs) over the segment of cycles
    /// `[start, end)`: workers spawned once per segment, phases
    /// separated by a reusable [`Barrier`], received runs distributed
    /// round-robin and bucketed/merged *cooperatively by the workers*
    /// through the T×T grid — the coordinator never sorts or scans a
    /// spike.  The per-thread merged delivery order equals the
    /// sequential schedule's, so results match bit-exactly.  Workers
    /// hand their [`ThreadState`] back at the segment end (stop gate in
    /// [`barrier_worker`]) so the rank can checkpoint between segments.
    #[allow(clippy::too_many_arguments)]
    fn seg_barrier<T: SplitTransport>(
        &mut self,
        comm: &T,
        local: Option<&T::Sub>,
        start: u64,
        end: u64,
        updater: &Updater,
        record_cycle_times: bool,
        faults: &RankFaults,
        phase_times: &mut PhaseTimes,
        cycle_times: &mut Vec<f64>,
        obs: &mut RankObs,
    ) -> Result<()> {
        let dual = self.strategy.dual_pathways();
        let m = comm.m_ranks();
        let worker_states = std::mem::take(&mut self.threads);
        let n_workers = worker_states.len();
        let steps = self.steps_per_cycle;
        let record_spikes = self.record_spikes;
        let group_start = self.group_start as u16;
        let group_size = self.group_size;

        let slots: Vec<WorkerSlot> = (0..n_workers)
            .map(|_| WorkerSlot {
                data: Mutex::new(SlotData {
                    local_out: (0..group_size).map(|_| Vec::new()).collect(),
                    global_out: (0..m).map(|_| Vec::new()).collect(),
                    ..SlotData::default()
                }),
            })
            .collect();
        // the T×T bucket grid of the cooperative receive: row = producer
        // (bucket phase), column = consumer (merge phase)
        let grid: Vec<Vec<Mutex<BucketCell>>> = (0..n_workers)
            .map(|_| {
                (0..n_workers)
                    .map(|_| Mutex::new(BucketCell::default()))
                    .collect()
            })
            .collect();
        // workers borrow the routing index for the whole scope; the
        // coordinator does not route, so it lends the field out
        let shards = std::mem::take(&mut self.shards);
        let barrier = Barrier::new(n_workers + 1);
        // the stop gate: raised before releasing the *runs ready*
        // barrier so workers exit cleanly at the segment end and on
        // the comm-error unwind path alike
        let stop = AtomicBool::new(false);

        let (threads_back, outcome) = std::thread::scope(|scope| {
            let handles: Vec<_> = worker_states
                .into_iter()
                .enumerate()
                .map(|(i, th)| {
                    let slot = &slots[i];
                    let barrier = &barrier;
                    let grid = &grid;
                    let shards = &shards;
                    let stop = &stop;
                    scope.spawn(move || {
                        barrier_worker(
                            i,
                            th,
                            updater,
                            slot,
                            grid,
                            shards,
                            barrier,
                            stop,
                            start,
                            end,
                            steps,
                            dual,
                            group_start,
                            record_spikes,
                        )
                    })
                })
                .collect();
            let mut inflight: VecDeque<InFlight<T::Pending>> =
                VecDeque::new();
            // errors break to the one exit so workers are always
            // released through the stop gate and the pipeline is
            // abandoned, never leaked (both error points below leave
            // the workers parked at the *runs ready* barrier)
            let mut outcome: Result<()> = Ok(());

            for s in start..end {
                // cooperative cancellation: agree collectively at epoch
                // boundaries, then unwind through the comm-error exit
                // (workers stay parked at the *runs ready* barrier, the
                // position the stop gate below releases them from)
                if let Err(e) =
                    check_cancel(&obs.hooks, comm, s, self.epoch_cycles)
                {
                    outcome = Err(e);
                    break;
                }
                // drain early deposits and complete due exchanges
                // before handing the runs out
                if let Err(e) = self.service_exchanges(
                    &mut inflight,
                    s,
                    false,
                    phase_times,
                ) {
                    outcome = Err(e.into());
                    break;
                }
                let mut sw = Stopwatch::start();
                let mut cycle_secs = 0.0;

                // ---- deliver: distribute runs, workers bucket+merge ------
                let p0 = obs.tracer.start();
                {
                    let mut queues: Vec<MutexGuard<'_, SlotData>> = slots
                        .iter()
                        .map(|sl| sl.data.lock().unwrap())
                        .collect();
                    for (i, run) in
                        self.recv.short.drain_runs().enumerate()
                    {
                        queues[i % n_workers].runs_short.push(run);
                    }
                    for (i, run) in
                        self.recv.long.drain_runs().enumerate()
                    {
                        queues[i % n_workers].runs_long.push(run);
                    }
                }
                barrier.wait(); // runs ready
                barrier.wait(); // buckets ready
                barrier.wait(); // deliver done
                cycle_secs += sw.charge(phase_times, Phase::Deliver);
                obs.tracer.span("deliver", p0, SpanCtx::cycle(s));

                // ---- update ----------------------------------------------
                let p0 = obs.tracer.start();
                barrier.wait(); // update done
                let upd = sw.charge(phase_times, Phase::Update);
                obs.tracer.span("update", p0, SpanCtx::cycle(s));
                cycle_secs += upd;
                let p0 = obs.tracer.start();
                let extra = straggle(
                    faults,
                    s / self.epoch_cycles,
                    upd,
                    phase_times,
                    &mut sw,
                );
                if extra > 0.0 {
                    obs.tracer.span("straggle", p0, SpanCtx::cycle(s));
                }
                cycle_secs += extra;

                // ---- collocate -------------------------------------------
                let p0 = obs.tracer.start();
                barrier.wait(); // collocate done
                // drain in virtual-thread order: this concatenation is
                // the ordering decision that matches the sequential
                // schedule.  Also reclaim the cleared run buffers the
                // workers consumed, so their capacity circulates back
                // through the RunSet pools.
                for sl in &slots {
                    let mut guard = sl.data.lock().unwrap();
                    let d = &mut *guard;
                    for run in d.runs_short.drain(..) {
                        self.recv.short.recycle(run);
                    }
                    for run in d.runs_long.drain(..) {
                        self.recv.long.recycle(run);
                    }
                    self.merge_local_out(&mut d.local_out);
                    for (dest, part) in d.global_out.iter_mut().enumerate()
                    {
                        self.global_send[dest].append(part);
                    }
                }
                cycle_secs += sw.charge(phase_times, Phase::Collocate);
                obs.tracer.span("collocate", p0, SpanCtx::cycle(s));
                if record_cycle_times {
                    cycle_times.push(cycle_secs);
                }
                obs.intervals.record_cycle(
                    cycle_secs,
                    (s + 1) % self.epoch_cycles == 0,
                );
                obs.maybe_progress(self.rank, s, self.epoch_cycles);

                // ---- communicate -----------------------------------------
                if let Err(e) = self.communicate(
                    comm,
                    local,
                    s,
                    dual,
                    faults,
                    phase_times,
                    &mut inflight,
                ) {
                    outcome = Err(e.into());
                    break;
                }
            }
            // drain the pipeline to depth 0 at the segment end (see
            // `seg_sequential` for why this preserves spike trains)
            if outcome.is_ok() {
                outcome = self
                    .service_exchanges(&mut inflight, end, true, phase_times)
                    .map_err(Into::into);
            }
            if outcome.is_err() {
                self.abandon_inflight(&mut inflight);
            }

            // release the workers through the stop gate — they are
            // parked at the *runs ready* barrier on every exit path,
            // normal and error alike — and take their state back in
            // virtual-thread order
            stop.store(true, Ordering::Release);
            barrier.wait();
            let mut threads_back = Vec::with_capacity(handles.len());
            for h in handles {
                let (th, worker_spikes) =
                    h.join().expect("barrier worker panicked");
                self.spikes.extend(worker_spikes);
                threads_back.push(th);
            }
            (threads_back, outcome)
        });

        self.threads = threads_back;
        self.shards = shards;
        outcome
    }

    /// Virtual threads on dedicated worker OS threads: one scoped worker
    /// per [`ThreadState`], phase-stepped by command/reply channels — the
    /// PR 1 runtime, kept selectable for A/B comparison against the
    /// barrier runtime.  Delivery here is the **old** receive side: the
    /// runs are flattened into one batch, canonically sorted on the
    /// coordinator, and broadcast to every worker, each of which walks
    /// the whole batch with per-spike table lookups — the baseline the
    /// parallel bucket/merge path is benchmarked against.
    #[allow(clippy::too_many_arguments)]
    fn seg_channels<T: SplitTransport>(
        &mut self,
        comm: &T,
        local: Option<&T::Sub>,
        start: u64,
        end: u64,
        updater: &Updater,
        record_cycle_times: bool,
        faults: &RankFaults,
        phase_times: &mut PhaseTimes,
        cycle_times: &mut Vec<f64>,
        obs: &mut RankObs,
    ) -> Result<()> {
        let dual = self.strategy.dual_pathways();
        let m = comm.m_ranks();
        let worker_states = std::mem::take(&mut self.threads);
        let n_workers = worker_states.len();
        let steps = self.steps_per_cycle;
        let record_spikes = self.record_spikes;
        let group_start = self.group_start as u16;
        let group_size = self.group_size;

        let (threads_back, outcome) = std::thread::scope(|scope| {
            let mut cmd_txs = Vec::with_capacity(n_workers);
            let mut reply_rxs = Vec::with_capacity(n_workers);
            for th in worker_states {
                let (cmd_tx, cmd_rx) = mpsc::channel::<Cmd>();
                let (reply_tx, reply_rx) = mpsc::channel::<Reply>();
                scope.spawn(move || {
                    worker_loop(th, updater, group_start, cmd_rx, reply_tx)
                });
                cmd_txs.push(cmd_tx);
                reply_rxs.push(reply_rx);
            }
            // per-worker collocation buffers, recycled every cycle
            #[allow(clippy::type_complexity)]
            let mut coll_bufs: Vec<(
                Vec<Vec<SpikeMsg>>,
                Vec<Vec<SpikeMsg>>,
            )> = (0..n_workers)
                .map(|_| {
                    (
                        (0..group_size).map(|_| Vec::new()).collect(),
                        (0..m).map(|_| Vec::new()).collect(),
                    )
                })
                .collect();
            // flattened delivery batches of the legacy path,
            // recycled across cycles
            let mut flat: Pathways<Vec<SpikeMsg>> = Pathways::default();
            let mut inflight: VecDeque<InFlight<T::Pending>> =
                VecDeque::new();
            // errors break to the one exit so the workers always get
            // their `Finish` command and the pipeline is abandoned,
            // never leaked (both error points below leave every worker
            // idle at its command receive)
            let mut outcome: Result<()> = Ok(());

            for s in start..end {
                let first_step = s * steps;
                // cooperative cancellation: agree collectively at epoch
                // boundaries, then unwind through the comm-error exit
                // (workers stay idle at their command receive)
                if let Err(e) =
                    check_cancel(&obs.hooks, comm, s, self.epoch_cycles)
                {
                    outcome = Err(e);
                    break;
                }
                // drain early deposits and complete due exchanges
                // before delivery
                if let Err(e) = self.service_exchanges(
                    &mut inflight,
                    s,
                    false,
                    phase_times,
                ) {
                    outcome = Err(e.into());
                    break;
                }
                let mut sw = Stopwatch::start();
                let mut cycle_secs = 0.0;

                // ---- deliver ---------------------------------------------
                let p0 = obs.tracer.start();
                self.recv.short.flatten_into(&mut flat.short);
                pooled_deliver(
                    &mut flat.short,
                    false,
                    first_step,
                    &cmd_txs,
                    &reply_rxs,
                );
                self.recv.long.flatten_into(&mut flat.long);
                pooled_deliver(
                    &mut flat.long,
                    dual,
                    first_step,
                    &cmd_txs,
                    &reply_rxs,
                );
                cycle_secs += sw.charge(phase_times, Phase::Deliver);
                obs.tracer.span("deliver", p0, SpanCtx::cycle(s));

                // ---- update ----------------------------------------------
                let p0 = obs.tracer.start();
                for tx in &cmd_txs {
                    tx.send(Cmd::Update {
                        first_step,
                        steps,
                        dual,
                        record_spikes,
                    })
                    .expect("pool worker died");
                }
                for rx in &reply_rxs {
                    expect_done(rx);
                }
                let upd = sw.charge(phase_times, Phase::Update);
                obs.tracer.span("update", p0, SpanCtx::cycle(s));
                cycle_secs += upd;
                let p0 = obs.tracer.start();
                let extra = straggle(
                    faults,
                    s / self.epoch_cycles,
                    upd,
                    phase_times,
                    &mut sw,
                );
                if extra > 0.0 {
                    obs.tracer.span("straggle", p0, SpanCtx::cycle(s));
                }
                cycle_secs += extra;

                // ---- collocate -------------------------------------------
                let p0 = obs.tracer.start();
                for (tx, bufs) in cmd_txs.iter().zip(coll_bufs.iter_mut()) {
                    let (local, global) = std::mem::take(bufs);
                    tx.send(Cmd::Collocate { dual, local, global })
                        .expect("pool worker died");
                }
                // receive in virtual-thread order: the blocking recv
                // per worker is the ordering barrier that makes the
                // concatenation deterministic
                for (rx, bufs) in
                    reply_rxs.iter().zip(coll_bufs.iter_mut())
                {
                    match rx.recv().expect("pool worker died") {
                        Reply::Collocated {
                            local: mut loc,
                            mut global,
                        } => {
                            self.merge_local_out(&mut loc);
                            for (dest, part) in
                                global.iter_mut().enumerate()
                            {
                                self.global_send[dest].append(part);
                            }
                            *bufs = (loc, global);
                        }
                        _ => unreachable!("unexpected collocate reply"),
                    }
                }
                cycle_secs += sw.charge(phase_times, Phase::Collocate);
                obs.tracer.span("collocate", p0, SpanCtx::cycle(s));
                if record_cycle_times {
                    cycle_times.push(cycle_secs);
                }
                obs.intervals.record_cycle(
                    cycle_secs,
                    (s + 1) % self.epoch_cycles == 0,
                );
                obs.maybe_progress(self.rank, s, self.epoch_cycles);

                // ---- communicate -----------------------------------------
                if let Err(e) = self.communicate(
                    comm,
                    local,
                    s,
                    dual,
                    faults,
                    phase_times,
                    &mut inflight,
                ) {
                    outcome = Err(e.into());
                    break;
                }
            }
            // drain the pipeline to depth 0 at the segment end (see
            // `seg_sequential` for why this preserves spike trains)
            if outcome.is_ok() {
                outcome = self
                    .service_exchanges(&mut inflight, end, true, phase_times)
                    .map_err(Into::into);
            }
            if outcome.is_err() {
                self.abandon_inflight(&mut inflight);
            }

            // shut the pool down on every exit path — the workers are
            // idle at their command receive — and take their state
            // back in virtual-thread order
            for tx in &cmd_txs {
                tx.send(Cmd::Finish).expect("pool worker died");
            }
            let mut threads_back = Vec::with_capacity(n_workers);
            for rx in &reply_rxs {
                match rx.recv().expect("pool worker died") {
                    Reply::Finished { spikes: worker_spikes, state } => {
                        self.spikes.extend(worker_spikes);
                        threads_back.push(*state);
                    }
                    _ => unreachable!("unexpected finish reply"),
                }
            }
            (threads_back, outcome)
        });

        self.threads = threads_back;
        outcome
    }
}
