//! Per-rank simulation state and the cycle loop (paper Fig 3).
//!
//! Each rank owns its thread partitions (virtual threads — executed
//! sequentially inside the rank's OS thread for determinism on any host),
//! the dual connection/source/target tables, spike registers, MPI buffers
//! and ring buffers.  `run()` iterates deliver → update → collocate →
//! communicate for `S` cycles, with the communicate step depending on the
//! strategy: global exchange every cycle (conventional/intermediate) or
//! local swap + global exchange every D-th cycle (structure-aware).

use crate::comm::{Communicator, SpikeMsg};
use crate::config::Strategy;
use crate::engine::neuron::NeuronBlock;
use crate::engine::ringbuffer::RingBuffer;
use crate::engine::update::Updater;
use crate::network::{incoming_connections, Gid, ModelSpec};
use crate::placement::Placement;
use crate::tables::{ConnTable, LocalConn, Pathways, TargetTable};
use crate::util::timers::{Phase, PhaseTimes, Stopwatch};
use std::collections::HashMap;

/// One virtual thread's worth of state.
pub struct ThreadState {
    /// Ascending thread-local GIDs; index in this vec = local index.
    pub gids: Vec<Gid>,
    pub block: NeuronBlock,
    pub ring: RingBuffer,
    pub conn: Pathways<ConnTable>,
    pub targets: Pathways<TargetTable>,
    /// Scratch: per-step synaptic input row.
    syn_buf: Vec<f32>,
    /// Scratch: spiking local indices of the current step.
    spike_idx: Vec<u32>,
    /// Spike registers (local index, emission step), split by pathway.
    register: Pathways<Vec<(u32, u64)>>,
}

/// Measurements returned by a rank after the run.
pub struct RankResult {
    pub rank: usize,
    pub phase_times: PhaseTimes,
    /// (deliver+update+collocate) wall seconds per cycle (paper eq 18).
    pub cycle_times: Vec<f64>,
    /// Recorded spikes (emission step, gid), in emission order.
    pub spikes: Vec<(u64, Gid)>,
    /// Synapses hosted by this rank, by pathway.
    pub n_conns_short: usize,
    pub n_conns_long: usize,
    /// Local neurons (real, not ghosts).
    pub n_neurons: usize,
}

/// Full per-rank state.
pub struct RankState {
    rank: usize,
    strategy: Strategy,
    /// Cycles between global exchanges (1 unless structure-aware).
    epoch_cycles: u64,
    steps_per_cycle: u64,
    threads: Vec<ThreadState>,
    /// gid -> (thread, local index) for neurons hosted here.
    local_index: HashMap<Gid, (u16, u32)>,
    global_send: Vec<Vec<SpikeMsg>>,
    local_send: Vec<SpikeMsg>,
    recv_short: Vec<SpikeMsg>,
    recv_long: Vec<SpikeMsg>,
    record_spikes: bool,
    spikes: Vec<(u64, Gid)>,
}

impl RankState {
    /// Build tables and state for `rank`.  Collective: performs the
    /// target-table construction exchange, so *all* ranks must call this
    /// concurrently (as NEST's preparation phase does, §4.1.2).
    pub fn build(
        spec: &ModelSpec,
        placement: &Placement,
        strategy: Strategy,
        seed: u64,
        comm: &Communicator,
        record_spikes: bool,
    ) -> RankState {
        let rank = comm.rank();
        let m = comm.m_ranks();
        let t_m = placement.threads_per_rank();
        let dual = strategy.dual_pathways();
        let steps_per_cycle = spec.d_min_steps() as u64;
        let epoch_cycles =
            if dual { spec.delay_ratio() as u64 } else { 1 }.max(1);

        // --- thread partitions and local index
        let mut threads = Vec::with_capacity(t_m);
        let mut local_index: HashMap<Gid, (u16, u32)> = HashMap::new();
        for th in 0..t_m {
            let gids = placement.local_gids(spec, rank, th);
            for (i, &g) in gids.iter().enumerate() {
                local_index.insert(g, (th as u16, i as u32));
            }
            threads.push(gids);
        }

        // --- connection tables + target-table notifications
        // notification (dest rank) -> set of (source, long_range)
        let mut notify: Vec<std::collections::HashSet<(Gid, bool)>> =
            vec![Default::default(); m];
        let mut built_threads = Vec::with_capacity(t_m);
        for gids in threads {
            let mut entries_short: Vec<(Gid, LocalConn)> = Vec::new();
            let mut entries_long: Vec<(Gid, LocalConn)> = Vec::new();
            let mut max_delay: u16 = 1;
            for (idx, &target) in gids.iter().enumerate() {
                for c in incoming_connections(spec, seed, target) {
                    let long_range = dual && !c.intra;
                    let lc = LocalConn {
                        target_local: idx as u32,
                        weight: c.weight,
                        delay_steps: c.delay_steps,
                    };
                    max_delay = max_delay.max(c.delay_steps);
                    if long_range {
                        entries_long.push((c.source, lc));
                    } else {
                        entries_short.push((c.source, lc));
                    }
                    let src_rank = placement.rank_of(spec, c.source);
                    notify[src_rank].insert((c.source, long_range));
                }
            }
            let conn = Pathways {
                short: ConnTable::build(entries_short),
                long: ConnTable::build(entries_long),
            };
            let n_slots = max_delay as usize
                + (epoch_cycles * steps_per_cycle) as usize
                + 2;
            let ring = RingBuffer::new(gids.len(), n_slots);
            let mut block = NeuronBlock::build(&gids, spec.h_ms, |g| {
                spec.areas[spec.area_of(g)].neuron
            });
            // desynchronize the onset (NEST models randomize V_m); keyed
            // by GID so all placements/strategies see the same state
            block.init_membrane_jitter(&gids, 0.95);
            let syn_len = gids.len();
            built_threads.push(ThreadState {
                gids,
                block,
                ring,
                conn,
                targets: Pathways {
                    short: TargetTable::new(syn_len),
                    long: TargetTable::new(syn_len),
                },
                syn_buf: vec![0.0; syn_len],
                spike_idx: Vec::new(),
                register: Pathways::default(),
            });
        }
        let mut threads = built_threads;

        // --- collective target-table construction: tell each source's
        // host rank that we have targets of it (pathway encoded in cycle)
        let mut send: Vec<Vec<SpikeMsg>> = notify
            .into_iter()
            .map(|set| {
                let mut v: Vec<SpikeMsg> = set
                    .into_iter()
                    .map(|(source, long)| SpikeMsg {
                        source,
                        cycle: long as u32,
                    })
                    .collect();
                v.sort_by_key(|msg| (msg.source, msg.cycle));
                v
            })
            .collect();
        let (recv, _) = comm.alltoall(&mut send);
        for (src_rank, buf) in recv.iter().enumerate() {
            for msg in buf {
                let (th, idx) = local_index[&msg.source];
                threads[th as usize]
                    .targets
                    .get_mut(msg.cycle == 1)
                    .add(idx as usize, src_rank as u16);
            }
        }

        RankState {
            rank,
            strategy,
            epoch_cycles,
            steps_per_cycle,
            threads,
            local_index,
            global_send: (0..m).map(|_| Vec::new()).collect(),
            local_send: Vec::new(),
            recv_short: Vec::new(),
            recv_long: Vec::new(),
            record_spikes,
            spikes: Vec::new(),
        }
    }

    pub fn n_local_neurons(&self) -> usize {
        self.local_index.len()
    }

    /// Deliver a batch of spikes through the given pathway's tables.
    /// Spikes are sorted by (source, step) first so ring-buffer
    /// accumulation order is canonical (DESIGN.md §6).
    fn deliver(&mut self, long_range: bool, mut batch: Vec<SpikeMsg>, first_step: u64) {
        batch.sort_unstable_by_key(|msg| (msg.source, msg.cycle));
        for th in &mut self.threads {
            let table = th.conn.get(long_range);
            for msg in &batch {
                for c in table.lookup(msg.source) {
                    let arrive = msg.cycle as u64 + c.delay_steps as u64;
                    debug_assert!(
                        arrive >= first_step,
                        "causality violation: spike from {} arrives at \
                         step {arrive} < current step {first_step}",
                        msg.source
                    );
                    th.ring.add(arrive, c.target_local, c.weight);
                }
            }
        }
    }

    /// Run the state-propagation loop for `s_cycles` cycles.
    pub fn run(
        mut self,
        comm: &Communicator,
        s_cycles: u64,
        updater: &Updater,
        record_cycle_times: bool,
    ) -> RankResult {
        let mut phase_times = PhaseTimes::new();
        let mut cycle_times =
            Vec::with_capacity(if record_cycle_times { s_cycles as usize } else { 0 });
        let dual = self.strategy.dual_pathways();

        for s in 0..s_cycles {
            let first_step = s * self.steps_per_cycle;
            let mut sw = Stopwatch::start();
            let mut cycle_secs = 0.0;

            // ---- deliver -------------------------------------------------
            let short_batch = std::mem::take(&mut self.recv_short);
            if !short_batch.is_empty() {
                self.deliver(false, short_batch, first_step);
            }
            let long_batch = std::mem::take(&mut self.recv_long);
            if !long_batch.is_empty() {
                self.deliver(dual, long_batch, first_step);
            }
            cycle_secs += sw.charge(&mut phase_times, Phase::Deliver);

            // ---- update --------------------------------------------------
            for th in &mut self.threads {
                for step in first_step..first_step + self.steps_per_cycle {
                    th.ring.take_row(step, &mut th.syn_buf);
                    th.spike_idx.clear();
                    updater.step(&mut th.block, &th.syn_buf, &mut th.spike_idx);
                    for &idx in &th.spike_idx {
                        if self.record_spikes {
                            self.spikes.push((step, th.gids[idx as usize]));
                        }
                        if dual {
                            if !th.targets.short.ranks(idx as usize).is_empty()
                            {
                                th.register.short.push((idx, step));
                            }
                            if !th.targets.long.ranks(idx as usize).is_empty()
                            {
                                th.register.long.push((idx, step));
                            }
                        } else if !th
                            .targets
                            .short
                            .ranks(idx as usize)
                            .is_empty()
                        {
                            th.register.short.push((idx, step));
                        }
                    }
                }
            }
            cycle_secs += sw.charge(&mut phase_times, Phase::Update);

            // ---- collocate -----------------------------------------------
            if dual {
                // short-range spikes into the local exchange buffer
                for th in &mut self.threads {
                    for &(idx, step) in &th.register.short {
                        self.local_send.push(SpikeMsg {
                            source: th.gids[idx as usize],
                            cycle: step as u32,
                        });
                    }
                    th.register.short.clear();
                    // long-range spikes accumulate in the global MPI
                    // buffers across the epoch
                    for &(idx, step) in &th.register.long {
                        let gid = th.gids[idx as usize];
                        for &r in th.targets.long.ranks(idx as usize) {
                            self.global_send[r as usize].push(SpikeMsg {
                                source: gid,
                                cycle: step as u32,
                            });
                        }
                    }
                    th.register.long.clear();
                }
            } else {
                for th in &mut self.threads {
                    for &(idx, step) in &th.register.short {
                        let gid = th.gids[idx as usize];
                        for &r in th.targets.short.ranks(idx as usize) {
                            self.global_send[r as usize].push(SpikeMsg {
                                source: gid,
                                cycle: step as u32,
                            });
                        }
                    }
                    th.register.short.clear();
                }
            }
            cycle_secs += sw.charge(&mut phase_times, Phase::Collocate);
            if record_cycle_times {
                cycle_times.push(cycle_secs);
            }

            // ---- communicate ---------------------------------------------
            if dual {
                self.recv_short = comm.local_swap(&mut self.local_send);
            }
            if (s + 1) % self.epoch_cycles == 0 {
                let (recv, timing) = comm.alltoall(&mut self.global_send);
                self.recv_long = recv.into_iter().flatten().collect();
                phase_times.add(Phase::Synchronize, timing.sync_secs);
                phase_times.add(Phase::DataExchange, timing.data_secs);
                for buf in &mut self.global_send {
                    buf.clear();
                }
            }
        }

        let (mut n_short, mut n_long, mut n_neurons) = (0usize, 0usize, 0usize);
        for th in &self.threads {
            n_short += th.conn.short.n_connections();
            n_long += th.conn.long.n_connections();
            n_neurons += th.gids.len();
        }
        RankResult {
            rank: self.rank,
            phase_times,
            cycle_times,
            spikes: self.spikes,
            n_conns_short: n_short,
            n_conns_long: n_long,
            n_neurons,
        }
    }
}
