//! The parallel receive path: sorting, routing and merging of incoming
//! spike batches (arXiv 2109.11358's parallel spike sorting, adapted to
//! this engine's thread-sharded delivery).
//!
//! Incoming spikes arrive as **runs** — the per-sender receive buffers
//! of the transport, one per (exchange, source rank).  Spike compression
//! emits one message per (source neuron, target rank) per emission step,
//! and every source GID is hosted by exactly one rank, so the canonical
//! key `(source, cycle)` is **globally unique across all runs of a
//! deliver phase**.  That uniqueness is what makes the whole scheme
//! exact rather than approximate:
//!
//! 1. each run is sorted canonically on its own ([`sort_run`] — workers
//!    do this in parallel, replacing the coordinator's single
//!    `sort_unstable` over the flattened batch);
//! 2. a k-way merge of canonically sorted runs with unique keys produces
//!    *the* canonical order — bit-identical to sorting the flattened
//!    batch, with no reliance on f64 order-independence across modes;
//! 3. scattering a canonically ordered stream into per-thread buckets
//!    ([`bucket_runs`]) keeps every bucket canonically ordered, so the
//!    consuming thread's merge over its buckets ([`merge_routed`]) again
//!    yields the canonical order.
//!
//! Uniqueness is *asserted* (`debug_assert`), not assumed: a duplicate
//! key would make unstable sorting and merge tie-breaking
//! order-ambiguous, so any future change that breaks compression fails
//! loudly in debug builds.
//!
//! Routing resolves each spike through [`SourceShards`] to
//! `(owning thread, connection-group index)` pairs — the consuming
//! thread receives [`RoutedSpike`]s whose `group` field already names
//! its connection-table row, so the per-spike table search disappears
//! from the delivery hot loop.
//!
//! [`RunSet`] owns the run buffers between communicate and deliver and
//! recycles them through an internal pool, preserving the transport
//! layer's zero-steady-state-allocation contract: capacity stolen from
//! a transport receive buffer is returned to it from the pool on the
//! next exchange.

use crate::comm::SpikeMsg;
use crate::network::Gid;
use crate::tables::SourceShards;

/// A received spike routed to one consuming thread: the canonical key
/// plus the pre-resolved connection-group index in that thread's table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RoutedSpike {
    pub source: Gid,
    pub cycle: u32,
    /// Group index in the consuming thread's `ConnTable` of the pathway
    /// this spike was routed through.
    pub group: u32,
}

/// The canonical delivery order — (source, emission step).  Every batch
/// sort in the engine goes through [`sort_canonical`] with this exact
/// key; sharing the helper is what keeps all execution paths
/// bit-identical.
#[inline]
pub fn canonical_key(msg: &SpikeMsg) -> (Gid, u32) {
    (msg.source, msg.cycle)
}

/// Sort a batch into canonical order.  Unstable: safe because canonical
/// keys are unique wherever the engine sorts (asserted by [`sort_run`]
/// on the receive path and by the target-table notify batch in
/// `engine::rank`).
pub fn sort_canonical(batch: &mut [SpikeMsg]) {
    batch.sort_unstable_by_key(canonical_key);
}

/// Sort one receive run canonically and assert key uniqueness — the
/// precondition for unstable sorting and for the merge in
/// [`bucket_runs`] being deterministic.
pub fn sort_run(run: &mut [SpikeMsg]) {
    sort_canonical(run);
    debug_assert!(
        run.windows(2)
            .all(|w| canonical_key(&w[0]) < canonical_key(&w[1])),
        "duplicate (source, cycle) key within a receive run — spike \
         compression guarantees one message per (source, target rank) \
         per step, so a duplicate means compression is broken and \
         unstable canonical sorting is no longer order-safe"
    );
}

/// Sort every run, k-way merge them into the canonical stream, and
/// scatter each spike to its owning threads via `shards`: `push(t, sp)`
/// is called for every (spike, owning thread) pair, in canonical spike
/// order per thread.  All runs are cleared (capacity kept) — the caller
/// recycles the buffers.  `heads` is caller-owned scratch so the merge
/// allocates nothing in steady state.
pub fn bucket_runs(
    shards: &SourceShards,
    runs: &mut [Vec<SpikeMsg>],
    heads: &mut Vec<usize>,
    mut push: impl FnMut(u16, RoutedSpike),
) {
    for run in runs.iter_mut() {
        sort_run(run);
    }
    {
        let mut scatter = |msg: SpikeMsg| {
            let hit = shards.lookup(msg.source);
            for (&t, &g) in hit.threads.iter().zip(hit.groups) {
                push(
                    t,
                    RoutedSpike {
                        source: msg.source,
                        cycle: msg.cycle,
                        group: g,
                    },
                );
            }
        };
        match runs.len() {
            0 => {}
            // single sorted run: already the canonical stream
            1 => {
                for &msg in runs[0].iter() {
                    scatter(msg);
                }
            }
            _ => {
                heads.clear();
                heads.resize(runs.len(), 0);
                loop {
                    let mut best: Option<(usize, (Gid, u32))> = None;
                    for (r, run) in runs.iter().enumerate() {
                        if let Some(msg) = run.get(heads[r]) {
                            let k = canonical_key(msg);
                            match best {
                                None => best = Some((r, k)),
                                Some((_, kb)) => {
                                    debug_assert_ne!(
                                        k, kb,
                                        "duplicate (source, cycle) key \
                                         across receive runs"
                                    );
                                    if k < kb {
                                        best = Some((r, k));
                                    }
                                }
                            }
                        }
                    }
                    let Some((r, _)) = best else { break };
                    let msg = runs[r][heads[r]];
                    heads[r] += 1;
                    scatter(msg);
                }
            }
        }
    }
    for run in runs.iter_mut() {
        run.clear();
    }
}

/// K-way merge of canonically sorted routed buckets: `deliver` sees
/// every spike of every bucket exactly once, in canonical order — the
/// consuming thread's half of the parallel receive.  Keys are unique
/// across buckets (asserted), so the merge is deterministic.  `heads`
/// is caller-owned scratch.
pub fn merge_routed(
    buckets: &[&[RoutedSpike]],
    heads: &mut Vec<usize>,
    mut deliver: impl FnMut(RoutedSpike),
) {
    match buckets.len() {
        0 => {}
        1 => {
            for &sp in buckets[0] {
                deliver(sp);
            }
        }
        _ => {
            heads.clear();
            heads.resize(buckets.len(), 0);
            loop {
                let mut best: Option<(usize, (Gid, u32))> = None;
                for (b, bucket) in buckets.iter().enumerate() {
                    if let Some(sp) = bucket.get(heads[b]) {
                        let k = (sp.source, sp.cycle);
                        match best {
                            None => best = Some((b, k)),
                            Some((_, kb)) => {
                                debug_assert_ne!(
                                    k, kb,
                                    "duplicate (source, cycle) key across \
                                     delivery buckets"
                                );
                                if k < kb {
                                    best = Some((b, k));
                                }
                            }
                        }
                    }
                }
                let Some((b, _)) = best else { break };
                let sp = buckets[b][heads[b]];
                heads[b] += 1;
                deliver(sp);
            }
        }
    }
}

/// The receive runs of one pathway between communicate and deliver,
/// with an internal buffer pool so capacity circulates instead of
/// being reallocated: [`RunSet::push_run`] *swaps* the caller's buffer
/// against a pooled empty one (the transport keeps its capacity), and
/// cleared run buffers return via [`RunSet::reclaim`] /
/// [`RunSet::recycle`].
#[derive(Default)]
pub struct RunSet {
    runs: Vec<Vec<SpikeMsg>>,
    pool: Vec<Vec<SpikeMsg>>,
}

impl RunSet {
    /// Take the contents of `buf` as a new run (no-op when empty).
    /// `buf` is left holding a pooled empty buffer, so transport
    /// receive buffers keep circulating capacity.
    pub fn push_run(&mut self, buf: &mut Vec<SpikeMsg>) {
        if buf.is_empty() {
            return;
        }
        let mut run = self.pool.pop().unwrap_or_default();
        debug_assert!(run.is_empty());
        std::mem::swap(&mut run, buf);
        self.runs.push(run);
    }

    pub fn is_empty(&self) -> bool {
        self.runs.is_empty()
    }

    pub fn n_runs(&self) -> usize {
        self.runs.len()
    }

    /// The pending runs, read-only (checkpoint serialization).
    pub fn runs(&self) -> &[Vec<SpikeMsg>] {
        &self.runs
    }

    /// The pending runs, for in-place sorting/bucketing.
    pub fn runs_mut(&mut self) -> &mut [Vec<SpikeMsg>] {
        &mut self.runs
    }

    /// Move the pending runs out (hand-off to barrier workers); the
    /// cleared buffers come back through [`RunSet::recycle`].
    pub fn drain_runs(&mut self) -> std::vec::Drain<'_, Vec<SpikeMsg>> {
        self.runs.drain(..)
    }

    /// Return all in-place-consumed (now cleared) runs to the pool.
    pub fn reclaim(&mut self) {
        for run in self.runs.drain(..) {
            debug_assert!(run.is_empty(), "reclaiming a non-empty run");
            self.pool.push(run);
        }
    }

    /// Return one cleared run buffer that traveled through a worker
    /// slot to the pool.
    pub fn recycle(&mut self, run: Vec<SpikeMsg>) {
        debug_assert!(run.is_empty(), "recycling a non-empty run");
        self.pool.push(run);
    }

    /// Flatten all pending runs into one batch (recycling the run
    /// buffers) — the legacy channel runtime's delivery input, which
    /// still sorts the flat batch on the coordinator.
    pub fn flatten_into(&mut self, out: &mut Vec<SpikeMsg>) {
        for mut run in self.runs.drain(..) {
            out.extend_from_slice(&run);
            run.clear();
            self.pool.push(run);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tables::{ConnTable, LocalConn};
    use crate::util::rng::Pcg64;

    fn msg(source: Gid, cycle: u32) -> SpikeMsg {
        SpikeMsg { source, cycle }
    }

    fn conn(t: u32, d: u16) -> LocalConn {
        LocalConn { target_local: t, weight: 0.25, delay_steps: d }
    }

    /// Shards over `n_threads` tables where thread t owns the sources
    /// with `src % n_threads == t` plus, optionally, broadcast sources
    /// owned by every thread.
    fn modulo_shards(
        n_threads: usize,
        n_sources: u32,
        broadcast: &[Gid],
    ) -> (SourceShards, Vec<ConnTable>) {
        let tables: Vec<ConnTable> = (0..n_threads)
            .map(|t| {
                let mut entries: Vec<(Gid, LocalConn)> = (0..n_sources)
                    .filter(|s| *s as usize % n_threads == t)
                    .map(|s| (s, conn(s, 1)))
                    .collect();
                entries.extend(broadcast.iter().map(|&s| (s, conn(s, 2))));
                ConnTable::build(entries)
            })
            .collect();
        (SourceShards::build(tables.iter()), tables)
    }

    #[test]
    fn sort_run_orders_canonically() {
        let mut run = vec![msg(5, 2), msg(1, 9), msg(5, 1), msg(0, 3)];
        sort_run(&mut run);
        assert_eq!(run, vec![msg(0, 3), msg(1, 9), msg(5, 1), msg(5, 2)]);
    }

    #[test]
    fn bucket_then_merge_equals_flat_canonical_sort() {
        // the core bit-identity property: per thread, the merge over
        // bucketed runs reproduces exactly the subsequence that thread
        // would extract from the canonically sorted flat batch
        let n_threads = 3;
        let (shards, _) = modulo_shards(n_threads, 50, &[7]);
        let mut rng = Pcg64::seed_from_u64(42);
        // 4 runs with disjoint (source, cycle) keys, interleaved sources
        let mut runs: Vec<Vec<SpikeMsg>> = (0..4)
            .map(|r| {
                (0..40)
                    .map(|i| msg(rng.below(50) as Gid, (i * 4 + r) as u32))
                    .collect()
            })
            .collect();
        let mut flat: Vec<SpikeMsg> =
            runs.iter().flatten().copied().collect();

        // reference: flat canonical sort, scatter in order
        sort_canonical(&mut flat);
        let mut want: Vec<Vec<RoutedSpike>> = vec![Vec::new(); n_threads];
        for m in &flat {
            let hit = shards.lookup(m.source);
            for (&t, &g) in hit.threads.iter().zip(hit.groups) {
                want[t as usize].push(RoutedSpike {
                    source: m.source,
                    cycle: m.cycle,
                    group: g,
                });
            }
        }

        // parallel path: bucket runs, then merge per thread
        let mut buckets: Vec<Vec<RoutedSpike>> = vec![Vec::new(); n_threads];
        let mut heads = Vec::new();
        bucket_runs(&shards, &mut runs, &mut heads, |t, sp| {
            buckets[t as usize].push(sp)
        });
        assert!(runs.iter().all(|r| r.is_empty()), "runs must be cleared");
        for t in 0..n_threads {
            let mut got = Vec::new();
            merge_routed(&[buckets[t].as_slice()], &mut heads, |sp| {
                got.push(sp)
            });
            assert_eq!(got, want[t], "thread {t}");
        }
    }

    #[test]
    fn merge_over_split_buckets_reproduces_single_bucket() {
        // splitting a thread's spikes across producer buckets (as the
        // cooperative grid does) must not change the merged order
        let (shards, _) = modulo_shards(2, 20, &[]);
        let mut rng = Pcg64::seed_from_u64(9);
        let mut runs: Vec<Vec<SpikeMsg>> = vec![(0..60)
            .map(|i| msg(rng.below(20) as Gid, i as u32))
            .collect()];
        let mut single: Vec<RoutedSpike> = Vec::new();
        let mut heads = Vec::new();
        let mut runs_copy = runs.clone();
        bucket_runs(&shards, &mut runs_copy, &mut heads, |t, sp| {
            if t == 0 {
                single.push(sp)
            }
        });
        // split the same stream across three buckets by round-robin of
        // distinct sources (keeps each bucket canonically sorted)
        let mut parts: Vec<Vec<RoutedSpike>> = vec![Vec::new(); 3];
        bucket_runs(&shards, &mut runs, &mut heads, |t, sp| {
            if t == 0 {
                parts[(sp.source % 3) as usize].push(sp)
            }
        });
        let views: Vec<&[RoutedSpike]> =
            parts.iter().map(|p| p.as_slice()).collect();
        let mut merged = Vec::new();
        merge_routed(&views, &mut heads, |sp| merged.push(sp));
        assert_eq!(merged, single);
    }

    #[test]
    fn broadcast_source_reaches_every_thread() {
        let n_threads = 4;
        let (shards, tables) = modulo_shards(n_threads, 8, &[3]);
        let mut runs = vec![vec![msg(3, 10)]];
        let mut hits: Vec<(u16, RoutedSpike)> = Vec::new();
        let mut heads = Vec::new();
        bucket_runs(&shards, &mut runs, &mut heads, |t, sp| {
            hits.push((t, sp))
        });
        assert_eq!(hits.len(), n_threads);
        for (t, sp) in hits {
            // the routed group must resolve to source 3 in that table
            let cs = tables[t as usize].group(sp.group as usize);
            let direct = tables[t as usize].lookup(3);
            assert_eq!(
                cs.iter().collect::<Vec<_>>(),
                direct.iter().collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn empty_shards_drop_everything() {
        let shards = SourceShards::build(std::iter::empty::<&ConnTable>());
        let mut runs = vec![vec![msg(1, 1), msg(2, 2)], vec![msg(3, 3)]];
        let mut heads = Vec::new();
        bucket_runs(&shards, &mut runs, &mut heads, |_, _| {
            panic!("nothing should be routed through empty shards")
        });
        assert!(runs.iter().all(|r| r.is_empty()));
    }

    #[test]
    fn empty_runs_and_buckets_are_noops() {
        let (shards, _) = modulo_shards(2, 4, &[]);
        let mut heads = Vec::new();
        let mut runs: Vec<Vec<SpikeMsg>> = vec![Vec::new(), Vec::new()];
        bucket_runs(&shards, &mut runs, &mut heads, |_, _| {
            panic!("no spikes")
        });
        merge_routed(&[], &mut heads, |_| panic!("no buckets"));
        merge_routed(&[&[], &[]], &mut heads, |_| panic!("empty buckets"));
    }

    #[test]
    fn runset_recycles_capacity() {
        let mut set = RunSet::default();
        let mut buf = Vec::with_capacity(64);
        buf.push(msg(1, 1));
        set.push_run(&mut buf);
        assert!(buf.is_empty());
        assert_eq!(set.n_runs(), 1);
        // consume in place, then reclaim
        for run in set.runs_mut() {
            run.clear();
        }
        set.reclaim();
        assert!(set.is_empty());
        // the pooled buffer (with its capacity) backs the next push
        let mut buf2 = vec![msg(2, 2)];
        set.push_run(&mut buf2);
        assert!(buf2.capacity() >= 64, "pooled capacity must circulate");
        // empty buffers are not runs
        let mut empty = Vec::new();
        set.push_run(&mut empty);
        assert_eq!(set.n_runs(), 1);
    }

    #[test]
    fn runset_flatten_preserves_contents() {
        let mut set = RunSet::default();
        set.push_run(&mut vec![msg(5, 1), msg(2, 1)]);
        set.push_run(&mut vec![msg(9, 3)]);
        let mut out = Vec::new();
        set.flatten_into(&mut out);
        assert_eq!(out.len(), 3);
        assert!(set.is_empty());
    }
}
