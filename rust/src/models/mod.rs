//! Model zoo: the paper's two benchmark networks plus a small sanity net.
//!
//! All constructors take a `scale` factor so the same specification runs
//! at paper scale inside the virtual cluster and at laptop scale in the
//! functional engine.

pub mod mam_data;

use crate::network::spec::{
    AreaSpec, DelayDist, LifParams, NeuronKind, WeightRule,
};
use crate::network::ModelSpec;
use anyhow::Result;

/// Paper-scale constants of the MAM-benchmark (§4.2).
pub const MAMB_NEURONS_PER_AREA: u32 = 130_000;
pub const MAMB_K_INTRA: u32 = 3_000;
pub const MAMB_K_INTER: u32 = 3_000;
pub const MAMB_RATE_HZ: f64 = 2.5;

/// The MAM-benchmark (§4.2): `n_areas` equal areas of ignore-and-fire
/// neurons at a constant 2.5 spikes/s; intra delays N(1.25, 0.625) ms,
/// inter delays N(5, 2.5) ms with lower cutoff `d_min_inter`.
///
/// `scale` multiplies neurons per area; indegrees scale proportionally
/// (capped below by 1) so the per-neuron workload stays representative.
pub fn mam_benchmark(
    n_areas: usize,
    scale: f64,
    d_min_inter_ms: f64,
) -> Result<ModelSpec> {
    let n = ((MAMB_NEURONS_PER_AREA as f64 * scale).round() as u32).max(2);
    let k_intra =
        ((MAMB_K_INTRA as f64 * scale).round() as u32).clamp(1, n - 1);
    let k_inter = if n_areas > 1 {
        ((MAMB_K_INTER as f64 * scale).round() as u32).max(1)
    } else {
        0
    };
    let areas = (0..n_areas)
        .map(|i| AreaSpec {
            name: format!("A{i:02}"),
            n,
            neuron: NeuronKind::ignore_and_fire_hz(MAMB_RATE_HZ, 0.1),
        })
        .collect();
    ModelSpec::new(
        format!("mam-benchmark-{n_areas}x{n}"),
        areas,
        k_intra,
        k_inter,
        WeightRule::default(),
        DelayDist::new(1.25, 0.625, 0.1),
        DelayDist::new(5.0, 2.5, d_min_inter_ms),
        0.1,
    )
}

/// MAM-benchmark variant with heterogeneous area sizes and/or rates
/// (Fig 8a/8b).  Sizes and rates are drawn from normal distributions with
/// the given CVs around the scaled means, floored at small positive
/// values, deterministically from `sample_seed`.
pub fn mam_benchmark_heterogeneous(
    n_areas: usize,
    scale: f64,
    d_min_inter_ms: f64,
    cv_area_size: f64,
    cv_spike_rate: f64,
    sample_seed: u64,
) -> Result<ModelSpec> {
    use crate::util::rng::Pcg64;
    let mean_n = (MAMB_NEURONS_PER_AREA as f64 * scale).max(2.0);
    let mut rng = Pcg64::new(sample_seed, 0x6865_7465_726f);
    let areas = (0..n_areas)
        .map(|i| {
            let n = rng
                .normal_ms(mean_n, cv_area_size * mean_n)
                .max(mean_n * 0.1)
                .round() as u32;
            let rate = rng
                .normal_ms(MAMB_RATE_HZ, cv_spike_rate * MAMB_RATE_HZ)
                .max(0.1);
            AreaSpec {
                name: format!("A{i:02}"),
                n: n.max(2),
                neuron: NeuronKind::ignore_and_fire_hz(rate, 0.1),
            }
        })
        .collect();
    let n_ref = mean_n as u32;
    let k_intra =
        ((MAMB_K_INTRA as f64 * scale).round() as u32).clamp(1, n_ref.saturating_sub(1).max(1));
    let k_inter = ((MAMB_K_INTER as f64 * scale).round() as u32).max(1);
    ModelSpec::new(
        format!("mam-benchmark-het-{n_areas}"),
        areas,
        k_intra,
        k_inter,
        WeightRule::default(),
        DelayDist::new(1.25, 0.625, 0.1),
        DelayDist::new(5.0, 2.5, d_min_inter_ms),
        0.1,
    )
}

/// The multi-area model of macaque visual cortex (MAM) in its ground
/// state: 32 areas with data-derived heterogeneous sizes (CV ≈ 0.2) and
/// per-area target rates (V2 most active, ≈ +68 % spikes), LIF neurons
/// with identical intrinsic parameters, ≈ 1/3 inter-area synapses.
///
/// Connectivity here is generated (uniform fixed-indegree) rather than
/// taken from the experimental matrices; the performance-relevant
/// covariates are preserved — see DESIGN.md §2.
pub fn mam(scale: f64, d_min_inter_ms: f64) -> Result<ModelSpec> {
    let lif_base = LifParams::default();
    let areas = mam_data::AREAS
        .iter()
        .map(|d| {
            let n = ((d.n_full as f64 * scale).round() as u32).max(2);
            let params = LifParams {
                // drive calibrated so the area fires near its target rate
                i_e_pa: lif_base.i_e_for_rate(d.rate_hz),
                ..lif_base
            };
            AreaSpec {
                name: d.name.to_string(),
                n,
                neuron: NeuronKind::Lif(params),
            }
        })
        .collect();
    // paper: K_N ~ 6000 with ~1/3 inter-area (~1800 long-range)
    let k_intra = ((4200.0 * scale).round() as u32).max(1);
    let k_inter = ((1800.0 * scale).round() as u32).max(1);
    ModelSpec::new(
        format!("mam-{:.4}x", scale),
        areas,
        k_intra,
        k_inter,
        WeightRule::default(),
        DelayDist::new(1.25, 0.625, 0.1),
        DelayDist::new(5.0, 2.5, d_min_inter_ms),
        0.1,
    )
}

/// Small deterministic two-area LIF network for tests and the quickstart
/// example.  Weights are binary fractions (exact f64 sums) so the
/// strategy-equivalence test can require bit-identical spike trains.
pub fn sanity_net(n_per_area: u32, n_areas: usize) -> Result<ModelSpec> {
    let params = LifParams {
        // healthy suprathreshold drive (asymptote ~0.7 mV above theta) so
        // recurrent kicks of ±0.25/1.0 mV visibly shift spike times
        i_e_pa: LifParams::default().i_e_for_rate(30.0),
        ..LifParams::default()
    };
    let areas = (0..n_areas)
        .map(|i| AreaSpec {
            name: format!("S{i}"),
            n: n_per_area,
            neuron: NeuronKind::Lif(params),
        })
        .collect();
    let k_intra = (n_per_area / 10).clamp(1, n_per_area - 1);
    let k_inter = if n_areas > 1 { (n_per_area / 20).max(1) } else { 0 };
    ModelSpec::new(
        format!("sanity-{n_areas}x{n_per_area}"),
        areas,
        k_intra,
        k_inter,
        WeightRule { w_mv: 0.25, g: 4.0, inh_fraction: 0.2 },
        DelayDist::new(1.25, 0.625, 0.1),
        DelayDist::new(5.0, 2.5, 1.0),
        0.1,
    )
}

/// Deep-pipeline LIF net: every delay — intra *and* inter — is drawn
/// tightly around 5 ms (sigma 0.05 ms) over a 1 ms min-delay cutoff, so
/// the cycle stays 1 ms while every rank's *realized* minimum incoming
/// delay sits near 5 ms ≈ 5 cycles.  That multi-cycle slack is exactly
/// what a depth-D split-phase pipeline (`--comm-depth`) needs:
/// conventional runs on this net sustain up to 4 exchange rounds in
/// flight.  Weights are binary fractions (exact f64 ring-buffer sums)
/// like `sanity_net`, so depth-equivalence tests can require bit-exact
/// spike trains.
pub fn deep_pipeline_net(
    n_per_area: u32,
    n_areas: usize,
) -> Result<ModelSpec> {
    anyhow::ensure!(
        n_per_area >= 2,
        "deep_pipeline_net needs at least 2 neurons per area (got \
         {n_per_area}): the indegree clamp requires n - 1 >= 1"
    );
    let params = LifParams {
        i_e_pa: LifParams::default().i_e_for_rate(30.0),
        ..LifParams::default()
    };
    let areas = (0..n_areas)
        .map(|i| AreaSpec {
            name: format!("P{i}"),
            n: n_per_area,
            neuron: NeuronKind::Lif(params),
        })
        .collect();
    let k_intra = (n_per_area / 10).clamp(1, n_per_area - 1);
    let k_inter = if n_areas > 1 { (n_per_area / 20).max(1) } else { 0 };
    ModelSpec::new(
        format!("deep-pipeline-{n_areas}x{n_per_area}"),
        areas,
        k_intra,
        k_inter,
        WeightRule { w_mv: 0.25, g: 4.0, inh_fraction: 0.2 },
        DelayDist::new(5.0, 0.05, 1.0),
        DelayDist::new(5.0, 0.05, 1.0),
        0.1,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mam_benchmark_scales() {
        let m = mam_benchmark(4, 0.01, 1.0).unwrap();
        assert_eq!(m.n_areas(), 4);
        assert_eq!(m.total_neurons(), 4 * 1300);
        assert_eq!(m.k_intra, 30);
        assert_eq!(m.k_inter, 30);
        assert_eq!(m.delay_ratio(), 10);
    }

    #[test]
    fn mam_benchmark_single_area_has_no_inter() {
        let m = mam_benchmark(1, 0.01, 1.0).unwrap();
        assert_eq!(m.k_inter, 0);
    }

    #[test]
    fn mam_has_32_heterogeneous_areas() {
        let m = mam(0.001, 1.0).unwrap();
        assert_eq!(m.n_areas(), 32);
        let sizes: Vec<f64> =
            m.areas.iter().map(|a| a.n as f64).collect();
        let cv = crate::util::stats::cv(&sizes);
        assert!((0.1..0.35).contains(&cv), "size CV {cv}");
        // V2 present and largest-ish firing target
        assert!(m.areas.iter().any(|a| a.name == "V2"));
    }

    #[test]
    fn mam_delay_ratio_follows_cutoff() {
        for d in [1.0, 0.5, 2.0] {
            let m = mam(0.001, d).unwrap();
            assert_eq!(m.delay_ratio(), (d / 0.1).round() as u32);
        }
    }

    #[test]
    fn heterogeneous_sampling_is_seed_deterministic() {
        let a = mam_benchmark_heterogeneous(8, 0.01, 1.0, 0.2, 0.0, 7)
            .unwrap();
        let b = mam_benchmark_heterogeneous(8, 0.01, 1.0, 0.2, 0.0, 7)
            .unwrap();
        let c = mam_benchmark_heterogeneous(8, 0.01, 1.0, 0.2, 0.0, 8)
            .unwrap();
        let sizes =
            |m: &crate::network::ModelSpec| -> Vec<u32> {
                m.areas.iter().map(|x| x.n).collect()
            };
        assert_eq!(sizes(&a), sizes(&b));
        assert_ne!(sizes(&a), sizes(&c));
    }

    #[test]
    fn heterogeneous_cv_zero_is_homogeneous_rate() {
        let m = mam_benchmark_heterogeneous(4, 0.01, 1.0, 0.0, 0.0, 1)
            .unwrap();
        let intervals: std::collections::HashSet<_> = m
            .areas
            .iter()
            .map(|a| match a.neuron {
                NeuronKind::IgnoreAndFire { interval_steps } => interval_steps,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(intervals.len(), 1);
    }

    #[test]
    fn deep_pipeline_net_has_multicycle_slack() {
        let m = deep_pipeline_net(100, 2).unwrap();
        // cycle = the 1 ms cutoff (10 steps at h = 0.1), while drawn
        // delays concentrate near 5 ms = 5 cycles
        assert_eq!(m.d_min_steps(), 10);
        assert_eq!(m.delay_ratio(), 1);
        let mut rng = crate::util::rng::Pcg64::seed_from_u64(3);
        for _ in 0..5000 {
            let d = m.delay_intra.draw_steps(&mut rng, m.h_ms);
            assert!((40..=60).contains(&d), "delay {d} steps off 5 ms");
            let d = m.delay_inter.draw_steps(&mut rng, m.h_ms);
            assert!((40..=60).contains(&d), "delay {d} steps off 5 ms");
        }
    }

    #[test]
    fn sanity_net_exact_weights() {
        let m = sanity_net(100, 2).unwrap();
        assert_eq!(m.weights.w_mv, 0.25);
        assert_eq!(m.weight_of(99), -1.0); // inhibitory: -4 * 0.25
    }
}
