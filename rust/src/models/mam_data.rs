//! Per-area data table for the multi-area model of macaque visual cortex
//! (32 areas; Schmidt et al. 2018).
//!
//! Neuron counts are representative full-scale values reproducing the
//! paper's statistics: mean area size ≈ 130 000 with CV ≈ 0.2, total
//! ≈ 4.1 M neurons.  Ground-state target rates average 2.5 spikes/s with
//! V2 ≈ 68 % above the network mean (§2.4.3).  The counts stand in for
//! the experimentally derived population sizes (substitution documented
//! in DESIGN.md §2); the *distributional* properties the performance
//! study depends on are preserved.

/// Static per-area record.
pub struct AreaData {
    pub name: &'static str,
    /// Full-scale neuron count (1 mm² column, both layers' populations).
    pub n_full: u32,
    /// Ground-state target firing rate [spikes/s].
    pub rate_hz: f64,
}

/// The 32 vision-related areas of the MAM in the conventional parcellation
/// order (FV91).
pub const AREAS: [AreaData; 32] = [
    AreaData { name: "V1", n_full: 197_936, rate_hz: 1.8 },
    AreaData { name: "V2", n_full: 182_346, rate_hz: 4.2 },
    AreaData { name: "VP", n_full: 168_120, rate_hz: 2.4 },
    AreaData { name: "V3", n_full: 151_825, rate_hz: 2.2 },
    AreaData { name: "V3A", n_full: 132_611, rate_hz: 2.0 },
    AreaData { name: "MT", n_full: 146_128, rate_hz: 2.8 },
    AreaData { name: "V4t", n_full: 141_152, rate_hz: 2.7 },
    AreaData { name: "V4", n_full: 156_423, rate_hz: 3.0 },
    AreaData { name: "VOT", n_full: 137_793, rate_hz: 2.5 },
    AreaData { name: "MSTd", n_full: 119_546, rate_hz: 2.6 },
    AreaData { name: "PIP", n_full: 121_369, rate_hz: 2.1 },
    AreaData { name: "PO", n_full: 120_751, rate_hz: 1.9 },
    AreaData { name: "DP", n_full: 123_490, rate_hz: 2.3 },
    AreaData { name: "MIP", n_full: 119_650, rate_hz: 2.0 },
    AreaData { name: "MDP", n_full: 118_752, rate_hz: 1.7 },
    AreaData { name: "VIP", n_full: 117_010, rate_hz: 3.1 },
    AreaData { name: "LIP", n_full: 122_607, rate_hz: 3.2 },
    AreaData { name: "PITv", n_full: 124_954, rate_hz: 2.6 },
    AreaData { name: "PITd", n_full: 124_453, rate_hz: 2.4 },
    AreaData { name: "MSTl", n_full: 117_869, rate_hz: 2.3 },
    AreaData { name: "CITv", n_full: 114_212, rate_hz: 2.2 },
    AreaData { name: "CITd", n_full: 113_573, rate_hz: 2.1 },
    AreaData { name: "FEF", n_full: 134_634, rate_hz: 3.4 },
    AreaData { name: "TF", n_full: 130_302, rate_hz: 1.9 },
    AreaData { name: "AITv", n_full: 110_221, rate_hz: 2.3 },
    AreaData { name: "FST", n_full: 112_980, rate_hz: 2.5 },
    AreaData { name: "7a", n_full: 127_524, rate_hz: 2.7 },
    AreaData { name: "STPp", n_full: 116_852, rate_hz: 2.4 },
    AreaData { name: "STPa", n_full: 109_795, rate_hz: 2.2 },
    AreaData { name: "46", n_full: 139_243, rate_hz: 3.0 },
    AreaData { name: "AITd", n_full: 108_980, rate_hz: 2.4 },
    AreaData { name: "TH", n_full: 81_369, rate_hz: 1.6 },
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn thirty_two_unique_areas() {
        assert_eq!(AREAS.len(), 32);
        let names: std::collections::HashSet<_> =
            AREAS.iter().map(|a| a.name).collect();
        assert_eq!(names.len(), 32);
    }

    #[test]
    fn mean_size_and_cv_match_paper() {
        let sizes: Vec<f64> = AREAS.iter().map(|a| a.n_full as f64).collect();
        let mean = stats::mean(&sizes);
        assert!(
            (120_000.0..140_000.0).contains(&mean),
            "mean area size {mean}"
        );
        let cv = stats::cv(&sizes);
        assert!((0.12..0.28).contains(&cv), "area-size CV {cv}");
    }

    #[test]
    fn rates_average_ground_state_with_v2_hotspot() {
        let rates: Vec<f64> = AREAS.iter().map(|a| a.rate_hz).collect();
        let mean = stats::mean(&rates);
        assert!((2.2..2.8).contains(&mean), "mean rate {mean}");
        let v2 = AREAS.iter().find(|a| a.name == "V2").unwrap();
        // V2 generates approximately 68% more spikes than average
        let excess = v2.rate_hz / mean - 1.0;
        assert!((0.5..0.9).contains(&excess), "V2 excess {excess}");
        // V2 is the most active area
        assert!(rates.iter().all(|&r| r <= v2.rate_hz));
    }
}
