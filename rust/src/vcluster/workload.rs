//! Derive per-rank workload characteristics from a model specification,
//! a strategy and a machine size — the inputs of the performance model.
//!
//! Only *counts and rates* are used (no connectivity instantiation), so
//! workloads can be derived at full paper scale (130 000 neurons per rank,
//! M = 128) in microseconds.

use crate::config::Strategy;
use crate::network::spec::NeuronKind;
use crate::network::ModelSpec;
use crate::theory::delivery::{
    f_irr_conventional, p_at_least_one, DeliveryScenario,
};
use anyhow::{bail, Result};

/// Expected number of distinct *remote* ranks receiving at least one of
/// a spike's `k_inter` inter-area synapses, targets uniform over the
/// `m - 1` other ranks.  Saturates at `m - 1` for paper-scale indegrees
/// (K_inter = 3000 reaches every rank up to M ≈ 1000) and drops below it
/// for sparse inter-area connectivity.
fn expected_target_ranks(m: usize, k_inter: f64) -> f64 {
    if m <= 1 || k_inter <= 0.0 {
        return 0.0;
    }
    let others = m as f64 - 1.0;
    others * p_at_least_one(others, k_inter)
}

/// Static per-rank load characteristics.
#[derive(Clone, Debug)]
pub struct RankLoad {
    /// Real neurons hosted (ghosts excluded from update).
    pub n_neurons: f64,
    /// Is the model LIF (rate-sensitive update) or ignore-and-fire?
    pub lif: bool,
    /// Spikes emitted by this rank per resolution step.
    pub spikes_per_step: f64,
    /// Synapses delivered *to* this rank per step (intra, inter).
    pub syn_in_intra_per_step: f64,
    pub syn_in_inter_per_step: f64,
    /// Spikes arriving at this rank per step (for irregular-access
    /// accounting), split by pathway.
    pub spikes_in_intra_per_step: f64,
    pub spikes_in_inter_per_step: f64,
}

/// Whole-cluster workload.
#[derive(Clone, Debug)]
pub struct Workload {
    pub m: usize,
    pub strategy: Strategy,
    /// Delay ratio D (communication epoch of the structure-aware scheme).
    pub d: u32,
    /// MPI_Group extension (paper §3 future work): group id per rank when
    /// an area spans several ranks; members exchange intra-area spikes in
    /// a group-local collective every cycle.  `None` = one rank per area.
    pub groups: Option<Vec<usize>>,
    pub per_rank: Vec<RankLoad>,
    /// Fraction of irregular accesses per delivered synapse, by pathway
    /// (depends on placement scheme and T_M).
    pub f_irr_intra: f64,
    pub f_irr_inter: f64,
    /// Wire bytes per emitted spike.
    pub bytes_per_spike: f64,
    /// Expected send-buffer entries written per emitted spike in the
    /// collocate phase.  Conventional: one entry per rank (round-robin
    /// spreads every neuron's targets over all M ranks at paper-scale
    /// indegrees).  Dual pathways: 1 local-pathway entry plus one global
    /// entry per distinct remote target rank (spike compression) — equal
    /// to M only when K_inter saturates the other M−1 ranks.
    pub entries_per_spike: f64,
}

impl Workload {
    /// Build from a model spec.  `t_m` is the machine's threads/rank.
    pub fn derive(
        spec: &ModelSpec,
        strategy: Strategy,
        m: usize,
        t_m: usize,
    ) -> Result<Workload> {
        if m == 0 {
            bail!("m must be >= 1");
        }
        let n_areas = spec.n_areas();
        if strategy.structure_aware_placement() && n_areas < m {
            bail!("structure-aware placement needs >= {m} areas");
        }
        let d = spec.delay_ratio().max(1);

        // per-area neurons, rates, kind
        let area_n: Vec<f64> =
            spec.areas.iter().map(|a| a.n as f64).collect();
        let area_rate: Vec<f64> = spec
            .areas
            .iter()
            .map(|a| match a.neuron {
                NeuronKind::Lif(p) => p.tonic_rate_hz(),
                NeuronKind::IgnoreAndFire { interval_steps } => {
                    1000.0 / (interval_steps as f64 * spec.h_ms)
                }
            })
            .collect();
        let lif = matches!(spec.areas[0].neuron, NeuronKind::Lif(_));
        let n_total: f64 = area_n.iter().sum();
        let h_s = spec.h_ms * 1e-3;
        let total_spikes_per_step: f64 = area_n
            .iter()
            .zip(&area_rate)
            .map(|(n, r)| n * r * h_s)
            .sum();
        let k_intra = spec.k_intra as f64;
        let k_inter = spec.k_inter as f64;
        let k_n = k_intra + k_inter;

        // rank -> hosted area indices (or even split for round robin)
        let mut per_rank = Vec::with_capacity(m);
        if strategy.structure_aware_placement() {
            for rank in 0..m {
                let areas: Vec<usize> =
                    (0..n_areas).filter(|a| a % m == rank).collect();
                let n_r: f64 = areas.iter().map(|&a| area_n[a]).sum();
                let spikes_r: f64 = areas
                    .iter()
                    .map(|&a| area_n[a] * area_rate[a] * h_s)
                    .sum();
                // intra synapses of hosted areas arrive here; inter
                // synapses: each neuron here has k_inter incoming from
                // elsewhere, weighted by source activity ~ network mean
                let syn_intra = spikes_r * k_intra;
                let mean_rate_weighted = total_spikes_per_step / n_total;
                let syn_inter = n_r * k_inter * mean_rate_weighted;
                // arriving distinct spikes: intra = own spikes; inter =
                // (almost) every spike of other ranks reaches every rank
                // at K_inter=3000 over M-1 ranks
                let spikes_other = total_spikes_per_step - spikes_r;
                per_rank.push(RankLoad {
                    n_neurons: n_r,
                    lif,
                    spikes_per_step: spikes_r,
                    syn_in_intra_per_step: syn_intra,
                    syn_in_inter_per_step: syn_inter,
                    spikes_in_intra_per_step: spikes_r,
                    spikes_in_inter_per_step: spikes_other,
                });
            }
        } else {
            // round robin: everything balanced
            let n_r = n_total / m as f64;
            let spikes_r = total_spikes_per_step / m as f64;
            let syn_r = total_spikes_per_step * k_n / m as f64;
            for _ in 0..m {
                per_rank.push(RankLoad {
                    n_neurons: n_r,
                    lif,
                    spikes_per_step: spikes_r,
                    syn_in_intra_per_step: syn_r,
                    syn_in_inter_per_step: 0.0,
                    spikes_in_intra_per_step: total_spikes_per_step,
                    spikes_in_inter_per_step: 0.0,
                });
            }
        }

        // irregular-access fractions from the §2.3 theory
        let mean_n_m = n_total / m as f64;
        let scenario = DeliveryScenario {
            n_m: mean_n_m,
            k_n,
            k_intra,
            k_inter,
        };
        let (f_intra, f_inter) = if strategy.structure_aware_placement() {
            crate::theory::delivery::f_irr_structure_parts(&scenario, m, t_m)
        } else {
            let f = f_irr_conventional(&scenario, m, t_m);
            (f, f)
        };

        let entries_per_spike = if strategy.dual_pathways() {
            1.0 + expected_target_ranks(m, k_inter)
        } else {
            m as f64
        };

        Ok(Workload {
            m,
            strategy,
            d: if strategy.dual_pathways() { d } else { 1 },
            groups: None,
            per_rank,
            f_irr_intra: f_intra,
            f_irr_inter: f_inter,
            bytes_per_spike: crate::comm::SPIKE_WIRE_BYTES as f64,
            entries_per_spike,
        })
    }

    /// MPI_Group extension (paper §3): distribute `m >= n_areas` ranks
    /// over the areas proportionally to area size (largest-remainder), so
    /// neurons per rank stay approximately constant.  Intra-area spikes
    /// are exchanged group-locally every cycle; global communication
    /// stays at every D-th cycle.  Regains load balance with respect to
    /// network structure.
    pub fn derive_grouped(
        spec: &ModelSpec,
        m: usize,
        t_m: usize,
    ) -> Result<Workload> {
        let n_areas = spec.n_areas();
        if m < n_areas {
            bail!("grouped placement needs >= {n_areas} ranks");
        }
        let base = Workload::derive(spec, Strategy::StructureAware,
                                    n_areas, t_m)?;
        // ranks per area: one each, remainder by largest area size
        let n_total: f64 =
            spec.areas.iter().map(|a| a.n as f64).sum();
        let mut g: Vec<usize> = vec![1; n_areas];
        let mut frac: Vec<(usize, f64)> = spec
            .areas
            .iter()
            .enumerate()
            .map(|(a, ar)| (a, ar.n as f64 / n_total * m as f64))
            .collect();
        let mut assigned = n_areas;
        frac.sort_by(|x, y| y.1.partial_cmp(&x.1).unwrap());
        let mut i = 0;
        while assigned < m {
            let (a, _) = frac[i % frac.len()];
            // greedily give extra ranks to the areas with the highest
            // remaining per-rank load
            let (best, _) = (0..n_areas)
                .map(|a2| (a2, spec.areas[a2].n as f64 / g[a2] as f64))
                .fold((a, 0.0), |acc, (a2, load)| {
                    if load > acc.1 {
                        (a2, load)
                    } else {
                        acc
                    }
                });
            g[best] += 1;
            assigned += 1;
            i += 1;
        }
        // expand per-area loads into per-rank shares
        let mut per_rank = Vec::with_capacity(m);
        let mut groups = Vec::with_capacity(m);
        for (a, load) in base.per_rank.iter().enumerate() {
            let k = g[a] as f64;
            for _ in 0..g[a] {
                groups.push(a);
                per_rank.push(RankLoad {
                    n_neurons: load.n_neurons / k,
                    lif: load.lif,
                    spikes_per_step: load.spikes_per_step / k,
                    syn_in_intra_per_step: load.syn_in_intra_per_step / k,
                    syn_in_inter_per_step: load.syn_in_inter_per_step / k,
                    spikes_in_intra_per_step: load.spikes_in_intra_per_step,
                    spikes_in_inter_per_step: load.spikes_in_inter_per_step,
                });
            }
        }
        Ok(Workload {
            m,
            strategy: Strategy::StructureAware,
            d: base.d,
            groups: Some(groups),
            per_rank,
            f_irr_intra: base.f_irr_intra,
            f_irr_inter: base.f_irr_inter,
            bytes_per_spike: base.bytes_per_spike,
            // 1 group-local entry + per-remote-rank global entries,
            // evaluated at this workload's rank count
            entries_per_spike: 1.0
                + expected_target_ranks(m, spec.k_inter as f64),
        })
    }

    /// Mean neurons per rank.
    pub fn mean_n_per_rank(&self) -> f64 {
        self.per_rank.iter().map(|r| r.n_neurons).sum::<f64>()
            / self.m as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn round_robin_is_balanced() {
        let spec = models::mam_benchmark(8, 1.0, 1.0).unwrap();
        let w =
            Workload::derive(&spec, Strategy::Conventional, 8, 48).unwrap();
        assert_eq!(w.d, 1);
        let n0 = w.per_rank[0].n_neurons;
        assert!(w
            .per_rank
            .iter()
            .all(|r| (r.n_neurons - n0).abs() < 1e-9));
        assert!((n0 - 130_000.0).abs() < 1.0);
        // 2.5 Hz * 130k * 0.1ms = 32.5 spikes per step per rank
        assert!((w.per_rank[0].spikes_per_step - 32.5).abs() < 0.2);
    }

    #[test]
    fn structure_aware_uses_delay_ratio() {
        let spec = models::mam_benchmark(8, 1.0, 1.0).unwrap();
        let w =
            Workload::derive(&spec, Strategy::StructureAware, 8, 48).unwrap();
        assert_eq!(w.d, 10);
        // intermediate keeps D=1 despite area placement
        let wi =
            Workload::derive(&spec, Strategy::Intermediate, 8, 48).unwrap();
        assert_eq!(wi.d, 1);
    }

    #[test]
    fn heterogeneous_areas_imbalance_structure_aware_only() {
        let spec =
            models::mam_benchmark_heterogeneous(8, 1.0, 1.0, 0.2, 0.0, 3)
                .unwrap();
        let wc =
            Workload::derive(&spec, Strategy::Conventional, 8, 48).unwrap();
        let ws =
            Workload::derive(&spec, Strategy::StructureAware, 8, 48).unwrap();
        let cv = |w: &Workload| {
            let ns: Vec<f64> =
                w.per_rank.iter().map(|r| r.n_neurons).collect();
            crate::util::stats::cv(&ns)
        };
        assert!(cv(&wc) < 1e-9);
        assert!(cv(&ws) > 0.1);
    }

    #[test]
    fn irregular_fraction_lower_for_structure_aware_intra() {
        let spec = models::mam_benchmark(128, 1.0, 1.0).unwrap();
        let wc =
            Workload::derive(&spec, Strategy::Conventional, 128, 48).unwrap();
        let ws =
            Workload::derive(&spec, Strategy::StructureAware, 128, 48)
                .unwrap();
        assert!(
            ws.f_irr_intra < wc.f_irr_intra,
            "intra {} !< conv {}",
            ws.f_irr_intra,
            wc.f_irr_intra
        );
    }

    #[test]
    fn collocation_entries_reflect_distinct_target_ranks() {
        // paper-scale K_inter = 3000 saturates the other M-1 ranks, so
        // the dual-pathway entry count coincides with the conventional
        // all-M fan-out ...
        let spec = models::mam_benchmark(8, 1.0, 1.0).unwrap();
        let wc =
            Workload::derive(&spec, Strategy::Conventional, 8, 48).unwrap();
        assert_eq!(wc.entries_per_spike, 8.0);
        let ws =
            Workload::derive(&spec, Strategy::StructureAware, 8, 48).unwrap();
        assert!(
            (ws.entries_per_spike - 8.0).abs() < 0.05,
            "{}",
            ws.entries_per_spike
        );
        // ... but sparse inter-area connectivity (K_inter = 3 here)
        // reaches far fewer than M-1 remote ranks: 1 + 7·(1-(6/7)^3)
        let sparse = models::mam_benchmark(8, 0.001, 1.0).unwrap();
        assert_eq!(sparse.k_inter, 3);
        let w = Workload::derive(&sparse, Strategy::StructureAware, 8, 48)
            .unwrap();
        assert!(
            w.entries_per_spike > 1.0 && w.entries_per_spike < 5.0,
            "{}",
            w.entries_per_spike
        );
        // intermediate placement keeps the conventional communication
        // scheme, hence the conventional entry count
        let wi = Workload::derive(&sparse, Strategy::Intermediate, 8, 48)
            .unwrap();
        assert_eq!(wi.entries_per_spike, 8.0);
        // single rank: the dual scheme degenerates to the local pathway
        let solo = Workload::derive(
            &models::mam_benchmark(2, 0.001, 1.0).unwrap(),
            Strategy::StructureAware,
            1,
            48,
        )
        .unwrap();
        assert_eq!(solo.entries_per_spike, 1.0);
    }

    #[test]
    fn mam_v2_rank_has_highest_spike_load() {
        let spec = models::mam(1.0, 1.0).unwrap();
        let w = Workload::derive(&spec, Strategy::StructureAware, 32, 48)
            .unwrap();
        // V2 is area index 1 -> rank 1
        let v2 = &w.per_rank[1];
        assert!(w
            .per_rank
            .iter()
            .all(|r| r.spikes_per_step <= v2.spikes_per_step + 1e-9));
    }

    #[test]
    fn grouped_placement_balances_heterogeneous_areas() {
        let spec = models::mam(1.0, 1.0).unwrap();
        let w = Workload::derive_grouped(&spec, 64, 48).unwrap();
        assert_eq!(w.per_rank.len(), 64);
        let groups = w.groups.as_ref().unwrap();
        assert_eq!(groups.len(), 64);
        // every area has at least one rank; larger areas get more
        let mut per_area = vec![0usize; spec.n_areas()];
        for &g in groups {
            per_area[g] += 1;
        }
        assert!(per_area.iter().all(|&k| k >= 1));
        assert_eq!(per_area.iter().sum::<usize>(), 64);
        // neurons per rank far better balanced than 1-area-per-rank
        let grouped_ns: Vec<f64> =
            w.per_rank.iter().map(|r| r.n_neurons).collect();
        let single = Workload::derive(
            &spec,
            Strategy::StructureAware,
            32,
            48,
        )
        .unwrap();
        let single_ns: Vec<f64> =
            single.per_rank.iter().map(|r| r.n_neurons).collect();
        assert!(
            crate::util::stats::cv(&grouped_ns)
                < crate::util::stats::cv(&single_ns),
            "grouping did not improve balance"
        );
    }

    #[test]
    fn grouped_preserves_total_load() {
        let spec = models::mam(1.0, 1.0).unwrap();
        let w = Workload::derive_grouped(&spec, 48, 48).unwrap();
        let single =
            Workload::derive(&spec, Strategy::StructureAware, 32, 48)
                .unwrap();
        let tot = |w: &Workload| -> f64 {
            w.per_rank.iter().map(|r| r.n_neurons).sum()
        };
        assert!((tot(&w) - tot(&single)).abs() < 1e-6);
    }

    #[test]
    fn grouped_rejects_fewer_ranks_than_areas() {
        let spec = models::mam(1.0, 1.0).unwrap();
        assert!(Workload::derive_grouped(&spec, 16, 48).is_err());
    }

    #[test]
    fn rejects_too_few_areas() {
        let spec = models::mam_benchmark(4, 1.0, 1.0).unwrap();
        assert!(
            Workload::derive(&spec, Strategy::StructureAware, 8, 48)
                .is_err()
        );
    }
}
