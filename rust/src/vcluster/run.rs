//! The virtual-cluster event loop: generate per-rank cycle times from the
//! calibrated cost + noise models, apply barrier semantics per
//! communication epoch, and account phase times the way NEST's timers do
//! (§4.1).

use super::machine::MachineProfile;
use super::workload::Workload;
use crate::util::rng::Pcg64;
use crate::util::timers::{Phase, PhaseTimes};
use anyhow::Result;

/// Options of one virtual-cluster run.
#[derive(Clone, Copy, Debug)]
pub struct VcOptions {
    /// Biological model time [ms].
    pub t_model_ms: f64,
    /// Resolution step [ms] (cycle = d_min = one step in the paper setup).
    pub h_ms: f64,
    pub seed: u64,
    /// Keep the full per-rank cycle-time series (Figs 7b / 12).
    pub record_cycle_times: bool,
}

impl Default for VcOptions {
    fn default() -> Self {
        Self {
            t_model_ms: 10_000.0,
            h_ms: 0.1,
            seed: 654,
            record_cycle_times: false,
        }
    }
}

/// Result of a virtual-cluster run.
pub struct VcResult {
    /// Mean accumulated phase times across ranks [s].
    pub mean_times: PhaseTimes,
    /// Per-rank accumulated phase times.
    pub rank_times: Vec<PhaseTimes>,
    /// Per-rank cycle-time series [s] (empty unless recorded).
    pub cycle_times: Vec<Vec<f64>>,
    /// Per-epoch maxima of lumped cycle times [s] (always recorded; one
    /// entry per global exchange).
    pub epoch_maxima: Vec<f64>,
    pub s_cycles: u64,
    pub t_model_ms: f64,
    /// Average wire bytes per rank pair per global exchange.
    pub bytes_per_pair: f64,
}

impl VcResult {
    pub fn rtf(&self) -> f64 {
        self.mean_times.rtf(self.t_model_ms / 1000.0)
    }

    /// Pure data-exchange real-time factor (the dashed line of Fig 1b).
    pub fn data_rtf(&self) -> f64 {
        self.mean_times.get(Phase::DataExchange)
            / (self.t_model_ms / 1000.0)
    }
}

/// Per-rank static cost decomposition [s per cycle].
struct BaseCosts {
    deliver: f64,
    update: f64,
    collocate: f64,
    total: f64,
}

fn base_costs(
    machine: &MachineProfile,
    w: &Workload,
    rank: usize,
) -> BaseCosts {
    let r = &w.per_rank[rank];
    let c_up = if r.lif {
        machine.c_update_lif
    } else {
        machine.c_update_ianf
    };
    let update = r.n_neurons * c_up + r.spikes_per_step * machine.c_spike_emit;
    let deliver = r.syn_in_intra_per_step
        * (machine.c_syn + w.f_irr_intra * machine.c_miss)
        + r.syn_in_inter_per_step
            * (machine.c_syn + w.f_irr_inter * machine.c_miss);
    // collocation: one send-buffer entry per (spike, target rank); the
    // dual-pathway entry count (1 local + per-remote-rank global) comes
    // from the workload so sparse inter-area connectivity is cheaper
    // than the conventional all-M fan-out
    let collocate =
        r.spikes_per_step * w.entries_per_spike * machine.c_collocate;
    BaseCosts { deliver, update, collocate, total: deliver + update + collocate }
}

/// Run the model for `opts.t_model_ms` of biological time.
pub fn run_cluster(
    machine: &MachineProfile,
    workload: &Workload,
    opts: &VcOptions,
) -> Result<VcResult> {
    let m = workload.m;
    let s_cycles = (opts.t_model_ms / opts.h_ms).round().max(1.0) as u64;
    let d = workload.d.max(1) as u64;

    // Static per-rank costs under the machine's capacity absorption:
    // only a machine-dependent fraction of a rank's relative load excess
    // surfaces as cycle-time excess — idle per-node capacity soaks up the
    // rest (§2.4.3: V2's extra spikes cost +24 % time on SuperMUC-NG but
    // +7 % on JURECA-DC).  The damping is symmetric around the mean, so
    // rank-averaged phase times stay comparable across placements and
    // imbalance surfaces in the synchronization phase, as in the paper.
    let raw: Vec<BaseCosts> =
        (0..m).map(|r| base_costs(machine, workload, r)).collect();
    let mean_total =
        raw.iter().map(|b| b.total).sum::<f64>() / m as f64;
    let bases: Vec<BaseCosts> = raw
        .into_iter()
        .map(|b| {
            let raw_rel = b.total / mean_total;
            let damped_rel =
                (1.0 + machine.imbalance_gain * (raw_rel - 1.0)).max(0.1);
            let scale = damped_rel / raw_rel;
            BaseCosts {
                deliver: b.deliver * scale,
                update: b.update * scale,
                collocate: b.collocate * scale,
                total: b.total * scale,
            }
        })
        .collect();

    // noise state per rank
    let noise = &machine.noise;
    let mut rngs: Vec<Pcg64> =
        (0..m).map(|r| Pcg64::new(opts.seed, r as u64)).collect();
    let mut slow: Vec<f64> = rngs
        .iter_mut()
        .map(|rng| rng.normal_ms(0.0, noise.sigma_slow))
        .collect();
    // stationary AR(1): innovation std = sigma_slow * sqrt(1 - phi^2)
    let innov = noise.sigma_slow
        * (1.0 - noise.phi_slow * noise.phi_slow).max(0.0).sqrt();

    let mut rank_times = vec![PhaseTimes::new(); m];
    let mut cycle_times: Vec<Vec<f64>> = if opts.record_cycle_times {
        vec![Vec::with_capacity(s_cycles as usize); m]
    } else {
        vec![Vec::new(); m]
    };
    let mut epoch_maxima = Vec::with_capacity((s_cycles / d) as usize + 1);
    let mut lumped = vec![0.0f64; m];
    let mut this_cycle = vec![0.0f64; m];
    let mut total_bytes_per_pair = 0.0f64;
    let mut n_exchanges = 0u64;

    // spikes accumulated per rank since the last global exchange
    let mut acc_spikes = vec![0.0f64; m];

    for s in 0..s_cycles {
        for r in 0..m {
            let rng = &mut rngs[r];
            // slow AR(1) drift
            slow[r] = noise.phi_slow * slow[r] + rng.normal_ms(0.0, innov);
            let mut rel = 1.0 + slow[r] + rng.normal_ms(0.0, noise.sigma_fast);
            if rng.chance(noise.minor_prob) {
                rel += noise.minor_scale;
            }
            if rng.chance(noise.extreme_prob) {
                rel += rng.uniform_range(2.0, noise.extreme_scale_max);
            }
            // absolute OS jitter, folded into the relative factor
            rel += rng.normal_ms(0.0, noise.sigma_abs_s).abs() / bases[r].total;
            let rel = rel.max(0.05);
            let b = &bases[r];
            let t_cycle = b.total * rel;
            // charge the phases proportionally to their base shares
            let pt = &mut rank_times[r];
            pt.add(Phase::Deliver, b.deliver * rel);
            pt.add(Phase::Update, b.update * rel);
            pt.add(Phase::Collocate, b.collocate * rel);
            if opts.record_cycle_times {
                cycle_times[r].push(t_cycle);
            }
            lumped[r] += t_cycle;
            this_cycle[r] = t_cycle;
            acc_spikes[r] += workload.per_rank[r].spikes_per_step;
            if workload.strategy.dual_pathways() {
                // local pathway swap every cycle (charged as exchange)
                rank_times[r].add(Phase::DataExchange, machine.c_local_swap);
            }
        }

        // MPI_Group extension: members of a group exchange intra-area
        // spikes collectively every cycle — a group-local barrier plus a
        // small-group alltoall (paper §3 future work)
        if let Some(groups) = &workload.groups {
            let n_groups = groups.iter().max().map(|&g| g + 1).unwrap_or(0);
            for gid in 0..n_groups {
                let members: Vec<usize> = (0..m)
                    .filter(|&r| groups[r] == gid)
                    .collect();
                if members.len() < 2 {
                    continue;
                }
                let gmax = members
                    .iter()
                    .map(|&r| this_cycle[r])
                    .fold(f64::MIN, f64::max);
                let spikes_pair = members
                    .iter()
                    .map(|&r| workload.per_rank[r].spikes_per_step)
                    .fold(0.0f64, f64::max);
                let t_data = machine.alltoall.time(
                    members.len(),
                    spikes_pair * workload.bytes_per_spike,
                );
                for &r in &members {
                    let wait = gmax - this_cycle[r];
                    rank_times[r].add(Phase::Synchronize, wait);
                    rank_times[r].add(Phase::DataExchange, t_data);
                    // the group advances together: slower members pace
                    // the lumped time toward the global barrier
                    lumped[r] += wait + t_data;
                }
            }
        }

        // global exchange every D-th cycle: barrier + alltoall
        if (s + 1) % d == 0 {
            let max = lumped.iter().cloned().fold(f64::MIN, f64::max);
            epoch_maxima.push(max);
            let max_spikes =
                acc_spikes.iter().cloned().fold(0.0f64, f64::max);
            let bytes_per_pair = max_spikes * workload.bytes_per_spike;
            let t_data = machine.alltoall.time(m, bytes_per_pair);
            total_bytes_per_pair += bytes_per_pair;
            n_exchanges += 1;
            for r in 0..m {
                rank_times[r].add(Phase::Synchronize, max - lumped[r]);
                rank_times[r].add(Phase::DataExchange, t_data);
                lumped[r] = 0.0;
                acc_spikes[r] = 0.0;
            }
        }
    }

    Ok(VcResult {
        mean_times: PhaseTimes::mean_of(&rank_times),
        rank_times,
        cycle_times,
        epoch_maxima,
        s_cycles,
        t_model_ms: opts.t_model_ms,
        bytes_per_pair: if n_exchanges > 0 {
            total_bytes_per_pair / n_exchanges as f64
        } else {
            0.0
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Strategy;
    use crate::models;
    use crate::util::stats;

    fn opts(t_model_ms: f64) -> VcOptions {
        VcOptions { t_model_ms, ..Default::default() }
    }

    fn run(
        strategy: Strategy,
        m: usize,
        t_model_ms: f64,
    ) -> (Workload, VcResult) {
        let spec = models::mam_benchmark(m, 1.0, 1.0).unwrap();
        let machine = MachineProfile::supermuc_ng();
        let w = Workload::derive(&spec, strategy, m, machine.t_m).unwrap();
        let res = run_cluster(&machine, &w, &opts(t_model_ms)).unwrap();
        (w, res)
    }

    #[test]
    fn conventional_cycle_time_near_calibration() {
        let spec = models::mam_benchmark(128, 1.0, 1.0).unwrap();
        let machine = MachineProfile::supermuc_ng();
        let w =
            Workload::derive(&spec, Strategy::Conventional, 128, 48).unwrap();
        let mut o = opts(200.0);
        o.record_cycle_times = true;
        let res = run_cluster(&machine, &w, &o).unwrap();
        let all: Vec<f64> =
            res.cycle_times.iter().flatten().cloned().collect();
        let mean = stats::mean(&all);
        // paper Fig 7b: mean cycle time ~1.6 ms at M=128
        assert!(
            (1.2e-3..2.1e-3).contains(&mean),
            "mean cycle {mean}"
        );
        let cv = stats::cv(&all);
        assert!((0.03..0.12).contains(&cv), "cv {cv}");
    }

    #[test]
    fn structure_aware_beats_conventional_at_scale() {
        let (_, conv) = run(Strategy::Conventional, 128, 100.0);
        let (_, stru) = run(Strategy::StructureAware, 128, 100.0);
        assert!(
            stru.rtf() < conv.rtf(),
            "struct {} !< conv {}",
            stru.rtf(),
            conv.rtf()
        );
        // sync and data exchange both improve
        use crate::util::timers::Phase;
        assert!(
            stru.mean_times.get(Phase::Synchronize)
                < conv.mean_times.get(Phase::Synchronize)
        );
        assert!(
            stru.mean_times.get(Phase::DataExchange)
                < conv.mean_times.get(Phase::DataExchange)
        );
    }

    #[test]
    fn weak_scaling_shape_matches_paper() {
        // RTF grows with M for conventional, slower for structure-aware
        let rtf = |strategy, m| run(strategy, m, 50.0).1.rtf();
        let c16 = rtf(Strategy::Conventional, 16);
        let c128 = rtf(Strategy::Conventional, 128);
        let s16 = rtf(Strategy::StructureAware, 16);
        let s128 = rtf(Strategy::StructureAware, 128);
        assert!(c128 > c16, "conv not growing: {c16} -> {c128}");
        assert!(s128 > s16 * 0.9);
        let conv_slope = c128 - c16;
        let struct_slope = s128 - s16;
        assert!(
            struct_slope < conv_slope,
            "scaling slopes {struct_slope} !< {conv_slope}"
        );
        // overall runtime reduction at M=128 in the 15-45% band
        let red = 1.0 - s128 / c128;
        assert!((0.10..0.50).contains(&red), "reduction {red}");
    }

    #[test]
    fn bytes_per_pair_matches_paper_buffer_sizes() {
        // paper reports ~317 B/pair at M=128 conventional (10 s run)
        let (_, conv) = run(Strategy::Conventional, 128, 50.0);
        assert!(
            (150.0..500.0).contains(&conv.bytes_per_pair),
            "bytes {}",
            conv.bytes_per_pair
        );
        let (_, stru) = run(Strategy::StructureAware, 128, 50.0);
        let ratio = stru.bytes_per_pair / conv.bytes_per_pair;
        assert!((8.0..12.0).contains(&ratio), "D-fold bytes ratio {ratio}");
    }

    #[test]
    fn epoch_count_follows_delay_ratio() {
        let (_, conv) = run(Strategy::Conventional, 16, 10.0);
        let (_, stru) = run(Strategy::StructureAware, 16, 10.0);
        assert_eq!(conv.epoch_maxima.len(), 100);
        assert_eq!(stru.epoch_maxima.len(), 10);
    }

    #[test]
    fn deterministic_per_seed() {
        let (_, a) = run(Strategy::Conventional, 16, 10.0);
        let (_, b) = run(Strategy::Conventional, 16, 10.0);
        assert_eq!(a.rtf(), b.rtf());
    }

    #[test]
    fn serial_correlation_present_in_cycle_times() {
        let spec = models::mam_benchmark(16, 1.0, 1.0).unwrap();
        let machine = MachineProfile::supermuc_ng();
        let w =
            Workload::derive(&spec, Strategy::Conventional, 16, 48).unwrap();
        let mut o = opts(1000.0);
        o.record_cycle_times = true;
        let res = run_cluster(&machine, &w, &o).unwrap();
        // pool over ranks: single-series estimates of a near-unit-root
        // component are noisy
        let (mut ac1, mut ac500) = (0.0, 0.0);
        for row in &res.cycle_times {
            ac1 += stats::autocorr(row, 1);
            ac500 += stats::autocorr(row, 500);
        }
        ac1 /= res.cycle_times.len() as f64;
        ac500 /= res.cycle_times.len() as f64;
        assert!(ac1 > 0.1, "lag-1 autocorr {ac1}");
        // correlation persists over hundreds of cycles (Fig 12)
        assert!(ac500 > 0.03, "lag-500 autocorr {ac500}");
    }

    #[test]
    fn grouped_extension_reduces_sync_for_unbalanced_model() {
        // MPI_Group future-work scheme (paper §3): splitting large areas
        // over several ranks regains load balance; global sync drops
        // versus the one-area-per-rank scheme at comparable resources
        let spec = models::mam(1.0, 1.0).unwrap();
        let machine = MachineProfile::supermuc_ng();
        let o = opts(50.0);
        let single =
            Workload::derive(&spec, Strategy::StructureAware, 32, 48)
                .unwrap();
        let grouped =
            Workload::derive_grouped(&spec, 64, 48).unwrap();
        let rs = run_cluster(&machine, &single, &o).unwrap();
        let rg = run_cluster(&machine, &grouped, &o).unwrap();
        use crate::util::timers::Phase;
        // per-rank compute halves (2x ranks); sync should drop MORE than
        // proportionally thanks to the regained balance
        let sync_s = rs.mean_times.get(Phase::Synchronize);
        let sync_g = rg.mean_times.get(Phase::Synchronize);
        assert!(
            sync_g < 0.75 * sync_s,
            "grouped sync {sync_g} !<< single {sync_s}"
        );
        assert!(rg.rtf() < rs.rtf(), "{} !< {}", rg.rtf(), rs.rtf());
    }

    #[test]
    fn intermediate_strategy_between_the_two() {
        let spec = models::mam(1.0, 1.0).unwrap();
        let machine = MachineProfile::supermuc_ng();
        let o = opts(50.0);
        let rtf = |strategy| {
            let w =
                Workload::derive(&spec, strategy, 32, machine.t_m).unwrap();
            run_cluster(&machine, &w, &o).unwrap()
        };
        let conv = rtf(Strategy::Conventional);
        let inter = rtf(Strategy::Intermediate);
        let stru = rtf(Strategy::StructureAware);
        use crate::util::timers::Phase;
        // intermediate: better delivery than conventional...
        assert!(
            inter.mean_times.get(Phase::Deliver)
                < conv.mean_times.get(Phase::Deliver)
        );
        // ...but worse synchronization (imbalance, same comm frequency)
        assert!(
            inter.mean_times.get(Phase::Synchronize)
                > conv.mean_times.get(Phase::Synchronize)
        );
        // fully structure-aware wins back sync time vs intermediate
        assert!(
            stru.mean_times.get(Phase::Synchronize)
                < inter.mean_times.get(Phase::Synchronize)
        );
    }
}
