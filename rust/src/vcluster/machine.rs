//! Machine profiles: per-operation cost parameters, the `MPI_Alltoall`
//! cost curve and the cycle-time noise process.
//!
//! Parameters are calibrated against the paper's own measurements (see
//! EXPERIMENTS.md §Calibration): e.g. on the SuperMUC-NG profile the
//! MAM-benchmark at M=128 must produce a conventional cycle-time
//! distribution with major mode ≈ 1.6 ms, CV ≈ 0.056 and an Alltoall
//! data-exchange reduction of ≈ 76 % at D=10.

/// `MPI_Alltoall` wall-time model: latency table over process counts
/// (piecewise-linear in log2 M, capturing the algorithm-switch jumps of
/// Fig 4) plus a bandwidth term over total bytes sent per rank.
#[derive(Clone, Debug)]
pub struct AlltoallModel {
    /// `(m, seconds)` latency anchor points, ascending in `m`.
    pub lat_points: Vec<(usize, f64)>,
    /// Effective per-rank injection bandwidth [bytes/s].
    pub bw_bytes_per_sec: f64,
}

impl AlltoallModel {
    /// Latency for `m` ranks (log-linear interpolation between anchors,
    /// clamped at the ends).
    pub fn latency(&self, m: usize) -> f64 {
        let pts = &self.lat_points;
        assert!(!pts.is_empty());
        if m <= pts[0].0 {
            return pts[0].1;
        }
        if m >= pts[pts.len() - 1].0 {
            return pts[pts.len() - 1].1;
        }
        for w in pts.windows(2) {
            let (m0, t0) = w[0];
            let (m1, t1) = w[1];
            if m >= m0 && m <= m1 {
                let x = ((m as f64).log2() - (m0 as f64).log2())
                    / ((m1 as f64).log2() - (m0 as f64).log2());
                return t0 + x * (t1 - t0);
            }
        }
        unreachable!()
    }

    /// Wall time of one collective with `bytes_per_pair` bytes to each of
    /// the other `m-1` ranks.
    pub fn time(&self, m: usize, bytes_per_pair: f64) -> f64 {
        let total = bytes_per_pair * (m.saturating_sub(1)) as f64;
        self.latency(m) + total / self.bw_bytes_per_sec
    }
}

/// Cycle-time noise: two-component relative noise (fast iid + slowly
/// drifting AR(1) — the serial correlations of Fig 12) plus a minor mode
/// and rare extremes (the bimodal shape and heavy tail of Fig 7b).
#[derive(Clone, Copy, Debug)]
pub struct NoiseModel {
    /// Absolute (cycle-length independent) iid jitter std [s] — OS noise,
    /// interrupts; dominates when strong scaling shrinks the cycle.
    pub sigma_abs_s: f64,
    /// Std of the fast iid component, relative to the base cycle time.
    pub sigma_fast: f64,
    /// Std of the slow AR(1) component (stationary), relative.
    pub sigma_slow: f64,
    /// AR(1) coefficient of the slow component (per cycle).
    pub phi_slow: f64,
    /// Probability of a minor-mode cycle.
    pub minor_prob: f64,
    /// Relative bump of a minor-mode cycle (e.g. 0.17 ≈ the 1.9 ms vs
    /// 1.62 ms modes of Fig 7b).
    pub minor_scale: f64,
    /// Probability of an extreme cycle.
    pub extreme_prob: f64,
    /// Max relative scale of extremes (uniformly 1..max multiples).
    pub extreme_scale_max: f64,
}

/// Full machine profile.
#[derive(Clone, Debug)]
pub struct MachineProfile {
    pub name: &'static str,
    /// Hardware threads per node used by one MPI rank.
    pub t_m: usize,
    /// Update cost per LIF neuron per step [s] (state propagation).
    pub c_update_lif: f64,
    /// Update cost per ignore-and-fire neuron per step [s].
    pub c_update_ianf: f64,
    /// Extra update cost per emitted spike (threshold handling, register
    /// write) [s].
    pub c_spike_emit: f64,
    /// Streaming cost per delivered synapse [s].
    pub c_syn: f64,
    /// Penalty per irregular (first-synapse) access [s].
    pub c_miss: f64,
    /// Collocation cost per (spike, target rank) entry [s].
    pub c_collocate: f64,
    /// Per-cycle cost of the structure-aware local buffer swap [s].
    pub c_local_swap: f64,
    /// Fraction of a rank's relative load excess that shows up as
    /// cycle-time excess; the rest is absorbed by idle per-node capacity.
    /// Calibrated against §2.4.3: V2's ≈ +68 % spike load appears as a
    /// +24 % cycle time on SuperMUC-NG but only +7 % on JURECA-DC.
    pub imbalance_gain: f64,
    pub alltoall: AlltoallModel,
    pub noise: NoiseModel,
}

impl MachineProfile {
    /// SuperMUC-NG: 48 cores/node, Skylake, OmniPath.
    pub fn supermuc_ng() -> MachineProfile {
        MachineProfile {
            name: "SuperMUC-NG",
            t_m: 48,
            // calibrated so the MAM-benchmark at M=128 shows a ~1.4-1.6 ms
            // conventional cycle time with delivery dominant (Figs 7b/11)
            c_update_lif: 4.2e-9,
            c_update_ianf: 1.5e-9,
            c_spike_emit: 1.5e-7,
            // delivery dominated by irregular access (Pronold et al.):
            // streaming a synapse is cheap, the first touch is not
            c_syn: 1.2e-9,
            c_miss: 9.0e-9,
            c_collocate: 2.0e-9,
            c_local_swap: 2.0e-6,
            imbalance_gain: 0.45,
            alltoall: AlltoallModel {
                // Fig 4 shape: jumps between 32->64 and 64->128 reflect
                // OpenMPI algorithm switches
                lat_points: vec![
                    (2, 6e-6),
                    (16, 2.2e-5),
                    (32, 4.0e-5),
                    (64, 9.0e-5),
                    (128, 1.55e-4),
                ],
                bw_bytes_per_sec: 1.4e9,
            },
            // calibration (EXPERIMENTS.md): total CV ~0.06-0.08, lumped
            // CV ratio at D=10 ~0.70 (paper: 0.056 / 0.71) — the slow
            // AR(1) share controls how far lumping can reduce dispersion
            noise: NoiseModel {
                sigma_abs_s: 8.0e-5,
                sigma_fast: 0.035,
                sigma_slow: 0.065,
                phi_slow: 0.9995,
                minor_prob: 0.05,
                minor_scale: 0.17,
                extreme_prob: 2.0e-5,
                extreme_scale_max: 8.0,
            },
        }
    }

    /// JURECA-DC: 128 cores/node, EPYC Rome, InfiniBand HDR100; faster
    /// per-node compute, less sensitive to load imbalance.
    pub fn jureca_dc() -> MachineProfile {
        MachineProfile {
            name: "JURECA-DC",
            t_m: 128,
            c_update_lif: 1.6e-9,
            c_update_ianf: 1.0e-9,
            c_spike_emit: 1.0e-7,
            c_syn: 0.9e-9,
            c_miss: 5.5e-9,
            // collocation runs on the master thread, does not profit from
            // the extra cores (§2.4.3) — keep comparable to SuperMUC-NG
            c_collocate: 1.9e-9,
            c_local_swap: 1.5e-6,
            imbalance_gain: 0.16,
            alltoall: AlltoallModel {
                lat_points: vec![
                    (2, 5e-6),
                    (16, 1.8e-5),
                    (32, 3.2e-5),
                    (64, 7.0e-5),
                    (128, 1.2e-4),
                ],
                bw_bytes_per_sec: 2.2e9,
            },
            noise: NoiseModel {
                sigma_abs_s: 5.0e-5,
                sigma_fast: 0.030,
                sigma_slow: 0.055,
                phi_slow: 0.9995,
                minor_prob: 0.04,
                minor_scale: 0.15,
                extreme_prob: 1.5e-5,
                extreme_scale_max: 7.0,
            },
        }
    }

    pub fn by_name(name: &str) -> anyhow::Result<MachineProfile> {
        match name {
            "supermuc" | "supermuc-ng" | "SuperMUC-NG" => {
                Ok(Self::supermuc_ng())
            }
            "jureca" | "jureca-dc" | "JURECA-DC" => Ok(Self::jureca_dc()),
            other => anyhow::bail!("unknown machine profile {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_interpolates_and_clamps() {
        let a = MachineProfile::supermuc_ng().alltoall;
        assert_eq!(a.latency(2), 6e-6);
        assert_eq!(a.latency(1), 6e-6);
        assert_eq!(a.latency(128), 1.55e-4);
        assert_eq!(a.latency(4096), 1.55e-4);
        let l48 = a.latency(48);
        assert!(l48 > a.latency(32) && l48 < a.latency(64));
    }

    #[test]
    fn alltoall_time_sublinear_in_message_size() {
        // sending D x the data in one call is far cheaper than D calls
        let a = MachineProfile::supermuc_ng().alltoall;
        let one = a.time(128, 317.0);
        let ten = a.time(128, 3170.0);
        assert!(ten < 10.0 * one);
        // paper: ~86% predicted reduction in data-exchange time at D=10
        let reduction = 1.0 - (ten / 10.0) / one;
        assert!(
            (0.70..0.92).contains(&reduction),
            "reduction {reduction}"
        );
    }

    #[test]
    fn fig4_jumps_present() {
        let a = MachineProfile::supermuc_ng().alltoall;
        // jump from 32 to 64 ranks should be super-log (algorithm switch)
        let r1 = a.latency(32) / a.latency(16);
        let r2 = a.latency(64) / a.latency(32);
        assert!(r2 > r1, "no jump: {r1} vs {r2}");
    }

    #[test]
    fn profiles_by_name() {
        assert_eq!(MachineProfile::by_name("jureca").unwrap().t_m, 128);
        assert_eq!(MachineProfile::by_name("supermuc").unwrap().t_m, 48);
        assert!(MachineProfile::by_name("cray").is_err());
    }

    #[test]
    fn jureca_faster_but_less_imbalance_sensitive() {
        let s = MachineProfile::supermuc_ng();
        let j = MachineProfile::jureca_dc();
        assert!(j.c_update_lif < s.c_update_lif);
        assert!(j.imbalance_gain < s.imbalance_gain);
    }
}
