//! Virtual cluster: a discrete-event performance model of `M` ranks ×
//! `T_M` threads running the simulation cycle under barrier semantics.
//!
//! This is the hardware substitution for SuperMUC-NG / JURECA-DC
//! (DESIGN.md §2): per-rank cycle times are generated from calibrated
//! per-phase cost models (update, delivery with the §2.3 cache-locality
//! model, collocation) modulated by a noise process with the empirically
//! observed structure — bimodal with rare extremes and serially
//! correlated (paper Fig 7b/12).  Synchronization and wall-clock then
//! *emerge* from max-over-ranks accounting per communication epoch, and
//! data-exchange time from an `MPI_Alltoall` cost curve with the Fig 4
//! shape.  Nothing about the conventional-vs-structure-aware comparison
//! is hard-coded; the strategies differ only in placement-derived loads,
//! barrier frequency and message aggregation, as in the paper.

pub mod machine;
pub mod workload;
pub mod run;

pub use machine::{AlltoallModel, MachineProfile, NoiseModel};
pub use run::{run_cluster, VcOptions, VcResult};
pub use workload::{RankLoad, Workload};
