//! Artifact registry: parse `artifacts/manifest.json`, lazily compile the
//! executables the run needs, and pick the right batch size (smallest
//! artifact batch that fits, with zero-padding handled by the updater).
//!
//! Manifest parsing and batch selection are always available; compiling
//! ([`Registry::executable`]) needs the `xla` feature.

#[cfg(feature = "xla")]
use super::Executable;
use crate::util::json::{self, Json};
use anyhow::{bail, Context, Result};
#[cfg(feature = "xla")]
use std::cell::RefCell;
#[cfg(feature = "xla")]
use std::collections::HashMap;
#[cfg(feature = "xla")]
use std::rc::Rc;

/// One manifest entry.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub batch: usize,
    pub steps: Option<usize>,
}

/// Registry over an artifact directory.  Owns the PJRT client, so it is
/// confined to the thread that created it (the XLA service thread).
pub struct Registry {
    dir: String,
    metas: Vec<ArtifactMeta>,
    #[cfg(feature = "xla")]
    client: RefCell<Option<xla::PjRtClient>>,
    #[cfg(feature = "xla")]
    compiled: RefCell<HashMap<String, Rc<Executable>>>,
}

/// Default artifact directory: `$NSIM_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> String {
    std::env::var("NSIM_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

impl Registry {
    pub fn open(dir: &str) -> Result<Registry> {
        let path = format!("{dir}/manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path} (run `make artifacts`)"))?;
        let v = json::parse(&text).context("parsing manifest.json")?;
        let arts = v
            .get("artifacts")
            .and_then(Json::as_arr)
            .context("manifest missing 'artifacts'")?;
        let mut metas = Vec::new();
        for a in arts {
            metas.push(ArtifactMeta {
                name: a
                    .get("name")
                    .and_then(Json::as_str)
                    .context("artifact missing name")?
                    .to_string(),
                file: a
                    .get("file")
                    .and_then(Json::as_str)
                    .context("artifact missing file")?
                    .to_string(),
                kind: a
                    .get("kind")
                    .and_then(Json::as_str)
                    .context("artifact missing kind")?
                    .to_string(),
                batch: a
                    .get("batch")
                    .and_then(Json::as_usize)
                    .context("artifact missing batch")?,
                steps: a.get("steps").and_then(Json::as_usize),
            });
        }
        Ok(Registry {
            dir: dir.to_string(),
            metas,
            #[cfg(feature = "xla")]
            client: RefCell::new(None),
            #[cfg(feature = "xla")]
            compiled: RefCell::new(HashMap::new()),
        })
    }

    pub fn open_default() -> Result<Registry> {
        Self::open(&default_dir())
    }

    pub fn metas(&self) -> &[ArtifactMeta] {
        &self.metas
    }

    /// Smallest artifact of `kind` whose batch is >= `n` (or the largest
    /// available if none fits — callers then chunk).
    pub fn pick(&self, kind: &str, n: usize) -> Result<&ArtifactMeta> {
        let mut candidates: Vec<&ArtifactMeta> =
            self.metas.iter().filter(|m| m.kind == kind).collect();
        if candidates.is_empty() {
            bail!("no artifact of kind {kind:?} in {}", self.dir);
        }
        candidates.sort_by_key(|m| m.batch);
        Ok(candidates
            .iter()
            .find(|m| m.batch >= n)
            .copied()
            .unwrap_or_else(|| candidates.last().unwrap()))
    }

    /// Compile (or fetch the cached) executable for a manifest entry.
    /// Creates the PJRT CPU client lazily on first use.
    #[cfg(feature = "xla")]
    pub fn executable(&self, meta: &ArtifactMeta) -> Result<Rc<Executable>> {
        if let Some(e) = self.compiled.borrow().get(&meta.name) {
            return Ok(e.clone());
        }
        if self.client.borrow().is_none() {
            *self.client.borrow_mut() = Some(
                xla::PjRtClient::cpu().context("creating PJRT CPU client")?,
            );
        }
        let client_ref = self.client.borrow();
        let client = client_ref.as_ref().unwrap();
        let exe = Rc::new(Executable::load(
            client, &self.dir, &meta.file, &meta.name, meta.batch,
        )?);
        self.compiled
            .borrow_mut()
            .insert(meta.name.clone(), exe.clone());
        Ok(exe)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<String> {
        let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
        if std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
            Some(dir)
        } else {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            None
        }
    }

    #[test]
    fn manifest_parses_and_lists_kinds() {
        let Some(dir) = artifacts_dir() else { return };
        let reg = Registry::open(&dir).unwrap();
        let kinds: std::collections::HashSet<_> =
            reg.metas().iter().map(|m| m.kind.as_str()).collect();
        assert!(kinds.contains("lif_step"));
        assert!(kinds.contains("ianf_step"));
        assert!(kinds.contains("lif_multistep"));
    }

    #[test]
    fn pick_smallest_fitting_batch() {
        let Some(dir) = artifacts_dir() else { return };
        let reg = Registry::open(&dir).unwrap();
        assert_eq!(reg.pick("lif_step", 100).unwrap().batch, 512);
        assert_eq!(reg.pick("lif_step", 513).unwrap().batch, 2048);
        assert_eq!(reg.pick("lif_step", 3000).unwrap().batch, 8192);
        // oversize request falls back to the largest
        assert_eq!(reg.pick("lif_step", 100_000).unwrap().batch, 8192);
        assert!(reg.pick("nonexistent", 1).is_err());
    }

    #[test]
    fn missing_dir_errors_helpfully() {
        match Registry::open("/nonexistent-dir") {
            Ok(_) => panic!("expected error for missing dir"),
            Err(err) => {
                assert!(err.to_string().contains("make artifacts"))
            }
        }
    }
}
