//! PJRT runtime: load the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them from the Rust coordinator.
//!
//! Interchange is HLO *text* — the image's xla_extension 0.5.1 rejects
//! jax ≥ 0.5 serialized protos (64-bit instruction ids), while the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! `xla::PjRtClient` is `Rc`-based and thus confined to one thread; the
//! multi-rank engine therefore talks to a dedicated *XLA service thread*
//! ([`updater::xla_updater`]) that owns the client and executables and
//! serves update-step requests over channels.  The XLA path demonstrates
//! the three-layer composition; the performance path is the native
//! updater.

pub mod registry;
pub mod updater;

#[cfg(feature = "xla")]
use anyhow::{Context, Result};

/// A compiled artifact plus its manifest metadata.  Not `Send`: lives on
/// the thread that created its client.  Only available with the `xla`
/// feature (see `Cargo.toml`); without it the registry still parses
/// manifests but cannot compile.
#[cfg(feature = "xla")]
pub struct Executable {
    pub name: String,
    pub batch: usize,
    exe: xla::PjRtLoadedExecutable,
}

#[cfg(feature = "xla")]
impl Executable {
    /// Load `<dir>/<file>` (HLO text) and compile it on `client`.
    pub fn load(
        client: &xla::PjRtClient,
        dir: &str,
        file: &str,
        name: &str,
        batch: usize,
    ) -> Result<Executable> {
        let path = format!("{dir}/{file}");
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {path}"))?;
        Ok(Executable { name: name.to_string(), batch, exe })
    }

    /// Execute with f32 vector inputs; returns the flattened tuple of f32
    /// vector outputs.
    pub fn run_f32(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|x| xla::Literal::vec1(x)).collect();
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        let parts = result.to_tuple()?;
        parts
            .into_iter()
            .map(|lit| lit.to_vec::<f32>().map_err(Into::into))
            .collect()
    }

    /// Execute with a 2-D f32 input at position `pos2d` of shape
    /// `[k, batch]` (row-major, passed flattened); all other inputs 1-D.
    pub fn run_f32_with_2d(
        &self,
        inputs: &[&[f32]],
        pos2d: usize,
        k: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let mut literals: Vec<xla::Literal> =
            Vec::with_capacity(inputs.len());
        for (i, x) in inputs.iter().enumerate() {
            let lit = xla::Literal::vec1(x);
            if i == pos2d {
                literals
                    .push(lit.reshape(&[k as i64, (x.len() / k) as i64])?);
            } else {
                literals.push(lit);
            }
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        let parts = result.to_tuple()?;
        parts
            .into_iter()
            .map(|lit| lit.to_vec::<f32>().map_err(Into::into))
            .collect()
    }
}
