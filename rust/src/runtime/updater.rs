//! The XLA update path: an [`Updater`] that advances neuron blocks through
//! the AOT-compiled Pallas kernels instead of native arithmetic.
//!
//! Because `xla::PjRtClient` is `Rc`-based (single-threaded), a dedicated
//! *service thread* owns the client, registry and executables; the rank
//! threads' update closures send step requests over an mpsc channel and
//! block on the reply.  Executions are thereby serialized — acceptable
//! for the composition-proof path (the performance path is
//! [`Updater::Native`]).
//!
//! Blocks are zero-padded to the artifact batch size; padded LIF lanes
//! are parked refractory (cannot spike), padded ignore-and-fire lanes get
//! an unreachable interval.  Oversized blocks are chunked.

#[cfg(feature = "xla")]
pub use pjrt::xla_updater;

/// Built without the `xla` feature: the three-layer composition path is
/// unavailable, surface a descriptive error instead of failing to link.
#[cfg(not(feature = "xla"))]
pub fn xla_updater(
    _spec: &crate::network::ModelSpec,
) -> anyhow::Result<crate::engine::update::Updater> {
    anyhow::bail!(
        "the XLA update path requires building with `--features xla` \
         (and the image-baked xla_extension crate); use \
         `--update-path native` instead"
    )
}

#[cfg(feature = "xla")]
mod pjrt {

use crate::engine::neuron::{LifScalars, NeuronBlock};
use crate::engine::update::Updater;
use crate::network::spec::NeuronKind;
use crate::network::ModelSpec;
use crate::runtime::registry::Registry;
use crate::runtime::Executable;
use anyhow::{Context, Result};
use std::rc::Rc;
use std::sync::mpsc;
use std::sync::Mutex;

const PARAM_LEN: usize = 8;

type StepReply = Result<Vec<Vec<f32>>>;

enum Request {
    Lif {
        scalars: LifScalars,
        v: Vec<f32>,
        refr: Vec<f32>,
        /// syn + per-neuron drive, pre-summed by the caller.
        input: Vec<f32>,
        reply: mpsc::Sender<StepReply>,
    },
    Ianf {
        phase: Vec<f32>,
        interval: Vec<f32>,
        syn: Vec<f32>,
        reply: mpsc::Sender<StepReply>,
    },
}

fn pad_to(xs: &[f32], len: usize, fill: f32) -> Vec<f32> {
    let mut out = vec![fill; len];
    out[..xs.len()].copy_from_slice(xs);
    out
}

fn serve_lif(
    exe: &Rc<Executable>,
    scalars: &LifScalars,
    v: &[f32],
    refr: &[f32],
    input: &[f32],
) -> StepReply {
    let batch = exe.batch;
    let n = v.len();
    let params: Vec<f32> = {
        let mut p = vec![0.0f32; PARAM_LEN];
        p[0] = scalars.p22;
        // p[1] (drive) stays 0: folded into `input` by the caller
        p[2] = scalars.theta;
        p[3] = scalars.v_reset;
        p[4] = scalars.ref_steps;
        p
    };
    let mut v_out = Vec::with_capacity(n);
    let mut r_out = Vec::with_capacity(n);
    let mut s_out = Vec::with_capacity(n);
    let mut off = 0usize;
    while off < n {
        let chunk = (n - off).min(batch);
        // padded lanes: refractory -> never spike
        let vb = pad_to(&v[off..off + chunk], batch, 0.0);
        let rb = pad_to(&refr[off..off + chunk], batch, 1.0);
        let ib = pad_to(&input[off..off + chunk], batch, 0.0);
        let out = exe.run_f32(&[&params, &vb, &rb, &ib])?;
        anyhow::ensure!(out.len() == 3, "lif_step must return 3 outputs");
        v_out.extend_from_slice(&out[0][..chunk]);
        r_out.extend_from_slice(&out[1][..chunk]);
        s_out.extend_from_slice(&out[2][..chunk]);
        off += chunk;
    }
    Ok(vec![v_out, r_out, s_out])
}

fn serve_ianf(
    exe: &Rc<Executable>,
    phase: &[f32],
    interval: &[f32],
    syn: &[f32],
) -> StepReply {
    let batch = exe.batch;
    let n = phase.len();
    let mut p_out = Vec::with_capacity(n);
    let mut s_out = Vec::with_capacity(n);
    let mut off = 0usize;
    while off < n {
        let chunk = (n - off).min(batch);
        let pb = pad_to(&phase[off..off + chunk], batch, 0.0);
        // padded lanes never reach their interval
        let ivb = pad_to(&interval[off..off + chunk], batch, f32::MAX);
        let sb = pad_to(&syn[off..off + chunk], batch, 0.0);
        let out = exe.run_f32(&[&pb, &ivb, &sb])?;
        anyhow::ensure!(out.len() == 2, "ianf_step must return 2 outputs");
        p_out.extend_from_slice(&out[0][..chunk]);
        s_out.extend_from_slice(&out[1][..chunk]);
        off += chunk;
    }
    Ok(vec![p_out, s_out])
}

fn service_main(
    rx: mpsc::Receiver<Request>,
    ready: mpsc::Sender<Result<()>>,
    needs_lif: bool,
    needs_ianf: bool,
) {
    // compile everything up front so errors surface at updater creation
    let setup = (|| -> Result<(Option<Rc<Executable>>, Option<Rc<Executable>>)> {
        let reg = Registry::open_default()?;
        let lif = if needs_lif {
            Some(reg.executable(reg.pick("lif_step", 512)?)?)
        } else {
            None
        };
        let ianf = if needs_ianf {
            Some(reg.executable(reg.pick("ianf_step", 512)?)?)
        } else {
            None
        };
        Ok((lif, ianf))
    })();
    let (lif, ianf) = match setup {
        Ok(pair) => {
            let _ = ready.send(Ok(()));
            pair
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    while let Ok(req) = rx.recv() {
        match req {
            Request::Lif { scalars, v, refr, input, reply } => {
                let exe = lif.as_ref().expect("LIF artifact not loaded");
                let _ = reply.send(serve_lif(exe, &scalars, &v, &refr, &input));
            }
            Request::Ianf { phase, interval, syn, reply } => {
                let exe = ianf.as_ref().expect("ianf artifact not loaded");
                let _ = reply.send(serve_ianf(exe, &phase, &interval, &syn));
            }
        }
    }
}

/// Build the XLA [`Updater`] for `spec`: spawns the service thread,
/// compiles the needed artifacts, and returns a thread-safe step closure.
pub fn xla_updater(spec: &ModelSpec) -> Result<Updater> {
    let needs_lif = spec
        .areas
        .iter()
        .any(|a| matches!(a.neuron, NeuronKind::Lif(_)));
    let needs_ianf = spec
        .areas
        .iter()
        .any(|a| matches!(a.neuron, NeuronKind::IgnoreAndFire { .. }));

    let (tx, rx) = mpsc::channel::<Request>();
    let (ready_tx, ready_rx) = mpsc::channel();
    std::thread::Builder::new()
        .name("xla-service".into())
        .spawn(move || service_main(rx, ready_tx, needs_lif, needs_ianf))
        .context("spawning XLA service thread")?;
    ready_rx
        .recv()
        .context("XLA service thread died during setup")??;

    // mpsc::Sender is Send but not Sync; guard it for the Fn closure
    let tx = Mutex::new(tx);
    Ok(Updater::Custom(Box::new(move |block, syn, spikes_out| {
        let (reply_tx, reply_rx) = mpsc::channel();
        let req = match block {
            NeuronBlock::Lif { scalars, drive, v, refr } => {
                if v.is_empty() {
                    return;
                }
                let input: Vec<f32> = syn
                    .iter()
                    .zip(drive.iter())
                    .map(|(s, d)| s + d)
                    .collect();
                Request::Lif {
                    scalars: *scalars,
                    v: v.clone(),
                    refr: refr.clone(),
                    input,
                    reply: reply_tx,
                }
            }
            NeuronBlock::IgnoreAndFire { phase, interval } => {
                if phase.is_empty() {
                    return;
                }
                Request::Ianf {
                    phase: phase.clone(),
                    interval: interval.clone(),
                    syn: syn.to_vec(),
                    reply: reply_tx,
                }
            }
        };
        tx.lock().unwrap().send(req).expect("XLA service gone");
        let out = reply_rx
            .recv()
            .expect("XLA service dropped reply")
            .expect("XLA update step failed");
        match block {
            NeuronBlock::Lif { v, refr, .. } => {
                v.copy_from_slice(&out[0]);
                refr.copy_from_slice(&out[1]);
                for (i, &s) in out[2].iter().enumerate() {
                    if s != 0.0 {
                        spikes_out.push(i as u32);
                    }
                }
            }
            NeuronBlock::IgnoreAndFire { phase, .. } => {
                phase.copy_from_slice(&out[0]);
                for (i, &s) in out[1].iter().enumerate() {
                    if s != 0.0 {
                        spikes_out.push(i as u32);
                    }
                }
            }
        }
    })))
}

} // mod pjrt
