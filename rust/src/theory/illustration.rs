//! Fig 5: the graphical intuition — identical synthetic per-cycle phase
//! timings evaluated under per-cycle barriers (conventional) vs one
//! barrier per D cycles (structure-aware).

use crate::util::rng::Pcg64;
use crate::util::stats::lump_sums;

/// Synthetic timing data for one illustration: `phase_times[rank][cycle]`
/// = (deliver, update, collocate) seconds.
pub struct Illustration {
    pub m: usize,
    pub s: usize,
    pub d: usize,
    pub cycle_times: Vec<Vec<f64>>,
}

/// Build the Fig 5 setting: S cycles on M ranks, mildly noisy phase times.
pub fn generate(m: usize, s: usize, d: usize, seed: u64) -> Illustration {
    let mut rng = Pcg64::seed_from_u64(seed);
    let cycle_times = (0..m)
        .map(|_| {
            (0..s)
                .map(|_| {
                    let deliver = rng.normal_ms(0.55e-3, 0.06e-3).max(0.0);
                    let update = rng.normal_ms(0.85e-3, 0.08e-3).max(0.0);
                    let collocate = rng.normal_ms(0.20e-3, 0.02e-3).max(0.0);
                    deliver + update + collocate
                })
                .collect()
        })
        .collect();
    Illustration { m, s, d, cycle_times }
}

/// Wall time and total synchronization time under per-`chunk` barriers.
pub fn wall_and_sync(times: &[Vec<f64>], chunk: usize) -> (f64, f64) {
    let lumped: Vec<Vec<f64>> =
        times.iter().map(|r| lump_sums(r, chunk)).collect();
    let epochs = lumped[0].len();
    let m = lumped.len() as f64;
    let mut wall = 0.0;
    let mut sync = 0.0;
    for e in 0..epochs {
        let col: Vec<f64> = lumped.iter().map(|r| r[e]).collect();
        let max = col.iter().cloned().fold(f64::MIN, f64::max);
        let mean = col.iter().sum::<f64>() / m;
        wall += max;
        sync += max - mean;
    }
    (wall, sync)
}

impl Illustration {
    /// (conventional wall, struct wall, conventional sync, struct sync).
    pub fn evaluate(&self) -> (f64, f64, f64, f64) {
        let (wall_c, sync_c) = wall_and_sync(&self.cycle_times, 1);
        let (wall_s, sync_s) = wall_and_sync(&self.cycle_times, self.d);
        (wall_c, wall_s, sync_c, sync_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_setting_shows_sync_reduction() {
        // the paper's illustration: S=10 cycles, M=32 ranks, D=10
        let ill = generate(32, 10, 10, 7);
        let (wall_c, wall_s, sync_c, sync_s) = ill.evaluate();
        assert!(wall_s < wall_c, "wall {wall_s} !< {wall_c}");
        assert!(sync_s < sync_c, "sync {sync_s} !< {sync_c}");
        // same computation, so walls differ exactly by the sync saving
        assert!(((wall_c - wall_s) - (sync_c - sync_s)).abs() < 1e-12);
    }

    #[test]
    fn long_run_ratio_near_theory() {
        let ill = generate(32, 20_000, 10, 11);
        let (_, _, sync_c, sync_s) = ill.evaluate();
        let ratio = sync_s / sync_c;
        assert!(
            (ratio - 1.0 / 10f64.sqrt()).abs() < 0.05,
            "ratio {ratio}"
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(8, 100, 5, 3).evaluate();
        let b = generate(8, 100, 5, 3).evaluate();
        assert_eq!(a, b);
    }
}
