//! Theoretical model of synchronization time (paper §2.2).
//!
//! Cycle times are `t ~ N(mu, sigma²)` iid across M ranks and S cycles.
//! With a barrier after every cycle the expected wall time is
//! `S (mu + xi_M sigma)` (eq 8); lumping D cycles between barriers gives
//! `S mu + S xi_M sigma / sqrt(D)` (eq 9), so expected synchronization
//! time shrinks by `1/sqrt(D)` (eq 11).

use crate::util::stats::{blom_xi, lump_sums, norm_cdf, p_max_in_tail};

/// Parameters of the normal cycle-time model (eq 2).
#[derive(Clone, Copy, Debug)]
pub struct CycleTimeModel {
    pub mu: f64,
    pub sigma: f64,
}

impl CycleTimeModel {
    /// The paper's measured MAM-benchmark cycle-time distribution
    /// (mu = 1.6 ms, sigma = 0.09 ms) — the default parameterization
    /// shared by `nsim theory` and the fig 6 harness.
    pub const fn paper_default() -> CycleTimeModel {
        CycleTimeModel { mu: 1.6e-3, sigma: 0.09e-3 }
    }

    /// Fit the model from measured interval moments (e.g. the pooled
    /// per-rank compute-interval statistics collected by `obs`).
    /// Returns `None` when there is nothing to fit (`n == 0` or a
    /// non-positive mean, which the normal model cannot represent).
    pub fn from_measured(
        n: u64,
        mean: f64,
        std_dev: f64,
    ) -> Option<CycleTimeModel> {
        (n > 0 && mean > 0.0 && std_dev >= 0.0)
            .then_some(CycleTimeModel { mu: mean, sigma: std_dev })
    }

    /// Lumped model over D cycles (eq 6): `N(D mu, D sigma²)`.
    pub fn lumped(&self, d: u32) -> CycleTimeModel {
        CycleTimeModel {
            mu: d as f64 * self.mu,
            sigma: (d as f64).sqrt() * self.sigma,
        }
    }

    /// Coefficient of variation.
    pub fn cv(&self) -> f64 {
        self.sigma / self.mu
    }

    /// Expected maximum over `m` ranks: `mu + xi_M sigma`.
    pub fn expected_max(&self, m: usize) -> f64 {
        self.mu + blom_xi(m) * self.sigma
    }
}

/// Expected wall-clock of `s` cycles under the conventional strategy
/// (eq 8), disregarding data exchange.
pub fn expected_wall_conventional(
    model: CycleTimeModel,
    m: usize,
    s: u64,
) -> f64 {
    s as f64 * model.expected_max(m)
}

/// Expected wall-clock under the structure-aware strategy with delay
/// ratio `d` (eq 9).
pub fn expected_wall_structure(
    model: CycleTimeModel,
    m: usize,
    s: u64,
    d: u32,
) -> f64 {
    let lum = model.lumped(d);
    (s as f64 / d as f64) * lum.expected_max(m)
}

/// Expected total synchronization time (the `S xi_M sigma` terms of
/// eqs 8/9) for each strategy.
pub fn expected_sync_times(
    model: CycleTimeModel,
    m: usize,
    s: u64,
    d: u32,
) -> (f64, f64) {
    let xi = blom_xi(m);
    let conv = s as f64 * xi * model.sigma;
    let struc = s as f64 * xi * model.sigma / (d as f64).sqrt();
    (conv, struc)
}

/// The headline ratio of expected synchronization times (eq 11).
pub fn sync_ratio(d: u32) -> f64 {
    1.0 / (d as f64).sqrt()
}

/// Predicted synchronization time hidden by the split-phase exchange
/// over a whole run of `s` cycles (`CommMode::Overlap`).
///
/// Per epoch of `d` lumped cycles the expected skew at the boundary is
/// `xi_M sigma sqrt(D)` (the sync term of eq 9).  A split-phase post
/// lets a rank compute up to `overlap_cycles` further cycles — bounded
/// by its realized inter-area delay slack, and by `d - 1` since the
/// next boundary forces completion at pipeline depth 1 — before it must
/// rendezvous, so up to `min(skew, overlap_cycles * mu)` of each
/// epoch's skew moves off the critical path.
pub fn predicted_overlap_gain(
    model: CycleTimeModel,
    m: usize,
    s: u64,
    d: u32,
    overlap_cycles: u32,
) -> f64 {
    predicted_depth_gain(model, m, s, d, 1, overlap_cycles)
}

/// [`predicted_overlap_gain`] generalized to a depth-`depth` exchange
/// pipeline (`--comm-depth`): with `depth` rounds in flight, completion
/// of an exchange is only forced at its `depth`-th following boundary,
/// so the compute window grows to `min(overlap_cycles, depth·d − 1)`
/// cycles.  This is what makes conventional runs (`d = 1`) profit from
/// the split phase at all — at depth 1 their window is zero, at depth
/// `n` it is `n − 1` cycles of the realized delay slack.
pub fn predicted_depth_gain(
    model: CycleTimeModel,
    m: usize,
    s: u64,
    d: u32,
    depth: u32,
    overlap_cycles: u32,
) -> f64 {
    let epochs = s as f64 / d as f64;
    let skew_per_epoch = blom_xi(m) * (d as f64).sqrt() * model.sigma;
    let window_cycles =
        overlap_cycles.min((depth * d).saturating_sub(1)) as f64;
    epochs * skew_per_epoch.min(window_cycles * model.mu)
}

/// Expected synchronization time of the **hybrid two-tier schedule**,
/// split by tier, over `s` cycles: areas span groups of `ranks_per_area`
/// ranks; within each epoch of `d` lumped cycles the local tier
/// rendezvous `local_rounds` times among the group's `ranks_per_area`
/// ranks (the intra-group alltoall of the short-range pathway — a
/// per-round expected skew of `xi_r · sigma`), and the global tier
/// barriers once across the `m / ranks_per_area` groups (skew
/// `xi_G · sqrt(d) · sigma`).  Returns `(local, global)` totals.
///
/// With `ranks_per_area = 1` the local tier costs nothing (`xi_1 = 0`,
/// the intra-rank swap has no synchronization) and the global term
/// reduces exactly to the flat model of [`expected_sync_times`].
pub fn expected_hybrid_sync_times(
    model: CycleTimeModel,
    m: usize,
    ranks_per_area: usize,
    s: u64,
    d: u32,
    local_rounds: u32,
) -> (f64, f64) {
    assert!(ranks_per_area >= 1 && m >= ranks_per_area);
    assert!(
        m % ranks_per_area == 0,
        "ranks must tile into equal area groups"
    );
    let epochs = s as f64 / d as f64;
    let local = epochs
        * local_rounds as f64
        * blom_xi(ranks_per_area)
        * model.sigma;
    let n_groups = m / ranks_per_area;
    let global = epochs * blom_xi(n_groups) * (d as f64).sqrt() * model.sigma;
    (local, global)
}

/// [`predicted_depth_gain`] for the hybrid two-tier schedule: how much
/// synchronization the split-phase depth-`depth` pipeline hides per run
/// when the local tier exchanges `local_rounds` times per epoch among
/// groups of `ranks_per_area` ranks.
///
/// Only the **global-tier** boundary skew is hideable — the local
/// rounds rendezvous every cycle regardless of how the global exchange
/// is phased.  With at least one local round per epoch the group's ranks
/// arrive at the boundary together, so the hideable skew is across the
/// `m / ranks_per_area` groups (`xi_G`), not across all `m` ranks; with
/// `local_rounds = 0` (or singleton groups) it falls back to the flat
/// cross-rank skew and reproduces [`predicted_depth_gain`] exactly.
#[allow(clippy::too_many_arguments)]
pub fn predicted_hybrid_depth_gain(
    model: CycleTimeModel,
    m: usize,
    ranks_per_area: usize,
    s: u64,
    d: u32,
    depth: u32,
    overlap_cycles: u32,
    local_rounds: u32,
) -> f64 {
    assert!(ranks_per_area >= 1 && m >= ranks_per_area);
    assert!(
        m % ranks_per_area == 0,
        "ranks must tile into equal area groups"
    );
    let groups_synced = ranks_per_area > 1 && local_rounds > 0;
    let units = if groups_synced { m / ranks_per_area } else { m };
    let epochs = s as f64 / d as f64;
    let skew_per_epoch = blom_xi(units) * (d as f64).sqrt() * model.sigma;
    let window_cycles =
        overlap_cycles.min((depth * d).saturating_sub(1)) as f64;
    epochs * skew_per_epoch.min(window_cycles * model.mu)
}

/// Fraction of the structure-aware synchronization time (eq 9's sync
/// term) that the overlap window hides: [`predicted_overlap_gain`]
/// normalized by the expected sync time of the same span (one epoch).
pub fn overlap_hidden_fraction(
    model: CycleTimeModel,
    m: usize,
    d: u32,
    overlap_cycles: u32,
) -> f64 {
    let (_, sync_per_epoch) = expected_sync_times(model, m, d as u64, d);
    if sync_per_epoch <= 0.0 {
        return 0.0;
    }
    let gain =
        predicted_overlap_gain(model, m, d as u64, d, overlap_cycles);
    (gain / sync_per_epoch).min(1.0)
}

/// Ratio of coefficients of variation after lumping (eq 7).
pub fn cv_ratio(d: u32) -> f64 {
    1.0 / (d as f64).sqrt()
}

/// Eq 12 applied to *measured* cycle times: the fraction of per-cycle
/// maxima expected to fall within the upper tail that a single draw hits
/// with probability `p_tail`, given `m` ranks.
pub fn maxima_tail_coverage(p_tail: f64, m: usize) -> f64 {
    p_max_in_tail(p_tail, m)
}

/// Empirical check utility: given per-rank cycle-time series
/// (`times[rank][cycle]`), compute total sync time under per-cycle
/// barriers: sum over cycles of `(max_r t[r][s]) - mean_r t[r][s]`...
/// The paper's synchronization time per rank is `max - own`; averaged
/// over ranks it is `max - mean`.  Lumping by `d` applies eq 4/5 first.
pub fn empirical_sync_time(times: &[Vec<f64>], d: usize) -> f64 {
    assert!(!times.is_empty());
    let lumped: Vec<Vec<f64>> =
        times.iter().map(|row| lump_sums(row, d)).collect();
    let epochs = lumped[0].len();
    assert!(lumped.iter().all(|r| r.len() == epochs));
    let m = lumped.len() as f64;
    let mut total = 0.0;
    for e in 0..epochs {
        let col: Vec<f64> = lumped.iter().map(|r| r[e]).collect();
        let max = col.iter().cloned().fold(f64::MIN, f64::max);
        let mean = col.iter().sum::<f64>() / m;
        total += max - mean;
    }
    total
}

/// Probability that a single N(mu, sigma) draw exceeds `q` — helper for
/// expressing measured quantiles in eq-12 terms.
pub fn tail_prob(model: CycleTimeModel, q: f64) -> f64 {
    1.0 - norm_cdf((q - model.mu) / model.sigma)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;
    use crate::util::stats;

    const MODEL: CycleTimeModel = CycleTimeModel::paper_default();

    #[test]
    fn from_measured_fits_positive_moments_only() {
        let m = CycleTimeModel::from_measured(100, 1.6e-3, 0.09e-3).unwrap();
        assert_eq!(m.mu, 1.6e-3);
        assert_eq!(m.sigma, 0.09e-3);
        assert!(CycleTimeModel::from_measured(0, 1.6e-3, 0.09e-3).is_none());
        assert!(CycleTimeModel::from_measured(10, 0.0, 0.09e-3).is_none());
        assert!(CycleTimeModel::from_measured(10, 1.0e-3, -1.0).is_none());
    }

    #[test]
    fn lumping_scales_mean_by_d_and_sigma_by_sqrt_d() {
        let l = MODEL.lumped(10);
        assert!((l.mu - 16.0e-3).abs() < 1e-12);
        assert!((l.sigma - 0.09e-3 * 10f64.sqrt()).abs() < 1e-12);
        assert!((l.cv() / MODEL.cv() - cv_ratio(10)).abs() < 1e-12);
    }

    #[test]
    fn sync_ratio_is_inverse_sqrt_d() {
        assert_eq!(sync_ratio(1), 1.0);
        assert!((sync_ratio(10) - 0.3162).abs() < 1e-3);
        // paper: theoretical prediction of 68% reduction at D=10
        assert!((1.0 - sync_ratio(10) - 0.68).abs() < 0.01);
    }

    #[test]
    fn expected_wall_difference_is_sync_difference() {
        let (s, m, d) = (100_000u64, 128usize, 10u32);
        let conv = expected_wall_conventional(MODEL, m, s);
        let stru = expected_wall_structure(MODEL, m, s, d);
        let (sync_c, sync_s) = expected_sync_times(MODEL, m, s, d);
        // eq 10: difference of walls equals difference of sync terms
        assert!(((conv - stru) - (sync_c - sync_s)).abs() < 1e-9);
        assert!((sync_s / sync_c - sync_ratio(d)).abs() < 1e-12);
    }

    #[test]
    fn monte_carlo_confirms_sync_model() {
        // iid normal cycle times, D=4, M=32: measured sync ratio ~ 1/2
        let (m, s, d) = (32usize, 20_000usize, 4usize);
        let mut rng = Pcg64::seed_from_u64(99);
        let times: Vec<Vec<f64>> = (0..m)
            .map(|_| (0..s).map(|_| rng.normal_ms(1.0, 0.05)).collect())
            .collect();
        let sync_conv = empirical_sync_time(&times, 1);
        let sync_struc = empirical_sync_time(&times, d);
        let ratio = sync_struc / sync_conv;
        // max-mean differs from the xi model by a small constant factor;
        // the *ratio* should match 1/sqrt(D) closely
        assert!(
            (ratio - sync_ratio(d as u32)).abs() < 0.05,
            "ratio {ratio} vs {}",
            sync_ratio(d as u32)
        );
    }

    #[test]
    fn overlap_gain_clamps_to_sync_time() {
        let (s, m, d) = (100_000u64, 128usize, 10u32);
        let (_, sync_struct) = expected_sync_times(MODEL, m, s, d);
        // no overlap window -> nothing hidden
        assert_eq!(predicted_overlap_gain(MODEL, m, s, d, 0), 0.0);
        // a huge window hides the entire sync term, never more
        let all = predicted_overlap_gain(MODEL, m, s, d, 1_000);
        assert!((all - sync_struct).abs() < 1e-9 * sync_struct.max(1.0));
        assert!(all <= sync_struct + 1e-12);
        // monotone in the window
        let g1 = predicted_overlap_gain(MODEL, m, s, d, 1);
        let g4 = predicted_overlap_gain(MODEL, m, s, d, 4);
        assert!(0.0 < g1 && g1 <= g4 && g4 <= all);
    }

    #[test]
    fn depth_gain_reduces_to_overlap_gain_at_depth_one() {
        let (s, m, d) = (50_000u64, 64usize, 10u32);
        for w in [0u32, 1, 4, 9, 100] {
            assert_eq!(
                predicted_depth_gain(MODEL, m, s, d, 1, w),
                predicted_overlap_gain(MODEL, m, s, d, w),
            );
        }
    }

    #[test]
    fn conventional_runs_gain_only_with_depth() {
        // d = 1: depth 1 has a zero window (the next boundary forces a
        // same-boundary completion), deeper pipelines open it up
        let (s, m) = (100_000u64, 128usize);
        assert_eq!(predicted_depth_gain(MODEL, m, s, 1, 1, 4), 0.0);
        let g2 = predicted_depth_gain(MODEL, m, s, 1, 2, 4);
        let g4 = predicted_depth_gain(MODEL, m, s, 1, 4, 4);
        assert!(0.0 < g2 && g2 <= g4, "g2={g2} g4={g4}");
        // the window never exceeds the realized slack: depth 8 with 4
        // cycles of slack gains no more than depth 5
        let g5 = predicted_depth_gain(MODEL, m, s, 1, 5, 4);
        let g8 = predicted_depth_gain(MODEL, m, s, 1, 8, 4);
        assert_eq!(g5, g8);
        // and the gain is bounded by the total sync time of the run
        let (sync_conv, _) = expected_sync_times(MODEL, m, s, 1);
        assert!(g8 <= sync_conv + 1e-12);
    }

    #[test]
    fn hybrid_reduces_to_flat_at_one_rank_per_area() {
        // ranks_per_area = 1: no local-tier cost, and the gain predictor
        // equals the flat depth predictor for every window and depth
        let (s, m, d) = (100_000u64, 128usize, 10u32);
        let (local, global) =
            expected_hybrid_sync_times(MODEL, m, 1, s, d, d);
        let (_, flat) = expected_sync_times(MODEL, m, s, d);
        assert_eq!(local, 0.0);
        assert!((global - flat).abs() < 1e-12 * flat.max(1.0));
        for depth in [1u32, 2, 4] {
            for w in [0u32, 1, 4, 9] {
                assert_eq!(
                    predicted_hybrid_depth_gain(MODEL, m, 1, s, d, depth, w, d),
                    predicted_depth_gain(MODEL, m, s, d, depth, w),
                );
            }
        }
    }

    #[test]
    fn hybrid_local_tier_scales_with_rounds_and_group_size() {
        let (s, m, d) = (10_000u64, 64usize, 10u32);
        let (l1, _) = expected_hybrid_sync_times(MODEL, m, 4, s, d, 1);
        let (l10, _) = expected_hybrid_sync_times(MODEL, m, 4, s, d, 10);
        assert!(l1 > 0.0 && (l10 / l1 - 10.0).abs() < 1e-9);
        // larger groups pay more skew per local round...
        let (l_r8, g_r8) = expected_hybrid_sync_times(MODEL, m, 8, s, d, 10);
        assert!(l_r8 > l10);
        // ...but the global boundary sees fewer independent units
        let (_, g_r4) = expected_hybrid_sync_times(MODEL, m, 4, s, d, 10);
        assert!(g_r8 < g_r4);
    }

    #[test]
    fn hybrid_gain_accounts_for_local_rounds() {
        // skew-limited regime (huge window): grouping reduces the
        // hideable boundary skew from xi_M to xi_{M/R} — the hybrid
        // schedule has *less* left for the overlap to hide
        let (s, m, d) = (100_000u64, 128usize, 10u32);
        let flat = predicted_hybrid_depth_gain(MODEL, m, 1, s, d, 1, 999, d);
        let grouped =
            predicted_hybrid_depth_gain(MODEL, m, 4, s, d, 1, 999, d);
        assert!(grouped < flat, "grouped {grouped} flat {flat}");
        // without local rounds the groups never rendezvous mid-epoch:
        // the boundary skew is across all ranks again
        let no_rounds =
            predicted_hybrid_depth_gain(MODEL, m, 4, s, d, 1, 999, 0);
        assert_eq!(no_rounds, flat);
        // the grouped gain equals the flat gain of M/R ranks
        let as_groups = predicted_depth_gain(MODEL, m / 4, s, d, 1, 999);
        assert!((grouped - as_groups).abs() < 1e-12 * as_groups.max(1.0));
    }

    #[test]
    fn overlap_window_clamped_by_epoch() {
        // the window cannot exceed d-1 cycles: completion is forced at
        // the next boundary, so d-1 and 10*d give the same prediction
        let (s, m, d) = (10_000u64, 64usize, 10u32);
        assert_eq!(
            predicted_overlap_gain(MODEL, m, s, d, d - 1),
            predicted_overlap_gain(MODEL, m, s, d, 10 * d),
        );
    }

    #[test]
    fn overlap_hidden_fraction_bounded() {
        let f0 = overlap_hidden_fraction(MODEL, 128, 10, 0);
        let f9 = overlap_hidden_fraction(MODEL, 128, 10, 9);
        assert_eq!(f0, 0.0);
        assert!(f0 <= f9 && f9 <= 1.0);
        // with mu >> sigma even one cycle of slack hides everything
        let wide = CycleTimeModel { mu: 1.0, sigma: 1e-6 };
        let f = overlap_hidden_fraction(wide, 128, 10, 1);
        assert!((f - 1.0).abs() < 1e-12, "fraction {f}");
    }

    #[test]
    fn maxima_tail_coverage_matches_paper_example() {
        // M=128: upper 3.5% of cycle times -> ~99% of per-cycle maxima
        let p = maxima_tail_coverage(0.035, 128);
        assert!((p - 0.99).abs() < 0.01, "p={p}");
    }

    #[test]
    fn tail_prob_consistency() {
        let q = MODEL.mu + 1.812 * MODEL.sigma; // ~96.5th percentile
        let p = tail_prob(MODEL, q);
        assert!((p - 0.035).abs() < 0.002, "p={p}");
    }

    #[test]
    fn expected_max_grows_with_m() {
        let e64 = MODEL.expected_max(64);
        let e128 = MODEL.expected_max(128);
        assert!(e128 > e64);
        assert!(e64 > MODEL.mu);
    }

    #[test]
    fn empirical_sync_zero_for_identical_ranks() {
        let times = vec![vec![1.0; 100], vec![1.0; 100]];
        assert!(empirical_sync_time(&times, 1).abs() < 1e-12);
    }

    #[test]
    fn lumped_monte_carlo_cv_matches_eq7_iid_only() {
        let mut rng = Pcg64::seed_from_u64(5);
        let xs: Vec<f64> =
            (0..200_000).map(|_| rng.normal_ms(1.6, 0.09)).collect();
        let lumped = stats::lump_sums(&xs, 10);
        let ratio = stats::cv(&lumped) / stats::cv(&xs);
        assert!((ratio - cv_ratio(10)).abs() < 0.01, "ratio {ratio}");
    }
}
