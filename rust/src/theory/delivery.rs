//! Theoretical model of spike-delivery cache locality (paper §2.3,
//! eqs 13–17).
//!
//! Delivering a spike to its *first* target synapse on a thread is an
//! irregular (uncached) memory access; subsequent synapses stream.  The
//! fraction of irregular accesses therefore equals the expected number of
//! (spike, thread) first-touches divided by the number of synapses a
//! spike serves.

/// Weak-scaling scenario parameters (defaults = paper Fig 6b).
#[derive(Clone, Copy, Debug)]
pub struct DeliveryScenario {
    /// Neurons per MPI process (`N_M`).
    pub n_m: f64,
    /// Incoming synapses per neuron (`K_N`).
    pub k_n: f64,
    /// Intra-area synapses per neuron (structure-aware case).
    pub k_intra: f64,
    /// Inter-area synapses per neuron.
    pub k_inter: f64,
}

impl Default for DeliveryScenario {
    fn default() -> Self {
        // Fig 6b: N_M ≈ 130,000, K_N ≈ 6000, K_intra = K_inter ≈ 3000
        Self { n_m: 130_000.0, k_n: 6_000.0, k_intra: 3_000.0, k_inter: 3_000.0 }
    }
}

/// `1 - (1 - 1/n)^k`, computed stably for large `n·k`: the probability
/// that at least one of `k` uniform draws over `n` bins hits a specific
/// bin.  Shared with the workload model (expected distinct target ranks
/// of a spike's inter-area synapses).
pub fn p_at_least_one(n: f64, k: f64) -> f64 {
    if n <= 1.0 {
        return 1.0;
    }
    -(k * (-1.0 / n).ln_1p()).exp_m1()
}

/// Eq 13: probability that a neuron has ≥ 1 target on a specific thread
/// under round-robin distribution (`n` total neurons, `n_t` thread-local
/// neurons, `k_n` synapses per neuron).
pub fn p_target_round_robin(n: f64, n_t: f64, k_n: f64) -> f64 {
    p_at_least_one(n, n_t * k_n)
}

/// Eq 14: fraction of irregular accesses, conventional round-robin
/// scheme, for `m` processes × `t_m` threads.
pub fn f_irr_conventional(sc: &DeliveryScenario, m: usize, t_m: usize) -> f64 {
    let n = sc.n_m * m as f64;
    let t = (m * t_m) as f64;
    let n_t = n / t;
    let p = p_target_round_robin(n, n_t, sc.k_n);
    (p * t / sc.k_n).min(1.0)
}

/// Eqs 15–17: fraction of irregular accesses, structure-aware scheme
/// (equal areas of `n_m` neurons, one area per process).
pub fn f_irr_structure(sc: &DeliveryScenario, m: usize, t_m: usize) -> f64 {
    let (fi, fe) = f_irr_structure_parts(sc, m, t_m);
    ((fi * sc.k_intra + fe * sc.k_inter) / sc.k_n).min(1.0)
}

/// Per-pathway irregular-access fractions of the structure-aware scheme,
/// normalized per synapse *of that pathway*:
/// `(p_intra·T_M / K_intra, p_inter·T_M·(M−1) / K_inter)`.
pub fn f_irr_structure_parts(
    sc: &DeliveryScenario,
    m: usize,
    t_m: usize,
) -> (f64, f64) {
    let n = sc.n_m * m as f64;
    let t = (m * t_m) as f64;
    let n_t = n / t;
    // eq 15: intra-area targets on the area's own process
    let p_intra = p_at_least_one(sc.n_m, n_t * sc.k_intra);
    // eq 16: inter-area targets on the other M-1 processes
    let p_inter = if m > 1 {
        p_at_least_one(n - sc.n_m, n_t * sc.k_inter)
    } else {
        0.0
    };
    let fi = (p_intra * t_m as f64 / sc.k_intra).min(1.0);
    let fe = if sc.k_inter > 0.0 {
        (p_inter * t_m as f64 * (m as f64 - 1.0) / sc.k_inter).min(1.0)
    } else {
        0.0
    };
    (fi, fe)
}

/// Relative reduction in irregular access, structure-aware vs
/// conventional (positive = structure-aware better).
pub fn irregular_access_reduction(
    sc: &DeliveryScenario,
    m: usize,
    t_m: usize,
) -> f64 {
    let conv = f_irr_conventional(sc, m, t_m);
    let stru = f_irr_structure(sc, m, t_m);
    1.0 - stru / conv
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn p_at_least_one_limits() {
        assert!((p_at_least_one(1e9, 1.0) - 1e-9).abs() < 1e-12);
        assert!(p_at_least_one(10.0, 1e6) > 0.999_999);
        assert_eq!(p_at_least_one(1.0, 5.0), 1.0);
    }

    #[test]
    fn single_process_single_thread_equal() {
        // M=1, T_M=1: every scheme delivers everything on one thread
        let sc = DeliveryScenario::default();
        let c = f_irr_conventional(&sc, 1, 1);
        let s = f_irr_structure(&sc, 1, 1);
        assert!((c - s).abs() < 1e-6, "c={c} s={s}");
    }

    #[test]
    fn paper_fig6b_reductions() {
        let sc = DeliveryScenario::default();
        // "at M=16 ... still similar for both strategies"
        let r16 = irregular_access_reduction(&sc, 16, 48);
        assert!(r16 < 0.08, "M=16 reduction {r16}");
        // "at M=32 ... 12% for T_M=48 and 29% for T_M=128"
        let r32_48 = irregular_access_reduction(&sc, 32, 48);
        let r32_128 = irregular_access_reduction(&sc, 32, 128);
        assert!((r32_48 - 0.12).abs() < 0.05, "{r32_48}");
        assert!((r32_128 - 0.29).abs() < 0.07, "{r32_128}");
        // "at M=128 ... 37% for T_M=48 and 43% for T_M=128"
        let r128_48 = irregular_access_reduction(&sc, 128, 48);
        let r128_128 = irregular_access_reduction(&sc, 128, 128);
        assert!((r128_48 - 0.37).abs() < 0.06, "{r128_48}");
        assert!((r128_128 - 0.43).abs() < 0.06, "{r128_128}");
    }

    #[test]
    fn reduction_grows_with_m_and_threads() {
        let sc = DeliveryScenario::default();
        let ms = [16usize, 32, 64, 128];
        let r48: Vec<f64> = ms
            .iter()
            .map(|&m| irregular_access_reduction(&sc, m, 48))
            .collect();
        assert!(r48.windows(2).all(|w| w[0] < w[1]), "{r48:?}");
        for &m in &ms[1..] {
            assert!(
                irregular_access_reduction(&sc, m, 128)
                    > irregular_access_reduction(&sc, m, 48)
            );
        }
    }

    #[test]
    fn fractions_bounded() {
        let sc = DeliveryScenario::default();
        for &m in &[1usize, 4, 16, 64, 256] {
            for &t in &[1usize, 8, 48, 128] {
                for f in [f_irr_conventional(&sc, m, t), f_irr_structure(&sc, m, t)] {
                    assert!((0.0..=1.0).contains(&f), "f={f} m={m} t={t}");
                }
            }
        }
    }

    #[test]
    fn full_dispersion_limit() {
        // with enough processes every target lives on its own thread:
        // conventional fraction approaches 1
        let sc = DeliveryScenario {
            n_m: 100.0,
            k_n: 60.0,
            k_intra: 30.0,
            k_inter: 30.0,
        };
        let f = f_irr_conventional(&sc, 512, 48);
        assert!(f > 0.9, "f={f}");
    }
}
