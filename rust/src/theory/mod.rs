//! The paper's analytical machinery.
//!
//! * [`sync`] — order-statistics model of synchronization time
//!   (§2.2, eqs 2–12): expected per-cycle maxima via Blom's `xi_M`, CLT
//!   lumping of D cycles, the `1/sqrt(D)` synchronization-time ratio and
//!   the quantile interval of per-cycle maxima.
//! * [`delivery`] — cache-locality model of spike delivery (§2.3,
//!   eqs 13–17): fraction of irregular (first-synapse) memory accesses
//!   under round-robin vs structure-aware placement.
//! * [`illustration`] — the synthetic-timing construction of Fig 5.

pub mod sync;
pub mod delivery;
pub mod illustration;
