//! Neuron-to-rank/thread placement schemes (paper §2.1, §4.1.1).
//!
//! * [`Placement::RoundRobin`] — NEST's conventional scheme: virtual
//!   process `vp = gid mod (M·T)`, rank `vp mod M`.  Balances workload but
//!   scatters every area across all ranks.
//! * [`Placement::AreaAligned`] — the structure-aware scheme: every area is
//!   confined to one rank (`rank = area mod M`), neurons spread round-robin
//!   over the rank's threads.  Heterogeneous area sizes then produce the
//!   load imbalance the paper analyses; the implied padding of NEST's
//!   en-bloc creation is reported as ghost neurons.

use crate::network::spec::ModelSpec;
use crate::network::Gid;
use anyhow::{bail, Result};

#[derive(Clone, Debug)]
pub enum Placement {
    RoundRobin { m: usize, t: usize },
    AreaAligned { m: usize, t: usize, area_rank: Vec<usize> },
}

impl Placement {
    pub fn round_robin(m: usize, t: usize) -> Placement {
        Placement::RoundRobin { m, t }
    }

    /// Area-aligned placement over `m` ranks: area `a` lives on rank
    /// `a mod m`.  Errors if there are fewer areas than ranks (idle ranks
    /// have no neurons to host — the paper never runs this regime).
    pub fn area_aligned(spec: &ModelSpec, m: usize, t: usize) -> Result<Placement> {
        if spec.n_areas() < m {
            bail!(
                "area-aligned placement needs >= {m} areas, model has {}",
                spec.n_areas()
            );
        }
        let area_rank = (0..spec.n_areas()).map(|a| a % m).collect();
        Ok(Placement::AreaAligned { m, t, area_rank })
    }

    pub fn m_ranks(&self) -> usize {
        match self {
            Placement::RoundRobin { m, .. } => *m,
            Placement::AreaAligned { m, .. } => *m,
        }
    }

    pub fn threads_per_rank(&self) -> usize {
        match self {
            Placement::RoundRobin { t, .. } => *t,
            Placement::AreaAligned { t, .. } => *t,
        }
    }

    /// Rank hosting `gid`.
    pub fn rank_of(&self, spec: &ModelSpec, gid: Gid) -> usize {
        match self {
            Placement::RoundRobin { m, t } => (gid as usize) % (m * t) % m,
            Placement::AreaAligned { area_rank, .. } => {
                area_rank[spec.area_of(gid)]
            }
        }
    }

    /// Thread within the hosting rank.
    pub fn thread_of(&self, spec: &ModelSpec, gid: Gid) -> usize {
        match self {
            Placement::RoundRobin { m, t } => (gid as usize) % (m * t) / m,
            Placement::AreaAligned { t, .. } => {
                let area = spec.area_of(gid);
                let local = (gid - spec.area_range(area).start) as usize;
                local % t
            }
        }
    }

    /// All GIDs hosted by `(rank, thread)` in ascending order — the
    /// canonical thread-local indexing used by state arrays and ring
    /// buffers.
    pub fn local_gids(
        &self,
        spec: &ModelSpec,
        rank: usize,
        thread: usize,
    ) -> Vec<Gid> {
        match self {
            Placement::RoundRobin { m, t } => {
                let vp = thread * m + rank;
                let stride = (m * t) as Gid;
                (0..spec.total_neurons())
                    .skip(vp)
                    .step_by(stride as usize)
                    .take_while(|&g| g < spec.total_neurons())
                    .collect()
            }
            Placement::AreaAligned { area_rank, t, .. } => {
                let mut out = Vec::new();
                for (a, &r) in area_rank.iter().enumerate() {
                    if r != rank {
                        continue;
                    }
                    let range = spec.area_range(a);
                    for gid in range.clone() {
                        if ((gid - range.start) as usize) % t == thread {
                            out.push(gid);
                        }
                    }
                }
                out
            }
        }
    }

    /// Real neurons per rank.
    pub fn rank_counts(&self, spec: &ModelSpec) -> Vec<usize> {
        let m = self.m_ranks();
        let mut counts = vec![0usize; m];
        match self {
            Placement::RoundRobin { .. } => {
                for gid in 0..spec.total_neurons() {
                    counts[self.rank_of(spec, gid)] += 1;
                }
            }
            Placement::AreaAligned { area_rank, .. } => {
                for (a, &r) in area_rank.iter().enumerate() {
                    let range = spec.area_range(a);
                    counts[r] += (range.end - range.start) as usize;
                }
            }
        }
        counts
    }

    /// Ghost ("frozen") neurons per rank implied by NEST's en-bloc creation
    /// trick (§4.1.1): every rank is padded to the size of the fullest
    /// rank; ghosts exist but are excluded from the update phase.
    pub fn ghost_counts(&self, spec: &ModelSpec) -> Vec<usize> {
        let counts = self.rank_counts(spec);
        let max = counts.iter().copied().max().unwrap_or(0);
        counts.iter().map(|&c| max - c).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::spec::{AreaSpec, DelayDist, LifParams, NeuronKind, WeightRule};

    fn spec(sizes: &[u32]) -> ModelSpec {
        let areas = sizes
            .iter()
            .enumerate()
            .map(|(i, &n)| AreaSpec {
                name: format!("A{i}"),
                n,
                neuron: NeuronKind::Lif(LifParams::default()),
            })
            .collect();
        ModelSpec::new(
            "t",
            areas,
            5,
            5,
            WeightRule::default(),
            DelayDist::new(1.25, 0.625, 0.1),
            DelayDist::new(5.0, 2.5, 1.0),
            0.1,
        )
        .unwrap()
    }

    #[test]
    fn round_robin_matches_nest_vp_rule() {
        let s = spec(&[40, 40]);
        let p = Placement::round_robin(2, 3);
        // vp = gid % 6, rank = vp % 2, thread = vp / 2
        assert_eq!(p.rank_of(&s, 0), 0);
        assert_eq!(p.rank_of(&s, 1), 1);
        assert_eq!(p.thread_of(&s, 0), 0);
        assert_eq!(p.thread_of(&s, 2), 1);
        assert_eq!(p.thread_of(&s, 5), 2);
        assert_eq!(p.rank_of(&s, 5), 1);
    }

    #[test]
    fn area_aligned_confines_areas() {
        let s = spec(&[30, 20, 25]);
        let p = Placement::area_aligned(&s, 3, 2).unwrap();
        for gid in 0..30 {
            assert_eq!(p.rank_of(&s, gid), 0);
        }
        for gid in 30..50 {
            assert_eq!(p.rank_of(&s, gid), 1);
        }
        for gid in 50..75 {
            assert_eq!(p.rank_of(&s, gid), 2);
        }
    }

    #[test]
    fn area_aligned_wraps_when_more_areas_than_ranks() {
        let s = spec(&[10, 10, 10, 10]);
        let p = Placement::area_aligned(&s, 2, 1).unwrap();
        assert_eq!(p.rank_of(&s, 0), 0);
        assert_eq!(p.rank_of(&s, 10), 1);
        assert_eq!(p.rank_of(&s, 20), 0);
        assert_eq!(p.rank_of(&s, 30), 1);
    }

    #[test]
    fn rejects_fewer_areas_than_ranks() {
        let s = spec(&[10, 10]);
        assert!(Placement::area_aligned(&s, 3, 1).is_err());
    }

    #[test]
    fn local_gids_partition_everything() {
        let s = spec(&[33, 21, 17]);
        for p in [
            Placement::round_robin(2, 3),
            Placement::area_aligned(&s, 3, 2).unwrap(),
        ] {
            let mut seen = vec![false; s.total_neurons() as usize];
            for rank in 0..p.m_ranks() {
                for thread in 0..p.threads_per_rank() {
                    for gid in p.local_gids(&s, rank, thread) {
                        assert_eq!(p.rank_of(&s, gid), rank);
                        assert_eq!(p.thread_of(&s, gid), thread);
                        assert!(!seen[gid as usize], "gid {gid} duplicated");
                        seen[gid as usize] = true;
                    }
                }
            }
            assert!(seen.iter().all(|&x| x), "not all gids placed");
        }
    }

    #[test]
    fn local_gids_sorted_ascending() {
        let s = spec(&[29, 31]);
        let p = Placement::area_aligned(&s, 2, 3).unwrap();
        for rank in 0..2 {
            for th in 0..3 {
                let gids = p.local_gids(&s, rank, th);
                assert!(gids.windows(2).all(|w| w[0] < w[1]));
            }
        }
    }

    #[test]
    fn round_robin_balances_within_one() {
        let s = spec(&[101, 57]);
        let p = Placement::round_robin(4, 2);
        let counts = p.rank_counts(&s);
        let max = counts.iter().max().unwrap();
        let min = counts.iter().min().unwrap();
        assert!(max - min <= 2, "{counts:?}");
        assert!(p.ghost_counts(&s).iter().all(|&g| g <= 2));
    }

    #[test]
    fn area_aligned_ghosts_reflect_imbalance() {
        let s = spec(&[100, 60]);
        let p = Placement::area_aligned(&s, 2, 1).unwrap();
        assert_eq!(p.rank_counts(&s), vec![100, 60]);
        assert_eq!(p.ghost_counts(&s), vec![0, 40]);
    }
}
