//! Neuron-to-rank/thread placement schemes (paper §2.1, §4.1.1).
//!
//! * [`Placement::RoundRobin`] — NEST's conventional scheme: virtual
//!   process `vp = gid mod (M·T)`, rank `vp mod M`.  Balances workload but
//!   scatters every area across all ranks.
//! * [`Placement::AreaAligned`] — the structure-aware scheme: every area is
//!   confined to one **rank group** of `ranks_per_area` consecutive ranks
//!   (`group = area mod (M / ranks_per_area)`), neurons spread round-robin
//!   over the group's `ranks_per_area · T` virtual slots (rank-major, so
//!   `ranks_per_area = 1` degenerates to the original one-area-per-rank
//!   scheme with `thread = local mod T`, bit-identically).  Rank groups
//!   are what the hierarchical communicator API maps local communicators
//!   onto: the ranks of one group exchange the area's short-range spikes
//!   every cycle over their own sub-communicator.  Heterogeneous area
//!   sizes still produce the load imbalance the paper analyses; the
//!   implied padding of NEST's en-bloc creation is reported as ghost
//!   neurons.

use crate::network::spec::ModelSpec;
use crate::network::Gid;
use anyhow::{bail, Result};

#[derive(Clone, Debug)]
pub enum Placement {
    RoundRobin {
        m: usize,
        t: usize,
    },
    AreaAligned {
        m: usize,
        t: usize,
        /// Ranks jointly hosting each area group; group `g` owns the
        /// contiguous ranks `g·ranks_per_area .. (g+1)·ranks_per_area`.
        ranks_per_area: usize,
        /// Area → rank-group index.
        area_group: Vec<usize>,
    },
}

impl Placement {
    pub fn round_robin(m: usize, t: usize) -> Placement {
        Placement::RoundRobin { m, t }
    }

    /// Area-aligned placement over `m` ranks with one rank per area
    /// group: area `a` lives on rank `a mod m`.  Shorthand for
    /// [`Placement::area_aligned_grouped`] with `ranks_per_area = 1`.
    pub fn area_aligned(spec: &ModelSpec, m: usize, t: usize) -> Result<Placement> {
        Placement::area_aligned_grouped(spec, m, t, 1)
    }

    /// Area-aligned placement with multi-rank area groups: the `m` ranks
    /// split into `m / ranks_per_area` contiguous groups, area `a` maps
    /// onto group `a mod (m / ranks_per_area)`, and its neurons spread
    /// round-robin over the group's `ranks_per_area · t` virtual slots
    /// (rank-major).  Errors if `m` is not a multiple of
    /// `ranks_per_area` or there are fewer areas than groups (idle
    /// groups have no neurons to host — the paper never runs this
    /// regime).
    pub fn area_aligned_grouped(
        spec: &ModelSpec,
        m: usize,
        t: usize,
        ranks_per_area: usize,
    ) -> Result<Placement> {
        if ranks_per_area == 0 {
            bail!("ranks_per_area must be >= 1");
        }
        if m % ranks_per_area != 0 {
            bail!(
                "ranks ({m}) must be a multiple of ranks_per_area \
                 ({ranks_per_area}): area groups are contiguous rank \
                 blocks of equal size"
            );
        }
        let n_groups = m / ranks_per_area;
        if spec.n_areas() < n_groups {
            bail!(
                "area-aligned placement needs >= {n_groups} areas (one \
                 per rank group of {ranks_per_area}), model has {}",
                spec.n_areas()
            );
        }
        let area_group = (0..spec.n_areas()).map(|a| a % n_groups).collect();
        Ok(Placement::AreaAligned { m, t, ranks_per_area, area_group })
    }

    pub fn m_ranks(&self) -> usize {
        match self {
            Placement::RoundRobin { m, .. } => *m,
            Placement::AreaAligned { m, .. } => *m,
        }
    }

    pub fn threads_per_rank(&self) -> usize {
        match self {
            Placement::RoundRobin { t, .. } => *t,
            Placement::AreaAligned { t, .. } => *t,
        }
    }

    /// Ranks jointly hosting one area group (1 unless grouped
    /// area-aligned placement is in use).
    pub fn ranks_per_area(&self) -> usize {
        match self {
            Placement::RoundRobin { .. } => 1,
            Placement::AreaAligned { ranks_per_area, .. } => *ranks_per_area,
        }
    }

    /// Communicator-group color of `rank`: its area group under the
    /// structure-aware placement, the rank itself otherwise (every rank
    /// a singleton group).
    pub fn group_of_rank(&self, rank: usize) -> usize {
        match self {
            Placement::RoundRobin { .. } => rank,
            Placement::AreaAligned { ranks_per_area, .. } => {
                rank / ranks_per_area
            }
        }
    }

    /// Global rank ids of `rank`'s area group, ascending (contiguous by
    /// construction).
    pub fn group_ranks(&self, rank: usize) -> std::ops::Range<usize> {
        match self {
            Placement::RoundRobin { .. } => rank..rank + 1,
            Placement::AreaAligned { ranks_per_area, .. } => {
                let g = rank / ranks_per_area;
                g * ranks_per_area..(g + 1) * ranks_per_area
            }
        }
    }

    /// Rank hosting `gid`.
    pub fn rank_of(&self, spec: &ModelSpec, gid: Gid) -> usize {
        match self {
            Placement::RoundRobin { m, t } => (gid as usize) % (m * t) % m,
            Placement::AreaAligned { t, ranks_per_area, area_group, .. } => {
                let area = spec.area_of(gid);
                let local = (gid - spec.area_range(area).start) as usize;
                let slot = local % (ranks_per_area * t);
                area_group[area] * ranks_per_area + slot % ranks_per_area
            }
        }
    }

    /// Thread within the hosting rank.
    pub fn thread_of(&self, spec: &ModelSpec, gid: Gid) -> usize {
        match self {
            Placement::RoundRobin { m, t } => (gid as usize) % (m * t) / m,
            Placement::AreaAligned { t, ranks_per_area, .. } => {
                let area = spec.area_of(gid);
                let local = (gid - spec.area_range(area).start) as usize;
                local % (ranks_per_area * t) / ranks_per_area
            }
        }
    }

    /// All GIDs hosted by `(rank, thread)` in ascending order — the
    /// canonical thread-local indexing used by state arrays and ring
    /// buffers.
    pub fn local_gids(
        &self,
        spec: &ModelSpec,
        rank: usize,
        thread: usize,
    ) -> Vec<Gid> {
        match self {
            Placement::RoundRobin { m, t } => {
                let vp = thread * m + rank;
                let stride = (m * t) as Gid;
                (0..spec.total_neurons())
                    .skip(vp)
                    .step_by(stride as usize)
                    .take_while(|&g| g < spec.total_neurons())
                    .collect()
            }
            Placement::AreaAligned { area_group, t, ranks_per_area, .. } => {
                let r = *ranks_per_area;
                let my_group = rank / r;
                // the virtual slot of this (rank, thread) within the
                // group's rank-major slot cycle of length r·t
                let my_slot = thread * r + rank % r;
                let mut out = Vec::new();
                for (a, &g) in area_group.iter().enumerate() {
                    if g != my_group {
                        continue;
                    }
                    let range = spec.area_range(a);
                    for gid in range.clone() {
                        if ((gid - range.start) as usize) % (r * t)
                            == my_slot
                        {
                            out.push(gid);
                        }
                    }
                }
                out
            }
        }
    }

    /// Real neurons per rank.
    pub fn rank_counts(&self, spec: &ModelSpec) -> Vec<usize> {
        let m = self.m_ranks();
        let mut counts = vec![0usize; m];
        match self {
            Placement::RoundRobin { .. } => {
                for gid in 0..spec.total_neurons() {
                    counts[self.rank_of(spec, gid)] += 1;
                }
            }
            Placement::AreaAligned { area_group, t, ranks_per_area, .. } => {
                // closed form: neurons with area-local index ≡ j
                // (mod r·t) land on rank g·r + j mod r; count of such
                // indices in [0, n) is ceil((n - j) / (r·t)) for j < n
                let (r, t) = (*ranks_per_area, *t);
                let cycle = r * t;
                for (a, &g) in area_group.iter().enumerate() {
                    let range = spec.area_range(a);
                    let n = (range.end - range.start) as usize;
                    for rr in 0..r {
                        let mut c = 0usize;
                        for th in 0..t {
                            let j = th * r + rr;
                            if j < n {
                                c += (n - j).div_ceil(cycle);
                            }
                        }
                        counts[g * r + rr] += c;
                    }
                }
            }
        }
        counts
    }

    /// Ghost ("frozen") neurons per rank implied by NEST's en-bloc creation
    /// trick (§4.1.1): every rank is padded to the size of the fullest
    /// rank; ghosts exist but are excluded from the update phase.
    pub fn ghost_counts(&self, spec: &ModelSpec) -> Vec<usize> {
        let counts = self.rank_counts(spec);
        let max = counts.iter().copied().max().unwrap_or(0);
        counts.iter().map(|&c| max - c).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::spec::{AreaSpec, DelayDist, LifParams, NeuronKind, WeightRule};

    fn spec(sizes: &[u32]) -> ModelSpec {
        let areas = sizes
            .iter()
            .enumerate()
            .map(|(i, &n)| AreaSpec {
                name: format!("A{i}"),
                n,
                neuron: NeuronKind::Lif(LifParams::default()),
            })
            .collect();
        ModelSpec::new(
            "t",
            areas,
            5,
            5,
            WeightRule::default(),
            DelayDist::new(1.25, 0.625, 0.1),
            DelayDist::new(5.0, 2.5, 1.0),
            0.1,
        )
        .unwrap()
    }

    #[test]
    fn round_robin_matches_nest_vp_rule() {
        let s = spec(&[40, 40]);
        let p = Placement::round_robin(2, 3);
        // vp = gid % 6, rank = vp % 2, thread = vp / 2
        assert_eq!(p.rank_of(&s, 0), 0);
        assert_eq!(p.rank_of(&s, 1), 1);
        assert_eq!(p.thread_of(&s, 0), 0);
        assert_eq!(p.thread_of(&s, 2), 1);
        assert_eq!(p.thread_of(&s, 5), 2);
        assert_eq!(p.rank_of(&s, 5), 1);
    }

    #[test]
    fn area_aligned_confines_areas() {
        let s = spec(&[30, 20, 25]);
        let p = Placement::area_aligned(&s, 3, 2).unwrap();
        for gid in 0..30 {
            assert_eq!(p.rank_of(&s, gid), 0);
        }
        for gid in 30..50 {
            assert_eq!(p.rank_of(&s, gid), 1);
        }
        for gid in 50..75 {
            assert_eq!(p.rank_of(&s, gid), 2);
        }
    }

    #[test]
    fn area_aligned_wraps_when_more_areas_than_ranks() {
        let s = spec(&[10, 10, 10, 10]);
        let p = Placement::area_aligned(&s, 2, 1).unwrap();
        assert_eq!(p.rank_of(&s, 0), 0);
        assert_eq!(p.rank_of(&s, 10), 1);
        assert_eq!(p.rank_of(&s, 20), 0);
        assert_eq!(p.rank_of(&s, 30), 1);
    }

    #[test]
    fn rejects_fewer_areas_than_ranks() {
        let s = spec(&[10, 10]);
        assert!(Placement::area_aligned(&s, 3, 1).is_err());
    }

    #[test]
    fn local_gids_partition_everything() {
        let s = spec(&[33, 21, 17]);
        for p in [
            Placement::round_robin(2, 3),
            Placement::area_aligned(&s, 3, 2).unwrap(),
        ] {
            let mut seen = vec![false; s.total_neurons() as usize];
            for rank in 0..p.m_ranks() {
                for thread in 0..p.threads_per_rank() {
                    for gid in p.local_gids(&s, rank, thread) {
                        assert_eq!(p.rank_of(&s, gid), rank);
                        assert_eq!(p.thread_of(&s, gid), thread);
                        assert!(!seen[gid as usize], "gid {gid} duplicated");
                        seen[gid as usize] = true;
                    }
                }
            }
            assert!(seen.iter().all(|&x| x), "not all gids placed");
        }
    }

    #[test]
    fn local_gids_sorted_ascending() {
        let s = spec(&[29, 31]);
        let p = Placement::area_aligned(&s, 2, 3).unwrap();
        for rank in 0..2 {
            for th in 0..3 {
                let gids = p.local_gids(&s, rank, th);
                assert!(gids.windows(2).all(|w| w[0] < w[1]));
            }
        }
    }

    #[test]
    fn round_robin_balances_within_one() {
        let s = spec(&[101, 57]);
        let p = Placement::round_robin(4, 2);
        let counts = p.rank_counts(&s);
        let max = counts.iter().max().unwrap();
        let min = counts.iter().min().unwrap();
        assert!(max - min <= 2, "{counts:?}");
        assert!(p.ghost_counts(&s).iter().all(|&g| g <= 2));
    }

    #[test]
    fn grouped_matches_ungrouped_at_one_rank_per_area() {
        // ranks_per_area = 1 must reproduce the original scheme
        // bit-identically (same ranks, same threads, same counts)
        let s = spec(&[33, 21, 17]);
        let a = Placement::area_aligned(&s, 3, 2).unwrap();
        let b = Placement::area_aligned_grouped(&s, 3, 2, 1).unwrap();
        for gid in 0..s.total_neurons() {
            assert_eq!(a.rank_of(&s, gid), b.rank_of(&s, gid));
            assert_eq!(a.thread_of(&s, gid), b.thread_of(&s, gid));
        }
        assert_eq!(a.rank_counts(&s), b.rank_counts(&s));
        assert_eq!(a.ranks_per_area(), 1);
    }

    #[test]
    fn grouped_confines_areas_to_rank_groups() {
        let s = spec(&[40, 30]);
        let p = Placement::area_aligned_grouped(&s, 4, 2, 2).unwrap();
        assert_eq!(p.ranks_per_area(), 2);
        // area 0 -> group 0 (ranks 0..2), area 1 -> group 1 (ranks 2..4)
        for gid in 0..40 {
            assert!(p.rank_of(&s, gid) < 2);
        }
        for gid in 40..70 {
            assert!((2..4).contains(&p.rank_of(&s, gid)));
        }
        // both ranks of a group host a share of their area
        let counts = p.rank_counts(&s);
        assert!(counts.iter().all(|&c| c > 0), "{counts:?}");
        assert_eq!(counts.iter().sum::<usize>(), 70);
        assert_eq!(p.group_of_rank(0), 0);
        assert_eq!(p.group_of_rank(3), 1);
        assert_eq!(p.group_ranks(1), 0..2);
        assert_eq!(p.group_ranks(2), 2..4);
        // round-robin placements are all singleton groups
        let rr = Placement::round_robin(4, 2);
        assert_eq!(rr.ranks_per_area(), 1);
        assert_eq!(rr.group_of_rank(3), 3);
        assert_eq!(rr.group_ranks(2), 2..3);
    }

    #[test]
    fn grouped_partitions_everything() {
        let s = spec(&[33, 21, 17, 29]);
        for rpa in [1usize, 2, 4] {
            let p =
                Placement::area_aligned_grouped(&s, 4, 3, rpa).unwrap();
            let mut seen = vec![false; s.total_neurons() as usize];
            for rank in 0..p.m_ranks() {
                for thread in 0..p.threads_per_rank() {
                    let gids = p.local_gids(&s, rank, thread);
                    assert!(gids.windows(2).all(|w| w[0] < w[1]));
                    for gid in gids {
                        assert_eq!(p.rank_of(&s, gid), rank);
                        assert_eq!(p.thread_of(&s, gid), thread);
                        assert!(
                            !seen[gid as usize],
                            "gid {gid} duplicated (rpa={rpa})"
                        );
                        seen[gid as usize] = true;
                    }
                }
            }
            assert!(
                seen.iter().all(|&x| x),
                "rpa={rpa}: not all gids placed"
            );
        }
    }

    #[test]
    fn grouped_rank_counts_match_brute_force() {
        let s = spec(&[31, 22, 17, 40]);
        let p = Placement::area_aligned_grouped(&s, 6, 2, 3).unwrap();
        let mut brute = vec![0usize; 6];
        for gid in 0..s.total_neurons() {
            brute[p.rank_of(&s, gid)] += 1;
        }
        assert_eq!(p.rank_counts(&s), brute);
    }

    #[test]
    fn grouped_validation_errors() {
        let s = spec(&[10, 10, 10]);
        // m not a multiple of ranks_per_area
        assert!(Placement::area_aligned_grouped(&s, 4, 1, 3).is_err());
        // more groups than areas: 4 groups of 2 need >= 4 areas
        assert!(Placement::area_aligned_grouped(&s, 8, 1, 2).is_err());
        // zero group size
        assert!(Placement::area_aligned_grouped(&s, 4, 1, 0).is_err());
        // ok: 3 areas on 3 groups of 2
        assert!(Placement::area_aligned_grouped(&s, 6, 2, 2).is_ok());
    }

    #[test]
    fn area_aligned_ghosts_reflect_imbalance() {
        let s = spec(&[100, 60]);
        let p = Placement::area_aligned(&s, 2, 1).unwrap();
        assert_eq!(p.rank_counts(&s), vec![100, 60]);
        assert_eq!(p.ghost_counts(&s), vec![0, 40]);
    }
}
