//! # nsim — structure-aware brain-scale spiking-network simulation
//!
//! Reproduction of *Exploiting network topology in brain-scale simulations
//! of spiking neural networks* (Lober, Diesmann, Kunkel; CS.DC 2026) as a
//! three-layer Rust + JAX + Pallas stack.
//!
//! The crate contains two complementary execution substrates:
//!
//! * a **functional engine** ([`engine`]) — a NEST-like distributed
//!   simulation kernel in which MPI ranks are OS threads communicating
//!   through a simulated MPI layer ([`comm`]).  It executes real spiking
//!   networks and proves that the conventional and structure-aware
//!   strategies are *observationally equivalent* (identical spike trains);
//! * a **virtual cluster** ([`vcluster`]) — a discrete-event performance
//!   model of `M` ranks × `T_M` threads with calibrated per-phase cost
//!   models, an `MPI_Alltoall` cost curve and serially-correlated
//!   cycle-time noise, which reproduces the paper's figures at full
//!   SuperMUC-NG / JURECA-DC scale (the hardware substitution documented in
//!   `DESIGN.md` §2).
//!
//! The [`theory`] module implements the paper's analytical machinery
//! (order statistics of cycle-time maxima, CLT lumping, irregular-access
//! fractions), and [`figures`] regenerates every figure of the evaluation.
//!
//! Layer boundaries:
//! * L1/L2 live in `python/compile` (Pallas kernel + jax step functions),
//!   lowered once to `artifacts/*.hlo.txt`;
//! * [`runtime`] loads those artifacts through PJRT (`xla` crate) so the
//!   update phase can run the compiled XLA computation;
//! * everything else — placement, tables, communication, scheduling — is
//!   the L3 coordinator in this crate.

pub mod util;
pub mod config;
pub mod network;
pub mod models;
pub mod placement;
pub mod tables;
pub mod comm;
pub mod obs;
pub mod engine;
pub mod runtime;
pub mod serve;
pub mod vcluster;
pub mod theory;
pub mod figures;

/// Simulation resolution step in ms (NEST default used throughout the paper).
pub const H_MS: f64 = 0.1;
