//! `nsim` — launcher for the structure-aware spiking-network simulation
//! framework.
//!
//! Subcommands:
//!   simulate   run the functional engine on a bundled model
//!   launch     run one OS process per rank over the socket transport
//!   serve      long-running job server over a Unix-domain socket
//!   submit     client for `serve`: submit/status/cancel/fetch jobs
//!   scenarios  list the scenario catalog (built-ins + configs/scenarios)
//!   figure     regenerate one figure of the paper (see --list)
//!   figures    regenerate every figure
//!   theory     print the analytical predictions (eqs 7/11/12/13-17)
//!   info       print artifact/registry and model-zoo information

use anyhow::{bail, Context, Result};
use nsim::config::{RunConfig, Strategy, TransportKind};
use nsim::figures::{run_figure, FigOptions, ALL_FIGURES};
use nsim::models;
use nsim::util::cli::Args;
use nsim::util::tablefmt::{fnum, Table};
use nsim::util::timers::Phase;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand() {
        Some("simulate") => cmd_simulate(&args),
        Some("launch") => cmd_launch(&args),
        Some("serve") => cmd_serve(&args),
        Some("submit") => cmd_submit(&args),
        Some("scenarios") => cmd_scenarios(&args),
        Some("figure") => cmd_figure(&args),
        Some("figures") => cmd_figures(&args),
        Some("theory") => cmd_theory(&args),
        Some("info") => cmd_info(&args),
        _ => {
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    println!(
        "nsim — structure-aware brain-scale spiking-network simulation\n\
         \n\
         usage: nsim <command> [options]\n\
         \n\
         commands:\n\
           simulate --model <sanity|deep-pipeline|mam-benchmark|mam>\n\
                    [--strategy s]\n\
                    [--ranks M] [--threads T] [--t-model ms] [--seed n]\n\
                    [--scale f] [--areas n] [--update-path native|xla]\n\
                    [--exec sequential|pooled|pooled-channels]\n\
                    [--comm blocking|overlap] [--comm-depth D]\n\
                    [--quota spikes] [--ranks-per-area R]\n\
                    [--transport shmem|socket]       comm fabric\n\
                    [--socket-rank r] [--socket-dir d]  (socket mode:\n\
                    this process runs rank r; usually set by launch)\n\
                    [--record-spikes]\n\
                    [--spikes-out path]              spike train as text\n\
                    [--lesion-area name [--lesion-factor f]]  scale (or\n\
                    sever, f=0) one area's long-range pathways\n\
                    [--record-cycle-times]           raw per-cycle vectors\n\
                    [--trace out.json]               Perfetto span trace\n\
                    [--stats-json out.json]          machine-readable report\n\
                    [--comm-timeout secs]            comm watchdog\n\
                    [--checkpoint-every epochs] [--checkpoint-path p]\n\
                    [--restore path]                 resume a snapshot\n\
                    [--fault-plan plan.json]         fault injection\n\
                    [--straggler r:factor:from:to[,..]]\n\
                    [--delay-deposit r:ms:from:to[,..]]\n\
                    [--kill-at r:epoch[,..]]\n\
           launch   --ranks M [simulate options]\n\
                    spawn M `simulate` processes over the socket\n\
                    transport, merge their --spikes-out files, and\n\
                    propagate any child failure (per-process --trace /\n\
                    --stats-json outputs get a .rank<r> suffix)\n\
           serve    --socket path [--workers N] [--workdir dir]\n\
                    [--scenario-dir dir]  scenario catalog overlay\n\
                    [--stats-json base] [--trace base]  per-job outputs\n\
                    (suffixed .job-<n>) [--trace-mode unbounded|ring[:N]]\n\
                    [--checkpoint-every epochs]  default job checkpointing\n\
                    run a job server on a Unix-domain socket; stop it\n\
                    with `nsim submit --shutdown`\n\
           submit   --socket path --scenario name [--params JSON]\n\
                    [--sweep JSON]  fan one submission into a grid\n\
                    [--follow] [--spikes-out base]  stream to terminal\n\
                    state, write per-job spike trains\n\
                    | --list | --status id | --cancel id\n\
                    | --result id [--spikes-out path] | --shutdown\n\
           scenarios [--dir dir] [--json]\n\
                    list the scenario catalog (built-ins overlaid by\n\
                    --dir, default configs/scenarios)\n\
           figure <name> [--t-model ms] [--seed n] [--out dir]\n\
           figures [--t-model ms] [--out dir]\n\
           theory [--d D] [--ranks M] [--threads T] [--ranks-per-area R]\n\
           info\n\
         \n\
         figures: {}",
        ALL_FIGURES.join(" ")
    );
}

fn build_model(
    args: &Args,
    m_ranks: usize,
) -> Result<nsim::network::ModelSpec> {
    let name = args.str_or("model", "sanity");
    let scale = args.f64_or("scale", 0.01)?;
    let d_min_inter = args.f64_or("d-min-inter", 1.0)?;
    let lesion_area = args.str_opt("lesion-area");
    let lesion_factor = args.f64_opt("lesion-factor")?;
    if lesion_area.is_none() && lesion_factor.is_some() {
        bail!("--lesion-factor without --lesion-area");
    }
    let spec = match name.as_str() {
        "sanity" => {
            let n = args.usize_or("n-per-area", 500)? as u32;
            let areas = args.usize_or("areas", m_ranks.max(2))?;
            models::sanity_net(n, areas)
        }
        "deep-pipeline" => {
            let n = args.usize_or("n-per-area", 240)? as u32;
            let areas = args.usize_or("areas", m_ranks.max(2))?;
            models::deep_pipeline_net(n, areas)
        }
        "mam-benchmark" | "mamb" => {
            let areas = args.usize_or("areas", m_ranks.max(2))?;
            models::mam_benchmark(areas, scale, d_min_inter)
        }
        "mam" => models::mam(scale, d_min_inter),
        other => bail!("unknown model {other:?}"),
    }?;
    // perturbation variants: scale (or sever, factor 0) one area's
    // long-range pathways — same draws, same topology, scaled weights
    match lesion_area {
        Some(area) => spec.with_lesion(&area, lesion_factor.unwrap_or(0.0)),
        None => Ok(spec),
    }
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let trace_path = args.str_opt("trace");
    let stats_path = args.str_opt("stats-json");
    let spikes_path = args.str_opt("spikes-out");
    if trace_path.as_deref() == Some("true") {
        bail!("--trace needs an output path, e.g. --trace trace.json");
    }
    if stats_path.as_deref() == Some("true") {
        bail!(
            "--stats-json needs an output path, e.g. --stats-json \
             stats.json"
        );
    }
    if spikes_path.as_deref() == Some("true") {
        bail!(
            "--spikes-out needs an output path, e.g. --spikes-out \
             spikes.txt"
        );
    }
    // raw per-cycle time vectors are opt-in (--record-cycle-times):
    // the streaming interval histograms below are always on and bounded
    let cfg = RunConfig {
        record_spikes: true,
        ..RunConfig::default()
    }
    .override_from_args(args)?;
    let socket_rank = args.str_opt("socket-rank");
    let socket_dir = args.str_opt("socket-dir");
    if cfg.transport == TransportKind::Socket {
        if socket_rank.is_none() || socket_dir.is_none() {
            bail!(
                "--transport socket runs one rank per process and needs \
                 --socket-rank and --socket-dir (usually supplied by \
                 `nsim launch`)"
            );
        }
    } else if socket_rank.is_some() || socket_dir.is_some() {
        bail!(
            "--socket-rank/--socket-dir only apply with \
             --transport socket"
        );
    }
    let spec = build_model(args, cfg.m_ranks)?;
    args.finish()?;

    println!(
        "model {} | {} areas | {} neurons | strategy {} | M={} T={} \
         R/area={} | exec {} | comm {} (depth {}) | transport {}{} | \
         T_model {} ms | D={}",
        spec.name,
        spec.n_areas(),
        spec.total_neurons(),
        cfg.strategy.name(),
        cfg.m_ranks,
        cfg.threads_per_rank,
        cfg.ranks_per_area,
        cfg.exec.name(),
        cfg.comm.name(),
        cfg.comm_depth,
        cfg.transport.name(),
        socket_rank
            .as_deref()
            .map(|r| format!(" [rank {r}]"))
            .unwrap_or_default(),
        cfg.t_model_ms,
        spec.delay_ratio(),
    );
    let t0 = std::time::Instant::now();
    let res = if cfg.transport == TransportKind::Socket {
        run_socket_rank(
            &spec,
            &cfg,
            socket_rank.as_deref().unwrap_or_default(),
            socket_dir.as_deref().unwrap_or_default(),
        )?
    } else {
        nsim::engine::simulate(&spec, &cfg)?
    };
    let wall = t0.elapsed().as_secs_f64();

    let mut table = Table::new(&["phase", "mean s", "share", "slowest s"]);
    let total = res.mean_times.total();
    for p in Phase::ALL {
        let secs = res.mean_times.get(p);
        table.row(vec![
            p.name().into(),
            fnum(secs),
            format!("{:.1}%", 100.0 * secs / total.max(1e-12)),
            fnum(res.max_times.get(p)),
        ]);
    }
    println!("{}", table.render());
    println!(
        "cycles {} | spikes {} | mean rate {:.2} /s | RTF {:.1} | \
         wall {:.2}s",
        res.s_cycles,
        res.n_spikes(),
        res.mean_rate_hz(spec.total_neurons() as usize),
        res.rtf(),
        wall,
    );
    let cs = &res.comm_stats;
    println!(
        "comm: a2a {} | swaps {} | bytes {} | resizes {} | max/pair {} | \
         depth {} | overlapped {} | early-drained {} | post {} | wait {} \
         | hidden {}",
        cs.alltoall_calls,
        cs.local_swaps,
        cs.bytes_sent,
        cs.resize_rounds,
        cs.max_send_per_pair,
        res.effective_comm_depth,
        cs.overlapped_exchanges,
        cs.early_drained_sources,
        fnum(cs.post_secs),
        fnum(cs.complete_wait_secs),
        fnum(cs.hidden_secs),
    );
    for (tier, ts) in [
        ("global", &res.comm_tiers.global),
        ("local", &res.comm_tiers.local),
    ] {
        println!(
            "comm[{tier}]: a2a {} | swaps {} | bytes {} | resizes {} | \
             sync {} | wait {} | hidden {}",
            ts.alltoall_calls,
            ts.local_swaps,
            ts.bytes_sent,
            ts.resize_rounds,
            fnum(ts.sync_secs),
            fnum(ts.complete_wait_secs),
            fnum(ts.hidden_secs),
        );
    }

    // observability summary: pooled compute-interval distribution,
    // straggler attribution and the sync-model closure
    let (n, mu, sigma) =
        nsim::obs::intervals::pooled(res.intervals.iter().map(|t| &t.local));
    if n > 0 {
        println!(
            "intervals: n {n} | mean {:.4} ms | sd {:.4} ms | cv {:.3}",
            mu * 1e3,
            sigma * 1e3,
            if mu > 0.0 { sigma / mu } else { 0.0 },
        );
    }
    if let Some((rank, waits, late)) = res.blame.merged_all().top() {
        println!(
            "stragglers: most-blamed rank {rank} (last arriver in \
             {waits} waits, {} s total lateness)",
            fnum(late),
        );
    }
    if let Some(model) = nsim::obs::report::fitted_model(&res) {
        let (pred_local, pred_global) =
            nsim::obs::report::predicted_sync(model, &cfg, &res);
        let m = res.m_ranks.max(1) as f64;
        let meas_global = (res.comm_tiers.global.sync_secs
            + res.comm_tiers.global.complete_wait_secs)
            / m;
        let meas_local = (res.comm_tiers.local.sync_secs
            + res.comm_tiers.local.complete_wait_secs)
            / m;
        println!(
            "T_sync[global]: predicted {} s | measured {} s",
            fnum(pred_global),
            fnum(meas_global),
        );
        println!(
            "T_sync[local]:  predicted {} s | measured {} s",
            fnum(pred_local),
            fnum(meas_local),
        );
    }
    if let Some(p) = trace_path {
        nsim::obs::trace::write_chrome_trace(
            std::path::Path::new(&p),
            &res.spans,
            res.m_ranks,
        )?;
        println!("trace: {} spans -> {p}", res.spans.len());
    }
    if let Some(p) = stats_path {
        nsim::obs::report::write_report(
            std::path::Path::new(&p),
            &spec.name,
            &cfg,
            &res,
        )?;
        println!("stats: -> {p}");
    }
    if let Some(p) = spikes_path {
        write_spike_file(&p, &res.spikes)?;
        println!("spikes: {} -> {p}", res.spikes.len());
    }
    Ok(())
}

/// Dispatch one socket-transport rank (Unix only — the transport is
/// built on Unix-domain sockets).
#[cfg(unix)]
fn run_socket_rank(
    spec: &nsim::network::ModelSpec,
    cfg: &RunConfig,
    rank: &str,
    dir: &str,
) -> Result<nsim::engine::SimResult> {
    let rank: usize = rank
        .parse()
        .with_context(|| format!("bad --socket-rank {rank:?}"))?;
    nsim::engine::simulate_socket(
        spec,
        cfg,
        rank,
        std::path::Path::new(dir),
    )
}

#[cfg(not(unix))]
fn run_socket_rank(
    _spec: &nsim::network::ModelSpec,
    _cfg: &RunConfig,
    _rank: &str,
    _dir: &str,
) -> Result<nsim::engine::SimResult> {
    bail!("--transport socket requires a Unix platform")
}

/// One spike per line, `step gid`, already in the canonical
/// `(step, gid)` order — the textual form the launcher merges and the
/// equivalence checks diff.
fn write_spike_file(path: &str, spikes: &[(u64, u32)]) -> Result<()> {
    use std::fmt::Write as _;
    let mut out = String::with_capacity(spikes.len() * 12);
    for &(step, gid) in spikes {
        let _ = writeln!(out, "{step} {gid}");
    }
    std::fs::write(path, out)
        .with_context(|| format!("writing spike file {path}"))
}

/// `nsim launch`: spawn `--ranks` copies of `simulate` over the socket
/// transport, one OS process per rank, and fail if any child fails.
///
/// All simulate options are forwarded verbatim to every child, with
/// three exceptions: per-process output paths (`--trace`,
/// `--stats-json`) get a `.rank<r>` suffix so the processes do not
/// clobber each other; `--spikes-out` becomes per-rank files the
/// launcher merges (and deletes) after all children exit; and the
/// launcher owns `--ranks`/`--transport`/`--socket-*` itself.
fn cmd_launch(args: &Args) -> Result<()> {
    let ranks = args.usize_or("ranks", 2)?;
    anyhow::ensure!(ranks >= 1, "launch needs --ranks >= 1");
    let spikes_out = args.str_opt("spikes-out");
    if spikes_out.as_deref() == Some("true") {
        bail!(
            "--spikes-out needs an output path, e.g. --spikes-out \
             spikes.txt"
        );
    }
    // everything else forwards verbatim — deliberately no
    // args.finish() here: the children validate their own options

    enum Fwd {
        /// Forwarded to every child unchanged.
        Plain(String),
        /// A per-process output path: child r gets `<base>.rank<r>`.
        RankPath { key: String, base: String },
    }

    // re-derive the forwarded argument list from the raw argv (Args
    // normalizes --key=value and --key value identically, but we must
    // preserve *which* tokens belong to which option to rewrite them)
    let raw: Vec<String> = std::env::args().skip(1).collect();
    anyhow::ensure!(
        raw.first().map(String::as_str) == Some("launch"),
        "launch must be the first argument"
    );
    let mut fwd: Vec<Fwd> = Vec::new();
    let mut i = 1;
    while i < raw.len() {
        let Some(body) = raw[i].strip_prefix("--") else {
            fwd.push(Fwd::Plain(raw[i].clone()));
            i += 1;
            continue;
        };
        let (key, inline_val) = match body.split_once('=') {
            Some((k, v)) => (k.to_string(), Some(v.to_string())),
            None => (body.to_string(), None),
        };
        // same value-detection rule as Args::parse: the next token is
        // this option's value iff it does not start with "--"
        let sep_val = if inline_val.is_none() {
            raw.get(i + 1)
                .filter(|n| !n.starts_with("--"))
                .cloned()
        } else {
            None
        };
        i += 1 + sep_val.is_some() as usize;
        let val = inline_val.or(sep_val);
        match key.as_str() {
            // launcher-owned: never forwarded (the launcher re-issues
            // --ranks and the socket wiring itself)
            "ranks" | "spikes-out" | "transport" | "socket-rank"
            | "socket-dir" => {}
            // per-process outputs: suffixed per rank
            "trace" | "stats-json" => {
                let base = val.ok_or_else(|| {
                    anyhow::anyhow!("--{key} needs an output path")
                })?;
                fwd.push(Fwd::RankPath { key, base });
            }
            _ => {
                fwd.push(Fwd::Plain(format!("--{key}")));
                if let Some(v) = val {
                    fwd.push(Fwd::Plain(v));
                }
            }
        }
    }

    let dir = std::env::temp_dir()
        .join(format!("nsim-launch-{}", std::process::id()));
    std::fs::create_dir_all(&dir)
        .with_context(|| format!("creating {}", dir.display()))?;
    let exe = std::env::current_exe().context("locating nsim binary")?;
    println!(
        "launch: {ranks} ranks over the socket transport in {}",
        dir.display()
    );
    let mut children = Vec::with_capacity(ranks);
    for r in 0..ranks {
        let mut cmd = std::process::Command::new(&exe);
        cmd.arg("simulate");
        for f in &fwd {
            match f {
                Fwd::Plain(s) => {
                    cmd.arg(s);
                }
                Fwd::RankPath { key, base } => {
                    cmd.arg(format!("--{key}"));
                    cmd.arg(format!("{base}.rank{r}"));
                }
            }
        }
        cmd.arg("--ranks").arg(ranks.to_string());
        cmd.arg("--transport").arg("socket");
        cmd.arg("--socket-rank").arg(r.to_string());
        cmd.arg("--socket-dir").arg(&dir);
        if let Some(base) = &spikes_out {
            cmd.arg("--spikes-out").arg(format!("{base}.rank{r}"));
        }
        let child = cmd
            .spawn()
            .with_context(|| format!("spawning rank {r}"))?;
        children.push((r, child));
    }
    let mut failures = Vec::new();
    for (r, mut child) in children {
        let status = child
            .wait()
            .with_context(|| format!("waiting for rank {r}"))?;
        if !status.success() {
            failures.push((r, status));
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
    if !failures.is_empty() {
        for (r, status) in &failures {
            eprintln!("launch: rank {r} failed ({status})");
        }
        bail!("{} of {ranks} rank process(es) failed", failures.len());
    }
    if let Some(base) = &spikes_out {
        let mut all: Vec<(u64, u64)> = Vec::new();
        for r in 0..ranks {
            let part = format!("{base}.rank{r}");
            let text = std::fs::read_to_string(&part)
                .with_context(|| format!("reading {part}"))?;
            for line in text.lines() {
                let mut it = line.split_whitespace();
                let step: u64 = it
                    .next()
                    .and_then(|s| s.parse().ok())
                    .with_context(|| format!("bad spike line {line:?}"))?;
                let gid: u64 = it
                    .next()
                    .and_then(|s| s.parse().ok())
                    .with_context(|| format!("bad spike line {line:?}"))?;
                all.push((step, gid));
            }
            let _ = std::fs::remove_file(&part);
        }
        // per-rank trains are already (step, gid)-sorted; the global
        // sort merges them into the canonical order of the in-process
        // engine, which is what the equivalence checks diff against
        all.sort_unstable();
        use std::fmt::Write as _;
        let mut out = String::with_capacity(all.len() * 12);
        for (step, gid) in &all {
            let _ = writeln!(out, "{step} {gid}");
        }
        std::fs::write(base, out)
            .with_context(|| format!("writing merged {base}"))?;
        println!("launch: merged {} spikes -> {base}", all.len());
    }
    println!("launch: all {ranks} ranks completed");
    Ok(())
}

/// `nsim serve`: run the job server until a client sends `shutdown`.
#[cfg(unix)]
fn cmd_serve(args: &Args) -> Result<()> {
    use nsim::serve::server::{self, ServeOpts};
    let socket = args.str_or("socket", "nsim-serve.sock");
    let mut opts = ServeOpts::new(&socket);
    opts.workers = args.usize_or("workers", 2)?;
    opts.workdir = args.str_or("workdir", ".").into();
    opts.scenario_dir =
        Some(args.str_or("scenario-dir", "configs/scenarios").into());
    opts.stats_base = args.str_opt("stats-json");
    opts.trace_base = args.str_opt("trace");
    if let Some(mode) = args.str_opt("trace-mode") {
        opts.trace_mode = nsim::config::parse_trace_mode(&mode)?;
    }
    opts.checkpoint_every = args.u64_or("checkpoint-every", 0)?;
    args.finish()?;
    let workers = opts.workers;
    let handle = server::start(opts)?;
    println!("serve: listening on {socket} with {workers} workers");
    handle.join();
    println!("serve: shut down");
    Ok(())
}

#[cfg(not(unix))]
fn cmd_serve(_args: &Args) -> Result<()> {
    bail!("`nsim serve` needs Unix-domain sockets (Unix only)")
}

/// Parse a `--params` / `--sweep` CLI value: a JSON object literal.
#[cfg(unix)]
fn parse_json_object(
    what: &str,
    text: Option<&str>,
) -> Result<std::collections::BTreeMap<String, nsim::util::json::Json>> {
    let Some(text) = text else { return Ok(Default::default()) };
    let v = nsim::util::json::parse(text)
        .with_context(|| format!("parsing --{what}"))?;
    v.as_obj()
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("--{what} must be a JSON object"))
}

/// `nsim submit`: client ops against a running `nsim serve`.
#[cfg(unix)]
fn cmd_submit(args: &Args) -> Result<()> {
    use nsim::serve::Client;
    use nsim::util::json;

    let socket = args.str_or("socket", "nsim-serve.sock");
    let list = args.flag("list");
    let status = args.str_opt("status");
    let cancel = args.str_opt("cancel");
    let result = args.str_opt("result");
    let shutdown = args.flag("shutdown");
    let scenario = args.str_opt("scenario");
    let params_text = args.str_opt("params");
    let sweep_text = args.str_opt("sweep");
    let follow = args.flag("follow");
    let verbose = args.flag("verbose");
    let spikes_out = args.str_opt("spikes-out");
    args.finish()?;

    let mut client = Client::connect(std::path::Path::new(&socket))?;
    if list {
        println!("{}", json::to_string_pretty(&client.jobs()?));
        return Ok(());
    }
    if let Some(id) = status {
        println!("{}", json::to_string_pretty(&client.status(&id)?));
        return Ok(());
    }
    if let Some(id) = cancel {
        let resp = client.cancel(&id)?;
        println!(
            "cancel {id}: was {}",
            resp.get("was")
                .and_then(json::Json::as_str)
                .unwrap_or("unknown")
        );
        return Ok(());
    }
    if let Some(id) = result {
        let resp = client.result(&id)?;
        let state = resp
            .get("state")
            .and_then(json::Json::as_str)
            .unwrap_or("unknown");
        if let (Some(path), Some(spikes)) = (
            &spikes_out,
            resp.get("spikes").and_then(json::Json::as_str),
        ) {
            std::fs::write(path, spikes)
                .with_context(|| format!("writing {path}"))?;
            println!("result {id}: {state}, spikes -> {path}");
        } else {
            println!("result {id}: {state}");
            if let Some(e) =
                resp.get("error").and_then(json::Json::as_str)
            {
                println!("  error: {e}");
            }
        }
        return Ok(());
    }
    if shutdown {
        client.shutdown()?;
        println!("server shutting down");
        return Ok(());
    }

    let Some(scenario) = scenario else {
        bail!(
            "submit needs --scenario (or one of --list --status \
             --cancel --result --shutdown)"
        );
    };
    let params = parse_json_object("params", params_text.as_deref())?;
    let sweep = parse_json_object("sweep", sweep_text.as_deref())?;
    let ids = client.submit(&scenario, &params, &sweep, follow)?;
    println!("submitted: {}", ids.join(" "));
    if !follow {
        return Ok(());
    }
    let ends = client.follow_until_complete(|ev| {
        match ev.get("event").and_then(json::Json::as_str) {
            Some("state") => {
                let job = ev
                    .get("job")
                    .and_then(json::Json::as_str)
                    .unwrap_or("?");
                let state = ev
                    .get("state")
                    .and_then(json::Json::as_str)
                    .unwrap_or("?");
                println!("{job}: {state}");
            }
            Some("resume") => {
                let job = ev
                    .get("job")
                    .and_then(json::Json::as_str)
                    .unwrap_or("?");
                println!("{job}: resuming from checkpoint");
            }
            Some("progress") if verbose => {
                let job = ev
                    .get("job")
                    .and_then(json::Json::as_str)
                    .unwrap_or("?");
                let cycle = ev
                    .get("cycle")
                    .and_then(json::Json::as_usize)
                    .unwrap_or(0);
                let total = ev
                    .get("s_cycles")
                    .and_then(json::Json::as_usize)
                    .unwrap_or(0);
                println!("{job}: cycle {cycle}/{total}");
            }
            _ => {}
        }
    })?;
    for end in &ends {
        if let (Some(base), Some(spikes)) = (&spikes_out, &end.spikes) {
            let path = if ends.len() == 1 {
                base.clone()
            } else {
                format!("{base}.{}", end.job)
            };
            std::fs::write(&path, spikes)
                .with_context(|| format!("writing {path}"))?;
            println!("{}: spikes -> {path}", end.job);
        }
    }
    let bad: Vec<&str> = ends
        .iter()
        .filter(|e| e.state != "done")
        .map(|e| e.job.as_str())
        .collect();
    if !bad.is_empty() {
        bail!("jobs did not complete: {}", bad.join(" "));
    }
    Ok(())
}

#[cfg(not(unix))]
fn cmd_submit(_args: &Args) -> Result<()> {
    bail!("`nsim submit` needs Unix-domain sockets (Unix only)")
}

/// `nsim scenarios`: list the catalog without a server.
fn cmd_scenarios(args: &Args) -> Result<()> {
    let dir = args.str_or("dir", "configs/scenarios");
    let as_json = args.flag("json");
    args.finish()?;
    let cat = nsim::serve::Catalog::load(Some(std::path::Path::new(
        &dir,
    )))?;
    if as_json {
        println!(
            "{}",
            nsim::util::json::to_string_pretty(&cat.to_json())
        );
        return Ok(());
    }
    for s in cat.iter() {
        println!("{:<18} {}", s.name, s.description);
    }
    Ok(())
}

fn cmd_figure(args: &Args) -> Result<()> {
    let name = args
        .positional
        .get(1)
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("usage: nsim figure <name>"))?;
    let opts = FigOptions {
        t_model_ms: args.f64_or("t-model", 1_000.0)?,
        seed: args.u64_or("seed", 654)?,
    };
    let out = args.str_or("out", "results");
    args.finish()?;
    run_figure(&name, &opts)?.emit(&out)
}

fn cmd_figures(args: &Args) -> Result<()> {
    let opts = FigOptions {
        t_model_ms: args.f64_or("t-model", 1_000.0)?,
        seed: args.u64_or("seed", 654)?,
    };
    let out = args.str_or("out", "results");
    args.finish()?;
    for name in ALL_FIGURES {
        run_figure(name, &opts)?.emit(&out)?;
    }
    Ok(())
}

fn cmd_theory(args: &Args) -> Result<()> {
    use nsim::theory::{delivery, sync};
    let d = args.usize_or("d", 10)? as u32;
    let m = args.usize_or("ranks", 128)?;
    let t_m = args.usize_or("threads", 48)?;
    let ranks_per_area = args.usize_or("ranks-per-area", 1)?;
    if ranks_per_area == 0 || m % ranks_per_area != 0 {
        bail!(
            "--ranks-per-area must be >= 1 and divide --ranks \
             ({m} % {ranks_per_area} != 0)"
        );
    }
    args.finish()?;

    println!("== synchronization theory (eqs 2-12) ==");
    println!(
        "xi_M(M={m}) = {:.3} sd; sync ratio 1/sqrt(D={d}) = {:.3}",
        nsim::util::stats::blom_xi(m),
        sync::sync_ratio(d)
    );
    println!(
        "upper 3.5% of cycle times cover {:.1}% of per-cycle maxima (eq 12)",
        100.0 * sync::maxima_tail_coverage(0.035, m)
    );
    let model = sync::CycleTimeModel::paper_default();
    let window = d.saturating_sub(1);
    println!(
        "split-phase overlap: a window of D-1={window} cycles hides \
         {:.0}% of the remaining sync time \
         (predicted gain {:.2} s per 100k cycles)",
        100.0 * sync::overlap_hidden_fraction(model, m, d, window),
        sync::predicted_overlap_gain(model, m, 100_000, d, window),
    );
    // conventional runs (d = 1) gain nothing at depth 1; a depth-D
    // pipeline opens a window of depth-1 cycles of the realized slack
    let slack = 4u32;
    println!(
        "depth-D pipeline (conventional, {slack} cycles realized slack): \
         gain per 100k cycles = {:.2} s (depth 2), {:.2} s (depth 4)",
        sync::predicted_depth_gain(model, m, 100_000, 1, 2, slack),
        sync::predicted_depth_gain(model, m, 100_000, 1, 4, slack),
    );
    // hybrid two-tier schedule: D local rounds per epoch inside each
    // area group, one global exchange across the groups per epoch
    let (local_sync, global_sync) = sync::expected_hybrid_sync_times(
        model,
        m,
        ranks_per_area,
        100_000,
        d,
        d,
    );
    println!(
        "hybrid two-tier (R={ranks_per_area}/area, {} groups, D={d} \
         local rounds/epoch): per 100k cycles local sync {local_sync:.2} \
         s, global sync {global_sync:.2} s; overlap hides up to {:.2} s \
         of the global tier",
        m / ranks_per_area,
        sync::predicted_hybrid_depth_gain(
            model,
            m,
            ranks_per_area,
            100_000,
            d,
            1,
            d.saturating_sub(1),
            d,
        ),
    );
    let sc = delivery::DeliveryScenario::default();
    println!("\n== spike-delivery theory (eqs 13-17) ==");
    println!(
        "f_irr conventional(M={m}, T={t_m}) = {:.4}",
        delivery::f_irr_conventional(&sc, m, t_m)
    );
    println!(
        "f_irr structure-aware          = {:.4} ({:.0}% reduction)",
        delivery::f_irr_structure(&sc, m, t_m),
        100.0 * delivery::irregular_access_reduction(&sc, m, t_m)
    );
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    args.finish()?;
    println!("model zoo:");
    for (name, spec) in [
        ("sanity (2x500)", models::sanity_net(500, 2)?),
        (
            "mam-benchmark 32 areas (paper scale)",
            models::mam_benchmark(32, 1.0, 1.0)?,
        ),
        ("mam (paper scale)", models::mam(1.0, 1.0)?),
    ] {
        println!(
            "  {name}: {} areas, {} neurons, K={}, D={}",
            spec.n_areas(),
            spec.total_neurons(),
            spec.k_total(),
            spec.delay_ratio()
        );
    }
    match nsim::runtime::registry::Registry::open_default() {
        Ok(reg) => {
            println!(
                "artifacts ({}):",
                nsim::runtime::registry::default_dir()
            );
            for m in reg.metas() {
                println!(
                    "  {} kind={} batch={}{}",
                    m.name,
                    m.kind,
                    m.batch,
                    m.steps
                        .map(|k| format!(" steps={k}"))
                        .unwrap_or_default()
                );
            }
        }
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    for s in [
        Strategy::Conventional,
        Strategy::Intermediate,
        Strategy::StructureAware,
    ] {
        println!(
            "strategy {}: area placement={}, dual pathways={}",
            s.name(),
            s.structure_aware_placement(),
            s.dual_pathways()
        );
    }
    Ok(())
}
