//! `nsim` — launcher for the structure-aware spiking-network simulation
//! framework.
//!
//! Subcommands:
//!   simulate   run the functional engine on a bundled model
//!   figure     regenerate one figure of the paper (see --list)
//!   figures    regenerate every figure
//!   theory     print the analytical predictions (eqs 7/11/12/13-17)
//!   info       print artifact/registry and model-zoo information

use anyhow::{bail, Result};
use nsim::config::{RunConfig, Strategy};
use nsim::figures::{run_figure, FigOptions, ALL_FIGURES};
use nsim::models;
use nsim::util::cli::Args;
use nsim::util::tablefmt::{fnum, Table};
use nsim::util::timers::Phase;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand() {
        Some("simulate") => cmd_simulate(&args),
        Some("figure") => cmd_figure(&args),
        Some("figures") => cmd_figures(&args),
        Some("theory") => cmd_theory(&args),
        Some("info") => cmd_info(&args),
        _ => {
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    println!(
        "nsim — structure-aware brain-scale spiking-network simulation\n\
         \n\
         usage: nsim <command> [options]\n\
         \n\
         commands:\n\
           simulate --model <sanity|mam-benchmark|mam> [--strategy s]\n\
                    [--ranks M] [--threads T] [--t-model ms] [--seed n]\n\
                    [--scale f] [--areas n] [--update-path native|xla]\n\
                    [--exec sequential|pooled|pooled-channels]\n\
                    [--comm blocking|overlap] [--comm-depth D]\n\
                    [--quota spikes] [--ranks-per-area R]\n\
                    [--record-spikes]\n\
                    [--record-cycle-times]           raw per-cycle vectors\n\
                    [--trace out.json]               Perfetto span trace\n\
                    [--stats-json out.json]          machine-readable report\n\
                    [--comm-timeout secs]            comm watchdog\n\
                    [--checkpoint-every epochs] [--checkpoint-path p]\n\
                    [--restore path]                 resume a snapshot\n\
                    [--fault-plan plan.json]         fault injection\n\
                    [--straggler r:factor:from:to[,..]]\n\
                    [--delay-deposit r:ms:from:to[,..]]\n\
                    [--kill-at r:epoch[,..]]\n\
           figure <name> [--t-model ms] [--seed n] [--out dir]\n\
           figures [--t-model ms] [--out dir]\n\
           theory [--d D] [--ranks M] [--threads T] [--ranks-per-area R]\n\
           info\n\
         \n\
         figures: {}",
        ALL_FIGURES.join(" ")
    );
}

fn build_model(
    args: &Args,
    m_ranks: usize,
) -> Result<nsim::network::ModelSpec> {
    let name = args.str_or("model", "sanity");
    let scale = args.f64_or("scale", 0.01)?;
    let d_min_inter = args.f64_or("d-min-inter", 1.0)?;
    match name.as_str() {
        "sanity" => {
            let n = args.usize_or("n-per-area", 500)? as u32;
            let areas = args.usize_or("areas", m_ranks.max(2))?;
            models::sanity_net(n, areas)
        }
        "mam-benchmark" | "mamb" => {
            let areas = args.usize_or("areas", m_ranks.max(2))?;
            models::mam_benchmark(areas, scale, d_min_inter)
        }
        "mam" => models::mam(scale, d_min_inter),
        other => bail!("unknown model {other:?}"),
    }
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let trace_path = args.str_opt("trace");
    let stats_path = args.str_opt("stats-json");
    if trace_path.as_deref() == Some("true") {
        bail!("--trace needs an output path, e.g. --trace trace.json");
    }
    if stats_path.as_deref() == Some("true") {
        bail!(
            "--stats-json needs an output path, e.g. --stats-json \
             stats.json"
        );
    }
    // raw per-cycle time vectors are opt-in (--record-cycle-times):
    // the streaming interval histograms below are always on and bounded
    let cfg = RunConfig {
        record_spikes: true,
        ..RunConfig::default()
    }
    .override_from_args(args)?;
    let spec = build_model(args, cfg.m_ranks)?;
    args.finish()?;

    println!(
        "model {} | {} areas | {} neurons | strategy {} | M={} T={} \
         R/area={} | exec {} | comm {} (depth {}) | T_model {} ms | D={}",
        spec.name,
        spec.n_areas(),
        spec.total_neurons(),
        cfg.strategy.name(),
        cfg.m_ranks,
        cfg.threads_per_rank,
        cfg.ranks_per_area,
        cfg.exec.name(),
        cfg.comm.name(),
        cfg.comm_depth,
        cfg.t_model_ms,
        spec.delay_ratio(),
    );
    let t0 = std::time::Instant::now();
    let res = nsim::engine::simulate(&spec, &cfg)?;
    let wall = t0.elapsed().as_secs_f64();

    let mut table = Table::new(&["phase", "mean s", "share", "slowest s"]);
    let total = res.mean_times.total();
    for p in Phase::ALL {
        let secs = res.mean_times.get(p);
        table.row(vec![
            p.name().into(),
            fnum(secs),
            format!("{:.1}%", 100.0 * secs / total.max(1e-12)),
            fnum(res.max_times.get(p)),
        ]);
    }
    println!("{}", table.render());
    println!(
        "cycles {} | spikes {} | mean rate {:.2} /s | RTF {:.1} | \
         wall {:.2}s",
        res.s_cycles,
        res.n_spikes(),
        res.mean_rate_hz(spec.total_neurons() as usize),
        res.rtf(),
        wall,
    );
    let cs = &res.comm_stats;
    println!(
        "comm: a2a {} | swaps {} | bytes {} | resizes {} | max/pair {} | \
         depth {} | overlapped {} | early-drained {} | post {} | wait {} \
         | hidden {}",
        cs.alltoall_calls,
        cs.local_swaps,
        cs.bytes_sent,
        cs.resize_rounds,
        cs.max_send_per_pair,
        res.effective_comm_depth,
        cs.overlapped_exchanges,
        cs.early_drained_sources,
        fnum(cs.post_secs),
        fnum(cs.complete_wait_secs),
        fnum(cs.hidden_secs),
    );
    for (tier, ts) in [
        ("global", &res.comm_tiers.global),
        ("local", &res.comm_tiers.local),
    ] {
        println!(
            "comm[{tier}]: a2a {} | swaps {} | bytes {} | resizes {} | \
             sync {} | wait {} | hidden {}",
            ts.alltoall_calls,
            ts.local_swaps,
            ts.bytes_sent,
            ts.resize_rounds,
            fnum(ts.sync_secs),
            fnum(ts.complete_wait_secs),
            fnum(ts.hidden_secs),
        );
    }

    // observability summary: pooled compute-interval distribution,
    // straggler attribution and the sync-model closure
    let (n, mu, sigma) =
        nsim::obs::intervals::pooled(res.intervals.iter().map(|t| &t.local));
    if n > 0 {
        println!(
            "intervals: n {n} | mean {:.4} ms | sd {:.4} ms | cv {:.3}",
            mu * 1e3,
            sigma * 1e3,
            if mu > 0.0 { sigma / mu } else { 0.0 },
        );
    }
    if let Some((rank, waits, late)) = res.blame.merged_all().top() {
        println!(
            "stragglers: most-blamed rank {rank} (last arriver in \
             {waits} waits, {} s total lateness)",
            fnum(late),
        );
    }
    if let Some(model) = nsim::obs::report::fitted_model(&res) {
        let (pred_local, pred_global) =
            nsim::obs::report::predicted_sync(model, &cfg, &res);
        let m = res.m_ranks.max(1) as f64;
        let meas_global = (res.comm_tiers.global.sync_secs
            + res.comm_tiers.global.complete_wait_secs)
            / m;
        let meas_local = (res.comm_tiers.local.sync_secs
            + res.comm_tiers.local.complete_wait_secs)
            / m;
        println!(
            "T_sync[global]: predicted {} s | measured {} s",
            fnum(pred_global),
            fnum(meas_global),
        );
        println!(
            "T_sync[local]:  predicted {} s | measured {} s",
            fnum(pred_local),
            fnum(meas_local),
        );
    }
    if let Some(p) = trace_path {
        nsim::obs::trace::write_chrome_trace(
            std::path::Path::new(&p),
            &res.spans,
            res.m_ranks,
        )?;
        println!("trace: {} spans -> {p}", res.spans.len());
    }
    if let Some(p) = stats_path {
        nsim::obs::report::write_report(
            std::path::Path::new(&p),
            &spec.name,
            &cfg,
            &res,
        )?;
        println!("stats: -> {p}");
    }
    Ok(())
}

fn cmd_figure(args: &Args) -> Result<()> {
    let name = args
        .positional
        .get(1)
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("usage: nsim figure <name>"))?;
    let opts = FigOptions {
        t_model_ms: args.f64_or("t-model", 1_000.0)?,
        seed: args.u64_or("seed", 654)?,
    };
    let out = args.str_or("out", "results");
    args.finish()?;
    run_figure(&name, &opts)?.emit(&out)
}

fn cmd_figures(args: &Args) -> Result<()> {
    let opts = FigOptions {
        t_model_ms: args.f64_or("t-model", 1_000.0)?,
        seed: args.u64_or("seed", 654)?,
    };
    let out = args.str_or("out", "results");
    args.finish()?;
    for name in ALL_FIGURES {
        run_figure(name, &opts)?.emit(&out)?;
    }
    Ok(())
}

fn cmd_theory(args: &Args) -> Result<()> {
    use nsim::theory::{delivery, sync};
    let d = args.usize_or("d", 10)? as u32;
    let m = args.usize_or("ranks", 128)?;
    let t_m = args.usize_or("threads", 48)?;
    let ranks_per_area = args.usize_or("ranks-per-area", 1)?;
    if ranks_per_area == 0 || m % ranks_per_area != 0 {
        bail!(
            "--ranks-per-area must be >= 1 and divide --ranks \
             ({m} % {ranks_per_area} != 0)"
        );
    }
    args.finish()?;

    println!("== synchronization theory (eqs 2-12) ==");
    println!(
        "xi_M(M={m}) = {:.3} sd; sync ratio 1/sqrt(D={d}) = {:.3}",
        nsim::util::stats::blom_xi(m),
        sync::sync_ratio(d)
    );
    println!(
        "upper 3.5% of cycle times cover {:.1}% of per-cycle maxima (eq 12)",
        100.0 * sync::maxima_tail_coverage(0.035, m)
    );
    let model = sync::CycleTimeModel::paper_default();
    let window = d.saturating_sub(1);
    println!(
        "split-phase overlap: a window of D-1={window} cycles hides \
         {:.0}% of the remaining sync time \
         (predicted gain {:.2} s per 100k cycles)",
        100.0 * sync::overlap_hidden_fraction(model, m, d, window),
        sync::predicted_overlap_gain(model, m, 100_000, d, window),
    );
    // conventional runs (d = 1) gain nothing at depth 1; a depth-D
    // pipeline opens a window of depth-1 cycles of the realized slack
    let slack = 4u32;
    println!(
        "depth-D pipeline (conventional, {slack} cycles realized slack): \
         gain per 100k cycles = {:.2} s (depth 2), {:.2} s (depth 4)",
        sync::predicted_depth_gain(model, m, 100_000, 1, 2, slack),
        sync::predicted_depth_gain(model, m, 100_000, 1, 4, slack),
    );
    // hybrid two-tier schedule: D local rounds per epoch inside each
    // area group, one global exchange across the groups per epoch
    let (local_sync, global_sync) = sync::expected_hybrid_sync_times(
        model,
        m,
        ranks_per_area,
        100_000,
        d,
        d,
    );
    println!(
        "hybrid two-tier (R={ranks_per_area}/area, {} groups, D={d} \
         local rounds/epoch): per 100k cycles local sync {local_sync:.2} \
         s, global sync {global_sync:.2} s; overlap hides up to {:.2} s \
         of the global tier",
        m / ranks_per_area,
        sync::predicted_hybrid_depth_gain(
            model,
            m,
            ranks_per_area,
            100_000,
            d,
            1,
            d.saturating_sub(1),
            d,
        ),
    );
    let sc = delivery::DeliveryScenario::default();
    println!("\n== spike-delivery theory (eqs 13-17) ==");
    println!(
        "f_irr conventional(M={m}, T={t_m}) = {:.4}",
        delivery::f_irr_conventional(&sc, m, t_m)
    );
    println!(
        "f_irr structure-aware          = {:.4} ({:.0}% reduction)",
        delivery::f_irr_structure(&sc, m, t_m),
        100.0 * delivery::irregular_access_reduction(&sc, m, t_m)
    );
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    args.finish()?;
    println!("model zoo:");
    for (name, spec) in [
        ("sanity (2x500)", models::sanity_net(500, 2)?),
        (
            "mam-benchmark 32 areas (paper scale)",
            models::mam_benchmark(32, 1.0, 1.0)?,
        ),
        ("mam (paper scale)", models::mam(1.0, 1.0)?),
    ] {
        println!(
            "  {name}: {} areas, {} neurons, K={}, D={}",
            spec.n_areas(),
            spec.total_neurons(),
            spec.k_total(),
            spec.delay_ratio()
        );
    }
    match nsim::runtime::registry::Registry::open_default() {
        Ok(reg) => {
            println!(
                "artifacts ({}):",
                nsim::runtime::registry::default_dir()
            );
            for m in reg.metas() {
                println!(
                    "  {} kind={} batch={}{}",
                    m.name,
                    m.kind,
                    m.batch,
                    m.steps
                        .map(|k| format!(" steps={k}"))
                        .unwrap_or_default()
                );
            }
        }
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    for s in [
        Strategy::Conventional,
        Strategy::Intermediate,
        Strategy::StructureAware,
    ] {
        println!(
            "strategy {}: area placement={}, dual pathways={}",
            s.name(),
            s.structure_aware_placement(),
            s.dual_pathways()
        );
    }
    Ok(())
}
