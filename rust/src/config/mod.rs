//! Run-level configuration: strategy selection, topology of the (simulated)
//! machine, model time, seeds and the update-execution path.
//!
//! Configs can be built programmatically, loaded from a JSON file, and
//! overridden from CLI options — the launcher (`main.rs`) composes all
//! three.

use crate::obs::TraceMode;
use crate::util::cli::Args;
use crate::util::json::{self, Json};
use anyhow::{bail, Context, Result};

/// The three simulation strategies compared in the paper (Figs 7/9).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// Round-robin neuron distribution, global communication every cycle.
    Conventional,
    /// Structure-aware neuron distribution, but conventional global
    /// communication every `d_min` (middle bars of Fig 9).
    Intermediate,
    /// Structure-aware distribution + dual local/global pathways with
    /// global communication every D-th cycle.
    StructureAware,
}

impl Strategy {
    pub fn parse(s: &str) -> Result<Strategy> {
        Ok(match s {
            "conventional" | "conv" => Strategy::Conventional,
            "intermediate" | "inter" => Strategy::Intermediate,
            "structure-aware" | "struct" | "structure_aware" => {
                Strategy::StructureAware
            }
            other => bail!("unknown strategy {other:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Conventional => "conventional",
            Strategy::Intermediate => "intermediate",
            Strategy::StructureAware => "structure-aware",
        }
    }

    /// Does this strategy place whole areas on single ranks?
    pub fn structure_aware_placement(&self) -> bool {
        !matches!(self, Strategy::Conventional)
    }

    /// Does this strategy use the dual local/global communication scheme?
    pub fn dual_pathways(&self) -> bool {
        matches!(self, Strategy::StructureAware)
    }
}

/// How a rank executes its virtual threads.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Iterate virtual threads in place on the rank's OS thread — the
    /// reference schedule (and the only sensible one for T = 1).
    Sequential,
    /// Persistent barrier-synced worker runtime with thread-sharded
    /// spike delivery: one worker OS thread per virtual thread, spawned
    /// once per run and phase-stepped by barriers (no channel traffic,
    /// no steady-state allocation); bit-identical to `Sequential` by
    /// construction, see `engine::rank`.
    Pooled,
    /// The legacy per-phase command/reply channel pool (PR 1), kept
    /// selectable for A/B comparison against the barrier runtime.
    PooledChannels,
}

impl ExecMode {
    pub fn parse(s: &str) -> Result<ExecMode> {
        Ok(match s {
            "sequential" | "seq" => ExecMode::Sequential,
            "pooled" | "pool" | "parallel" | "barrier" => ExecMode::Pooled,
            "pooled-channels" | "channels" | "channel-pool" => {
                ExecMode::PooledChannels
            }
            other => bail!("unknown exec mode {other:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ExecMode::Sequential => "sequential",
            ExecMode::Pooled => "pooled",
            ExecMode::PooledChannels => "pooled-channels",
        }
    }
}

/// How the engine runs the epoch-boundary global exchange.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommMode {
    /// The blocking collective: a barrier in front of the exchange puts
    /// the full synchronization skew on the critical path once per epoch.
    Blocking,
    /// Split-phase exchange (`comm::nonblocking`): post at the epoch
    /// boundary without waiting, keep running local cycles of the next
    /// epoch, and complete just before the first cycle whose delivery
    /// deadline — epoch boundary plus the rank's realized inter-area
    /// delay slack (floored by `d_min_inter`) — needs the spikes.
    /// Bit-identical spike trains to `Blocking` by construction.
    Overlap,
}

impl CommMode {
    pub fn parse(s: &str) -> Result<CommMode> {
        Ok(match s {
            "blocking" | "block" | "sync" => CommMode::Blocking,
            "overlap" | "nonblocking" | "nb" | "async" => CommMode::Overlap,
            other => bail!("unknown comm mode {other:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            CommMode::Blocking => "blocking",
            CommMode::Overlap => "overlap",
        }
    }
}

/// Which communication fabric carries the collectives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// Ranks as OS threads in one process over the shared-memory
    /// `comm::World` — the historical (and default) backend.
    Shmem,
    /// One OS process per rank over Unix-domain sockets
    /// (`comm::socket`) — the multi-process backend behind
    /// `nsim launch`.  A socket-mode `simulate` invocation runs *one*
    /// rank and rendezvouses with its peers through `--socket-dir`.
    Socket,
}

impl TransportKind {
    pub fn parse(s: &str) -> Result<TransportKind> {
        Ok(match s {
            "shmem" | "shared-memory" | "threads" => TransportKind::Shmem,
            "socket" | "uds" | "multiprocess" => TransportKind::Socket,
            other => bail!("unknown transport {other:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::Shmem => "shmem",
            TransportKind::Socket => "socket",
        }
    }
}

/// How the update phase executes the neuron model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpdatePath {
    /// Native Rust arithmetic (bit-identical to the Pallas kernel's op
    /// order) — the performance path.
    Native,
    /// Through the AOT-compiled XLA artifact via PJRT — proves the
    /// three-layer composition; serialized by a global client lock.
    Xla,
}

impl UpdatePath {
    pub fn parse(s: &str) -> Result<UpdatePath> {
        Ok(match s {
            "native" => UpdatePath::Native,
            "xla" | "pjrt" => UpdatePath::Xla,
            other => bail!("unknown update path {other:?}"),
        })
    }
}

/// A deterministic compute straggler: rank `rank`'s update phase is
/// inflated by `factor` in epochs `[from_epoch, to_epoch)` — the paper's
/// slowest-node scenario made reproducible.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StragglerFault {
    pub rank: usize,
    pub factor: f64,
    pub from_epoch: u64,
    pub to_epoch: u64,
}

/// A deterministic communication straggler: rank `rank` delays its
/// epoch-boundary global deposit by `delay_ms` (wall-clock) in epochs
/// `[from_epoch, to_epoch)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DepositDelayFault {
    pub rank: usize,
    pub delay_ms: f64,
    pub from_epoch: u64,
    pub to_epoch: u64,
}

/// A hard fault: rank `rank` dies at the start of epoch `epoch` (its
/// thread unwinds cleanly; the survivors' watchdogs report it).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KillFault {
    pub rank: usize,
    pub epoch: u64,
}

/// A deterministic fault-injection plan, honored by the engine and the
/// shared-memory world: compute stragglers, delayed deposits and
/// kill-at-epoch faults (see `EXPERIMENTS.md` for the validation
/// protocol).  Empty by default — no faults, zero overhead.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    pub stragglers: Vec<StragglerFault>,
    pub deposit_delays: Vec<DepositDelayFault>,
    pub kills: Vec<KillFault>,
}

/// The [`FaultPlan`] projected onto one rank — what a rank thread
/// actually consults on its hot path.
#[derive(Clone, Debug, Default)]
pub struct RankFaults {
    pub stragglers: Vec<StragglerFault>,
    pub deposit_delays: Vec<DepositDelayFault>,
    /// Earliest epoch at which this rank is killed, if any.
    pub kill_epoch: Option<u64>,
}

impl RankFaults {
    pub fn is_empty(&self) -> bool {
        self.stragglers.is_empty()
            && self.deposit_delays.is_empty()
            && self.kill_epoch.is_none()
    }

    /// Combined update-phase inflation factor in `epoch` (1.0 = none;
    /// overlapping windows multiply).
    pub fn straggle_factor(&self, epoch: u64) -> f64 {
        self.stragglers
            .iter()
            .filter(|s| s.from_epoch <= epoch && epoch < s.to_epoch)
            .map(|s| s.factor)
            .product()
    }

    /// Total injected delay before the epoch's global deposit, in ms.
    pub fn deposit_delay_ms(&self, epoch: u64) -> f64 {
        self.deposit_delays
            .iter()
            .filter(|d| d.from_epoch <= epoch && epoch < d.to_epoch)
            .map(|d| d.delay_ms)
            .sum()
    }
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        self.stragglers.is_empty()
            && self.deposit_delays.is_empty()
            && self.kills.is_empty()
    }

    /// Project the plan onto one rank.
    pub fn for_rank(&self, rank: usize) -> RankFaults {
        RankFaults {
            stragglers: self
                .stragglers
                .iter()
                .copied()
                .filter(|s| s.rank == rank)
                .collect(),
            deposit_delays: self
                .deposit_delays
                .iter()
                .copied()
                .filter(|d| d.rank == rank)
                .collect(),
            kill_epoch: self
                .kills
                .iter()
                .filter(|k| k.rank == rank)
                .map(|k| k.epoch)
                .min(),
        }
    }

    /// Parse the CLI straggler spec `rank:factor:from:to[,...]`.
    pub fn parse_stragglers(spec: &str) -> Result<Vec<StragglerFault>> {
        spec.split(',')
            .map(|item| {
                let item = item.trim();
                let p: Vec<&str> = item.split(':').collect();
                if p.len() != 4 {
                    bail!(
                        "bad straggler spec {item:?}: expected \
                         rank:factor:from_epoch:to_epoch"
                    );
                }
                Ok(StragglerFault {
                    rank: p[0]
                        .parse()
                        .with_context(|| format!("rank in {item:?}"))?,
                    factor: p[1]
                        .parse()
                        .with_context(|| format!("factor in {item:?}"))?,
                    from_epoch: p[2].parse().with_context(|| {
                        format!("from_epoch in {item:?}")
                    })?,
                    to_epoch: p[3]
                        .parse()
                        .with_context(|| format!("to_epoch in {item:?}"))?,
                })
            })
            .collect()
    }

    /// Parse the CLI deposit-delay spec `rank:delay_ms:from:to[,...]`.
    pub fn parse_delays(spec: &str) -> Result<Vec<DepositDelayFault>> {
        spec.split(',')
            .map(|item| {
                let item = item.trim();
                let p: Vec<&str> = item.split(':').collect();
                if p.len() != 4 {
                    bail!(
                        "bad delay-deposit spec {item:?}: expected \
                         rank:delay_ms:from_epoch:to_epoch"
                    );
                }
                Ok(DepositDelayFault {
                    rank: p[0]
                        .parse()
                        .with_context(|| format!("rank in {item:?}"))?,
                    delay_ms: p[1]
                        .parse()
                        .with_context(|| format!("delay_ms in {item:?}"))?,
                    from_epoch: p[2].parse().with_context(|| {
                        format!("from_epoch in {item:?}")
                    })?,
                    to_epoch: p[3]
                        .parse()
                        .with_context(|| format!("to_epoch in {item:?}"))?,
                })
            })
            .collect()
    }

    /// Parse the CLI kill spec `rank:epoch[,...]`.
    pub fn parse_kills(spec: &str) -> Result<Vec<KillFault>> {
        spec.split(',')
            .map(|item| {
                let item = item.trim();
                let p: Vec<&str> = item.split(':').collect();
                if p.len() != 2 {
                    bail!("bad kill-at spec {item:?}: expected rank:epoch");
                }
                Ok(KillFault {
                    rank: p[0]
                        .parse()
                        .with_context(|| format!("rank in {item:?}"))?,
                    epoch: p[1]
                        .parse()
                        .with_context(|| format!("epoch in {item:?}"))?,
                })
            })
            .collect()
    }

    /// Load from a JSON object with optional `stragglers`,
    /// `deposit_delays` and `kills` arrays.
    pub fn from_json(v: &Json) -> Result<FaultPlan> {
        fn usize_field(e: &Json, key: &str) -> Result<usize> {
            e.get(key).and_then(Json::as_usize).with_context(|| {
                format!("fault entry missing numeric {key:?}: {e}")
            })
        }
        fn u64_field(e: &Json, key: &str) -> Result<u64> {
            e.get(key).and_then(Json::as_u64).with_context(|| {
                format!("fault entry missing numeric {key:?}: {e}")
            })
        }
        fn f64_field(e: &Json, key: &str) -> Result<f64> {
            e.get(key).and_then(Json::as_f64).with_context(|| {
                format!("fault entry missing numeric {key:?}: {e}")
            })
        }
        let mut plan = FaultPlan::default();
        if let Some(arr) = v.get("stragglers").and_then(Json::as_arr) {
            for e in arr {
                plan.stragglers.push(StragglerFault {
                    rank: usize_field(e, "rank")?,
                    factor: f64_field(e, "factor")?,
                    from_epoch: u64_field(e, "from_epoch")?,
                    to_epoch: u64_field(e, "to_epoch")?,
                });
            }
        }
        if let Some(arr) = v.get("deposit_delays").and_then(Json::as_arr) {
            for e in arr {
                plan.deposit_delays.push(DepositDelayFault {
                    rank: usize_field(e, "rank")?,
                    delay_ms: f64_field(e, "delay_ms")?,
                    from_epoch: u64_field(e, "from_epoch")?,
                    to_epoch: u64_field(e, "to_epoch")?,
                });
            }
        }
        if let Some(arr) = v.get("kills").and_then(Json::as_arr) {
            for e in arr {
                plan.kills.push(KillFault {
                    rank: usize_field(e, "rank")?,
                    epoch: u64_field(e, "epoch")?,
                });
            }
        }
        Ok(plan)
    }

    pub fn from_json_file(path: &str) -> Result<FaultPlan> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading fault plan {path}"))?;
        let v = json::parse(&text)
            .with_context(|| format!("parsing fault plan {path}"))?;
        Self::from_json(&v)
    }

    /// Cross-field validation against the run shape.
    pub fn validate(
        &self,
        m_ranks: usize,
        comm_timeout: Option<f64>,
    ) -> Result<()> {
        for s in &self.stragglers {
            if s.rank >= m_ranks {
                bail!(
                    "straggler rank {} out of range (ranks = {m_ranks})",
                    s.rank
                );
            }
            if !s.factor.is_finite() || s.factor < 1.0 {
                bail!(
                    "straggler factor must be >= 1 (got {}): a factor \
                     below 1 would *speed up* the rank",
                    s.factor
                );
            }
            if s.from_epoch >= s.to_epoch {
                bail!(
                    "straggler epoch window [{}, {}) is empty",
                    s.from_epoch,
                    s.to_epoch
                );
            }
        }
        for d in &self.deposit_delays {
            if d.rank >= m_ranks {
                bail!(
                    "delay-deposit rank {} out of range (ranks = {m_ranks})",
                    d.rank
                );
            }
            if !d.delay_ms.is_finite() || d.delay_ms < 0.0 {
                bail!("deposit delay must be >= 0 ms (got {})", d.delay_ms);
            }
            if d.from_epoch >= d.to_epoch {
                bail!(
                    "delay-deposit epoch window [{}, {}) is empty",
                    d.from_epoch,
                    d.to_epoch
                );
            }
        }
        for k in &self.kills {
            if k.rank >= m_ranks {
                bail!(
                    "kill-at rank {} out of range (ranks = {m_ranks})",
                    k.rank
                );
            }
        }
        if !self.kills.is_empty() && comm_timeout.is_none() {
            bail!(
                "a kill-at-epoch fault requires --comm-timeout: without \
                 a watchdog deadline the surviving ranks would wait \
                 forever for the killed rank's deposits"
            );
        }
        Ok(())
    }
}

/// Full run configuration for the functional engine.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub strategy: Strategy,
    /// Number of (simulated) MPI ranks.
    pub m_ranks: usize,
    /// Virtual threads per rank (NEST's T_M); affects table partitioning.
    pub threads_per_rank: usize,
    /// Biological model time to simulate, in ms.
    pub t_model_ms: f64,
    /// Master seed for connectivity and model construction.
    pub seed: u64,
    pub update_path: UpdatePath,
    /// How each rank executes its virtual threads.
    pub exec: ExecMode,
    /// Blocking vs split-phase (overlapped) global exchange.
    pub comm: CommMode,
    /// Which fabric carries the collectives: shared-memory threads in
    /// one process (the default) or one process per rank over
    /// Unix-domain sockets (`--transport socket`, driven by
    /// `nsim launch`).  The spike trains are bit-identical either way;
    /// only the substrate underneath the `Transport` trait changes.
    pub transport: TransportKind,
    /// Split-phase pipeline depth: how many exchange rounds may be in
    /// flight per rank under `CommMode::Overlap` (1 = post one round and
    /// complete it before the next boundary, today's overlap; >1 keeps D
    /// consecutive min-delay intervals' exchanges in flight — only
    /// sustainable when the realized remote delays exceed `depth` cycles,
    /// which the engine validates collectively at startup).  Ignored
    /// under `CommMode::Blocking`.
    pub comm_depth: usize,
    /// Initial spike quota per rank pair of the communication buffers
    /// (NEST starts small and grows via the two-round resize protocol).
    pub comm_quota: usize,
    /// Ranks jointly hosting one area under the structure-aware
    /// placements: the `m_ranks` ranks split into `m_ranks /
    /// ranks_per_area` contiguous groups, each area maps onto one group,
    /// and the group exchanges the area's short-range spikes every cycle
    /// over its own local sub-communicator (the paper's hybrid
    /// local/global architecture).  1 (the default) keeps one area per
    /// rank with an intra-rank buffer swap — bit-identical to the
    /// pre-hierarchical engine.  Requires a structure-aware strategy and
    /// `m_ranks % ranks_per_area == 0`.
    pub ranks_per_area: usize,
    /// Record (cycle, gid) spike events for verification.
    pub record_spikes: bool,
    /// Record raw per-rank per-cycle time vectors (unbounded memory —
    /// opt-in via `--record-cycle-times`; the streaming interval
    /// histograms of `obs::intervals` are always on and bounded).
    pub record_cycle_times: bool,
    /// Record trace spans for every phase step and communication
    /// operation (`--trace <path>`; off = one branch per site).
    pub trace: bool,
    /// Trace-buffer bounding (`--trace-mode unbounded|ring[:N]`):
    /// `ring` keeps only the most recent N spans per rank sink so a
    /// long-running traced process (the job server) stays bounded
    /// instead of growing past the sink capacity without limit.
    pub trace_mode: TraceMode,
    /// Watchdog deadline in seconds applied to every communicator wait
    /// (barrier-framed collective phases and split-phase completion
    /// rendezvous).  `None` (the default) keeps today's unbounded waits;
    /// with a deadline set, a dead or stalled peer turns the silent hang
    /// into a structured `CommError::Timeout` naming the tier, epoch,
    /// ring slot and missing ranks.
    pub comm_timeout: Option<f64>,
    /// Snapshot the full engine state every N epochs (0 = disabled).
    /// Snapshots are taken at epoch boundaries with all split-phase
    /// exchanges drained to depth 0, so the comm state is empty by
    /// construction (see `engine::checkpoint`).
    pub checkpoint_every: u64,
    /// Path periodic snapshots are written to (atomic write + rename;
    /// each snapshot replaces the previous one).
    pub checkpoint_path: String,
    /// Restore engine state from a snapshot file before running; the
    /// resumed run produces bit-identical spike trains to the
    /// uninterrupted run.
    pub restore: Option<String>,
    /// Deterministic fault-injection plan (empty = no faults).
    pub faults: FaultPlan,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            strategy: Strategy::Conventional,
            m_ranks: 2,
            threads_per_rank: 2,
            t_model_ms: 100.0,
            seed: 12,
            update_path: UpdatePath::Native,
            exec: ExecMode::Pooled,
            comm: CommMode::Blocking,
            transport: TransportKind::Shmem,
            comm_depth: 1,
            comm_quota: 1024,
            ranks_per_area: 1,
            record_spikes: false,
            record_cycle_times: false,
            trace: false,
            trace_mode: TraceMode::Unbounded,
            comm_timeout: None,
            checkpoint_every: 0,
            checkpoint_path: "nsim.ckpt".to_string(),
            restore: None,
            faults: FaultPlan::default(),
        }
    }
}

impl RunConfig {
    /// Apply `--strategy --ranks --threads --t-model --seed --update-path
    /// --exec --comm --comm-depth --quota --ranks-per-area
    /// --record-spikes --record-cycle-times` CLI overrides.
    pub fn override_from_args(mut self, args: &Args) -> Result<RunConfig> {
        if let Some(s) = args.str_opt("strategy") {
            self.strategy = Strategy::parse(&s)?;
        }
        self.m_ranks = args.usize_or("ranks", self.m_ranks)?;
        self.threads_per_rank =
            args.usize_or("threads", self.threads_per_rank)?;
        self.t_model_ms = args.f64_or("t-model", self.t_model_ms)?;
        self.seed = args.u64_or("seed", self.seed)?;
        if let Some(s) = args.str_opt("update-path") {
            self.update_path = UpdatePath::parse(&s)?;
        }
        if let Some(s) = args.str_opt("exec") {
            self.exec = ExecMode::parse(&s)?;
        }
        if let Some(s) = args.str_opt("comm") {
            self.comm = CommMode::parse(&s)?;
        }
        if let Some(s) = args.str_opt("transport") {
            self.transport = TransportKind::parse(&s)?;
        }
        self.comm_depth = args.usize_or("comm-depth", self.comm_depth)?;
        self.comm_quota = args.usize_or("quota", self.comm_quota)?;
        self.ranks_per_area =
            args.usize_or("ranks-per-area", self.ranks_per_area)?;
        if args.flag("record-spikes") {
            self.record_spikes = true;
        }
        if args.flag("record-cycle-times") {
            self.record_cycle_times = true;
        }
        // --trace takes the output path as its value; its presence
        // switches span recording on (the path itself is consumed by
        // the launcher, which writes the trace after the run)
        if args.str_opt("trace").is_some() {
            self.trace = true;
        }
        if let Some(s) = args.str_opt("trace-mode") {
            self.trace_mode = parse_trace_mode(&s)?;
        }
        if let Some(t) = args.f64_opt("comm-timeout")? {
            self.comm_timeout = Some(t);
        }
        self.checkpoint_every =
            args.u64_or("checkpoint-every", self.checkpoint_every)?;
        if let Some(p) = args.str_opt("checkpoint-path") {
            self.checkpoint_path = p;
        }
        if let Some(p) = args.str_opt("restore") {
            self.restore = Some(p);
        }
        if let Some(p) = args.str_opt("fault-plan") {
            self.faults = FaultPlan::from_json_file(&p)?;
        }
        if let Some(s) = args.str_opt("straggler") {
            self.faults
                .stragglers
                .extend(FaultPlan::parse_stragglers(&s)?);
        }
        if let Some(s) = args.str_opt("delay-deposit") {
            self.faults
                .deposit_delays
                .extend(FaultPlan::parse_delays(&s)?);
        }
        if let Some(s) = args.str_opt("kill-at") {
            self.faults.kills.extend(FaultPlan::parse_kills(&s)?);
        }
        self.validate()?;
        Ok(self)
    }

    /// Load from a JSON object (all fields optional, defaults apply).
    pub fn from_json(v: &Json) -> Result<RunConfig> {
        let mut cfg = RunConfig::default();
        if let Some(s) = v.get("strategy").and_then(Json::as_str) {
            cfg.strategy = Strategy::parse(s)?;
        }
        if let Some(x) = v.get("ranks").and_then(Json::as_usize) {
            cfg.m_ranks = x;
        }
        if let Some(x) = v.get("threads").and_then(Json::as_usize) {
            cfg.threads_per_rank = x;
        }
        if let Some(x) = v.get("t_model_ms").and_then(Json::as_f64) {
            cfg.t_model_ms = x;
        }
        if let Some(x) = v.get("seed").and_then(Json::as_f64) {
            cfg.seed = x as u64;
        }
        if let Some(s) = v.get("update_path").and_then(Json::as_str) {
            cfg.update_path = UpdatePath::parse(s)?;
        }
        if let Some(s) = v.get("exec").and_then(Json::as_str) {
            cfg.exec = ExecMode::parse(s)?;
        }
        if let Some(s) = v.get("comm").and_then(Json::as_str) {
            cfg.comm = CommMode::parse(s)?;
        }
        if let Some(s) = v.get("transport").and_then(Json::as_str) {
            cfg.transport = TransportKind::parse(s)?;
        }
        if let Some(x) = v.get("comm_depth").and_then(Json::as_usize) {
            cfg.comm_depth = x;
        }
        if let Some(x) = v.get("comm_quota").and_then(Json::as_usize) {
            cfg.comm_quota = x;
        }
        if let Some(x) = v.get("ranks_per_area").and_then(Json::as_usize) {
            cfg.ranks_per_area = x;
        }
        if let Some(b) = v.get("record_spikes").and_then(Json::as_bool) {
            cfg.record_spikes = b;
        }
        if let Some(b) = v.get("record_cycle_times").and_then(Json::as_bool) {
            cfg.record_cycle_times = b;
        }
        if let Some(b) = v.get("trace").and_then(Json::as_bool) {
            cfg.trace = b;
        }
        if let Some(s) = v.get("trace_mode").and_then(Json::as_str) {
            cfg.trace_mode = parse_trace_mode(s)?;
        }
        if let Some(x) = v.get("comm_timeout").and_then(Json::as_f64) {
            cfg.comm_timeout = Some(x);
        }
        if let Some(x) = v.get("checkpoint_every").and_then(Json::as_u64) {
            cfg.checkpoint_every = x;
        }
        if let Some(s) = v.get("checkpoint_path").and_then(Json::as_str) {
            cfg.checkpoint_path = s.to_string();
        }
        if let Some(s) = v.get("restore").and_then(Json::as_str) {
            cfg.restore = Some(s.to_string());
        }
        if let Some(f) = v.get("faults") {
            cfg.faults = FaultPlan::from_json(f)?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_json_file(path: &str) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        let v = json::parse(&text)
            .with_context(|| format!("parsing config {path}"))?;
        Self::from_json(&v)
    }

    pub fn validate(&self) -> Result<()> {
        if self.m_ranks == 0 {
            bail!("ranks must be >= 1");
        }
        if self.threads_per_rank == 0 {
            bail!("threads must be >= 1");
        }
        if self.t_model_ms <= 0.0 {
            bail!("t_model_ms must be positive");
        }
        if self.comm_quota == 0 {
            bail!("comm_quota must be >= 1");
        }
        if self.comm_depth == 0 {
            bail!(
                "comm_depth must be >= 1 (1 = one exchange in flight, \
                 today's overlap; >1 pipelines that many rounds)"
            );
        }
        if self.ranks_per_area == 0 {
            bail!(
                "ranks_per_area must be >= 1 (1 = one area per rank, \
                 today's layout; >1 spans each area over a rank group \
                 with a local sub-communicator)"
            );
        }
        if self.ranks_per_area > 1
            && !self.strategy.structure_aware_placement()
        {
            bail!(
                "ranks_per_area > 1 requires a structure-aware strategy \
                 (intermediate or structure-aware): the conventional \
                 round-robin placement scatters every area across all \
                 ranks, so there is no area group to form"
            );
        }
        if self.m_ranks % self.ranks_per_area != 0 {
            bail!(
                "ranks ({}) must be a multiple of ranks_per_area ({}): \
                 area groups are contiguous rank blocks of equal size",
                self.m_ranks,
                self.ranks_per_area
            );
        }
        if let Some(t) = self.comm_timeout {
            if !t.is_finite() || t <= 0.0 {
                bail!("comm_timeout must be a positive number of seconds");
            }
        }
        if self.checkpoint_every > 0 && self.checkpoint_path.is_empty() {
            bail!(
                "checkpoint_path must be non-empty when \
                 checkpoint_every > 0"
            );
        }
        // Only checkpoint *writing* is shmem-only: the snapshot
        // collectives assemble rank parts through one shared in-process
        // CkptCtx, which cannot span process boundaries.  Restoring is
        // per-rank file reads and works over any transport.
        if self.transport == TransportKind::Socket
            && self.checkpoint_every > 0
        {
            bail!(
                "checkpoint writing is not supported over the socket \
                 transport: the snapshot collectives assemble rank \
                 parts through the shared-memory checkpoint context, \
                 which cannot span processes.  --restore works over \
                 socket (each rank process restores its own part from \
                 the snapshot file); checkpoints themselves must be \
                 written by a shmem run — the serving layer's \
                 shmem-backed resume path does exactly that.  Drop \
                 --checkpoint-every or run with --transport shmem"
            );
        }
        self.faults.validate(self.m_ranks, self.comm_timeout)?;
        Ok(())
    }
}

/// Parse a `--trace-mode` / `"trace_mode"` value: `unbounded`, `ring`
/// (default per-sink capacity) or `ring:N` (keep the last N spans per
/// rank sink).
pub fn parse_trace_mode(s: &str) -> Result<TraceMode> {
    match s {
        "unbounded" => Ok(TraceMode::Unbounded),
        "ring" => Ok(TraceMode::Ring(crate::obs::SINK_CAPACITY)),
        other => match other.strip_prefix("ring:") {
            Some(n) => {
                let cap: usize = n.parse().with_context(|| {
                    format!("bad ring capacity {n:?} in trace mode")
                })?;
                if cap == 0 {
                    bail!("trace-mode ring capacity must be >= 1");
                }
                Ok(TraceMode::Ring(cap))
            }
            None => bail!(
                "unknown trace mode {other:?} (expected unbounded, \
                 ring, or ring:N)"
            ),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_parse_roundtrip() {
        for s in [
            Strategy::Conventional,
            Strategy::Intermediate,
            Strategy::StructureAware,
        ] {
            assert_eq!(Strategy::parse(s.name()).unwrap(), s);
        }
        assert!(Strategy::parse("bogus").is_err());
    }

    #[test]
    fn strategy_semantics() {
        assert!(!Strategy::Conventional.structure_aware_placement());
        assert!(Strategy::Intermediate.structure_aware_placement());
        assert!(!Strategy::Intermediate.dual_pathways());
        assert!(Strategy::StructureAware.dual_pathways());
    }

    #[test]
    fn cli_overrides() {
        let args = Args::parse([
            "run",
            "--strategy",
            "struct",
            "--ranks",
            "8",
            "--t-model",
            "250.0",
        ])
        .unwrap();
        let cfg = RunConfig::default().override_from_args(&args).unwrap();
        assert_eq!(cfg.strategy, Strategy::StructureAware);
        assert_eq!(cfg.m_ranks, 8);
        assert_eq!(cfg.t_model_ms, 250.0);
        assert_eq!(cfg.threads_per_rank, 2); // default preserved
    }

    #[test]
    fn json_config() {
        let v = json::parse(
            r#"{"strategy": "intermediate", "ranks": 4, "seed": 654}"#,
        )
        .unwrap();
        let cfg = RunConfig::from_json(&v).unwrap();
        assert_eq!(cfg.strategy, Strategy::Intermediate);
        assert_eq!(cfg.m_ranks, 4);
        assert_eq!(cfg.seed, 654);
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut cfg = RunConfig::default();
        cfg.m_ranks = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = RunConfig::default();
        cfg.t_model_ms = -1.0;
        assert!(cfg.validate().is_err());
        let mut cfg = RunConfig::default();
        cfg.comm_quota = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = RunConfig::default();
        cfg.comm_depth = 0;
        let err = cfg.validate().unwrap_err();
        assert!(
            format!("{err:#}").contains("comm_depth must be >= 1"),
            "unexpected error: {err:#}"
        );
    }

    #[test]
    fn exec_mode_parse_roundtrip() {
        for e in [
            ExecMode::Sequential,
            ExecMode::Pooled,
            ExecMode::PooledChannels,
        ] {
            assert_eq!(ExecMode::parse(e.name()).unwrap(), e);
        }
        assert_eq!(ExecMode::parse("seq").unwrap(), ExecMode::Sequential);
        assert_eq!(ExecMode::parse("parallel").unwrap(), ExecMode::Pooled);
        assert_eq!(ExecMode::parse("barrier").unwrap(), ExecMode::Pooled);
        assert_eq!(
            ExecMode::parse("channels").unwrap(),
            ExecMode::PooledChannels
        );
        assert!(ExecMode::parse("bogus").is_err());
    }

    #[test]
    fn comm_mode_parse_roundtrip() {
        for c in [CommMode::Blocking, CommMode::Overlap] {
            assert_eq!(CommMode::parse(c.name()).unwrap(), c);
        }
        assert_eq!(CommMode::parse("nb").unwrap(), CommMode::Overlap);
        assert_eq!(
            CommMode::parse("nonblocking").unwrap(),
            CommMode::Overlap
        );
        assert_eq!(CommMode::parse("sync").unwrap(), CommMode::Blocking);
        assert!(CommMode::parse("bogus").is_err());
    }

    #[test]
    fn comm_mode_overrides() {
        // conservative default: the blocking collective
        assert_eq!(RunConfig::default().comm, CommMode::Blocking);

        let args = Args::parse(["run", "--comm", "overlap"]).unwrap();
        let cfg = RunConfig::default().override_from_args(&args).unwrap();
        assert_eq!(cfg.comm, CommMode::Overlap);

        let v = json::parse(r#"{"comm": "overlap"}"#).unwrap();
        let cfg = RunConfig::from_json(&v).unwrap();
        assert_eq!(cfg.comm, CommMode::Overlap);
    }

    #[test]
    fn comm_depth_overrides() {
        // default: one exchange in flight (exactly the PR 3 behavior)
        assert_eq!(RunConfig::default().comm_depth, 1);

        let args =
            Args::parse(["run", "--comm", "overlap", "--comm-depth", "4"])
                .unwrap();
        let cfg = RunConfig::default().override_from_args(&args).unwrap();
        assert_eq!(cfg.comm_depth, 4);

        let v = json::parse(r#"{"comm": "overlap", "comm_depth": 2}"#)
            .unwrap();
        let cfg = RunConfig::from_json(&v).unwrap();
        assert_eq!(cfg.comm_depth, 2);

        // --comm-depth 0 is rejected at parse time with the actionable
        // message (not deferred to the engine)
        let args = Args::parse(["run", "--comm-depth", "0"]).unwrap();
        assert!(RunConfig::default().override_from_args(&args).is_err());
        let v = json::parse(r#"{"comm_depth": 0}"#).unwrap();
        assert!(RunConfig::from_json(&v).is_err());
    }

    #[test]
    fn ranks_per_area_overrides_and_validation() {
        // default: one area per rank (the pre-hierarchical layout)
        assert_eq!(RunConfig::default().ranks_per_area, 1);

        let args = Args::parse([
            "run",
            "--strategy",
            "struct",
            "--ranks",
            "8",
            "--ranks-per-area",
            "2",
        ])
        .unwrap();
        let cfg = RunConfig::default().override_from_args(&args).unwrap();
        assert_eq!(cfg.ranks_per_area, 2);

        let v = json::parse(
            r#"{"strategy": "structure-aware", "ranks": 4,
                "ranks_per_area": 2}"#,
        )
        .unwrap();
        let cfg = RunConfig::from_json(&v).unwrap();
        assert_eq!(cfg.ranks_per_area, 2);

        // zero rejected with the actionable message
        let cfg = RunConfig {
            ranks_per_area: 0,
            ..RunConfig::default()
        };
        let err = cfg.validate().unwrap_err();
        assert!(
            format!("{err:#}").contains("ranks_per_area must be >= 1"),
            "unexpected error: {err:#}"
        );

        // conventional placement has no area groups to form
        let cfg = RunConfig {
            strategy: Strategy::Conventional,
            m_ranks: 4,
            ranks_per_area: 2,
            ..RunConfig::default()
        };
        let err = cfg.validate().unwrap_err();
        assert!(
            format!("{err:#}").contains("structure-aware strategy"),
            "unexpected error: {err:#}"
        );
        // the intermediate strategy places by area: groups allowed
        let cfg = RunConfig {
            strategy: Strategy::Intermediate,
            ..cfg
        };
        assert!(cfg.validate().is_ok());

        // rank count must tile into equal groups
        let cfg = RunConfig {
            strategy: Strategy::StructureAware,
            m_ranks: 6,
            ranks_per_area: 4,
            ..RunConfig::default()
        };
        let err = cfg.validate().unwrap_err();
        assert!(
            format!("{err:#}").contains("multiple of ranks_per_area"),
            "unexpected error: {err:#}"
        );
    }

    #[test]
    fn fault_plan_cli_specs() {
        let args = Args::parse([
            "run",
            "--ranks",
            "4",
            "--straggler",
            "1:3.0:0:4, 2:2:1:2",
            "--kill-at",
            "3:5",
            "--delay-deposit",
            "0:5:1:3",
            "--comm-timeout",
            "2.0",
        ])
        .unwrap();
        let cfg = RunConfig::default().override_from_args(&args).unwrap();
        assert_eq!(cfg.comm_timeout, Some(2.0));
        assert_eq!(cfg.faults.stragglers.len(), 2);
        assert_eq!(
            cfg.faults.stragglers[0],
            StragglerFault {
                rank: 1,
                factor: 3.0,
                from_epoch: 0,
                to_epoch: 4
            }
        );
        assert_eq!(cfg.faults.kills, vec![KillFault { rank: 3, epoch: 5 }]);
        assert_eq!(cfg.faults.deposit_delays.len(), 1);

        // per-rank projection: windows apply, absent ranks are inert
        let rf = cfg.faults.for_rank(1);
        assert_eq!(rf.straggle_factor(2), 3.0);
        assert_eq!(rf.straggle_factor(4), 1.0, "window is half-open");
        assert_eq!(cfg.faults.for_rank(3).kill_epoch, Some(5));
        assert_eq!(cfg.faults.for_rank(0).kill_epoch, None);
        assert!(cfg.faults.for_rank(0).deposit_delay_ms(1) == 5.0);
        assert!(cfg.faults.for_rank(0).deposit_delay_ms(3) == 0.0);

        // malformed specs are rejected at parse time
        assert!(FaultPlan::parse_stragglers("1:2.0:0").is_err());
        assert!(FaultPlan::parse_kills("1:2:3").is_err());
        assert!(FaultPlan::parse_delays("x:1:0:1").is_err());
    }

    #[test]
    fn fault_plan_json_and_validation() {
        let v = json::parse(
            r#"{"ranks": 2, "comm_timeout": 1.5,
                "faults": {"stragglers": [{"rank": 1, "factor": 2.5,
                    "from_epoch": 0, "to_epoch": 3}],
                    "kills": [{"rank": 0, "epoch": 2}]}}"#,
        )
        .unwrap();
        let cfg = RunConfig::from_json(&v).unwrap();
        assert_eq!(cfg.faults.stragglers[0].factor, 2.5);
        assert_eq!(cfg.faults.kills[0], KillFault { rank: 0, epoch: 2 });

        // a kill without a watchdog would hang the survivors: rejected
        let v = json::parse(
            r#"{"ranks": 2, "faults": {"kills": [{"rank": 0,
                "epoch": 2}]}}"#,
        )
        .unwrap();
        let err = RunConfig::from_json(&v).unwrap_err();
        assert!(
            format!("{err:#}").contains("comm-timeout"),
            "unexpected error: {err:#}"
        );

        // out-of-range rank
        let plan = FaultPlan {
            stragglers: vec![StragglerFault {
                rank: 7,
                factor: 2.0,
                from_epoch: 0,
                to_epoch: 1,
            }],
            ..FaultPlan::default()
        };
        assert!(plan.validate(2, None).is_err());

        // a deflating factor and an empty window are both rejected
        let plan = FaultPlan {
            stragglers: vec![StragglerFault {
                rank: 0,
                factor: 0.5,
                from_epoch: 0,
                to_epoch: 1,
            }],
            ..FaultPlan::default()
        };
        assert!(plan.validate(2, None).is_err());
        let plan = FaultPlan {
            stragglers: vec![StragglerFault {
                rank: 0,
                factor: 2.0,
                from_epoch: 3,
                to_epoch: 3,
            }],
            ..FaultPlan::default()
        };
        assert!(plan.validate(2, None).is_err());
    }

    #[test]
    fn checkpoint_and_timeout_knobs() {
        // defaults: everything off
        let cfg = RunConfig::default();
        assert_eq!(cfg.checkpoint_every, 0);
        assert!(cfg.restore.is_none());
        assert!(cfg.comm_timeout.is_none());
        assert!(cfg.faults.is_empty());

        let args = Args::parse([
            "run",
            "--checkpoint-every",
            "2",
            "--checkpoint-path",
            "out.ckpt",
            "--restore",
            "in.ckpt",
        ])
        .unwrap();
        let cfg = RunConfig::default().override_from_args(&args).unwrap();
        assert_eq!(cfg.checkpoint_every, 2);
        assert_eq!(cfg.checkpoint_path, "out.ckpt");
        assert_eq!(cfg.restore.as_deref(), Some("in.ckpt"));

        let v = json::parse(
            r#"{"checkpoint_every": 3, "checkpoint_path": "run.ckpt",
                "restore": "prev.ckpt", "comm_timeout": 0.25}"#,
        )
        .unwrap();
        let cfg = RunConfig::from_json(&v).unwrap();
        assert_eq!(cfg.checkpoint_every, 3);
        assert_eq!(cfg.checkpoint_path, "run.ckpt");
        assert_eq!(cfg.restore.as_deref(), Some("prev.ckpt"));
        assert_eq!(cfg.comm_timeout, Some(0.25));

        // nonsense deadlines rejected
        let cfg = RunConfig {
            comm_timeout: Some(0.0),
            ..RunConfig::default()
        };
        assert!(cfg.validate().is_err());
        let cfg = RunConfig {
            checkpoint_every: 1,
            checkpoint_path: String::new(),
            ..RunConfig::default()
        };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn trace_and_cycle_time_knobs() {
        // defaults: no span recording, no raw per-cycle vectors
        let cfg = RunConfig::default();
        assert!(!cfg.trace);
        assert!(!cfg.record_cycle_times);

        // --trace carries the output path; its presence enables spans
        let args =
            Args::parse(["simulate", "--trace", "t.json"]).unwrap();
        let cfg = RunConfig::default().override_from_args(&args).unwrap();
        assert!(cfg.trace);

        let args =
            Args::parse(["simulate", "--record-cycle-times"]).unwrap();
        let cfg = RunConfig::default().override_from_args(&args).unwrap();
        assert!(cfg.record_cycle_times);
        assert!(!cfg.trace);

        let v = json::parse(
            r#"{"trace": true, "record_cycle_times": true}"#,
        )
        .unwrap();
        let cfg = RunConfig::from_json(&v).unwrap();
        assert!(cfg.trace);
        assert!(cfg.record_cycle_times);
    }

    #[test]
    fn transport_parse_roundtrip_and_overrides() {
        for t in [TransportKind::Shmem, TransportKind::Socket] {
            assert_eq!(TransportKind::parse(t.name()).unwrap(), t);
        }
        assert_eq!(
            TransportKind::parse("uds").unwrap(),
            TransportKind::Socket
        );
        assert_eq!(
            TransportKind::parse("multiprocess").unwrap(),
            TransportKind::Socket
        );
        assert_eq!(
            TransportKind::parse("threads").unwrap(),
            TransportKind::Shmem
        );
        assert!(TransportKind::parse("bogus").is_err());

        // conservative default: the in-process shared-memory world
        assert_eq!(RunConfig::default().transport, TransportKind::Shmem);

        let args =
            Args::parse(["run", "--transport", "socket"]).unwrap();
        let cfg = RunConfig::default().override_from_args(&args).unwrap();
        assert_eq!(cfg.transport, TransportKind::Socket);

        let v = json::parse(r#"{"transport": "socket"}"#).unwrap();
        let cfg = RunConfig::from_json(&v).unwrap();
        assert_eq!(cfg.transport, TransportKind::Socket);
    }

    #[test]
    fn socket_transport_rejects_checkpoint_writing_only() {
        // writing checkpoints stays rejected: the snapshot collectives
        // assemble parts through the shared-memory checkpoint context
        let cfg = RunConfig {
            transport: TransportKind::Socket,
            checkpoint_every: 2,
            ..RunConfig::default()
        };
        let err = format!("{:#}", cfg.validate().unwrap_err());
        assert!(err.contains("socket"), "unexpected error: {err}");
        // the wording names the unsupported piece and the supported
        // escape hatch — the serving layer relies on both halves
        assert!(
            err.contains("shared-memory checkpoint context"),
            "error must name the snapshot collectives' shmem context: \
             {err}"
        );
        assert!(
            err.contains("--restore works over socket"),
            "error must say restore is supported: {err}"
        );
        assert!(
            err.contains("serving layer"),
            "error must point at the serving layer's shmem-backed \
             resume path: {err}"
        );
        // restoring is per-rank file reads — allowed over socket (the
        // wholesale rejection this replaces banned it too)
        let cfg = RunConfig {
            transport: TransportKind::Socket,
            restore: Some("prev.ckpt".to_string()),
            ..RunConfig::default()
        };
        assert!(cfg.validate().is_ok());
        // plain socket runs validate fine
        let cfg = RunConfig {
            transport: TransportKind::Socket,
            ..RunConfig::default()
        };
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn trace_mode_parsing() {
        assert_eq!(RunConfig::default().trace_mode, TraceMode::Unbounded);

        let args =
            Args::parse(["run", "--trace-mode", "ring"]).unwrap();
        let cfg = RunConfig::default().override_from_args(&args).unwrap();
        assert_eq!(
            cfg.trace_mode,
            TraceMode::Ring(crate::obs::SINK_CAPACITY)
        );

        let args =
            Args::parse(["run", "--trace-mode", "ring:128"]).unwrap();
        let cfg = RunConfig::default().override_from_args(&args).unwrap();
        assert_eq!(cfg.trace_mode, TraceMode::Ring(128));

        let v = json::parse(r#"{"trace_mode": "ring:64"}"#).unwrap();
        let cfg = RunConfig::from_json(&v).unwrap();
        assert_eq!(cfg.trace_mode, TraceMode::Ring(64));

        let v = json::parse(r#"{"trace_mode": "unbounded"}"#).unwrap();
        let cfg = RunConfig::from_json(&v).unwrap();
        assert_eq!(cfg.trace_mode, TraceMode::Unbounded);

        for bad in ["ring:0", "ring:none", "reservoir"] {
            let args =
                Args::parse(["run", "--trace-mode", bad]).unwrap();
            assert!(
                RunConfig::default().override_from_args(&args).is_err(),
                "trace mode {bad:?} should be rejected"
            );
        }
    }

    #[test]
    fn exec_and_quota_overrides() {
        let args = Args::parse([
            "run",
            "--exec",
            "sequential",
            "--quota",
            "64",
        ])
        .unwrap();
        let cfg = RunConfig::default().override_from_args(&args).unwrap();
        assert_eq!(cfg.exec, ExecMode::Sequential);
        assert_eq!(cfg.comm_quota, 64);
        // defaults: pooled execution, NEST-like starting quota
        let cfg = RunConfig::default();
        assert_eq!(cfg.exec, ExecMode::Pooled);
        assert_eq!(cfg.comm_quota, 1024);

        let v = json::parse(
            r#"{"exec": "pooled", "comm_quota": 16}"#,
        )
        .unwrap();
        let cfg = RunConfig::from_json(&v).unwrap();
        assert_eq!(cfg.exec, ExecMode::Pooled);
        assert_eq!(cfg.comm_quota, 16);
    }
}
