//! The kernel's fundamental data structures (paper Fig 10), with the
//! structure-aware duplication into short-/long-range pathways (§4.1.2).
//!
//! * [`ConnTable`] — postsynaptic side: per (rank, thread, pathway), the
//!   thread-local connections in CSR form sorted by source GID (NEST's
//!   merged connection + source table; the sort enables the binary-search
//!   lookup a spike performs on arrival).
//! * [`TargetTable`] — presynaptic side: for every thread-local neuron the
//!   deduplicated list of ranks hosting at least one of its targets
//!   (NEST's *spike compression*: one message per target rank, not per
//!   target thread).
//! * [`SourceShards`] — rank-level source → owning-threads index built
//!   from the per-thread [`ConnTable`]s: for every source GID with at
//!   least one connection on this rank, the sorted list of virtual
//!   threads hosting connections from it.  The deliver phase uses it to
//!   route each received spike into exactly the per-thread queues that
//!   will consume it (`O(batch + hits)` instead of every thread scanning
//!   the full batch, `O(T·batch)`).
//! * [`Pathways`] — the pair of short-/long-range copies of a structure;
//!   the conventional strategy uses only the short slot.

use crate::network::Gid;

/// A connection as stored on the postsynaptic side; the source GID lives
/// in the CSR index, not here.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LocalConn {
    /// Thread-local index of the target neuron.
    pub target_local: u32,
    pub weight: f32,
    pub delay_steps: u16,
}

/// Above this source-GID range the dense index is not built and lookups
/// fall back to binary search (NEST's memory/speed trade-off: a dense
/// per-thread index costs 4 bytes x N_total).
const DENSE_INDEX_LIMIT: usize = 1 << 24;

/// CSR over connections grouped by source GID, sorted ascending.
#[derive(Clone, Debug, Default)]
pub struct ConnTable {
    sources: Vec<Gid>,
    offsets: Vec<u32>,
    conns: Vec<LocalConn>,
    /// Dense `gid -> group index` map (`u32::MAX` = no connections);
    /// empty when the GID range exceeds [`DENSE_INDEX_LIMIT`].
    dense: Vec<u32>,
}

impl ConnTable {
    /// Build from (source, connection) pairs.  The relative order of
    /// connections with the same source is preserved (stable sort), which
    /// makes multapse delivery order deterministic.
    pub fn build(mut entries: Vec<(Gid, LocalConn)>) -> ConnTable {
        entries.sort_by_key(|(src, _)| *src);
        let mut sources = Vec::new();
        let mut offsets = Vec::new();
        let mut conns = Vec::with_capacity(entries.len());
        let mut last: Option<Gid> = None;
        for (src, conn) in entries {
            if last != Some(src) {
                sources.push(src);
                offsets.push(conns.len() as u32);
                last = Some(src);
            }
            conns.push(conn);
        }
        offsets.push(conns.len() as u32);
        // dense O(1) index over the source-GID range (perf: replaces the
        // per-spike binary search in the deliver hot path — see
        // EXPERIMENTS.md §Perf)
        let max_src = sources.last().map(|&s| s as usize + 1).unwrap_or(0);
        let dense = if max_src > 0 && max_src <= DENSE_INDEX_LIMIT {
            let mut d = vec![u32::MAX; max_src];
            for (i, &s) in sources.iter().enumerate() {
                d[s as usize] = i as u32;
            }
            d
        } else {
            Vec::new()
        };
        ConnTable { sources, offsets, conns, dense }
    }

    /// Connections of `source` (empty slice if none) — the per-spike
    /// lookup of the deliver phase.
    #[inline]
    pub fn lookup(&self, source: Gid) -> &[LocalConn] {
        if !self.dense.is_empty() {
            let i = match self.dense.get(source as usize) {
                Some(&i) if i != u32::MAX => i as usize,
                _ => return &[],
            };
            let lo = self.offsets[i] as usize;
            let hi = self.offsets[i + 1] as usize;
            return &self.conns[lo..hi];
        }
        match self.sources.binary_search(&source) {
            Ok(i) => {
                let lo = self.offsets[i] as usize;
                let hi = self.offsets[i + 1] as usize;
                &self.conns[lo..hi]
            }
            Err(_) => &[],
        }
    }

    /// Does `source` have any connection in this table?  (Cheaper than
    /// `lookup` when only membership matters.)
    #[inline]
    pub fn has_source(&self, source: Gid) -> bool {
        if !self.dense.is_empty() {
            return matches!(self.dense.get(source as usize),
                            Some(&i) if i != u32::MAX);
        }
        self.sources.binary_search(&source).is_ok()
    }

    pub fn n_connections(&self) -> usize {
        self.conns.len()
    }

    pub fn n_sources(&self) -> usize {
        self.sources.len()
    }

    /// Iterate `(source, connections)` groups in ascending source order.
    pub fn iter_groups(
        &self,
    ) -> impl Iterator<Item = (Gid, &[LocalConn])> + '_ {
        self.sources.iter().enumerate().map(move |(i, &src)| {
            let lo = self.offsets[i] as usize;
            let hi = self.offsets[i + 1] as usize;
            (src, &self.conns[lo..hi])
        })
    }

    /// Approximate heap footprint in bytes (for the memory-overhead
    /// accounting of the dual-table scheme).
    pub fn heap_bytes(&self) -> usize {
        self.sources.len() * std::mem::size_of::<Gid>()
            + self.offsets.len() * 4
            + self.conns.len() * std::mem::size_of::<LocalConn>()
            + self.dense.len() * 4
    }
}

/// Rank-level source-membership index for thread-sharded spike delivery:
/// CSR from source GID to the virtual threads of this rank hosting at
/// least one connection from that source.  Built once per pathway at
/// rank-construction time by merging the per-thread connection tables;
/// shares the dense-index trade-off of [`ConnTable`].
#[derive(Clone, Debug, Default)]
pub struct SourceShards {
    sources: Vec<Gid>,
    offsets: Vec<u32>,
    threads: Vec<u16>,
    /// Dense `gid -> group index` map (`u32::MAX` = no connections);
    /// empty when the GID range exceeds [`DENSE_INDEX_LIMIT`].
    dense: Vec<u32>,
}

impl SourceShards {
    /// Merge the per-thread connection tables (iterated in virtual-thread
    /// order) into the rank-level source → threads index.
    pub fn build<'a, I>(tables: I) -> SourceShards
    where
        I: IntoIterator<Item = &'a ConnTable>,
    {
        let mut pairs: Vec<(Gid, u16)> = Vec::new();
        for (t, table) in tables.into_iter().enumerate() {
            // iter_groups yields each source once per table, ascending
            for (src, _) in table.iter_groups() {
                pairs.push((src, t as u16));
            }
        }
        pairs.sort_unstable();
        let mut sources = Vec::new();
        let mut offsets = Vec::new();
        let mut threads = Vec::with_capacity(pairs.len());
        let mut last: Option<Gid> = None;
        for (src, t) in pairs {
            if last != Some(src) {
                sources.push(src);
                offsets.push(threads.len() as u32);
                last = Some(src);
            }
            threads.push(t);
        }
        offsets.push(threads.len() as u32);
        let max_src = sources.last().map(|&s| s as usize + 1).unwrap_or(0);
        let dense = if max_src > 0 && max_src <= DENSE_INDEX_LIMIT {
            let mut d = vec![u32::MAX; max_src];
            for (i, &s) in sources.iter().enumerate() {
                d[s as usize] = i as u32;
            }
            d
        } else {
            Vec::new()
        };
        SourceShards { sources, offsets, threads, dense }
    }

    /// Virtual threads hosting connections from `source`, ascending
    /// (empty slice if none) — the per-spike routing lookup.
    #[inline]
    pub fn lookup(&self, source: Gid) -> &[u16] {
        if !self.dense.is_empty() {
            let i = match self.dense.get(source as usize) {
                Some(&i) if i != u32::MAX => i as usize,
                _ => return &[],
            };
            let lo = self.offsets[i] as usize;
            let hi = self.offsets[i + 1] as usize;
            return &self.threads[lo..hi];
        }
        match self.sources.binary_search(&source) {
            Ok(i) => {
                let lo = self.offsets[i] as usize;
                let hi = self.offsets[i + 1] as usize;
                &self.threads[lo..hi]
            }
            Err(_) => &[],
        }
    }

    /// Distinct sources with at least one connection on this rank.
    pub fn n_sources(&self) -> usize {
        self.sources.len()
    }

    /// Total (source, thread) routing entries.
    pub fn total_entries(&self) -> usize {
        self.threads.len()
    }

    /// Approximate heap footprint in bytes.
    pub fn heap_bytes(&self) -> usize {
        self.sources.len() * std::mem::size_of::<Gid>()
            + self.offsets.len() * 4
            + self.threads.len() * 2
            + self.dense.len() * 4
    }
}

/// Test bit `idx` of a has-targets bitmask built by
/// [`TargetTable::has_targets_mask`].
#[inline]
pub fn mask_test(mask: &[u64], idx: usize) -> bool {
    mask[idx / 64] & (1u64 << (idx % 64)) != 0
}

/// Presynaptic target table with spike compression: per thread-local
/// neuron, the sorted, deduplicated ranks hosting its targets.
#[derive(Clone, Debug, Default)]
pub struct TargetTable {
    ranks_of: Vec<Vec<u16>>,
}

impl TargetTable {
    pub fn new(n_local_neurons: usize) -> TargetTable {
        TargetTable { ranks_of: vec![Vec::new(); n_local_neurons] }
    }

    /// Register that local neuron `local_idx` has >= 1 target on `rank`.
    pub fn add(&mut self, local_idx: usize, rank: u16) {
        let v = &mut self.ranks_of[local_idx];
        if let Err(pos) = v.binary_search(&rank) {
            v.insert(pos, rank);
        }
    }

    /// Target ranks of a local neuron.
    #[inline]
    pub fn ranks(&self, local_idx: usize) -> &[u16] {
        &self.ranks_of[local_idx]
    }

    pub fn n_neurons(&self) -> usize {
        self.ranks_of.len()
    }

    /// Total (neuron, rank) entries — the communication fan-out.
    pub fn total_entries(&self) -> usize {
        self.ranks_of.iter().map(|v| v.len()).sum()
    }

    /// Per-neuron has-targets bitmask (64 neurons per word): bit `i` is
    /// set iff local neuron `i` has at least one target rank.  Built
    /// once after target-table construction so the update phase tests
    /// membership with [`mask_test`] instead of chasing the per-neuron
    /// rank vectors on every spike.
    pub fn has_targets_mask(&self) -> Vec<u64> {
        let mut mask = vec![0u64; self.ranks_of.len().div_ceil(64)];
        for (i, v) in self.ranks_of.iter().enumerate() {
            if !v.is_empty() {
                mask[i / 64] |= 1u64 << (i % 64);
            }
        }
        mask
    }
}

/// The short-/long-range duplication of §4.1.2.  `short` is also the
/// single table of the conventional scheme.
#[derive(Clone, Debug, Default)]
pub struct Pathways<T> {
    pub short: T,
    pub long: T,
}

impl<T> Pathways<T> {
    pub fn get(&self, long_range: bool) -> &T {
        if long_range {
            &self.long
        } else {
            &self.short
        }
    }

    pub fn get_mut(&mut self, long_range: bool) -> &mut T {
        if long_range {
            &mut self.long
        } else {
            &mut self.short
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn conn(t: u32, w: f32, d: u16) -> LocalConn {
        LocalConn { target_local: t, weight: w, delay_steps: d }
    }

    #[test]
    fn build_and_lookup() {
        let table = ConnTable::build(vec![
            (5, conn(0, 1.0, 1)),
            (2, conn(1, 2.0, 1)),
            (5, conn(2, 3.0, 2)),
            (9, conn(3, 4.0, 3)),
        ]);
        assert_eq!(table.n_sources(), 3);
        assert_eq!(table.n_connections(), 4);
        assert_eq!(table.lookup(2), &[conn(1, 2.0, 1)]);
        // multapse order preserved (stable by insertion)
        assert_eq!(table.lookup(5), &[conn(0, 1.0, 1), conn(2, 3.0, 2)]);
        assert!(table.lookup(7).is_empty());
        assert!(table.has_source(9));
        assert!(!table.has_source(0));
    }

    #[test]
    fn empty_table() {
        let table = ConnTable::build(vec![]);
        assert_eq!(table.n_connections(), 0);
        assert!(table.lookup(0).is_empty());
    }

    #[test]
    fn groups_cover_all_connections() {
        let mut rng = Pcg64::seed_from_u64(3);
        let entries: Vec<(Gid, LocalConn)> = (0..1000)
            .map(|i| (rng.below(100) as Gid, conn(i, 0.5, 1)))
            .collect();
        let table = ConnTable::build(entries.clone());
        let total: usize =
            table.iter_groups().map(|(_, conns)| conns.len()).sum();
        assert_eq!(total, 1000);
        // sources ascend strictly
        let srcs: Vec<Gid> = table.iter_groups().map(|(s, _)| s).collect();
        assert!(srcs.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn lookup_matches_linear_scan() {
        let mut rng = Pcg64::seed_from_u64(7);
        let entries: Vec<(Gid, LocalConn)> = (0..500)
            .map(|i| (rng.below(60) as Gid, conn(i, 1.0, 1)))
            .collect();
        let table = ConnTable::build(entries.clone());
        for probe in 0..60u32 {
            let want: Vec<LocalConn> = entries
                .iter()
                .filter(|(s, _)| *s == probe)
                .map(|(_, c)| *c)
                .collect();
            assert_eq!(table.lookup(probe), want.as_slice());
        }
    }

    #[test]
    fn target_table_dedups_and_sorts() {
        let mut t = TargetTable::new(3);
        t.add(0, 5);
        t.add(0, 2);
        t.add(0, 5);
        t.add(2, 1);
        assert_eq!(t.ranks(0), &[2, 5]);
        assert_eq!(t.ranks(1), &[] as &[u16]);
        assert_eq!(t.ranks(2), &[1]);
        assert_eq!(t.total_entries(), 3);
    }

    #[test]
    fn pathways_access() {
        let mut p: Pathways<Vec<u32>> = Pathways::default();
        p.get_mut(false).push(1);
        p.get_mut(true).push(2);
        assert_eq!(p.get(false), &vec![1]);
        assert_eq!(p.get(true), &vec![2]);
    }

    #[test]
    fn source_shards_route_to_owning_threads() {
        // thread 0 owns sources {2, 5}, thread 1 owns {5, 9}, thread 2
        // owns nothing
        let t0 = ConnTable::build(vec![
            (5, conn(0, 1.0, 1)),
            (2, conn(1, 2.0, 1)),
        ]);
        let t1 = ConnTable::build(vec![
            (9, conn(0, 1.0, 1)),
            (5, conn(1, 1.0, 1)),
            (5, conn(2, 1.0, 2)),
        ]);
        let t2 = ConnTable::build(vec![]);
        let shards = SourceShards::build([&t0, &t1, &t2]);
        assert_eq!(shards.lookup(2), &[0]);
        assert_eq!(shards.lookup(5), &[0, 1]); // ascending thread order
        assert_eq!(shards.lookup(9), &[1]);
        assert_eq!(shards.lookup(7), &[] as &[u16]);
        assert_eq!(shards.n_sources(), 3);
        assert_eq!(shards.total_entries(), 4);
    }

    #[test]
    fn source_shards_empty() {
        let shards = SourceShards::build(std::iter::empty::<&ConnTable>());
        assert_eq!(shards.n_sources(), 0);
        assert_eq!(shards.lookup(0), &[] as &[u16]);
    }

    #[test]
    fn source_shards_match_per_table_membership() {
        // property: shards.lookup(s) contains t iff tables[t].has_source(s)
        let mut rng = Pcg64::seed_from_u64(11);
        let tables: Vec<ConnTable> = (0..4)
            .map(|_| {
                let entries: Vec<(Gid, LocalConn)> = (0..200)
                    .map(|i| (rng.below(80) as Gid, conn(i, 1.0, 1)))
                    .collect();
                ConnTable::build(entries)
            })
            .collect();
        let shards = SourceShards::build(tables.iter());
        for src in 0..80u32 {
            let want: Vec<u16> = tables
                .iter()
                .enumerate()
                .filter(|(_, t)| t.has_source(src))
                .map(|(i, _)| i as u16)
                .collect();
            assert_eq!(shards.lookup(src), want.as_slice(), "source {src}");
        }
    }

    #[test]
    fn has_targets_mask_matches_ranks() {
        let mut t = TargetTable::new(130); // spans three 64-bit words
        t.add(0, 1);
        t.add(63, 2);
        t.add(64, 3);
        t.add(129, 4);
        let mask = t.has_targets_mask();
        assert_eq!(mask.len(), 3);
        for i in 0..130 {
            assert_eq!(
                mask_test(&mask, i),
                !t.ranks(i).is_empty(),
                "neuron {i}"
            );
        }
    }

    #[test]
    fn heap_bytes_scales_with_content() {
        let small = ConnTable::build(vec![(1, conn(0, 1.0, 1))]);
        let big = ConnTable::build(
            (0..1000).map(|i| (i as Gid, conn(i, 1.0, 1))).collect(),
        );
        assert!(big.heap_bytes() > small.heap_bytes() * 100);
    }
}
