//! The kernel's fundamental data structures (paper Fig 10), with the
//! structure-aware duplication into short-/long-range pathways (§4.1.2)
//! and the cache-aware layout of the receive side (arXiv 2109.12855).
//!
//! * [`ConnTable`] — postsynaptic side: per (rank, thread, pathway), the
//!   thread-local connections in CSR form grouped by source GID
//!   (ascending) in **structure-of-arrays** layout: `target_local`,
//!   `weight` and `delay_steps` live in three parallel arrays, so the
//!   per-spike walk is three contiguous scans instead of a strided walk
//!   over 12-byte structs.  Within a source group connections are
//!   **delay-bucketed** (stable-sorted by `delay_steps`), so the ring
//!   buffer writes of one spike hit each slot row once, sequentially —
//!   see [`ConnSlice::delay_runs`].  Reordering by delay changes the
//!   f64 accumulation order, which is only sound because the bundled
//!   models use exact binary-fraction weights (order-independent sums,
//!   DESIGN.md §6); `build` *asserts* that invariant instead of
//!   assuming it.
//! * [`TargetTable`] — presynaptic side: for every thread-local neuron the
//!   deduplicated list of ranks hosting at least one of its targets
//!   (NEST's *spike compression*: one message per target rank, not per
//!   target thread).
//! * [`SourceShards`] — rank-level source → (owning thread, connection
//!   group) index built from the per-thread [`ConnTable`]s: for every
//!   source GID with at least one connection on this rank, the sorted
//!   list of virtual threads hosting connections from it, each paired
//!   with the *group index* of that source in the owning thread's table.
//!   The parallel receive path uses it to scatter each received spike
//!   into exactly the per-thread buckets that will consume it, already
//!   resolved to a connection group — the consuming thread never
//!   searches its table again.  The dense O(1) source index lives here,
//!   **once per rank per pathway**, not in every per-thread
//!   [`ConnTable`] (which would cost `2·T·4·max_gid` bytes per rank).
//! * [`Pathways`] — the pair of short-/long-range copies of a structure;
//!   the conventional strategy uses only the short slot.

use crate::network::Gid;

/// A connection as stored on the postsynaptic side; the source GID lives
/// in the CSR index, not here.  [`ConnTable`] stores the three fields in
/// parallel arrays; this struct is the per-connection view yielded by
/// [`ConnSlice::iter`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LocalConn {
    /// Thread-local index of the target neuron.
    pub target_local: u32,
    pub weight: f32,
    pub delay_steps: u16,
}

/// Above this source-GID range the dense index is not built and lookups
/// fall back to binary search (NEST's memory/speed trade-off: the dense
/// index costs 4 bytes × `max_gid`, held once per rank per pathway in
/// [`SourceShards`]).
const DENSE_INDEX_LIMIT: usize = 1 << 24;

/// Build a dense `gid -> group index` map over `sources` (ascending,
/// deduplicated); empty when the GID range exceeds
/// [`DENSE_INDEX_LIMIT`].  `u32::MAX` marks "no connections".
fn build_dense(sources: &[Gid]) -> Vec<u32> {
    let max_src = sources.last().map(|&s| s as usize + 1).unwrap_or(0);
    if max_src == 0 || max_src > DENSE_INDEX_LIMIT {
        return Vec::new();
    }
    let mut d = vec![u32::MAX; max_src];
    for (i, &s) in sources.iter().enumerate() {
        d[s as usize] = i as u32;
    }
    d
}

/// One source group of a [`ConnTable`]: parallel borrows of the SoA
/// columns.  The deliver hot path walks `delay_runs()`; everything else
/// can reconstruct [`LocalConn`] values via `iter()`.
#[derive(Clone, Copy, Debug)]
pub struct ConnSlice<'a> {
    pub targets: &'a [u32],
    pub weights: &'a [f32],
    pub delays: &'a [u16],
}

impl<'a> ConnSlice<'a> {
    pub fn len(&self) -> usize {
        self.targets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.targets.is_empty()
    }

    /// Per-connection view (cold paths and tests).
    pub fn iter(&self) -> impl Iterator<Item = LocalConn> + 'a {
        self.targets
            .iter()
            .zip(self.weights)
            .zip(self.delays)
            .map(|((&t, &w), &d)| LocalConn {
                target_local: t,
                weight: w,
                delay_steps: d,
            })
    }

    /// Iterate the delay buckets of this group: maximal runs of equal
    /// `delay_steps` (contiguous because `build` sorts each group by
    /// delay), yielding `(delay, targets, weights)`.  One run = one ring
    /// slot row, so the caller's accumulation writes are sequential per
    /// row.
    pub fn delay_runs(
        &self,
    ) -> impl Iterator<Item = (u16, &'a [u32], &'a [f32])> + 'a {
        let (targets, weights, delays) =
            (self.targets, self.weights, self.delays);
        let mut i = 0usize;
        std::iter::from_fn(move || {
            if i >= delays.len() {
                return None;
            }
            let d = delays[i];
            let mut j = i + 1;
            while j < delays.len() && delays[j] == d {
                j += 1;
            }
            let run = (d, &targets[i..j], &weights[i..j]);
            i = j;
            Some(run)
        })
    }
}

/// In debug builds, verify the order-independence invariant the
/// delay-bucketed layout relies on: every weight must be an exact
/// multiple of 2⁻²⁴ with magnitude below 2²⁰, so partial f64 sums of any
/// realistic fan-in are exact and therefore independent of accumulation
/// order (DESIGN.md §6).
fn debug_assert_exact_weight(w: f32) {
    debug_assert!(
        {
            let scaled = w as f64 * (1u64 << 24) as f64;
            scaled.fract() == 0.0 && scaled.abs() < (1u64 << 44) as f64
        },
        "connection weight {w} is not an exact binary fraction \
         (multiple of 2^-24, |w| < 2^20): delay-bucketed delivery \
         reorders ring-buffer accumulation, which is only bit-safe for \
         order-independent sums (DESIGN.md §6)"
    );
}

/// CSR over connections grouped by source GID (ascending), columns in
/// SoA layout, each group delay-bucketed.  See the module docs.
#[derive(Clone, Debug, Default)]
pub struct ConnTable {
    sources: Vec<Gid>,
    offsets: Vec<u32>,
    target_local: Vec<u32>,
    weight: Vec<f32>,
    delay_steps: Vec<u16>,
}

impl ConnTable {
    /// Build from (source, connection) pairs.  Connections are grouped
    /// by source and delay-bucketed within each group (stable sort by
    /// `(source, delay_steps)`): the relative order of connections with
    /// the same source *and* delay is preserved, so multapse delivery
    /// order within a delay bucket stays insertion-deterministic, while
    /// the bucket reordering itself is covered by the asserted
    /// binary-fraction weight invariant.
    pub fn build(mut entries: Vec<(Gid, LocalConn)>) -> ConnTable {
        entries.sort_by_key(|(src, c)| (*src, c.delay_steps));
        let n = entries.len();
        let mut sources = Vec::new();
        let mut offsets = Vec::new();
        let mut target_local = Vec::with_capacity(n);
        let mut weight = Vec::with_capacity(n);
        let mut delay_steps = Vec::with_capacity(n);
        let mut last: Option<Gid> = None;
        for (src, conn) in entries {
            if last != Some(src) {
                sources.push(src);
                offsets.push(target_local.len() as u32);
                last = Some(src);
            }
            debug_assert_exact_weight(conn.weight);
            target_local.push(conn.target_local);
            weight.push(conn.weight);
            delay_steps.push(conn.delay_steps);
        }
        offsets.push(target_local.len() as u32);
        ConnTable { sources, offsets, target_local, weight, delay_steps }
    }

    /// The `i`-th source group (groups ascend by source GID) — the hot
    /// lookup of the parallel receive path, where [`SourceShards`] has
    /// already resolved each spike to its group index.
    #[inline]
    pub fn group(&self, i: usize) -> ConnSlice<'_> {
        let lo = self.offsets[i] as usize;
        let hi = self.offsets[i + 1] as usize;
        ConnSlice {
            targets: &self.target_local[lo..hi],
            weights: &self.weight[lo..hi],
            delays: &self.delay_steps[lo..hi],
        }
    }

    /// Connections of `source` (empty if none) by binary search — the
    /// cold-path lookup (tests, the legacy channel runtime).  Hot-path
    /// routing goes through [`SourceShards`], which carries pre-resolved
    /// group indices backed by the rank-level dense index.
    #[inline]
    pub fn lookup(&self, source: Gid) -> ConnSlice<'_> {
        match self.sources.binary_search(&source) {
            Ok(i) => self.group(i),
            Err(_) => ConnSlice { targets: &[], weights: &[], delays: &[] },
        }
    }

    /// Does `source` have any connection in this table?  (Cheaper than
    /// `lookup` when only membership matters.)
    #[inline]
    pub fn has_source(&self, source: Gid) -> bool {
        self.sources.binary_search(&source).is_ok()
    }

    pub fn n_connections(&self) -> usize {
        self.target_local.len()
    }

    pub fn n_sources(&self) -> usize {
        self.sources.len()
    }

    /// Iterate `(source, group)` pairs in ascending source order; the
    /// enumeration index is the group index [`SourceShards`] stores.
    pub fn iter_groups(
        &self,
    ) -> impl Iterator<Item = (Gid, ConnSlice<'_>)> + '_ {
        self.sources
            .iter()
            .enumerate()
            .map(move |(i, &src)| (src, self.group(i)))
    }

    /// Approximate heap footprint in bytes (for the memory-overhead
    /// accounting of the dual-table scheme).
    pub fn heap_bytes(&self) -> usize {
        self.sources.len() * std::mem::size_of::<Gid>()
            + self.offsets.len() * 4
            + self.target_local.len() * 4
            + self.weight.len() * 4
            + self.delay_steps.len() * 2
    }
}

/// One routing hit of [`SourceShards::lookup`]: for each owning thread
/// (ascending), the group index of the source in that thread's
/// [`ConnTable`] of the same pathway.
#[derive(Clone, Copy, Debug)]
pub struct ShardHit<'a> {
    pub threads: &'a [u16],
    pub groups: &'a [u32],
}

impl ShardHit<'_> {
    pub fn is_empty(&self) -> bool {
        self.threads.is_empty()
    }
}

/// Rank-level source → (owning thread, connection group) index for the
/// parallel receive path: CSR from source GID to the virtual threads of
/// this rank hosting at least one connection from that source, each
/// entry carrying the source's group index in the owning thread's
/// connection table.  Built once per pathway at rank-construction time
/// by merging the per-thread connection tables.  This is where the
/// dense O(1) source index lives — once per rank per pathway (4 bytes ×
/// `max_gid`), replacing the former per-(thread, pathway) copies.
#[derive(Clone, Debug, Default)]
pub struct SourceShards {
    sources: Vec<Gid>,
    offsets: Vec<u32>,
    threads: Vec<u16>,
    /// Parallel to `threads`: group index of the source in the owning
    /// thread's [`ConnTable`].
    groups: Vec<u32>,
    /// Dense `gid -> CSR group index` map (`u32::MAX` = no connections);
    /// empty when the GID range exceeds [`DENSE_INDEX_LIMIT`].
    dense: Vec<u32>,
}

impl SourceShards {
    /// Merge the per-thread connection tables (iterated in virtual-thread
    /// order) into the rank-level routing index.
    pub fn build<'a, I>(tables: I) -> SourceShards
    where
        I: IntoIterator<Item = &'a ConnTable>,
    {
        let mut triples: Vec<(Gid, u16, u32)> = Vec::new();
        for (t, table) in tables.into_iter().enumerate() {
            // iter_groups yields each source once per table, ascending;
            // the enumeration index is the group index group() resolves
            for (g, (src, _)) in table.iter_groups().enumerate() {
                triples.push((src, t as u16, g as u32));
            }
        }
        // (source, thread) pairs are unique, so unstable is safe
        triples.sort_unstable();
        let mut sources = Vec::new();
        let mut offsets = Vec::new();
        let mut threads = Vec::with_capacity(triples.len());
        let mut groups = Vec::with_capacity(triples.len());
        let mut last: Option<Gid> = None;
        for (src, t, g) in triples {
            if last != Some(src) {
                sources.push(src);
                offsets.push(threads.len() as u32);
                last = Some(src);
            }
            threads.push(t);
            groups.push(g);
        }
        offsets.push(threads.len() as u32);
        let dense = build_dense(&sources);
        SourceShards { sources, offsets, threads, groups, dense }
    }

    /// Owning threads of `source` (ascending) with the matching group
    /// indices (empty if none) — the per-spike routing lookup of the
    /// receive path.
    #[inline]
    pub fn lookup(&self, source: Gid) -> ShardHit<'_> {
        let i = if !self.dense.is_empty() {
            match self.dense.get(source as usize) {
                Some(&i) if i != u32::MAX => i as usize,
                _ => return ShardHit { threads: &[], groups: &[] },
            }
        } else {
            match self.sources.binary_search(&source) {
                Ok(i) => i,
                Err(_) => return ShardHit { threads: &[], groups: &[] },
            }
        };
        let lo = self.offsets[i] as usize;
        let hi = self.offsets[i + 1] as usize;
        ShardHit {
            threads: &self.threads[lo..hi],
            groups: &self.groups[lo..hi],
        }
    }

    /// Distinct sources with at least one connection on this rank.
    pub fn n_sources(&self) -> usize {
        self.sources.len()
    }

    /// Total (source, thread) routing entries.
    pub fn total_entries(&self) -> usize {
        self.threads.len()
    }

    /// Approximate heap footprint in bytes.
    pub fn heap_bytes(&self) -> usize {
        self.sources.len() * std::mem::size_of::<Gid>()
            + self.offsets.len() * 4
            + self.threads.len() * 2
            + self.groups.len() * 4
            + self.dense.len() * 4
    }
}

/// Test bit `idx` of a has-targets bitmask built by
/// [`TargetTable::has_targets_mask`].
#[inline]
pub fn mask_test(mask: &[u64], idx: usize) -> bool {
    mask[idx / 64] & (1u64 << (idx % 64)) != 0
}

/// Presynaptic target table with spike compression: per thread-local
/// neuron, the sorted, deduplicated ranks hosting its targets.
#[derive(Clone, Debug, Default)]
pub struct TargetTable {
    ranks_of: Vec<Vec<u16>>,
}

impl TargetTable {
    pub fn new(n_local_neurons: usize) -> TargetTable {
        TargetTable { ranks_of: vec![Vec::new(); n_local_neurons] }
    }

    /// Register that local neuron `local_idx` has >= 1 target on `rank`.
    pub fn add(&mut self, local_idx: usize, rank: u16) {
        let v = &mut self.ranks_of[local_idx];
        if let Err(pos) = v.binary_search(&rank) {
            v.insert(pos, rank);
        }
    }

    /// Target ranks of a local neuron.
    #[inline]
    pub fn ranks(&self, local_idx: usize) -> &[u16] {
        &self.ranks_of[local_idx]
    }

    pub fn n_neurons(&self) -> usize {
        self.ranks_of.len()
    }

    /// Total (neuron, rank) entries — the communication fan-out.
    pub fn total_entries(&self) -> usize {
        self.ranks_of.iter().map(|v| v.len()).sum()
    }

    /// Per-neuron has-targets bitmask (64 neurons per word): bit `i` is
    /// set iff local neuron `i` has at least one target rank.  Built
    /// once after target-table construction so the update phase tests
    /// membership with [`mask_test`] instead of chasing the per-neuron
    /// rank vectors on every spike.
    pub fn has_targets_mask(&self) -> Vec<u64> {
        let mut mask = vec![0u64; self.ranks_of.len().div_ceil(64)];
        for (i, v) in self.ranks_of.iter().enumerate() {
            if !v.is_empty() {
                mask[i / 64] |= 1u64 << (i % 64);
            }
        }
        mask
    }
}

/// The short-/long-range duplication of §4.1.2.  `short` is also the
/// single table of the conventional scheme.
#[derive(Clone, Debug, Default)]
pub struct Pathways<T> {
    pub short: T,
    pub long: T,
}

impl<T> Pathways<T> {
    pub fn get(&self, long_range: bool) -> &T {
        if long_range {
            &self.long
        } else {
            &self.short
        }
    }

    pub fn get_mut(&mut self, long_range: bool) -> &mut T {
        if long_range {
            &mut self.long
        } else {
            &mut self.short
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn conn(t: u32, w: f32, d: u16) -> LocalConn {
        LocalConn { target_local: t, weight: w, delay_steps: d }
    }

    fn collect(cs: ConnSlice<'_>) -> Vec<LocalConn> {
        cs.iter().collect()
    }

    #[test]
    fn build_and_lookup() {
        let table = ConnTable::build(vec![
            (5, conn(0, 1.0, 1)),
            (2, conn(1, 2.0, 1)),
            (5, conn(2, 3.0, 2)),
            (9, conn(3, 4.0, 3)),
        ]);
        assert_eq!(table.n_sources(), 3);
        assert_eq!(table.n_connections(), 4);
        assert_eq!(collect(table.lookup(2)), vec![conn(1, 2.0, 1)]);
        // delay buckets ascend; insertion order preserved within each
        assert_eq!(
            collect(table.lookup(5)),
            vec![conn(0, 1.0, 1), conn(2, 3.0, 2)]
        );
        assert!(table.lookup(7).is_empty());
        assert!(table.has_source(9));
        assert!(!table.has_source(0));
    }

    #[test]
    fn empty_table() {
        let table = ConnTable::build(vec![]);
        assert_eq!(table.n_connections(), 0);
        assert!(table.lookup(0).is_empty());
    }

    #[test]
    fn groups_are_delay_bucketed_and_stable_within_bucket() {
        let table = ConnTable::build(vec![
            (3, conn(10, 0.5, 4)),
            (3, conn(11, 0.25, 1)),
            (3, conn(12, 0.5, 4)),
            (3, conn(13, 0.125, 1)),
            (3, conn(14, 0.5, 2)),
        ]);
        // sorted by delay; ties keep insertion order (stable)
        assert_eq!(
            collect(table.lookup(3)),
            vec![
                conn(11, 0.25, 1),
                conn(13, 0.125, 1),
                conn(14, 0.5, 2),
                conn(10, 0.5, 4),
                conn(12, 0.5, 4),
            ]
        );
        // delay_runs covers the group as maximal equal-delay runs
        let runs: Vec<(u16, usize)> = table
            .lookup(3)
            .delay_runs()
            .map(|(d, t, w)| {
                assert_eq!(t.len(), w.len());
                (d, t.len())
            })
            .collect();
        assert_eq!(runs, vec![(1, 2), (2, 1), (4, 2)]);
    }

    #[test]
    fn groups_cover_all_connections() {
        let mut rng = Pcg64::seed_from_u64(3);
        let entries: Vec<(Gid, LocalConn)> = (0..1000)
            .map(|i| (rng.below(100) as Gid, conn(i, 0.5, 1)))
            .collect();
        let table = ConnTable::build(entries.clone());
        let total: usize =
            table.iter_groups().map(|(_, conns)| conns.len()).sum();
        assert_eq!(total, 1000);
        // sources ascend strictly
        let srcs: Vec<Gid> = table.iter_groups().map(|(s, _)| s).collect();
        assert!(srcs.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn lookup_matches_linear_scan() {
        let mut rng = Pcg64::seed_from_u64(7);
        let entries: Vec<(Gid, LocalConn)> = (0..500)
            .map(|i| (rng.below(60) as Gid, conn(i, 1.0, 1)))
            .collect();
        let table = ConnTable::build(entries.clone());
        for probe in 0..60u32 {
            let want: Vec<LocalConn> = entries
                .iter()
                .filter(|(s, _)| *s == probe)
                .map(|(_, c)| *c)
                .collect();
            assert_eq!(collect(table.lookup(probe)), want);
        }
    }

    #[test]
    fn group_matches_lookup() {
        let table = ConnTable::build(vec![
            (4, conn(0, 1.0, 1)),
            (8, conn(1, 2.0, 2)),
            (8, conn(2, 3.0, 1)),
        ]);
        let srcs: Vec<Gid> = table.iter_groups().map(|(s, _)| s).collect();
        for (i, src) in srcs.into_iter().enumerate() {
            assert_eq!(collect(table.group(i)), collect(table.lookup(src)));
        }
    }

    #[test]
    fn target_table_dedups_and_sorts() {
        let mut t = TargetTable::new(3);
        t.add(0, 5);
        t.add(0, 2);
        t.add(0, 5);
        t.add(2, 1);
        assert_eq!(t.ranks(0), &[2, 5]);
        assert_eq!(t.ranks(1), &[] as &[u16]);
        assert_eq!(t.ranks(2), &[1]);
        assert_eq!(t.total_entries(), 3);
    }

    #[test]
    fn pathways_access() {
        let mut p: Pathways<Vec<u32>> = Pathways::default();
        p.get_mut(false).push(1);
        p.get_mut(true).push(2);
        assert_eq!(p.get(false), &vec![1]);
        assert_eq!(p.get(true), &vec![2]);
    }

    #[test]
    fn source_shards_route_to_owning_threads_with_groups() {
        // thread 0 owns sources {2, 5}, thread 1 owns {5, 9}, thread 2
        // owns nothing
        let t0 = ConnTable::build(vec![
            (5, conn(0, 1.0, 1)),
            (2, conn(1, 2.0, 1)),
        ]);
        let t1 = ConnTable::build(vec![
            (9, conn(0, 1.0, 1)),
            (5, conn(1, 1.0, 1)),
            (5, conn(2, 1.0, 2)),
        ]);
        let t2 = ConnTable::build(vec![]);
        let shards = SourceShards::build([&t0, &t1, &t2]);
        assert_eq!(shards.lookup(2).threads, &[0]);
        assert_eq!(shards.lookup(5).threads, &[0, 1]); // ascending threads
        assert_eq!(shards.lookup(9).threads, &[1]);
        assert!(shards.lookup(7).is_empty());
        assert_eq!(shards.n_sources(), 3);
        assert_eq!(shards.total_entries(), 4);
        // group indices resolve back to the right per-thread groups
        let tables = [&t0, &t1, &t2];
        for src in [2u32, 5, 9] {
            let hit = shards.lookup(src);
            for (&t, &g) in hit.threads.iter().zip(hit.groups) {
                assert_eq!(
                    collect(tables[t as usize].group(g as usize)),
                    collect(tables[t as usize].lookup(src)),
                    "source {src} thread {t}"
                );
            }
        }
    }

    #[test]
    fn source_shards_empty() {
        let shards = SourceShards::build(std::iter::empty::<&ConnTable>());
        assert_eq!(shards.n_sources(), 0);
        assert!(shards.lookup(0).is_empty());
    }

    #[test]
    fn source_shards_match_per_table_membership() {
        // property: shards.lookup(s) contains (t, g) iff
        // tables[t].has_source(s), with g resolving to s's group
        let mut rng = Pcg64::seed_from_u64(11);
        let tables: Vec<ConnTable> = (0..4)
            .map(|_| {
                let entries: Vec<(Gid, LocalConn)> = (0..200)
                    .map(|i| (rng.below(80) as Gid, conn(i, 1.0, 1)))
                    .collect();
                ConnTable::build(entries)
            })
            .collect();
        let shards = SourceShards::build(tables.iter());
        for src in 0..80u32 {
            let want: Vec<u16> = tables
                .iter()
                .enumerate()
                .filter(|(_, t)| t.has_source(src))
                .map(|(i, _)| i as u16)
                .collect();
            let hit = shards.lookup(src);
            assert_eq!(hit.threads, want.as_slice(), "source {src}");
            for (&t, &g) in hit.threads.iter().zip(hit.groups) {
                assert_eq!(
                    collect(tables[t as usize].group(g as usize)),
                    collect(tables[t as usize].lookup(src)),
                    "source {src} thread {t}"
                );
            }
        }
    }

    #[test]
    fn has_targets_mask_matches_ranks() {
        let mut t = TargetTable::new(130); // spans three 64-bit words
        t.add(0, 1);
        t.add(63, 2);
        t.add(64, 3);
        t.add(129, 4);
        let mask = t.has_targets_mask();
        assert_eq!(mask.len(), 3);
        for i in 0..130 {
            assert_eq!(
                mask_test(&mask, i),
                !t.ranks(i).is_empty(),
                "neuron {i}"
            );
        }
    }

    #[test]
    fn heap_bytes_scales_with_content() {
        let small = ConnTable::build(vec![(1, conn(0, 1.0, 1))]);
        let big = ConnTable::build(
            (0..1000).map(|i| (i as Gid, conn(i, 1.0, 1))).collect(),
        );
        assert!(big.heap_bytes() > small.heap_bytes() * 100);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "not an exact binary fraction")]
    fn non_binary_weight_is_rejected_in_debug() {
        // 0.3 has no finite binary expansion: order-dependent f64 sums
        let _ = ConnTable::build(vec![(1, conn(0, 0.3, 1))]);
    }
}
