//! Network model specification and connectivity instantiation.
//!
//! A [`ModelSpec`](spec::ModelSpec) describes a multi-area network the way
//! the paper's models do: a list of areas (each a contiguous GID range with
//! a neuron parameterization), per-neuron intra-/inter-area indegrees, and
//! delay distributions with lower cutoffs — the inter-area cutoff
//! `d_min_inter` being `D` times the overall minimum delay `d_min`.
//!
//! Connectivity is *instantiated deterministically per target neuron*
//! ([`build::incoming_connections`]): every rank draws exactly the incoming
//! connections of its local targets from a per-target RNG stream, so the
//! realized network is identical regardless of how neurons are placed on
//! ranks — the property that makes the conventional-vs-structure-aware
//! equivalence test meaningful.

pub mod spec;
pub mod build;

pub use build::{incoming_connections, Conn};
pub use spec::{AreaSpec, DelayDist, Lesion, LifParams, ModelSpec, NeuronKind};

/// Global neuron id (order of creation, as in NEST).
pub type Gid = u32;
