//! Model specification types: areas, neuron parameterizations, delay
//! distributions and the multi-area wiring rule.

use super::Gid;
use anyhow::{bail, Result};

/// Gaussian delay distribution with a hard lower cutoff (paper §4.2: both
/// models impose a lower cutoff `d_min_inter` on inter-area delays).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DelayDist {
    pub mean_ms: f64,
    pub std_ms: f64,
    pub min_ms: f64,
}

impl DelayDist {
    pub fn new(mean_ms: f64, std_ms: f64, min_ms: f64) -> Self {
        Self { mean_ms, std_ms, min_ms }
    }

    /// Draw a delay in steps of `h_ms` (>= the cutoff in steps, >= 1).
    pub fn draw_steps(&self, rng: &mut crate::util::rng::Pcg64, h_ms: f64) -> u16 {
        let min_steps = self.min_steps(h_ms);
        let d = rng.normal_truncated_low(self.mean_ms, self.std_ms, self.min_ms);
        let steps = (d / h_ms).round() as i64;
        steps.max(min_steps as i64).min(u16::MAX as i64) as u16
    }

    /// Cutoff in resolution steps (>= 1: a delay of zero steps would break
    /// causality of the cycle-based exchange).
    pub fn min_steps(&self, h_ms: f64) -> u16 {
        ((self.min_ms / h_ms).round() as i64).max(1) as u16
    }
}

/// Leaky integrate-and-fire parameters (`iaf_psc_delta`); potentials are
/// relative to the resting potential.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LifParams {
    pub tau_m_ms: f64,
    pub c_m_pf: f64,
    pub t_ref_ms: f64,
    pub theta_mv: f64,
    pub v_reset_mv: f64,
    /// Constant external drive current [pA] — the deterministic stand-in
    /// for the Poisson drive of the original models (DESIGN.md §2).
    pub i_e_pa: f64,
}

impl Default for LifParams {
    fn default() -> Self {
        Self {
            tau_m_ms: 10.0,
            c_m_pf: 250.0,
            t_ref_ms: 2.0,
            theta_mv: 15.0,
            v_reset_mv: 0.0,
            i_e_pa: 0.0,
        }
    }
}

impl LifParams {
    /// Membrane propagator for step `h` (f32, matching the L1 kernel).
    pub fn p22(&self, h_ms: f64) -> f32 {
        (-h_ms / self.tau_m_ms).exp() as f32
    }

    /// Per-step drive term `(1 - p22) * R_m * I_e` (f32).
    pub fn drive(&self, h_ms: f64) -> f32 {
        let p22 = (-h_ms / self.tau_m_ms).exp();
        let r_m = self.tau_m_ms / self.c_m_pf;
        ((1.0 - p22) * r_m * self.i_e_pa) as f32
    }

    pub fn ref_steps(&self, h_ms: f64) -> f32 {
        (self.t_ref_ms / h_ms).round() as f32
    }

    /// Tonic firing rate under the constant drive `i_e_pa` alone (inverse
    /// of [`Self::i_e_for_rate`]); 0 if subthreshold.
    pub fn tonic_rate_hz(&self) -> f64 {
        let r_m = self.tau_m_ms / self.c_m_pf;
        let ri = r_m * self.i_e_pa;
        if ri <= self.theta_mv {
            return 0.0;
        }
        let t_int = -self.tau_m_ms * (1.0 - self.theta_mv / ri).ln();
        1000.0 / (self.t_ref_ms + t_int)
    }

    /// The i_e required for tonic firing at `rate_hz` in the absence of
    /// synaptic input (inverse LIF f-I curve, exact for the
    /// exact-integration update).  Returns 0 for unachievable rates.
    pub fn i_e_for_rate(&self, rate_hz: f64) -> f64 {
        if rate_hz <= 0.0 {
            return 0.0;
        }
        let isi_ms = 1000.0 / rate_hz;
        let t_int = isi_ms - self.t_ref_ms; // integration time between spikes
        if t_int <= 0.0 {
            return 0.0;
        }
        // v(t) = R I (1 - exp(-t/tau)); threshold at t_int:
        //   R I = theta / (1 - exp(-t_int/tau))
        let r_m = self.tau_m_ms / self.c_m_pf;
        let denom = 1.0 - (-t_int / self.tau_m_ms).exp();
        self.theta_mv / (denom * r_m)
    }
}

/// Neuron model of an area.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum NeuronKind {
    Lif(LifParams),
    /// MAM-benchmark's ignore-and-fire: fires every `interval` steps with a
    /// GID-derived phase; synaptic input is delivered but ignored.
    IgnoreAndFire {
        /// Firing interval in resolution steps (rate = 1e3/(interval*h) Hz).
        interval_steps: u32,
    },
}

impl NeuronKind {
    pub fn ignore_and_fire_hz(rate_hz: f64, h_ms: f64) -> NeuronKind {
        let interval = (1000.0 / (rate_hz * h_ms)).round().max(1.0) as u32;
        NeuronKind::IgnoreAndFire { interval_steps: interval }
    }
}

/// One cortical area: a contiguous GID range with homogeneous neuron
/// parameters.
#[derive(Clone, Debug)]
pub struct AreaSpec {
    pub name: String,
    pub n: u32,
    pub neuron: NeuronKind,
}

/// Synaptic weight rule: fixed excitatory weight; sources in the last
/// `inh_fraction` of their area are inhibitory with weight `-g * w`.
///
/// Weights are chosen as exact binary fractions in the bundled models so
/// that ring-buffer sums are order-independent in f64 (DESIGN.md §6).
#[derive(Clone, Copy, Debug)]
pub struct WeightRule {
    pub w_mv: f32,
    pub g: f32,
    pub inh_fraction: f64,
}

impl Default for WeightRule {
    fn default() -> Self {
        Self { w_mv: 0.125, g: 5.0, inh_fraction: 0.2 }
    }
}

/// A perturbation of one area's long-range (inter-area) pathways: every
/// inter-area connection with the lesioned area at either endpoint has
/// its weight scaled by `factor`; `factor == 0` severs the pathways
/// outright.  The connection *draws* are untouched — the lesioned
/// network has the exact same topology and RNG stream as the intact
/// one, so lesion effects are attributable to the weights alone.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Lesion {
    /// Index of the lesioned area.
    pub area: usize,
    /// Inter-area weight scale in [0, 1], an exact multiple of 1/256 so
    /// scaled weights stay exact binary fractions (DESIGN.md §6).
    pub factor: f32,
}

/// A multi-area network specification.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    pub areas: Vec<AreaSpec>,
    /// Incoming intra-area synapses per neuron.
    pub k_intra: u32,
    /// Incoming inter-area synapses per neuron.
    pub k_inter: u32,
    pub weights: WeightRule,
    pub delay_intra: DelayDist,
    pub delay_inter: DelayDist,
    /// Resolution step [ms].
    pub h_ms: f64,
    /// Lesions applied on top of the wiring rule (usually empty).
    pub lesions: Vec<Lesion>,
    /// Cached area GID offsets (areas[i] spans offsets[i]..offsets[i+1]).
    offsets: Vec<Gid>,
}

impl ModelSpec {
    pub fn new(
        name: impl Into<String>,
        areas: Vec<AreaSpec>,
        k_intra: u32,
        k_inter: u32,
        weights: WeightRule,
        delay_intra: DelayDist,
        delay_inter: DelayDist,
        h_ms: f64,
    ) -> Result<ModelSpec> {
        if areas.is_empty() {
            bail!("model needs at least one area");
        }
        if delay_inter.min_ms < delay_intra.min_ms {
            bail!(
                "inter-area delay cutoff ({} ms) below intra-area cutoff \
                 ({} ms) — violates the multi-area delay separation",
                delay_inter.min_ms,
                delay_intra.min_ms
            );
        }
        let mut offsets = Vec::with_capacity(areas.len() + 1);
        let mut acc: Gid = 0;
        offsets.push(0);
        for a in &areas {
            if a.n == 0 {
                bail!("area {} has zero neurons", a.name);
            }
            acc = acc
                .checked_add(a.n)
                .ok_or_else(|| anyhow::anyhow!("GID overflow"))?;
            offsets.push(acc);
        }
        Ok(ModelSpec {
            name: name.into(),
            areas,
            k_intra,
            k_inter,
            weights,
            delay_intra,
            delay_inter,
            h_ms,
            lesions: Vec::new(),
            offsets,
        })
    }

    /// Apply a lesion to the named area's long-range pathways.  The
    /// factor must be an exact multiple of 1/256 in [0, 1] so scaled
    /// weights remain exact binary fractions (order-independent f64
    /// sums, DESIGN.md §6).  The model is renamed so checkpoints of a
    /// lesioned run can never be restored into the intact network (the
    /// snapshot fingerprint includes the model name).
    pub fn with_lesion(mut self, area_name: &str, factor: f64) -> Result<ModelSpec> {
        let area = self
            .areas
            .iter()
            .position(|a| a.name == area_name)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "lesion target '{}' is not an area of model '{}' (areas: {})",
                    area_name,
                    self.name,
                    self.areas
                        .iter()
                        .map(|a| a.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })?;
        let scaled = factor * 256.0;
        if !(0.0..=1.0).contains(&factor) || scaled.fract() != 0.0 {
            bail!(
                "lesion factor {} must be a multiple of 1/256 in [0, 1] \
                 (exact binary fractions keep spike trains deterministic)",
                factor
            );
        }
        self.name = format!("{}+lesion-{}-{}of256", self.name, area_name, scaled as u32);
        self.lesions.push(Lesion { area, factor: factor as f32 });
        Ok(self)
    }

    /// Combined lesion scale for an inter-area connection between
    /// `src_area` and `dst_area` (1.0 when no lesion touches either
    /// endpoint).
    pub fn inter_weight_scale(&self, src_area: usize, dst_area: usize) -> f32 {
        let mut scale = 1.0f32;
        for l in &self.lesions {
            if l.area == src_area || l.area == dst_area {
                scale *= l.factor;
            }
        }
        scale
    }

    pub fn n_areas(&self) -> usize {
        self.areas.len()
    }

    pub fn total_neurons(&self) -> u32 {
        *self.offsets.last().unwrap()
    }

    /// GID range of an area.
    pub fn area_range(&self, area: usize) -> std::ops::Range<Gid> {
        self.offsets[area]..self.offsets[area + 1]
    }

    /// Area index hosting a GID (binary search over offsets).
    pub fn area_of(&self, gid: Gid) -> usize {
        debug_assert!(gid < self.total_neurons());
        match self.offsets.binary_search(&gid) {
            Ok(i) if i == self.offsets.len() - 1 => i - 1,
            Ok(i) => i,
            Err(i) => i - 1,
        }
    }

    /// Overall minimum delay in steps — the simulation-cycle length.
    pub fn d_min_steps(&self) -> u16 {
        self.delay_intra
            .min_steps(self.h_ms)
            .min(self.delay_inter.min_steps(self.h_ms))
    }

    /// Minimum inter-area delay in steps.
    pub fn d_min_inter_steps(&self) -> u16 {
        self.delay_inter.min_steps(self.h_ms)
    }

    /// The paper's delay ratio `D = d_min_inter / d_min` (eq 1), in whole
    /// cycles (floor — a fractional remainder cannot be exploited).
    pub fn delay_ratio(&self) -> u32 {
        (self.d_min_inter_steps() / self.d_min_steps()) as u32
    }

    /// Is `gid` an inhibitory source under the weight rule?
    pub fn is_inhibitory(&self, gid: Gid) -> bool {
        let area = self.area_of(gid);
        let r = self.area_range(area);
        let n = (r.end - r.start) as f64;
        let exc = (n * (1.0 - self.weights.inh_fraction)).round() as Gid;
        gid - r.start >= exc
    }

    /// Synaptic weight contributed by source `gid`.
    pub fn weight_of(&self, gid: Gid) -> f32 {
        if self.is_inhibitory(gid) {
            -self.weights.g * self.weights.w_mv
        } else {
            self.weights.w_mv
        }
    }

    /// Average incoming synapses per neuron (the paper's `K_N`).
    pub fn k_total(&self) -> u32 {
        self.k_intra + self.k_inter
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_area_spec() -> ModelSpec {
        ModelSpec::new(
            "test",
            vec![
                AreaSpec {
                    name: "A".into(),
                    n: 100,
                    neuron: NeuronKind::Lif(LifParams::default()),
                },
                AreaSpec {
                    name: "B".into(),
                    n: 50,
                    neuron: NeuronKind::Lif(LifParams::default()),
                },
            ],
            20,
            10,
            WeightRule::default(),
            DelayDist::new(1.25, 0.625, 0.1),
            DelayDist::new(5.0, 2.5, 1.0),
            0.1,
        )
        .unwrap()
    }

    #[test]
    fn gid_ranges_and_area_lookup() {
        let m = two_area_spec();
        assert_eq!(m.total_neurons(), 150);
        assert_eq!(m.area_range(0), 0..100);
        assert_eq!(m.area_range(1), 100..150);
        assert_eq!(m.area_of(0), 0);
        assert_eq!(m.area_of(99), 0);
        assert_eq!(m.area_of(100), 1);
        assert_eq!(m.area_of(149), 1);
    }

    #[test]
    fn delay_ratio_matches_paper_default() {
        let m = two_area_spec();
        assert_eq!(m.d_min_steps(), 1);
        assert_eq!(m.d_min_inter_steps(), 10);
        assert_eq!(m.delay_ratio(), 10);
    }

    #[test]
    fn delay_draws_respect_cutoff() {
        let m = two_area_spec();
        let mut rng = crate::util::rng::Pcg64::seed_from_u64(1);
        for _ in 0..5000 {
            let d = m.delay_inter.draw_steps(&mut rng, m.h_ms);
            assert!(d >= 10, "inter delay {d} below cutoff");
            let d = m.delay_intra.draw_steps(&mut rng, m.h_ms);
            assert!(d >= 1);
        }
    }

    #[test]
    fn inhibitory_split() {
        let m = two_area_spec();
        // area A: 100 neurons, 20% inhibitory -> gids 80..100
        assert!(!m.is_inhibitory(79));
        assert!(m.is_inhibitory(80));
        assert!(m.weight_of(0) > 0.0);
        assert!(m.weight_of(85) < 0.0);
        assert_eq!(m.weight_of(85), -5.0 * 0.125);
    }

    #[test]
    fn rejects_inverted_cutoffs() {
        let res = ModelSpec::new(
            "bad",
            vec![AreaSpec {
                name: "A".into(),
                n: 10,
                neuron: NeuronKind::Lif(LifParams::default()),
            }],
            1,
            1,
            WeightRule::default(),
            DelayDist::new(1.0, 0.1, 2.0),
            DelayDist::new(1.0, 0.1, 0.5),
            0.1,
        );
        assert!(res.is_err());
    }

    #[test]
    fn lif_f_i_curve_inverse() {
        let p = LifParams { i_e_pa: 0.0, ..Default::default() };
        let i = p.i_e_for_rate(10.0);
        // simulate: time to threshold with drive i should be ~ isi - t_ref
        let r_m = p.tau_m_ms / p.c_m_pf;
        let t = -p.tau_m_ms * (1.0 - p.theta_mv / (r_m * i)).ln();
        assert!((t + p.t_ref_ms - 100.0).abs() < 0.5, "isi={}", t + 2.0);
    }

    #[test]
    fn ignore_and_fire_rate_to_interval() {
        match NeuronKind::ignore_and_fire_hz(2.5, 0.1) {
            NeuronKind::IgnoreAndFire { interval_steps } => {
                assert_eq!(interval_steps, 4000)
            }
            _ => unreachable!(),
        }
    }
}
