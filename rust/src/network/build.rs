//! Deterministic per-target connectivity instantiation.
//!
//! NEST instantiates fixed-indegree connectivity on the postsynaptic side:
//! the rank hosting a target neuron draws that neuron's incoming synapses.
//! We give every target GID its own RNG stream derived from
//! `(master seed, gid)`, so the realized network — sources, weights,
//! delays, and their order — is a pure function of `(spec, seed)` and is
//! *independent of placement*.  This is the property the
//! conventional ≡ structure-aware equivalence test rests on.

use super::spec::ModelSpec;
use super::Gid;
use crate::util::rng::Pcg64;

/// One synapse, stored on the postsynaptic side.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Conn {
    pub source: Gid,
    pub weight: f32,
    pub delay_steps: u16,
    /// Intra-area (short-range) or inter-area (long-range)?
    pub intra: bool,
}

/// Draw the full incoming connection list of `target` (intra then inter,
/// each in draw order).  Deterministic in `(spec, seed, target)`.
pub fn incoming_connections(
    spec: &ModelSpec,
    seed: u64,
    target: Gid,
) -> Vec<Conn> {
    let mut rng = Pcg64::new(seed, 0x636f_6e6e_0000_0000 | target as u64);
    let area = spec.area_of(target);
    let range = spec.area_range(area);
    let n_area = (range.end - range.start) as u64;
    let n_total = spec.total_neurons() as u64;
    let n_extern = n_total - n_area;

    let mut out = Vec::with_capacity((spec.k_intra + spec.k_inter) as usize);

    // intra-area sources: uniform over own area, autapses rejected
    if n_area > 1 {
        for _ in 0..spec.k_intra {
            let src = loop {
                let cand = range.start + rng.below(n_area) as Gid;
                if cand != target {
                    break cand;
                }
            };
            out.push(Conn {
                source: src,
                weight: spec.weight_of(src),
                delay_steps: spec.delay_intra.draw_steps(&mut rng, spec.h_ms),
                intra: true,
            });
        }
    }

    // inter-area sources: uniform over all external neurons
    if n_extern > 0 {
        for _ in 0..spec.k_inter {
            let mut idx = rng.below(n_extern) as Gid;
            // skip over the target's own area range
            if idx >= range.start {
                idx += range.end - range.start;
            }
            // lesions scale long-range weights only — the draw
            // sequence (and thus topology) is identical to the intact
            // network's
            let scale = spec.inter_weight_scale(spec.area_of(idx), area);
            out.push(Conn {
                source: idx,
                weight: spec.weight_of(idx) * scale,
                delay_steps: spec.delay_inter.draw_steps(&mut rng, spec.h_ms),
                intra: false,
            });
        }
    }
    out
}

/// Total synapse count of the realized network (for reporting).
pub fn count_synapses(spec: &ModelSpec) -> u64 {
    let mut total = 0u64;
    for a in 0..spec.n_areas() {
        let r = spec.area_range(a);
        let n = (r.end - r.start) as u64;
        let k_intra = if n > 1 { spec.k_intra as u64 } else { 0 };
        let k_inter = if spec.n_areas() > 1 { spec.k_inter as u64 } else { 0 };
        total += n * (k_intra + k_inter);
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::spec::{AreaSpec, DelayDist, LifParams, NeuronKind, WeightRule};
    use crate::util::prop;

    fn spec(n_areas: usize, n_per_area: u32) -> ModelSpec {
        let areas = (0..n_areas)
            .map(|i| AreaSpec {
                name: format!("A{i}"),
                n: n_per_area,
                neuron: NeuronKind::Lif(LifParams::default()),
            })
            .collect();
        ModelSpec::new(
            "t",
            areas,
            30,
            15,
            WeightRule::default(),
            DelayDist::new(1.25, 0.625, 0.1),
            DelayDist::new(5.0, 2.5, 1.0),
            0.1,
        )
        .unwrap()
    }

    #[test]
    fn deterministic_per_target() {
        let s = spec(3, 200);
        for gid in [0u32, 150, 599] {
            assert_eq!(
                incoming_connections(&s, 42, gid),
                incoming_connections(&s, 42, gid)
            );
        }
    }

    #[test]
    fn different_seeds_differ() {
        let s = spec(3, 200);
        assert_ne!(
            incoming_connections(&s, 42, 0),
            incoming_connections(&s, 43, 0)
        );
    }

    #[test]
    fn indegrees_respected() {
        let s = spec(4, 100);
        let conns = incoming_connections(&s, 7, 250);
        assert_eq!(conns.iter().filter(|c| c.intra).count(), 30);
        assert_eq!(conns.iter().filter(|c| !c.intra).count(), 15);
    }

    #[test]
    fn no_autapses_and_correct_source_areas() {
        let s = spec(4, 100);
        prop::check(
            "source-areas",
            50,
            |rng| rng.below(400) as Gid,
            |&target| {
                let ta = s.area_of(target);
                for c in incoming_connections(&s, 11, target) {
                    if c.source == target {
                        return Err("autapse".into());
                    }
                    let sa = s.area_of(c.source);
                    if c.intra != (sa == ta) {
                        return Err(format!(
                            "pathway flag wrong: src area {sa}, tgt {ta}"
                        ));
                    }
                    if c.source >= s.total_neurons() {
                        return Err("source out of range".into());
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn delays_respect_pathway_cutoffs() {
        let s = spec(3, 150);
        for gid in 0..150u32 {
            for c in incoming_connections(&s, 5, gid) {
                if c.intra {
                    assert!(c.delay_steps >= 1);
                } else {
                    assert!(
                        c.delay_steps >= s.d_min_inter_steps(),
                        "inter delay {} < cutoff",
                        c.delay_steps
                    );
                }
            }
        }
    }

    #[test]
    fn weights_follow_ei_rule() {
        let s = spec(2, 100);
        for c in incoming_connections(&s, 9, 42) {
            if s.is_inhibitory(c.source) {
                assert!(c.weight < 0.0);
            } else {
                assert!(c.weight > 0.0);
            }
        }
    }

    #[test]
    fn single_area_has_no_inter_connections() {
        let s = spec(1, 100);
        let conns = incoming_connections(&s, 3, 10);
        assert!(conns.iter().all(|c| c.intra));
        assert_eq!(conns.len(), 30);
    }

    #[test]
    fn synapse_count() {
        let s = spec(4, 100);
        assert_eq!(count_synapses(&s), 400 * 45);
        let s1 = spec(1, 100);
        assert_eq!(count_synapses(&s1), 100 * 30);
    }

    #[test]
    fn lesion_scales_inter_weights_only_topology_unchanged() {
        let intact = spec(3, 100);
        let lesioned = spec(3, 100).with_lesion("A1", 0.5).unwrap();
        assert_ne!(intact.name, lesioned.name); // fingerprint safety
        for gid in 0..300u32 {
            let a = incoming_connections(&intact, 42, gid);
            let b = incoming_connections(&lesioned, 42, gid);
            assert_eq!(a.len(), b.len());
            for (ca, cb) in a.iter().zip(&b) {
                // identical draws: same sources, same delays
                assert_eq!(ca.source, cb.source);
                assert_eq!(ca.delay_steps, cb.delay_steps);
                assert_eq!(ca.intra, cb.intra);
                let touches = !ca.intra
                    && (intact.area_of(ca.source) == 1 || intact.area_of(gid) == 1);
                if touches {
                    assert_eq!(cb.weight, ca.weight * 0.5);
                } else {
                    assert_eq!(cb.weight, ca.weight);
                }
            }
        }
    }

    #[test]
    fn lesion_factor_zero_severs_pathways() {
        let severed = spec(2, 100).with_lesion("A0", 0.0).unwrap();
        for gid in 0..200u32 {
            for c in incoming_connections(&severed, 7, gid) {
                if !c.intra {
                    // every inter connection touches A0 in a 2-area net
                    assert_eq!(c.weight, 0.0);
                }
            }
        }
    }

    #[test]
    fn lesion_rejects_unknown_area_and_bad_factor() {
        let err = spec(2, 10).with_lesion("V1", 0.5).unwrap_err();
        assert!(err.to_string().contains("not an area"), "{err}");
        let err = spec(2, 10).with_lesion("A0", 0.3).unwrap_err();
        assert!(err.to_string().contains("1/256"), "{err}");
        assert!(spec(2, 10).with_lesion("A0", 1.5).is_err());
        assert!(spec(2, 10).with_lesion("A0", -0.5).is_err());
        assert!(spec(2, 10).with_lesion("A0", 0.25).is_ok());
    }

    #[test]
    fn intersource_distribution_covers_other_areas() {
        let s = spec(4, 100);
        let mut seen = [false; 4];
        for gid in 0..100u32 {
            for c in incoming_connections(&s, 1, gid) {
                if !c.intra {
                    seen[s.area_of(c.source)] = true;
                }
            }
        }
        assert!(!seen[0]); // own area never an inter source
        assert!(seen[1] && seen[2] && seen[3]);
    }
}
