//! The job server behind `nsim serve`: a Unix-domain socket accepting
//! frames ([`proto`](super::proto)), a bounded worker pool draining the
//! [`JobTable`], and per-client handler threads translating ops into
//! table calls.
//!
//! Jobs run through the ordinary in-process engine
//! (`engine::simulate_hooked`) with the serving hooks attached:
//! cooperative cancellation (the engine's stop gate) and per-epoch
//! progress reports republished as `progress` event frames.  Crash
//! resilience reuses the checkpoint machinery — a job configured with
//! `checkpoint_every` that dies from an injected kill is retried once
//! from its last snapshot (kill faults stripped, so the fault does not
//! re-fire at the restored epoch), and the resumed train is
//! bit-identical to an uninterrupted run because snapshots carry the
//! spikes recorded so far.

use super::job::{JobOutput, JobState, JobTable};
use super::proto::{self, kind};
use super::scenario::{expand_sweep, Catalog};
use crate::config::RunConfig;
use crate::engine;
use crate::obs::TraceMode;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::{BTreeMap, BTreeSet};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

/// Server configuration (the `nsim serve` flags).
#[derive(Clone, Debug)]
pub struct ServeOpts {
    /// Unix-domain socket path to listen on.
    pub socket: PathBuf,
    /// Worker pool size: at most this many jobs run concurrently.
    pub workers: usize,
    /// Directory for per-job scratch files (checkpoints).
    pub workdir: PathBuf,
    /// Optional scenario directory overlaying the built-in catalog.
    pub scenario_dir: Option<PathBuf>,
    /// Per-job stats documents land at `<base>.job-<n>` (the server-side
    /// analogue of `nsim launch`'s `.rank<r>` suffixing).
    pub stats_base: Option<String>,
    /// Per-job Chrome traces land at `<base>.job-<n>`.
    pub trace_base: Option<String>,
    /// Trace buffering mode for traced jobs (ring mode keeps servers
    /// bounded on long jobs).
    pub trace_mode: TraceMode,
    /// Default `checkpoint_every` applied to jobs that do not set their
    /// own (0 = no default checkpointing).
    pub checkpoint_every: u64,
}

impl ServeOpts {
    pub fn new(socket: impl Into<PathBuf>) -> ServeOpts {
        ServeOpts {
            socket: socket.into(),
            workers: 2,
            workdir: PathBuf::from("."),
            scenario_dir: None,
            stats_base: None,
            trace_base: None,
            trace_mode: TraceMode::Ring(crate::obs::SINK_CAPACITY),
            checkpoint_every: 0,
        }
    }
}

/// Everything the worker and handler threads share.
struct Ctx {
    opts: ServeOpts,
    catalog: Catalog,
    table: Arc<JobTable>,
    stop: AtomicBool,
}

/// A running server: join it (blocks until shutdown) or shut it down.
pub struct ServerHandle {
    ctx: Arc<Ctx>,
    accept: Option<thread::JoinHandle<()>>,
    workers: Vec<thread::JoinHandle<()>>,
}

/// Bind the socket, start the worker pool and the accept loop.
pub fn start(opts: ServeOpts) -> Result<ServerHandle> {
    let catalog = Catalog::load(opts.scenario_dir.as_deref())?;
    std::fs::create_dir_all(&opts.workdir).with_context(|| {
        format!("creating workdir {}", opts.workdir.display())
    })?;
    // a stale socket file from a dead server blocks bind(2)
    let _ = std::fs::remove_file(&opts.socket);
    let listener = UnixListener::bind(&opts.socket).with_context(|| {
        format!("binding serve socket {}", opts.socket.display())
    })?;
    listener
        .set_nonblocking(true)
        .context("setting serve socket nonblocking")?;

    let n_workers = opts.workers.max(1);
    let ctx = Arc::new(Ctx {
        opts,
        catalog,
        table: JobTable::new(),
        stop: AtomicBool::new(false),
    });

    let workers = (0..n_workers)
        .map(|w| {
            let ctx = ctx.clone();
            thread::Builder::new()
                .name(format!("serve-worker-{w}"))
                .spawn(move || worker_loop(&ctx))
                .expect("spawning serve worker")
        })
        .collect();

    let accept = {
        let ctx = ctx.clone();
        thread::Builder::new()
            .name("serve-accept".to_string())
            .spawn(move || accept_loop(listener, &ctx))
            .expect("spawning serve accept loop")
    };

    Ok(ServerHandle { ctx, accept: Some(accept), workers })
}

impl ServerHandle {
    /// The job table (for in-process embedding and tests).
    pub fn table(&self) -> Arc<JobTable> {
        self.ctx.table.clone()
    }

    /// Has a `shutdown` op (or [`ServerHandle::shutdown`]) been seen?
    pub fn stopping(&self) -> bool {
        self.ctx.stop.load(Ordering::Relaxed)
    }

    /// Request shutdown: stop accepting, drain the workers.
    pub fn shutdown(&self) {
        self.ctx.table.shutdown();
        self.ctx.stop.store(true, Ordering::Relaxed);
    }

    /// Block until the accept loop and every worker exit (after
    /// [`ServerHandle::shutdown`] or a client `shutdown` op), then
    /// remove the socket file.
    pub fn join(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        let _ = std::fs::remove_file(&self.ctx.opts.socket);
    }
}

fn accept_loop(listener: UnixListener, ctx: &Arc<Ctx>) {
    while !ctx.stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                let ctx = ctx.clone();
                let _ = thread::Builder::new()
                    .name("serve-client".to_string())
                    .spawn(move || handle_client(stream, &ctx));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(25));
            }
            Err(_) => thread::sleep(Duration::from_millis(25)),
        }
    }
}

fn worker_loop(ctx: &Arc<Ctx>) {
    while let Some((id, scenario, params, cancel)) = ctx.table.claim() {
        run_job(ctx, &id, &scenario, &params, &cancel);
    }
}

/// Checkpoint scratch path of one job.
fn ckpt_path(ctx: &Ctx, id: &str) -> PathBuf {
    ctx.opts.workdir.join(format!("{id}.ckpt"))
}

/// Run one claimed job through the engine, publishing every transition.
fn run_job(
    ctx: &Arc<Ctx>,
    id: &str,
    scenario: &str,
    params: &BTreeMap<String, Json>,
    cancel: &Arc<AtomicBool>,
) {
    let table = &ctx.table;
    let Some(s) = ctx.catalog.get(scenario) else {
        table.finish_failed(
            id,
            format!("scenario {scenario:?} vanished from the catalog"),
        );
        return;
    };
    table.set_state(id, JobState::Building);
    let (spec, mut cfg, knobs) = match s.instantiate(params) {
        Ok(parts) => parts,
        Err(e) => {
            table.finish_failed(id, format!("{e:#}"));
            return;
        }
    };

    // serving-layer output plumbing: per-job checkpoint scratch file,
    // per-job trace buffer
    if cfg.checkpoint_every == 0 {
        cfg.checkpoint_every = ctx.opts.checkpoint_every;
    }
    if cfg.checkpoint_every > 0 {
        cfg.checkpoint_path =
            ckpt_path(ctx, id).to_string_lossy().into_owned();
    }
    if ctx.opts.trace_base.is_some() {
        cfg.trace = true;
        cfg.trace_mode = ctx.opts.trace_mode;
    }
    if let Err(e) = cfg.validate() {
        table.finish_failed(id, format!("{e:#}"));
        return;
    }

    table.set_state(id, JobState::Running);

    // wall-clock deadline: past it, raise the job's own cancel gate —
    // the engine unwinds with Cancelled, which the timeout flag
    // reclassifies as a failure
    let timed_out = Arc::new(AtomicBool::new(false));
    let sim_done = Arc::new(AtomicBool::new(false));
    let deadline_thread = knobs.timeout_secs.map(|secs| {
        let cancel = cancel.clone();
        let timed_out = timed_out.clone();
        let sim_done = sim_done.clone();
        let deadline = Instant::now() + Duration::from_secs_f64(secs);
        thread::spawn(move || {
            while !sim_done.load(Ordering::Relaxed) {
                if Instant::now() >= deadline {
                    timed_out.store(true, Ordering::Relaxed);
                    cancel.store(true, Ordering::Relaxed);
                    return;
                }
                thread::sleep(Duration::from_millis(50));
            }
        })
    });

    let hooks = engine::SimHooks {
        cancel: Some(cancel.clone()),
        progress: Some({
            let table = table.clone();
            let id = id.to_string();
            Arc::new(move |p: engine::Progress| {
                table.publish_event(
                    &id,
                    Json::obj(vec![
                        ("event", "progress".into()),
                        ("job", id.as_str().into()),
                        ("cycle", (p.cycle as usize).into()),
                        ("s_cycles", (p.s_cycles as usize).into()),
                        ("intervals", p.intervals.to_json()),
                    ]),
                );
            })
        }),
        progress_every_epochs: 1,
    };

    let outcome = run_with_resume(ctx, id, &spec, &cfg, &hooks);

    sim_done.store(true, Ordering::Relaxed);
    if let Some(h) = deadline_thread {
        let _ = h.join();
    }

    match outcome {
        Ok((res, final_cfg)) => {
            let mut spikes_text =
                String::with_capacity(res.spikes.len() * 12);
            for &(step, gid) in &res.spikes {
                use std::fmt::Write as _;
                let _ = writeln!(spikes_text, "{step} {gid}");
            }
            let stats = crate::obs::report::run_report_for_job(
                &spec.name,
                &final_cfg,
                &res,
                Some(id),
            );
            if let Some(base) = &ctx.opts.stats_base {
                let path = format!("{base}.{id}");
                let _ = std::fs::write(
                    &path,
                    crate::util::json::to_string_pretty(&stats) + "\n",
                );
            }
            if let Some(base) = &ctx.opts.trace_base {
                let _ = crate::obs::trace::write_chrome_trace(
                    Path::new(&format!("{base}.{id}")),
                    &res.spans,
                    res.m_ranks,
                );
            }
            let _ = std::fs::remove_file(ckpt_path(ctx, id));
            table.finish_done(id, JobOutput { spikes_text, stats });
        }
        Err(e) => {
            let _ = std::fs::remove_file(ckpt_path(ctx, id));
            if e.downcast_ref::<engine::Cancelled>().is_some() {
                if timed_out.load(Ordering::Relaxed) {
                    table.finish_failed(
                        id,
                        format!(
                            "job exceeded its {}s wall-clock timeout",
                            knobs.timeout_secs.unwrap_or(0.0)
                        ),
                    );
                } else {
                    table.finish_cancelled(id);
                }
            } else {
                table.finish_failed(id, format!("{e:#}"));
            }
        }
    }
}

/// One engine run with a single checkpoint-resume retry.
///
/// A job whose config injects kill faults dies mid-run (the surviving
/// ranks surface a watchdog error, which masks the killed rank's own
/// bail — the first error in rank order wins).  If the job was
/// checkpointing, retry once from the latest snapshot with the kill
/// faults stripped — a restore at the kill epoch would otherwise
/// re-fire the fault forever.  Cancellation is never retried.
fn run_with_resume(
    ctx: &Arc<Ctx>,
    id: &str,
    spec: &crate::network::ModelSpec,
    cfg: &RunConfig,
    hooks: &engine::SimHooks,
) -> Result<(engine::SimResult, RunConfig)> {
    match engine::simulate_hooked(spec, cfg, hooks) {
        Ok(res) => Ok((res, cfg.clone())),
        Err(e) if e.downcast_ref::<engine::Cancelled>().is_some() => {
            Err(e)
        }
        Err(e) => {
            let ckpt = ckpt_path(ctx, id);
            if cfg.faults.kills.is_empty()
                || cfg.checkpoint_every == 0
                || !ckpt.exists()
            {
                return Err(e);
            }
            let mut retry = cfg.clone();
            retry.faults.kills.clear();
            retry.restore = Some(retry.checkpoint_path.clone());
            ctx.table.publish_event(
                id,
                Json::obj(vec![
                    ("event", "resume".into()),
                    ("job", id.into()),
                    ("error", format!("{e:#}").as_str().into()),
                    (
                        "restore",
                        retry.checkpoint_path.as_str().into(),
                    ),
                ]),
            );
            let res = engine::simulate_hooked(spec, &retry, hooks)
                .with_context(|| {
                    format!("resuming from {}", retry.checkpoint_path)
                })?;
            Ok((res, retry))
        }
    }
}

// ---------------------------------------------------------------------
// client handler

/// Serve one connection: a request/response loop that turns into an
/// event stream for `submit --follow` and `watch`.
fn handle_client(mut stream: UnixStream, ctx: &Arc<Ctx>) {
    loop {
        let req = match proto::read_frame(&mut stream) {
            Ok(Some(v)) => v,
            Ok(None) => return,
            Err(e) => {
                // typed rejection, then close: the framing is torn, so
                // nothing further on this connection can be parsed
                let _ = proto::write_frame(
                    &mut stream,
                    &proto::err(kind::BAD_REQUEST, format!("{e:#}")),
                );
                return;
            }
        };
        let Some(op) = req.get("op").and_then(Json::as_str) else {
            if proto::write_frame(
                &mut stream,
                &proto::err(
                    kind::BAD_REQUEST,
                    "request needs a string \"op\"",
                ),
            )
            .is_err()
            {
                return;
            }
            continue;
        };
        let keep_going = match op {
            "ping" => reply(&mut stream, proto::ok(vec![])),
            "scenarios" => reply(
                &mut stream,
                proto::ok(vec![(
                    "scenarios",
                    ctx.catalog.to_json(),
                )]),
            ),
            "submit" => handle_submit(&mut stream, ctx, &req),
            "status" => handle_status(&mut stream, ctx, &req),
            "result" => handle_result(&mut stream, ctx, &req),
            "cancel" => handle_cancel(&mut stream, ctx, &req),
            "jobs" => reply(
                &mut stream,
                proto::ok(vec![("jobs", ctx.table.jobs_json())]),
            ),
            "watch" => handle_watch(&mut stream, ctx, &req),
            "shutdown" => {
                let _ =
                    proto::write_frame(&mut stream, &proto::ok(vec![]));
                ctx.table.shutdown();
                ctx.stop.store(true, Ordering::Relaxed);
                return;
            }
            other => reply(
                &mut stream,
                proto::err(
                    kind::BAD_REQUEST,
                    format!("unknown op {other:?}"),
                ),
            ),
        };
        if !keep_going {
            return;
        }
    }
}

/// Write one response; `false` means the peer went away.
fn reply(stream: &mut UnixStream, v: Json) -> bool {
    proto::write_frame(stream, &v).is_ok()
}

fn job_id_of(req: &Json) -> Option<&str> {
    req.get("job").and_then(Json::as_str)
}

fn handle_status(
    stream: &mut UnixStream,
    ctx: &Arc<Ctx>,
    req: &Json,
) -> bool {
    let Some(id) = job_id_of(req) else {
        return reply(
            stream,
            proto::err(kind::BAD_REQUEST, "status needs a \"job\" id"),
        );
    };
    match ctx.table.status(id) {
        Some(st) => reply(stream, proto::ok(vec![("status", st)])),
        None => reply(
            stream,
            proto::err(kind::UNKNOWN_JOB, format!("no job {id:?}")),
        ),
    }
}

fn handle_result(
    stream: &mut UnixStream,
    ctx: &Arc<Ctx>,
    req: &Json,
) -> bool {
    let Some(id) = job_id_of(req) else {
        return reply(
            stream,
            proto::err(kind::BAD_REQUEST, "result needs a \"job\" id"),
        );
    };
    let Some((state, output, error)) = ctx.table.result(id) else {
        return reply(
            stream,
            proto::err(kind::UNKNOWN_JOB, format!("no job {id:?}")),
        );
    };
    let mut fields = vec![
        ("job", id.into()),
        ("state", state.name().into()),
    ];
    if let Some(out) = output {
        fields.push(("spikes", out.spikes_text.as_str().into()));
        fields.push(("stats", out.stats));
    }
    if let Some(err) = error {
        fields.push(("error", err.as_str().into()));
    }
    reply(stream, proto::ok(fields))
}

fn handle_cancel(
    stream: &mut UnixStream,
    ctx: &Arc<Ctx>,
    req: &Json,
) -> bool {
    let Some(id) = job_id_of(req) else {
        return reply(
            stream,
            proto::err(kind::BAD_REQUEST, "cancel needs a \"job\" id"),
        );
    };
    match ctx.table.cancel(id) {
        Some(seen) => reply(
            stream,
            proto::ok(vec![
                ("job", id.into()),
                ("was", seen.name().into()),
            ]),
        ),
        None => reply(
            stream,
            proto::err(kind::UNKNOWN_JOB, format!("no job {id:?}")),
        ),
    }
}

/// `submit`: validate the whole sweep grid *before* enqueuing anything
/// (a bad grid point is a typed `bad-params` rejection with nothing
/// started), then enqueue one job per grid point and optionally follow.
fn handle_submit(
    stream: &mut UnixStream,
    ctx: &Arc<Ctx>,
    req: &Json,
) -> bool {
    let Some(scenario) = req.get("scenario").and_then(Json::as_str)
    else {
        return reply(
            stream,
            proto::err(
                kind::BAD_REQUEST,
                "submit needs a string \"scenario\"",
            ),
        );
    };
    let Some(s) = ctx.catalog.get(scenario) else {
        return reply(
            stream,
            proto::err(
                kind::UNKNOWN_SCENARIO,
                format!(
                    "no scenario {scenario:?} (have: {})",
                    ctx.catalog.names().join(", ")
                ),
            ),
        );
    };
    let params = match req.get("params") {
        None => BTreeMap::new(),
        Some(v) => match v.as_obj() {
            Some(o) => o.clone(),
            None => {
                return reply(
                    stream,
                    proto::err(
                        kind::BAD_REQUEST,
                        "\"params\" must be an object",
                    ),
                )
            }
        },
    };
    let mut sweep: BTreeMap<String, Vec<Json>> = BTreeMap::new();
    if let Some(v) = req.get("sweep") {
        let Some(obj) = v.as_obj() else {
            return reply(
                stream,
                proto::err(
                    kind::BAD_REQUEST,
                    "\"sweep\" must be an object of value lists",
                ),
            );
        };
        for (k, vals) in obj {
            match vals.as_arr() {
                Some(list) if !list.is_empty() => {
                    sweep.insert(k.clone(), list.clone());
                }
                _ => {
                    return reply(
                        stream,
                        proto::err(
                            kind::BAD_REQUEST,
                            format!(
                                "sweep key {k:?} must map to a \
                                 non-empty array"
                            ),
                        ),
                    )
                }
            }
        }
    }

    let grid = expand_sweep(&params, &sweep);
    for point in &grid {
        if let Err(e) = s.instantiate(point) {
            return reply(
                stream,
                proto::err(kind::BAD_PARAMS, format!("{e:#}")),
            );
        }
    }

    let mut ids = Vec::with_capacity(grid.len());
    for point in grid {
        match ctx.table.submit(scenario, point) {
            Some(id) => ids.push(id),
            None => {
                return reply(
                    stream,
                    proto::err(
                        kind::SHUTDOWN,
                        "server is shutting down",
                    ),
                )
            }
        }
    }
    let ok = proto::ok(vec![(
        "jobs",
        Json::Arr(ids.iter().map(|i| i.as_str().into()).collect()),
    )]);
    if !reply(stream, ok) {
        return false;
    }
    if req.get("follow").and_then(Json::as_bool) == Some(true) {
        // submit() recorded every event so far in the history the
        // watch below replays — no gap between enqueue and follow
        let Some((history, rx)) = ctx.table.watch(&ids) else {
            return false;
        };
        return stream_events(stream, ctx, &ids, history, rx);
    }
    true
}

fn handle_watch(
    stream: &mut UnixStream,
    ctx: &Arc<Ctx>,
    req: &Json,
) -> bool {
    let ids: Vec<String> = match req.get("jobs").and_then(Json::as_arr)
    {
        Some(list) => list
            .iter()
            .filter_map(|v| v.as_str().map(str::to_string))
            .collect(),
        None => match job_id_of(req) {
            Some(id) => vec![id.to_string()],
            None => {
                return reply(
                    stream,
                    proto::err(
                        kind::BAD_REQUEST,
                        "watch needs \"job\" or \"jobs\"",
                    ),
                )
            }
        },
    };
    let Some((history, rx)) = ctx.table.watch(&ids) else {
        return reply(
            stream,
            proto::err(kind::UNKNOWN_JOB, "unknown job in watch set"),
        );
    };
    stream_events(stream, ctx, &ids, history, rx)
}

/// Forward history + live events until every followed job is terminal,
/// then a final `{"event": "complete"}` frame.  The connection stays
/// usable for further ops afterwards.
fn stream_events(
    stream: &mut UnixStream,
    ctx: &Arc<Ctx>,
    ids: &[String],
    history: Vec<Json>,
    rx: mpsc::Receiver<Json>,
) -> bool {
    let wanted: BTreeSet<&str> =
        ids.iter().map(String::as_str).collect();
    let mut terminal: BTreeSet<String> = BTreeSet::new();
    let mut deliver = |stream: &mut UnixStream,
                       ev: &Json,
                       terminal: &mut BTreeSet<String>|
     -> bool {
        if let (Some(job), Some(state)) = (
            ev.get("job").and_then(Json::as_str),
            ev.get("state").and_then(Json::as_str),
        ) {
            if wanted.contains(job)
                && ["done", "failed", "cancelled"].contains(&state)
            {
                terminal.insert(job.to_string());
            }
        }
        proto::write_frame(stream, ev).is_ok()
    };
    for ev in &history {
        if !deliver(stream, ev, &mut terminal) {
            return false;
        }
    }
    while terminal.len() < wanted.len() {
        match rx.recv_timeout(Duration::from_millis(100)) {
            Ok(ev) => {
                if !deliver(stream, &ev, &mut terminal) {
                    return false;
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if ctx.stop.load(Ordering::Relaxed) {
                    let _ = proto::write_frame(
                        stream,
                        &proto::err(
                            kind::SHUTDOWN,
                            "server is shutting down",
                        ),
                    );
                    return false;
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        }
    }
    proto::write_frame(
        stream,
        &Json::obj(vec![
            ("event", "complete".into()),
            (
                "jobs",
                Json::Arr(
                    ids.iter().map(|i| i.as_str().into()).collect(),
                ),
            ),
        ]),
    )
    .is_ok()
}
