//! Wire protocol of the job server: length-prefixed JSON frames over a
//! Unix-domain socket, in the same pure-Rust no-new-deps style as
//! `comm::socket`.
//!
//! Every frame is a little-endian `len: u32` header followed by `len`
//! bytes of UTF-8 JSON.  Requests are objects with an `"op"` key;
//! responses carry `"ok": true` plus op-specific fields, or
//! `"ok": false` with a typed `"kind"` and human-readable `"error"` —
//! a malformed request gets an error frame back, never a dead
//! connection.  A `submit` with `"follow": true` (and `watch`) turns
//! the connection into an event stream: `{"event": ...}` frames until
//! every followed job reaches a terminal state.

use crate::util::json::{self, Json};
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};

/// Upper bound on one frame (64 MiB) — final result frames carry whole
/// spike trains, but anything beyond this is a protocol violation, not
/// a big job.
pub const MAX_FRAME_BYTES: usize = 64 << 20;

/// Typed error kinds carried in `"kind"` of an error response.
pub mod kind {
    /// The request frame is not a JSON object with a known shape.
    pub const BAD_REQUEST: &str = "bad-request";
    /// The submission names a scenario the catalog does not have.
    pub const UNKNOWN_SCENARIO: &str = "unknown-scenario";
    /// A job id the server has never issued (or already forgot).
    pub const UNKNOWN_JOB: &str = "unknown-job";
    /// Scenario parameters failed validation.
    pub const BAD_PARAMS: &str = "bad-params";
    /// The server is shutting down and accepts no new jobs.
    pub const SHUTDOWN: &str = "server-shutdown";
}

/// Write one frame.
pub fn write_frame<W: Write>(w: &mut W, v: &Json) -> Result<()> {
    let payload = json::to_string(v);
    let bytes = payload.as_bytes();
    if bytes.len() > MAX_FRAME_BYTES {
        bail!(
            "frame of {} bytes exceeds the {} byte limit",
            bytes.len(),
            MAX_FRAME_BYTES
        );
    }
    w.write_all(&(bytes.len() as u32).to_le_bytes())
        .context("writing frame header")?;
    w.write_all(bytes).context("writing frame payload")?;
    w.flush().context("flushing frame")?;
    Ok(())
}

/// Read one frame.  `Ok(None)` means the peer closed the connection
/// cleanly (EOF at a frame boundary); a torn frame or oversized header
/// is an error.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Json>> {
    let mut hdr = [0u8; 4];
    let mut got = 0;
    while got < hdr.len() {
        match r.read(&mut hdr[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => bail!("connection closed mid frame header"),
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e).context("reading frame header"),
        }
    }
    let len = u32::from_le_bytes(hdr) as usize;
    if len > MAX_FRAME_BYTES {
        bail!(
            "frame header announces {len} bytes (limit \
             {MAX_FRAME_BYTES}) — not a serve-protocol peer?"
        );
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload).context("reading frame payload")?;
    let text = String::from_utf8(payload).context("frame is not UTF-8")?;
    let v = json::parse(&text).context("frame is not valid JSON")?;
    Ok(Some(v))
}

/// `{"ok": true, ...fields}`.
pub fn ok(fields: Vec<(&str, Json)>) -> Json {
    let mut all = vec![("ok", Json::Bool(true))];
    all.extend(fields);
    Json::obj(all)
}

/// `{"ok": false, "kind": kind, "error": msg}` — the typed rejection
/// every protocol error turns into.
pub fn err(kind: &str, msg: impl std::fmt::Display) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("kind", kind.into()),
        ("error", Json::Str(msg.to_string())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_roundtrip() {
        let v = Json::obj(vec![
            ("op", "submit".into()),
            ("scenario", "sanity-smoke".into()),
        ]);
        let mut buf = Vec::new();
        write_frame(&mut buf, &v).unwrap();
        write_frame(&mut buf, &Json::Bool(true)).unwrap();
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r).unwrap(), Some(v));
        assert_eq!(read_frame(&mut r).unwrap(), Some(Json::Bool(true)));
        assert_eq!(read_frame(&mut r).unwrap(), None, "clean EOF");
    }

    #[test]
    fn torn_and_oversized_frames_error() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Json::Num(1.0)).unwrap();
        // cut the payload short
        let torn = &buf[..buf.len() - 1];
        let mut r = torn;
        assert!(read_frame(&mut r).is_err());
        // an absurd header is rejected before allocating
        let huge = (MAX_FRAME_BYTES as u32 + 1).to_le_bytes();
        let mut r = &huge[..];
        assert!(read_frame(&mut r).is_err());
    }

    #[test]
    fn error_frames_are_typed() {
        let e = err(kind::UNKNOWN_SCENARIO, "no scenario \"nope\"");
        assert_eq!(e.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(
            e.get("kind").unwrap().as_str(),
            Some("unknown-scenario")
        );
        assert!(e.get("error").unwrap().as_str().unwrap().contains("nope"));
    }
}
