//! The scenario catalog: named, parameterizable simulation setups the
//! job server accepts (`configs/scenarios/*.json`, listed by
//! `nsim scenarios`).
//!
//! A scenario is a model block (which bundled network to instantiate,
//! with which knobs, optionally lesioned) plus a [`RunConfig`] JSON
//! block of defaults.  A submission names a scenario and optionally
//! overrides parameters; a sweep fans one submission out into the
//! cartesian product of per-parameter value lists, one job per grid
//! point.  Parameter routing is by key: model keys go to the network
//! constructor, `timeout_secs` to the job runner, everything else must
//! be a known config key — unknown keys are a typed `bad-params`
//! rejection, not a silent ignore.

use crate::config::{FaultPlan, RunConfig, TransportKind};
use crate::network::ModelSpec;
use crate::util::json::{self, Json};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Parameter keys routed to the model constructor.
pub const MODEL_KEYS: &[&str] = &[
    "scale",
    "areas",
    "n_per_area",
    "d_min_inter_ms",
    "lesion_area",
    "lesion_factor",
];

/// Parameter keys routed into the [`RunConfig`] JSON (a curated subset
/// of `RunConfig::from_json` — the serving layer owns transport,
/// recording and checkpoint paths itself).
pub const CONFIG_KEYS: &[&str] = &[
    "strategy",
    "ranks",
    "threads",
    "t_model_ms",
    "seed",
    "exec",
    "comm",
    "comm_depth",
    "comm_quota",
    "ranks_per_area",
    "comm_timeout",
    "checkpoint_every",
    "kill_at",
];

/// Parameter keys the job runner consumes directly.
pub const JOB_KEYS: &[&str] = &["timeout_secs"];

/// One catalog entry.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: String,
    pub description: String,
    /// Model block: `kind` plus constructor knobs ([`MODEL_KEYS`]).
    pub model: BTreeMap<String, Json>,
    /// `RunConfig` JSON defaults ([`CONFIG_KEYS`] subset).
    pub config: BTreeMap<String, Json>,
}

/// Job-runner knobs resolved from a submission.
#[derive(Clone, Copy, Debug, Default)]
pub struct JobKnobs {
    /// Wall-clock deadline; past it the job's cancel gate fires and the
    /// job reports failed.
    pub timeout_secs: Option<f64>,
}

impl Scenario {
    /// Parse one scenario document (`configs/scenarios/*.json` shape):
    /// `{"name": ..., "description": ..., "model": {"kind": ...},
    /// "config": {...}}`.
    pub fn from_json(v: &Json) -> Result<Scenario> {
        let name = v
            .get("name")
            .and_then(Json::as_str)
            .context("scenario needs a string \"name\"")?
            .to_string();
        let description = v
            .get("description")
            .and_then(Json::as_str)
            .unwrap_or_default()
            .to_string();
        let model = v
            .get("model")
            .and_then(Json::as_obj)
            .with_context(|| {
                format!("scenario {name:?} needs a \"model\" object")
            })?
            .clone();
        let kind = model
            .get("kind")
            .and_then(Json::as_str)
            .with_context(|| {
                format!("scenario {name:?} model needs a \"kind\"")
            })?;
        for key in model.keys() {
            if key != "kind" && !MODEL_KEYS.contains(&key.as_str()) {
                bail!("scenario {name:?}: unknown model key {key:?}");
            }
        }
        if !["sanity", "deep-pipeline", "mam-benchmark", "mam"]
            .contains(&kind)
        {
            bail!("scenario {name:?}: unknown model kind {kind:?}");
        }
        let config = match v.get("config") {
            Some(c) => c
                .as_obj()
                .with_context(|| {
                    format!("scenario {name:?} \"config\" must be an object")
                })?
                .clone(),
            None => BTreeMap::new(),
        };
        for key in config.keys() {
            if !CONFIG_KEYS.contains(&key.as_str()) {
                bail!("scenario {name:?}: unknown config key {key:?}");
            }
        }
        Ok(Scenario { name, description, model, config })
    }

    /// Resolve a submission's parameter overrides into the network,
    /// run config and job knobs.  The server forces `record_spikes`
    /// (results stream back) and the shmem transport (jobs run
    /// in-process; that is also what makes `--checkpoint-every` legal).
    pub fn instantiate(
        &self,
        params: &BTreeMap<String, Json>,
    ) -> Result<(ModelSpec, RunConfig, JobKnobs)> {
        let mut model = self.model.clone();
        let mut config = self.config.clone();
        let mut knobs = JobKnobs::default();
        for (k, v) in params {
            if MODEL_KEYS.contains(&k.as_str()) {
                model.insert(k.clone(), v.clone());
            } else if CONFIG_KEYS.contains(&k.as_str()) {
                config.insert(k.clone(), v.clone());
            } else if k == "timeout_secs" {
                knobs.timeout_secs = Some(
                    v.as_f64()
                        .filter(|t| t.is_finite() && *t > 0.0)
                        .context("timeout_secs must be a positive number")?,
                );
            } else {
                bail!(
                    "unknown parameter {k:?} (model keys: {}; config \
                     keys: {}; job keys: {})",
                    MODEL_KEYS.join(", "),
                    CONFIG_KEYS.join(", "),
                    JOB_KEYS.join(", "),
                );
            }
        }

        // kill_at is a CLI-style fault spec layered onto the config
        // after RunConfig::from_json (which has no such key)
        let kill_at = config.remove("kill_at");
        let mut cfg = RunConfig::from_json(&Json::Obj(config))
            .with_context(|| {
                format!("scenario {:?} run config", self.name)
            })?;
        if let Some(spec) = kill_at {
            let spec = spec
                .as_str()
                .context("kill_at must be a \"rank:epoch[,...]\" string")?;
            cfg.faults.kills.extend(FaultPlan::parse_kills(spec)?);
        }
        cfg.record_spikes = true;
        cfg.transport = TransportKind::Shmem;
        cfg.validate()?;

        let spec = build_model(&model, cfg.m_ranks)
            .with_context(|| format!("scenario {:?} model", self.name))?;
        Ok((spec, cfg, knobs))
    }

    /// Catalog-listing document for one entry.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", self.name.as_str().into()),
            ("description", self.description.as_str().into()),
            ("model", Json::Obj(self.model.clone())),
            ("config", Json::Obj(self.config.clone())),
        ])
    }
}

/// Instantiate the model block (defaults mirror `nsim simulate`'s
/// `build_model`).
fn build_model(
    model: &BTreeMap<String, Json>,
    m_ranks: usize,
) -> Result<ModelSpec> {
    let kind = model
        .get("kind")
        .and_then(Json::as_str)
        .context("model block needs a \"kind\"")?;
    let num = |key: &str, default: f64| -> Result<f64> {
        match model.get(key) {
            Some(v) => v
                .as_f64()
                .with_context(|| format!("model {key:?} must be a number")),
            None => Ok(default),
        }
    };
    let scale = num("scale", 0.01)?;
    let d_min_inter = num("d_min_inter_ms", 1.0)?;
    let areas = num("areas", m_ranks.max(2) as f64)? as usize;
    let spec = match kind {
        "sanity" => {
            crate::models::sanity_net(num("n_per_area", 500.0)? as u32, areas)?
        }
        "deep-pipeline" => crate::models::deep_pipeline_net(
            num("n_per_area", 240.0)? as u32,
            areas,
        )?,
        "mam-benchmark" => {
            crate::models::mam_benchmark(areas, scale, d_min_inter)?
        }
        "mam" => crate::models::mam(scale, d_min_inter)?,
        other => bail!("unknown model kind {other:?}"),
    };
    match model.get("lesion_area").and_then(Json::as_str) {
        Some(area) => {
            let factor = num("lesion_factor", 0.0)?;
            spec.with_lesion(area, factor)
        }
        None => {
            if model.contains_key("lesion_factor") {
                bail!("lesion_factor without lesion_area");
            }
            Ok(spec)
        }
    }
}

/// Expand a sweep (`{"param": [v1, v2, ...], ...}`) over base params
/// into the cartesian product of per-parameter values — one parameter
/// map per grid point, in deterministic order (keys sorted, values in
/// list order, last key fastest).
pub fn expand_sweep(
    base: &BTreeMap<String, Json>,
    sweep: &BTreeMap<String, Vec<Json>>,
) -> Vec<BTreeMap<String, Json>> {
    let mut grid = vec![base.clone()];
    for (key, values) in sweep {
        let mut next = Vec::with_capacity(grid.len() * values.len().max(1));
        for point in &grid {
            for v in values {
                let mut p = point.clone();
                p.insert(key.clone(), v.clone());
                next.push(p);
            }
        }
        grid = next;
    }
    grid
}

/// Built-in catalog entries, in the exact `configs/scenarios/*.json`
/// file format (each doubles as documentation of the schema).  Files in
/// the scenario directory overlay these by name.
const BUILTINS: &[&str] = &[
    r#"{
        "name": "mam-ground-state",
        "description": "multi-area model ground state: 32-area LIF net at a laptop scale, structure-aware placement",
        "model": {"kind": "mam", "scale": 0.002},
        "config": {"strategy": "structure-aware", "ranks": 2,
                   "threads": 2, "t_model_ms": 20.0, "seed": 12}
    }"#,
    r#"{
        "name": "deliver-heavy",
        "description": "dense sanity LIF net where spike delivery dominates (the bench A/B workload)",
        "model": {"kind": "sanity", "n_per_area": 500, "areas": 4},
        "config": {"strategy": "conventional", "ranks": 2, "threads": 2,
                   "t_model_ms": 50.0, "seed": 12}
    }"#,
    r#"{
        "name": "deep-pipeline",
        "description": "tight ~5 ms delays over a 1 ms cycle: multi-cycle slack for depth-D split-phase pipelining",
        "model": {"kind": "deep-pipeline", "n_per_area": 240, "areas": 4},
        "config": {"strategy": "conventional", "ranks": 2, "threads": 2,
                   "comm": "overlap", "comm_depth": 2,
                   "t_model_ms": 50.0, "seed": 12}
    }"#,
    r#"{
        "name": "mam-lesion-v1",
        "description": "MAM-benchmark perturbation: V1-analogue area A00 with its long-range pathways scaled to 1/2",
        "model": {"kind": "mam-benchmark", "scale": 0.01, "areas": 4,
                  "lesion_area": "A00", "lesion_factor": 0.5},
        "config": {"strategy": "structure-aware", "ranks": 2,
                   "threads": 2, "t_model_ms": 20.0, "seed": 12}
    }"#,
];

/// The scenario catalog: built-ins plus an optional directory overlay.
#[derive(Clone, Debug)]
pub struct Catalog {
    scenarios: BTreeMap<String, Scenario>,
}

impl Catalog {
    /// Only the compiled-in scenarios (hermetic — no filesystem).
    pub fn builtin() -> Catalog {
        let mut scenarios = BTreeMap::new();
        for text in BUILTINS {
            let v = json::parse(text).expect("builtin scenario JSON");
            let s = Scenario::from_json(&v).expect("builtin scenario");
            scenarios.insert(s.name.clone(), s);
        }
        Catalog { scenarios }
    }

    /// Built-ins overlaid with every `*.json` in `dir` (same-name files
    /// replace built-ins).  A missing directory is fine — the catalog
    /// is then just the built-ins; a malformed file is an error.
    pub fn load(dir: Option<&std::path::Path>) -> Result<Catalog> {
        let mut cat = Catalog::builtin();
        let Some(dir) = dir else { return Ok(cat) };
        let entries = match std::fs::read_dir(dir) {
            Ok(e) => e,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(cat)
            }
            Err(e) => {
                return Err(e).with_context(|| {
                    format!("reading scenario dir {}", dir.display())
                })
            }
        };
        let mut paths: Vec<_> = entries
            .collect::<std::io::Result<Vec<_>>>()
            .with_context(|| {
                format!("listing scenario dir {}", dir.display())
            })?
            .into_iter()
            .map(|e| e.path())
            .filter(|p| p.extension().is_some_and(|x| x == "json"))
            .collect();
        paths.sort();
        for p in paths {
            let text = std::fs::read_to_string(&p)
                .with_context(|| format!("reading {}", p.display()))?;
            let v = json::parse(&text)
                .with_context(|| format!("parsing {}", p.display()))?;
            let s = Scenario::from_json(&v)
                .with_context(|| format!("scenario file {}", p.display()))?;
            cat.scenarios.insert(s.name.clone(), s);
        }
        Ok(cat)
    }

    pub fn get(&self, name: &str) -> Option<&Scenario> {
        self.scenarios.get(name)
    }

    pub fn names(&self) -> Vec<&str> {
        self.scenarios.keys().map(String::as_str).collect()
    }

    pub fn iter(&self) -> impl Iterator<Item = &Scenario> {
        self.scenarios.values()
    }

    /// The `scenarios` op response payload.
    pub fn to_json(&self) -> Json {
        Json::Arr(self.scenarios.values().map(Scenario::to_json).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_catalog_has_the_promised_entries() {
        let cat = Catalog::builtin();
        for name in [
            "mam-ground-state",
            "deliver-heavy",
            "deep-pipeline",
            "mam-lesion-v1",
        ] {
            assert!(cat.get(name).is_some(), "missing builtin {name}");
        }
        let listing = cat.to_json();
        assert_eq!(
            listing.as_arr().unwrap().len(),
            cat.names().len()
        );
    }

    #[test]
    fn instantiate_applies_defaults_and_server_invariants() {
        let cat = Catalog::builtin();
        let s = cat.get("deliver-heavy").unwrap();
        let (spec, cfg, knobs) =
            s.instantiate(&BTreeMap::new()).unwrap();
        assert_eq!(spec.n_areas(), 4);
        assert!(cfg.record_spikes, "results must stream back");
        assert_eq!(cfg.transport, TransportKind::Shmem);
        assert!(knobs.timeout_secs.is_none());
    }

    #[test]
    fn params_route_by_key_and_unknowns_are_rejected() {
        let cat = Catalog::builtin();
        let s = cat.get("deliver-heavy").unwrap();
        let mut p = BTreeMap::new();
        p.insert("n_per_area".to_string(), Json::Num(40.0));
        p.insert("t_model_ms".to_string(), Json::Num(10.0));
        p.insert("timeout_secs".to_string(), Json::Num(30.0));
        let (spec, cfg, knobs) = s.instantiate(&p).unwrap();
        assert_eq!(spec.total_neurons(), 160);
        assert_eq!(cfg.t_model_ms, 10.0);
        assert_eq!(knobs.timeout_secs, Some(30.0));

        let mut p = BTreeMap::new();
        p.insert("bogus_knob".to_string(), Json::Num(1.0));
        let err = s.instantiate(&p).unwrap_err();
        assert!(
            format!("{err:#}").contains("unknown parameter"),
            "{err:#}"
        );
    }

    #[test]
    fn lesion_scenario_renames_model() {
        let cat = Catalog::builtin();
        let s = cat.get("mam-lesion-v1").unwrap();
        let (spec, _, _) = s.instantiate(&BTreeMap::new()).unwrap();
        assert!(
            spec.name.contains("lesion-A00"),
            "lesioned model must be fingerprint-distinct: {}",
            spec.name
        );
        assert_eq!(spec.lesions.len(), 1);
        // an off-grid factor is a typed rejection
        let mut p = BTreeMap::new();
        p.insert("lesion_factor".to_string(), Json::Num(0.3));
        assert!(s.instantiate(&p).is_err());
    }

    #[test]
    fn kill_at_param_needs_watchdog_and_lands_in_faults() {
        let cat = Catalog::builtin();
        let s = cat.get("deliver-heavy").unwrap();
        let mut p = BTreeMap::new();
        p.insert("kill_at".to_string(), Json::Str("1:2".to_string()));
        // without a watchdog the survivors would hang: rejected
        assert!(s.instantiate(&p).is_err());
        p.insert("comm_timeout".to_string(), Json::Num(5.0));
        let (_, cfg, _) = s.instantiate(&p).unwrap();
        assert_eq!(cfg.faults.kills.len(), 1);
        assert_eq!(cfg.faults.kills[0].rank, 1);
        assert_eq!(cfg.faults.kills[0].epoch, 2);
    }

    #[test]
    fn sweep_expands_to_the_cartesian_grid_in_order() {
        let mut base = BTreeMap::new();
        base.insert("t_model_ms".to_string(), Json::Num(10.0));
        let mut sweep = BTreeMap::new();
        sweep.insert(
            "seed".to_string(),
            vec![Json::Num(1.0), Json::Num(2.0)],
        );
        sweep.insert(
            "threads".to_string(),
            vec![Json::Num(1.0), Json::Num(2.0), Json::Num(4.0)],
        );
        let grid = expand_sweep(&base, &sweep);
        assert_eq!(grid.len(), 6);
        // keys iterate sorted: seed is the outer loop, threads inner
        assert_eq!(grid[0].get("seed"), Some(&Json::Num(1.0)));
        assert_eq!(grid[0].get("threads"), Some(&Json::Num(1.0)));
        assert_eq!(grid[1].get("threads"), Some(&Json::Num(2.0)));
        assert_eq!(grid[3].get("seed"), Some(&Json::Num(2.0)));
        for p in &grid {
            assert_eq!(p.get("t_model_ms"), Some(&Json::Num(10.0)));
        }
        // no sweep: the base point itself
        assert_eq!(expand_sweep(&base, &BTreeMap::new()).len(), 1);
    }

    #[test]
    fn scenario_files_reject_unknown_keys() {
        let v = json::parse(
            r#"{"name": "x", "model": {"kind": "sanity",
                "frobnicate": 1}}"#,
        )
        .unwrap();
        assert!(Scenario::from_json(&v).is_err());
        let v = json::parse(
            r#"{"name": "x", "model": {"kind": "sanity"},
                "config": {"warp_factor": 9}}"#,
        )
        .unwrap();
        assert!(Scenario::from_json(&v).is_err());
        let v = json::parse(
            r#"{"name": "x", "model": {"kind": "unknown-net"}}"#,
        )
        .unwrap();
        assert!(Scenario::from_json(&v).is_err());
    }
}
