//! The serving layer: `nsim serve` turns the engine into a
//! long-running job server.
//!
//! Clients connect over a Unix-domain socket and speak length-prefixed
//! JSON frames ([`proto`]): submit jobs drawn from a named scenario
//! catalog ([`scenario`]), watch lifecycle events
//! (`queued → building → running → done/failed/cancelled`, plus
//! periodic `progress` frames built from the engine's streaming
//! interval recorders), cancel, and fetch results — the final frame of
//! a successful job carries the spike train byte-identical to a direct
//! `nsim simulate --spikes-out` run plus the `nsim-stats-v1` document
//! with `config.job` stamped.
//!
//! A bounded worker pool ([`server`]) runs at most N jobs concurrently
//! through the ordinary in-process engine; cancellation rides the
//! engine's cooperative stop gate, per-job timeouts reuse it through a
//! deadline thread, and jobs configured with `checkpoint_every` are
//! retried once from their last snapshot if a (fault-injected) crash
//! takes them down.  [`client`] is the `nsim submit` side.

pub mod job;
pub mod proto;
pub mod scenario;

#[cfg(unix)]
pub mod client;
#[cfg(unix)]
pub mod server;

pub use job::{JobOutput, JobState, JobTable};
pub use scenario::{Catalog, Scenario};

#[cfg(unix)]
pub use client::Client;
#[cfg(unix)]
pub use server::{start, ServeOpts, ServerHandle};
