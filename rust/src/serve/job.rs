//! Job lifecycle and registry: every submission becomes a job with a
//! deterministic id (`job-0`, `job-1`, ...) walking the state machine
//! `queued → building → running → done | failed | cancelled`, with
//! every transition (and periodic progress) published as an event frame
//! to attached watchers.
//!
//! The registry is the single synchronization point between handler
//! threads (submit/status/cancel/watch) and the bounded worker pool
//! (claim next queued job, publish transitions): one mutex over the
//! table plus a condvar the workers park on.  Watchers never miss
//! events — subscribing atomically replays the job's event history and
//! registers the live channel under the same lock.

use crate::util::json::Json;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};

/// Job lifecycle states.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Building,
    Running,
    Done,
    Failed,
    Cancelled,
}

impl JobState {
    pub fn name(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Building => "building",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
        }
    }

    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            JobState::Done | JobState::Failed | JobState::Cancelled
        )
    }
}

/// Stored result of a finished job, served by the `result` op and
/// carried in the terminal `done` event.
#[derive(Clone, Debug)]
pub struct JobOutput {
    /// The spike train in the canonical `"{step} {gid}\n"` text form —
    /// byte-identical to `nsim simulate --spikes-out`.
    pub spikes_text: String,
    /// The `nsim-stats-v1` document (with `config.job` stamped).
    pub stats: Json,
}

struct JobEntry {
    scenario: String,
    params: BTreeMap<String, Json>,
    state: JobState,
    error: Option<String>,
    cancel: Arc<AtomicBool>,
    output: Option<JobOutput>,
    /// Every event published so far, replayed to late watchers.
    history: Vec<Json>,
    subscribers: Vec<mpsc::Sender<Json>>,
}

#[derive(Default)]
struct TableInner {
    jobs: BTreeMap<String, JobEntry>,
    /// Submission order; `ids` sort lexicographically only up to 9
    /// jobs, so the queue carries the order explicitly.
    queue: VecDeque<String>,
    next_id: u64,
    shutdown: bool,
}

/// The shared job table (see module docs).
#[derive(Default)]
pub struct JobTable {
    inner: Mutex<TableInner>,
    work: Condvar,
}

fn state_event(id: &str, state: JobState) -> Json {
    Json::obj(vec![
        ("event", "state".into()),
        ("job", id.into()),
        ("state", state.name().into()),
    ])
}

fn publish(entry: &mut JobEntry, ev: Json) {
    entry
        .subscribers
        .retain(|s| s.send(ev.clone()).is_ok());
    entry.history.push(ev);
}

impl JobTable {
    pub fn new() -> Arc<JobTable> {
        Arc::new(JobTable::default())
    }

    /// Enqueue a job; returns its id, or `None` when shutting down.
    pub fn submit(
        &self,
        scenario: &str,
        params: BTreeMap<String, Json>,
    ) -> Option<String> {
        let mut t = self.inner.lock().unwrap();
        if t.shutdown {
            return None;
        }
        let id = format!("job-{}", t.next_id);
        t.next_id += 1;
        let mut entry = JobEntry {
            scenario: scenario.to_string(),
            params,
            state: JobState::Queued,
            error: None,
            cancel: Arc::new(AtomicBool::new(false)),
            output: None,
            history: Vec::new(),
            subscribers: Vec::new(),
        };
        publish(&mut entry, state_event(&id, JobState::Queued));
        t.jobs.insert(id.clone(), entry);
        t.queue.push_back(id.clone());
        drop(t);
        self.work.notify_one();
        Some(id)
    }

    /// Worker side: block until a runnable job is queued (skipping jobs
    /// cancelled while still queued) or shutdown; returns the claimed
    /// job id with its scenario, params and cancel gate.
    #[allow(clippy::type_complexity)]
    pub fn claim(
        &self,
    ) -> Option<(String, String, BTreeMap<String, Json>, Arc<AtomicBool>)>
    {
        let mut t = self.inner.lock().unwrap();
        loop {
            while let Some(id) = t.queue.pop_front() {
                let Some(e) = t.jobs.get(&id) else { continue };
                // cancelled while queued: already terminal, skip
                if e.state != JobState::Queued {
                    continue;
                }
                return Some((
                    id.clone(),
                    e.scenario.clone(),
                    e.params.clone(),
                    e.cancel.clone(),
                ));
            }
            if t.shutdown {
                return None;
            }
            t = self.work.wait(t).unwrap();
        }
    }

    /// Publish a non-terminal transition (`building`, `running`).
    pub fn set_state(&self, id: &str, state: JobState) {
        debug_assert!(!state.is_terminal());
        let mut t = self.inner.lock().unwrap();
        if let Some(e) = t.jobs.get_mut(id) {
            if e.state.is_terminal() {
                return;
            }
            e.state = state;
            publish(e, state_event(id, state));
        }
    }

    /// Publish an auxiliary event (progress frames, resume notices).
    pub fn publish_event(&self, id: &str, ev: Json) {
        let mut t = self.inner.lock().unwrap();
        if let Some(e) = t.jobs.get_mut(id) {
            publish(e, ev);
        }
    }

    /// Terminal transition: `done` with the stored output.  The event
    /// carries the full spike train and stats document — the streamed
    /// result a follower writes to disk.
    pub fn finish_done(&self, id: &str, output: JobOutput) {
        let mut t = self.inner.lock().unwrap();
        let Some(e) = t.jobs.get_mut(id) else { return };
        if e.state.is_terminal() {
            return;
        }
        e.state = JobState::Done;
        let n_spikes =
            output.spikes_text.lines().count();
        let ev = Json::obj(vec![
            ("event", "state".into()),
            ("job", id.into()),
            ("state", "done".into()),
            ("n_spikes", n_spikes.into()),
            ("spikes", output.spikes_text.as_str().into()),
            ("stats", output.stats.clone()),
        ]);
        e.output = Some(output);
        publish(e, ev);
    }

    /// Terminal transition: `failed` with the error text.
    pub fn finish_failed(&self, id: &str, error: String) {
        let mut t = self.inner.lock().unwrap();
        let Some(e) = t.jobs.get_mut(id) else { return };
        if e.state.is_terminal() {
            return;
        }
        e.state = JobState::Failed;
        let ev = Json::obj(vec![
            ("event", "state".into()),
            ("job", id.into()),
            ("state", "failed".into()),
            ("error", error.as_str().into()),
        ]);
        e.error = Some(error);
        publish(e, ev);
    }

    /// Terminal transition: `cancelled`.
    pub fn finish_cancelled(&self, id: &str) {
        let mut t = self.inner.lock().unwrap();
        let Some(e) = t.jobs.get_mut(id) else { return };
        if e.state.is_terminal() {
            return;
        }
        e.state = JobState::Cancelled;
        publish(e, state_event(id, JobState::Cancelled));
    }

    /// Request cancellation.  A queued job goes terminal immediately;
    /// a building/running job has its cancel gate raised and goes
    /// terminal when the engine unwinds through the agreement
    /// reduction.  Returns the state observed, or `None` for an
    /// unknown id.
    pub fn cancel(&self, id: &str) -> Option<JobState> {
        let mut t = self.inner.lock().unwrap();
        let e = t.jobs.get_mut(id)?;
        let seen = e.state;
        if seen.is_terminal() {
            return Some(seen);
        }
        e.cancel.store(true, Ordering::Relaxed);
        if seen == JobState::Queued {
            e.state = JobState::Cancelled;
            publish(e, state_event(id, JobState::Cancelled));
        }
        Some(seen)
    }

    /// Subscribe to one or more jobs atomically: the returned history
    /// holds every event already published (across all requested ids,
    /// in publish order per job), and the receiver delivers everything
    /// after — no gap, no duplicate.  `None` if any id is unknown.
    pub fn watch(
        &self,
        ids: &[String],
    ) -> Option<(Vec<Json>, mpsc::Receiver<Json>)> {
        let mut t = self.inner.lock().unwrap();
        if !ids.iter().all(|id| t.jobs.contains_key(id)) {
            return None;
        }
        let (tx, rx) = mpsc::channel();
        let mut history = Vec::new();
        for id in ids {
            let e = t.jobs.get_mut(id).unwrap();
            history.extend(e.history.iter().cloned());
            if !e.state.is_terminal() {
                e.subscribers.push(tx.clone());
            }
        }
        Some((history, rx))
    }

    /// One job's status document.
    pub fn status(&self, id: &str) -> Option<Json> {
        let t = self.inner.lock().unwrap();
        let e = t.jobs.get(id)?;
        let mut fields = vec![
            ("job", id.into()),
            ("scenario", e.scenario.as_str().into()),
            ("state", e.state.name().into()),
        ];
        if let Some(err) = &e.error {
            fields.push(("error", err.as_str().into()));
        }
        Some(Json::obj(fields))
    }

    /// A finished job's stored output (state, output-if-done).
    pub fn result(
        &self,
        id: &str,
    ) -> Option<(JobState, Option<JobOutput>, Option<String>)> {
        let t = self.inner.lock().unwrap();
        let e = t.jobs.get(id)?;
        Some((e.state, e.output.clone(), e.error.clone()))
    }

    /// Listing of all jobs in id order.
    pub fn jobs_json(&self) -> Json {
        let t = self.inner.lock().unwrap();
        let mut rows: Vec<(u64, Json)> = t
            .jobs
            .iter()
            .map(|(id, e)| {
                let n: u64 = id
                    .strip_prefix("job-")
                    .and_then(|s| s.parse().ok())
                    .unwrap_or(u64::MAX);
                (
                    n,
                    Json::obj(vec![
                        ("job", id.as_str().into()),
                        ("scenario", e.scenario.as_str().into()),
                        ("state", e.state.name().into()),
                    ]),
                )
            })
            .collect();
        rows.sort_by_key(|(n, _)| *n);
        Json::Arr(rows.into_iter().map(|(_, v)| v).collect())
    }

    /// Params a worker needs to re-resolve a claimed job (kept for
    /// status introspection).
    pub fn params_of(&self, id: &str) -> Option<BTreeMap<String, Json>> {
        let t = self.inner.lock().unwrap();
        t.jobs.get(id).map(|e| e.params.clone())
    }

    /// Stop accepting submissions and wake every parked worker so the
    /// pool can drain.
    pub fn shutdown(&self) {
        self.inner.lock().unwrap().shutdown = true;
        self.work.notify_all();
    }

    pub fn is_shutdown(&self) -> bool {
        self.inner.lock().unwrap().shutdown
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev_state(ev: &Json) -> (&str, &str) {
        (
            ev.get("job").unwrap().as_str().unwrap(),
            ev.get("state").unwrap().as_str().unwrap(),
        )
    }

    #[test]
    fn lifecycle_publishes_every_transition() {
        let t = JobTable::new();
        let id = t.submit("s", BTreeMap::new()).unwrap();
        assert_eq!(id, "job-0");
        let (hist, rx) = t.watch(std::slice::from_ref(&id)).unwrap();
        assert_eq!(hist.len(), 1);
        assert_eq!(ev_state(&hist[0]), ("job-0", "queued"));
        t.set_state(&id, JobState::Building);
        t.set_state(&id, JobState::Running);
        t.finish_done(
            &id,
            JobOutput {
                spikes_text: "1 2\n3 4\n".to_string(),
                stats: Json::Null,
            },
        );
        let evs: Vec<Json> = rx.try_iter().collect();
        assert_eq!(evs.len(), 3);
        assert_eq!(ev_state(&evs[0]), ("job-0", "building"));
        assert_eq!(ev_state(&evs[1]), ("job-0", "running"));
        assert_eq!(ev_state(&evs[2]), ("job-0", "done"));
        assert_eq!(evs[2].get("n_spikes").unwrap().as_usize(), Some(2));
        assert_eq!(
            evs[2].get("spikes").unwrap().as_str(),
            Some("1 2\n3 4\n")
        );
        // terminal state is sticky
        t.finish_failed(&id, "late".into());
        let (state, out, err) = t.result(&id).unwrap();
        assert_eq!(state, JobState::Done);
        assert!(out.is_some());
        assert!(err.is_none());
    }

    #[test]
    fn queued_cancellation_is_immediate_and_skipped_by_workers() {
        let t = JobTable::new();
        let a = t.submit("s", BTreeMap::new()).unwrap();
        let b = t.submit("s", BTreeMap::new()).unwrap();
        assert_eq!(t.cancel(&a), Some(JobState::Queued));
        let (_, _, _, _) = {
            let claimed = t.claim().unwrap();
            assert_eq!(claimed.0, b, "cancelled job must be skipped");
            claimed
        };
        assert_eq!(
            t.status(&a).unwrap().get("state").unwrap().as_str(),
            Some("cancelled")
        );
        // unknown ids answer None, not panic
        assert!(t.cancel("job-99").is_none());
        assert!(t.status("job-99").is_none());
        assert!(t.watch(&["job-99".to_string()]).is_none());
    }

    #[test]
    fn running_cancellation_raises_the_gate_only() {
        let t = JobTable::new();
        let id = t.submit("s", BTreeMap::new()).unwrap();
        let (_, _, _, cancel) = t.claim().unwrap();
        t.set_state(&id, JobState::Running);
        assert_eq!(t.cancel(&id), Some(JobState::Running));
        assert!(cancel.load(Ordering::Relaxed), "gate must be raised");
        // still running until the engine unwinds
        assert_eq!(
            t.status(&id).unwrap().get("state").unwrap().as_str(),
            Some("running")
        );
        t.finish_cancelled(&id);
        assert_eq!(
            t.status(&id).unwrap().get("state").unwrap().as_str(),
            Some("cancelled")
        );
    }

    #[test]
    fn watch_replays_history_without_gaps_or_duplicates() {
        let t = JobTable::new();
        let id = t.submit("s", BTreeMap::new()).unwrap();
        t.set_state(&id, JobState::Building);
        let (hist, rx) = t.watch(std::slice::from_ref(&id)).unwrap();
        assert_eq!(hist.len(), 2);
        t.set_state(&id, JobState::Running);
        let evs: Vec<Json> = rx.try_iter().collect();
        assert_eq!(evs.len(), 1);
        assert_eq!(ev_state(&evs[0]), (id.as_str(), "running"));
    }

    #[test]
    fn shutdown_drains_claims_and_rejects_submissions() {
        let t = JobTable::new();
        t.shutdown();
        assert!(t.submit("s", BTreeMap::new()).is_none());
        assert!(t.claim().is_none());
    }
}
