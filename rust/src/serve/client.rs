//! Client side of the serve protocol (`nsim submit`): connect to the
//! server socket, send one-shot ops, or follow an event stream until
//! every submitted job is terminal.

use super::proto::{self};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::os::unix::net::UnixStream;
use std::path::Path;

/// A connection to a running job server.
pub struct Client {
    stream: UnixStream,
}

/// Outcome of following a job to its terminal state.
#[derive(Clone, Debug)]
pub struct JobEnd {
    pub job: String,
    pub state: String,
    /// Spike train text (`done` jobs only).
    pub spikes: Option<String>,
    /// Stats document (`done` jobs only).
    pub stats: Option<Json>,
    pub error: Option<String>,
}

impl Client {
    pub fn connect(socket: &Path) -> Result<Client> {
        let stream = UnixStream::connect(socket).with_context(|| {
            format!(
                "connecting to serve socket {} (is `nsim serve` \
                 running?)",
                socket.display()
            )
        })?;
        Ok(Client { stream })
    }

    /// One request/response round trip.  Error responses become typed
    /// `anyhow` errors carrying the server's `kind`.
    pub fn request(&mut self, req: &Json) -> Result<Json> {
        proto::write_frame(&mut self.stream, req)?;
        let resp = proto::read_frame(&mut self.stream)?
            .context("server closed the connection mid-request")?;
        check_ok(resp)
    }

    /// Read one event frame off a followed stream (`None` on EOF).
    pub fn read_event(&mut self) -> Result<Option<Json>> {
        proto::read_frame(&mut self.stream)
    }

    pub fn ping(&mut self) -> Result<()> {
        self.request(&Json::obj(vec![("op", "ping".into())]))?;
        Ok(())
    }

    /// The server's scenario catalog.
    pub fn scenarios(&mut self) -> Result<Json> {
        let resp =
            self.request(&Json::obj(vec![("op", "scenarios".into())]))?;
        resp.get("scenarios")
            .cloned()
            .context("scenarios response without a catalog")
    }

    /// Submit one scenario (optionally a sweep); returns the job ids.
    /// With `follow`, the connection turns into an event stream —
    /// consume it with [`Client::follow_until_complete`].
    pub fn submit(
        &mut self,
        scenario: &str,
        params: &BTreeMap<String, Json>,
        sweep: &BTreeMap<String, Json>,
        follow: bool,
    ) -> Result<Vec<String>> {
        let mut req = vec![
            ("op", Json::Str("submit".to_string())),
            ("scenario", scenario.into()),
        ];
        if !params.is_empty() {
            req.push(("params", Json::Obj(params.clone())));
        }
        if !sweep.is_empty() {
            req.push(("sweep", Json::Obj(sweep.clone())));
        }
        if follow {
            req.push(("follow", Json::Bool(true)));
        }
        let resp = self.request(&Json::obj(req))?;
        let ids = resp
            .get("jobs")
            .and_then(Json::as_arr)
            .context("submit response without job ids")?
            .iter()
            .filter_map(|v| v.as_str().map(str::to_string))
            .collect();
        Ok(ids)
    }

    /// Drain a followed event stream until the server's `complete`
    /// frame, returning every job's terminal outcome (and passing each
    /// event to `on_event` for display).
    pub fn follow_until_complete(
        &mut self,
        mut on_event: impl FnMut(&Json),
    ) -> Result<Vec<JobEnd>> {
        let mut ends: BTreeMap<String, JobEnd> = BTreeMap::new();
        loop {
            let ev = self
                .read_event()?
                .context("server closed the stream before complete")?;
            if ev.get("ok").and_then(Json::as_bool) == Some(false) {
                bail!(
                    "server aborted the stream: {}",
                    ev.get("error")
                        .and_then(Json::as_str)
                        .unwrap_or("unknown error")
                );
            }
            on_event(&ev);
            let event =
                ev.get("event").and_then(Json::as_str).unwrap_or("");
            if event == "complete" {
                return Ok(ends.into_values().collect());
            }
            if event != "state" {
                continue;
            }
            let (Some(job), Some(state)) = (
                ev.get("job").and_then(Json::as_str),
                ev.get("state").and_then(Json::as_str),
            ) else {
                continue;
            };
            if ["done", "failed", "cancelled"].contains(&state) {
                ends.insert(
                    job.to_string(),
                    JobEnd {
                        job: job.to_string(),
                        state: state.to_string(),
                        spikes: ev
                            .get("spikes")
                            .and_then(Json::as_str)
                            .map(str::to_string),
                        stats: ev.get("stats").cloned(),
                        error: ev
                            .get("error")
                            .and_then(Json::as_str)
                            .map(str::to_string),
                    },
                );
            }
        }
    }

    pub fn status(&mut self, job: &str) -> Result<Json> {
        let resp = self.request(&Json::obj(vec![
            ("op", "status".into()),
            ("job", job.into()),
        ]))?;
        resp.get("status")
            .cloned()
            .context("status response without a status block")
    }

    pub fn cancel(&mut self, job: &str) -> Result<Json> {
        self.request(&Json::obj(vec![
            ("op", "cancel".into()),
            ("job", job.into()),
        ]))
    }

    pub fn result(&mut self, job: &str) -> Result<Json> {
        self.request(&Json::obj(vec![
            ("op", "result".into()),
            ("job", job.into()),
        ]))
    }

    pub fn jobs(&mut self) -> Result<Json> {
        let resp =
            self.request(&Json::obj(vec![("op", "jobs".into())]))?;
        resp.get("jobs").cloned().context("jobs response without list")
    }

    pub fn shutdown(&mut self) -> Result<()> {
        self.request(&Json::obj(vec![("op", "shutdown".into())]))?;
        Ok(())
    }
}

/// Turn an `ok: false` response into a typed error.
fn check_ok(resp: Json) -> Result<Json> {
    match resp.get("ok").and_then(Json::as_bool) {
        Some(true) => Ok(resp),
        Some(false) => {
            let kind = resp
                .get("kind")
                .and_then(Json::as_str)
                .unwrap_or("unknown");
            let msg = resp
                .get("error")
                .and_then(Json::as_str)
                .unwrap_or("unspecified server error");
            bail!("[{kind}] {msg}")
        }
        None => bail!("malformed server response (no \"ok\" field)"),
    }
}
